"""Blocked MIPS top-k Pallas kernel — the retrieval hot spot of C-FedRAG.

Each data provider scores the query against its corpus shard and returns
its local top-k (paper Alg. 1, "Site-i retrieves m relevant contexts with
distance metrics").  On TPU this is a (Q, D) x (D, N) matmul on the MXU
fused with an on-chip running top-k merge, so candidate scores never
round-trip to HBM.

Tiling: grid (Q/BQ, N/BN); for a fixed query block the N-axis is the
innermost (arbitrary) dimension and the (BQ, K) running top-k lives in the
revisited output block (VMEM-resident across the whole N sweep).
BQ/BN default to 128/512 — MXU-aligned (128 lanes) and a working set of
BQ*D + BN*D + BQ*BN well under VMEM at D<=1024.  Small query batches clamp
BQ down, rounded up to a sublane multiple of 8 so the block stays
VPU/MXU-tileable.

Merge strategy: a SINGLE descending sort of the concatenated (BQ, K+BN)
candidate block, then keep the first K lanes — one fused pass replaces
the former K sequential argmax-extraction sweeps, so merge cost no longer
scales with K.  Two equivalent implementations, auto-selected:

  xla      ``lax.sort_key_val`` (stable) — interpret mode / CPU, where the
           sort primitive lowers natively
  bitonic  an explicit compare-exchange network of roll/where ops (padded
           to a power of two, index tie-break) — every op is VPU-native,
           for compiled TPU where Mosaic has no sort lowering

`interpret` auto-selects from the backend (compiled on TPU, interpreter
everywhere else) unless overridden explicitly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_I32_MAX = jnp.iinfo(jnp.int32).max


def _bitonic_topk_merge(scores, idx, k):
    """Descending bitonic sort of (scores, idx) pairs along the last axis,
    returning the first k columns.  scores: (R, C) f32; idx: (R, C) i32.
    Ties prefer the smaller index (matches lax.top_k).  Pure roll/where
    compare-exchange network — every op is VPU-native on TPU."""
    r, c = scores.shape
    p = 1 << max(c - 1, 1).bit_length()  # next power of two >= c (min 2)
    if p != c:
        scores = jnp.pad(scores, ((0, 0), (0, p - c)), constant_values=-jnp.inf)
        idx = jnp.pad(idx, ((0, 0), (0, p - c)), constant_values=_I32_MAX)
    lane = jax.lax.broadcasted_iota(jnp.int32, (r, p), 1)
    stage = 2
    while stage <= p:
        step = stage // 2
        while step >= 1:
            upper = (lane & step) != 0  # this lane holds the pair's upper element
            ps = jnp.where(upper, jnp.roll(scores, step, 1), jnp.roll(scores, -step, 1))
            pi = jnp.where(upper, jnp.roll(idx, step, 1), jnp.roll(idx, -step, 1))
            desc = (lane & stage) == 0  # block direction (final stage: all desc)
            self_greater = (scores > ps) | ((scores == ps) & (idx < pi))
            want_max = desc != upper  # desc block: lower lane takes the max
            take_self = self_greater == want_max
            scores = jnp.where(take_self, scores, ps)
            idx = jnp.where(take_self, idx, pi)
            step //= 2
        stage *= 2
    return scores[:, :k], idx[:, :k]


def _sort_topk_merge(scores, idx, k):
    """Stable descending sort via the XLA sort primitive.  Stability +
    concat order (running list before the new block) preserves the
    smaller-index tie preference of lax.top_k."""
    neg_s, si = jax.lax.sort_key_val(-scores, idx, dimension=-1)
    return -neg_s[:, :k], si[:, :k]


_MERGES = {"xla": _sort_topk_merge, "bitonic": _bitonic_topk_merge}


def _kernel(q_ref, c_ref, s_ref, i_ref, *, k: int, bn: int, n_valid: int, merge: str):
    nj = pl.program_id(1)

    @pl.when(nj == 0)
    def _init():
        s_ref[...] = jnp.full_like(s_ref, -jnp.inf)
        i_ref[...] = jnp.full_like(i_ref, _I32_MAX)

    q = q_ref[...].astype(jnp.float32)  # (BQ, D)
    c = c_ref[...].astype(jnp.float32)  # (BN, D)
    blk = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (BQ, BN)
    gidx = nj * bn + jax.lax.broadcasted_iota(jnp.int32, blk.shape, 1)
    blk = jnp.where(gidx < n_valid, blk, -jnp.inf)  # mask corpus padding

    cand_s = jnp.concatenate([s_ref[...], blk], axis=-1)
    cand_i = jnp.concatenate([i_ref[...], gidx], axis=-1)
    new_s, new_i = _MERGES[merge](cand_s, cand_i, k)
    s_ref[...] = new_s
    i_ref[...] = new_i


def retrieval_topk_pallas(
    queries: jax.Array,
    corpus: jax.Array,
    k: int,
    *,
    bq: int = 128,
    bn: int = 512,
    interpret: bool | None = None,
    merge: str | None = None,
):
    """queries: (Q, D); corpus: (N, D).  Returns (scores (Q,k) f32, idx (Q,k) i32).

    Q and N are padded up to block multiples internally; padded corpus rows
    are masked with -inf, padded query rows are sliced off.  ``interpret``
    defaults to compiled on TPU and interpreter mode elsewhere; ``merge``
    defaults to the XLA sort primitive under the interpreter and the
    bitonic network when compiled.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if merge is None:
        merge = "xla" if interpret else "bitonic"
    q, d = queries.shape
    n = corpus.shape[0]
    # clamp the query block to the batch, rounded up to a sublane multiple
    # of 8 so tiny Q never produces a non-MXU-aligned block shape
    bq = min(bq, max(8, q))
    bq = -(-bq // 8) * 8
    qp = (q + bq - 1) // bq * bq
    np_ = (n + bn - 1) // bn * bn
    if qp != q:
        queries = jnp.pad(queries, ((0, qp - q), (0, 0)))
    if np_ != n:
        corpus = jnp.pad(corpus, ((0, np_ - n), (0, 0)))

    grid = (qp // bq, np_ // bn)
    scores, idx = pl.pallas_call(
        functools.partial(_kernel, k=k, bn=bn, n_valid=n, merge=merge),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, k), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, corpus)
    return scores[:q], idx[:q]
