"""Blocked MIPS top-k Pallas kernel — the retrieval hot spot of C-FedRAG.

Each data provider scores the query against its corpus shard and returns
its local top-k (paper Alg. 1, "Site-i retrieves m relevant contexts with
distance metrics").  On TPU this is a (Q, D) x (D, N) matmul on the MXU
fused with an on-chip running top-k merge, so candidate scores never
round-trip to HBM.

Tiling: grid (Q/BQ, N/BN); for a fixed query block the N-axis is the
innermost (arbitrary) dimension and the (BQ, K) running top-k lives in the
revisited output block (VMEM-resident across the whole N sweep).
BQ/BN default to 128/512 — MXU-aligned (128 lanes) and a working set of
BQ*D + BN*D + BQ*BN well under VMEM at D<=1024.

Merge strategy: K selection passes over the concatenated (BQ, K+BN)
candidates per block — K is small (paper uses m=8) so the merge is
O(K * BN) VPU work against O(BN * D) MXU work per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_merge(scores, idx, k):
    """k extraction passes.  scores: (BQ, C) f32; idx: (BQ, C) i32."""
    out_s, out_i = [], []
    for _ in range(k):
        m = jnp.max(scores, axis=-1, keepdims=True)  # (BQ,1)
        am = jnp.argmax(scores, axis=-1)  # (BQ,)
        out_s.append(m[:, 0])
        out_i.append(jnp.take_along_axis(idx, am[:, None], axis=-1)[:, 0])
        scores = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) == am[:, None],
            -jnp.inf,
            scores,
        )
    return jnp.stack(out_s, -1), jnp.stack(out_i, -1)


def _kernel(q_ref, c_ref, s_ref, i_ref, *, k: int, bn: int, n_valid: int):
    nj = pl.program_id(1)

    @pl.when(nj == 0)
    def _init():
        s_ref[...] = jnp.full_like(s_ref, -jnp.inf)
        i_ref[...] = jnp.full_like(i_ref, -1)

    q = q_ref[...].astype(jnp.float32)  # (BQ, D)
    c = c_ref[...].astype(jnp.float32)  # (BN, D)
    blk = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (BQ, BN)
    gidx = nj * bn + jax.lax.broadcasted_iota(jnp.int32, blk.shape, 1)
    blk = jnp.where(gidx < n_valid, blk, -jnp.inf)  # mask corpus padding

    cand_s = jnp.concatenate([s_ref[...], blk], axis=-1)
    cand_i = jnp.concatenate([i_ref[...], gidx], axis=-1)
    new_s, new_i = _topk_merge(cand_s, cand_i, k)
    s_ref[...] = new_s
    i_ref[...] = new_i


def retrieval_topk_pallas(
    queries: jax.Array,
    corpus: jax.Array,
    k: int,
    *,
    bq: int = 128,
    bn: int = 512,
    interpret: bool = True,
):
    """queries: (Q, D); corpus: (N, D).  Returns (scores (Q,k) f32, idx (Q,k) i32).

    Q and N are padded up to block multiples internally; padded corpus rows
    are masked with -inf, padded query rows are sliced off.
    """
    q, d = queries.shape
    n = corpus.shape[0]
    bq = min(bq, max(8, q))
    qp = (q + bq - 1) // bq * bq
    np_ = (n + bn - 1) // bn * bn
    if qp != q:
        queries = jnp.pad(queries, ((0, qp - q), (0, 0)))
    if np_ != n:
        corpus = jnp.pad(corpus, ((0, np_ - n), (0, 0)))

    grid = (qp // bq, np_ // bn)
    scores, idx = pl.pallas_call(
        functools.partial(_kernel, k=k, bn=bn, n_valid=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, k), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, corpus)
    return scores[:q], idx[:q]
