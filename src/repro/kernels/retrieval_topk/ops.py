"""Jitted public wrapper: picks the Pallas kernel on TPU, the jnp oracle
elsewhere (CPU dry-runs / tests use interpret mode explicitly)."""
import functools

import jax

from repro.kernels.retrieval_topk.kernel import retrieval_topk_pallas
from repro.kernels.retrieval_topk.ref import retrieval_topk_ref


@functools.partial(jax.jit, static_argnames=("k", "use_pallas"))
def retrieval_topk(queries, corpus, k: int, use_pallas: bool = False):
    if use_pallas:
        return retrieval_topk_pallas(
            queries, corpus, k, interpret=jax.default_backend() != "tpu"
        )
    return retrieval_topk_ref(queries, corpus, k)
