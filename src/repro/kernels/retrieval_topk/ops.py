"""Jitted public wrapper: picks the Pallas kernel on TPU, the jnp oracle
elsewhere (the kernel auto-selects interpret mode from the backend, so
CPU dry-runs / tests run the same code through the interpreter)."""
import functools

import jax

from repro.kernels.retrieval_topk.kernel import retrieval_topk_pallas
from repro.kernels.retrieval_topk.ref import retrieval_topk_ref


@functools.partial(jax.jit, static_argnames=("k", "use_pallas"))
def retrieval_topk(queries, corpus, k: int, use_pallas: bool = False):
    """queries: (Q, D); corpus: (N, D) -> (scores (Q, k), idx (Q, k)).

    Batched natively over the query dimension: Q may be a single query or
    a whole request batch (B*Q rows) — one call, one kernel launch.
    """
    if use_pallas:
        return retrieval_topk_pallas(queries, corpus, k)
    return retrieval_topk_ref(queries, corpus, k)
