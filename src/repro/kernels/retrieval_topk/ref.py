"""Pure-jnp oracle for retrieval_topk."""
import jax
import jax.numpy as jnp


def retrieval_topk_ref(queries, corpus, k):
    scores = queries.astype(jnp.float32) @ corpus.astype(jnp.float32).T
    s, i = jax.lax.top_k(scores, k)
    return s, i.astype(jnp.int32)
