"""Pure-jnp oracle for flash-decode."""
import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k_cache, v_cache, lengths):
    b, h, dh = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    qr = q.astype(jnp.float32).reshape(b, kv, h // kv, dh)
    logits = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache.astype(jnp.float32))
    logits = logits / np.sqrt(dh)
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    logits = jnp.where(valid, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths):
    """Oracle for the paged kernel: materialize each row's contiguous view
    by gathering its table's pool blocks, then run the dense oracle.
    Positions past ``lengths`` (including every trash-backed lane) are
    masked identically, so this also defines the paged<->contiguous
    equivalence the serving engine's bit-parity tests rely on."""
    b = q.shape[0]
    bs = k_pool.shape[1]
    s_pad = block_tables.shape[1] * bs
    k_view = k_pool[block_tables].reshape(b, s_pad, *k_pool.shape[2:])
    v_view = v_pool[block_tables].reshape(b, s_pad, *v_pool.shape[2:])
    return decode_attention_ref(q, k_view, v_view, lengths)
