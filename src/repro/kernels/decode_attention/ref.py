"""Pure-jnp oracle for flash-decode."""
import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k_cache, v_cache, lengths):
    b, h, dh = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    qr = q.astype(jnp.float32).reshape(b, kv, h // kv, dh)
    logits = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache.astype(jnp.float32))
    logits = logits / np.sqrt(dh)
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    logits = jnp.where(valid, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)
