"""Flash-decode Pallas kernel: single-token attention against a long KV
cache, with (m, l, o) partials exposed for cross-device combine.

decode_32k / long_500k are memory-bound (read the whole KV cache once per
token); the kernel streams the cache through VMEM in BS-length tiles and
keeps the softmax state on-chip.  ``return_partials=True`` yields per-call
(m, l, o) so serving/dist_decode.py can shard the cache seq-dim over the
`data` axis and combine partials with one tiny psum — the beyond-paper
long-context optimization in EXPERIMENTS.md §Perf.

Grid (B, KV, S/BS); all H/KV query heads of a group ride in one block so
the (G, BS) logits hit the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr, *, bs, scale, n_s):
    sj = pl.program_id(2)

    @pl.when(sj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (BS, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, BS)
    kpos = sj * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[0], s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(sj == n_s - 1)
    def _finish():
        o_ref[0, 0] = acc_scr[...].astype(o_ref.dtype)
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]


def decode_attention_pallas(
    q: jax.Array,  # (B, H, dh) — one new token per sequence
    k_cache: jax.Array,  # (B, S, KV, dh)
    v_cache: jax.Array,
    lengths: jax.Array,  # (B,) valid cache length per sequence
    *,
    bs: int = 512,
    interpret: bool = True,
    return_partials: bool = False,
):
    b, h, dh = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    bs = min(bs, s)
    assert s % bs == 0, (s, bs)
    scale = 1.0 / np.sqrt(dh)

    qg = q.reshape(b, kv, g, dh)
    kt = k_cache.transpose(0, 2, 1, 3)  # (B, KV, S, dh)
    vt = v_cache.transpose(0, 2, 1, 3)
    grid = (b, kv, s // bs)

    o, m, l = pl.pallas_call(
        functools.partial(_kernel, bs=bs, scale=scale, n_s=s // bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bi, ki, sj: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh), lambda bi, ki, sj: (bi, ki, sj, 0)),
            pl.BlockSpec((1, 1, bs, dh), lambda bi, ki, sj: (bi, ki, sj, 0)),
            pl.BlockSpec((1,), lambda bi, ki, sj: (bi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bi, ki, sj: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, ki, sj: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, ki, sj: (bi, ki, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, g, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt.reshape(b, kv, s, dh) if kt.shape != (b, kv, s, dh) else kt, vt, lengths)
    if return_partials:
        return o, m, l  # caller combines across shards then normalizes
    out = o / jnp.maximum(l, 1e-30)
    return out.reshape(b, h, dh).astype(q.dtype)


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  m_scr, l_scr, acc_scr, *, bs, scale, n_t):
    """Grid (B, KV, n_max_blocks).  The scalar-prefetched block table
    drives the K/V BlockSpec index maps, so pool block ``tbl[b, t]``
    streams into VMEM for (batch b, logical block t) — the gather never
    materializes in HBM.  Masking is positional: logical position
    ``t * bs + lane`` is valid iff < lengths[b] — trash-backed lanes are
    always past the length and contribute exp(-inf) = 0 exactly."""
    tj = pl.program_id(2)
    bi = pl.program_id(0)

    @pl.when(tj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (BS, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, BS)
    kpos = tj * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[bi], s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * alpha + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(tj == n_t - 1)
    def _finish():
        o_ref[0, 0] = acc_scr[...].astype(o_ref.dtype)
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]


def paged_decode_attention_pallas(
    q: jax.Array,  # (B, H, dh) — one new token per sequence
    k_pool: jax.Array,  # (n_pool, bs, KV, dh) shared block pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, n_max_blocks) int32 pool ids per row
    lengths: jax.Array,  # (B,) valid cache length per sequence
    *,
    interpret: bool = True,
):
    """Flash-decode over a PAGED KV cache: same online-softmax stream as
    ``decode_attention_pallas``, but the sequence axis is a block table —
    the BlockSpec index map reads the scalar-prefetched table to pick
    which pool block to DMA per grid step (the vLLM-style paged-attention
    gather, done by the memory system instead of an HBM materialize)."""
    b, h, dh = q.shape
    n_pool, bs, kv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    n_t = block_tables.shape[1]
    g = h // kv
    scale = 1.0 / np.sqrt(dh)

    qg = q.reshape(b, kv, g, dh)
    kt = k_pool.transpose(0, 2, 1, 3)  # (n_pool, KV, BS, dh)
    vt = v_pool.transpose(0, 2, 1, 3)
    grid = (b, kv, n_t)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bi, ki, tj, tbl, lens: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh), lambda bi, ki, tj, tbl, lens: (tbl[bi, tj], ki, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh), lambda bi, ki, tj, tbl, lens: (tbl[bi, tj], ki, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bi, ki, tj, tbl, lens: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, ki, tj, tbl, lens: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, ki, tj, tbl, lens: (bi, ki, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    o, m, l = pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, scale=scale, n_t=n_t),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, g, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), qg, kt, vt)
    out = o / jnp.maximum(l, 1e-30)
    return out.reshape(b, h, dh).astype(q.dtype)


def combine_partials(o, m, l):
    """Combine a list of (o, m, l) partials from disjoint cache shards."""
    m_g = jnp.max(jnp.stack(m), axis=0)
    scaled_l = [li * jnp.exp(mi - m_g) for mi, li in zip(m, l)]
    scaled_o = [oi * jnp.exp(mi - m_g) for mi, oi in zip(m, o)]
    l_g = sum(scaled_l)
    return sum(scaled_o) / jnp.maximum(l_g, 1e-30)
