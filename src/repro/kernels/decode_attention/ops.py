"""Jitted public wrappers for flash-decode (contiguous + paged)."""
import functools

import jax

from repro.kernels.decode_attention.kernel import (
    combine_partials,
    decode_attention_pallas,
    paged_decode_attention_pallas,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    paged_decode_attention_ref,
)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def decode_attention(q, k_cache, v_cache, lengths, use_pallas: bool = False):
    if use_pallas:
        return decode_attention_pallas(
            q, k_cache, v_cache, lengths, interpret=jax.default_backend() != "tpu"
        )
    return decode_attention_ref(q, k_cache, v_cache, lengths)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, use_pallas: bool = False):
    """Single-token attention through a block table over a shared KV pool.
    ``use_pallas=True`` streams pool blocks via scalar-prefetch index maps
    (TPU target; interpret elsewhere); the default gathers in XLA."""
    if use_pallas:
        return paged_decode_attention_pallas(
            q, k_pool, v_pool, block_tables, lengths,
            interpret=jax.default_backend() != "tpu",
        )
    return paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths)
