"""Jitted public wrapper for flash-decode."""
import functools

import jax

from repro.kernels.decode_attention.kernel import (
    combine_partials,
    decode_attention_pallas,
)
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def decode_attention(q, k_cache, v_cache, lengths, use_pallas: bool = False):
    if use_pallas:
        return decode_attention_pallas(
            q, k_cache, v_cache, lengths, interpret=jax.default_backend() != "tpu"
        )
    return decode_attention_ref(q, k_cache, v_cache, lengths)
