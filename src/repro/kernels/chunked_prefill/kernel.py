"""Unified chunked-prefill Pallas kernel: paged flash attention over a
ragged q-tile, one dispatch for any mix of prefill chunks and decode steps.

Same scalar-prefetch split as ``paged_decode_attention_pallas`` — the
block table never materializes a gather in HBM; the BlockSpec index map
reads the prefetched table to DMA pool block ``tbl[desc[r, 0], t]`` per
grid step — but the q block is a (W, H) *tile of lanes* instead of a
single token, with per-row descriptors ``(slot, q_start, q_len, kv_len)``
carrying the ragged geometry (see ref.py for the mask contract).  Cold
prefills, warm suffix prefills riding a shared prefix, and 1-token decode
rows (q_len == 1) all run in the same grid.

Grid (R, KV, n_t); all W lanes x G group heads of a (row, kv-head) pair
ride in one (W*G, BS) logits block so the MXU sees a real tile even when
most rows are decodes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mixed_kernel(desc_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  m_scr, l_scr, acc_scr, *, bs, scale, n_t, g):
    """Online softmax over pool blocks for one (row, kv-head) pair.

    The flattened q axis interleaves lanes and group heads as
    ``i = lane * g + group``, so ``lane = i // g`` recovers the logical
    query position offset.  Probabilities are re-zeroed under the mask
    after the exp: for a live lane that's an exact identity (masked
    logits are NEG_INF, exp(NEG_INF - m) == +0.0 whenever any position
    is live), but a fully-masked lane keeps m == NEG_INF so the exp
    would give exp(0) == 1 per position — zeroing makes dead lanes
    contribute l == 0 and output exactly 0 instead."""
    ri = pl.program_id(0)
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (W*G, dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (BS, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (W*G, BS)
    lane = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
    kpos = tj * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    qpos = desc_ref[ri, 1] + lane
    valid = (kpos <= qpos) & (kpos < desc_ref[ri, 3]) & (lane < desc_ref[ri, 2])
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * alpha + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(tj == n_t - 1)
    def _finish():
        o_ref[0, 0] = acc_scr[...].astype(o_ref.dtype)
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]


def mixed_prefill_attention_pallas(
    q: jax.Array,  # (R, W, H, dh) — W ragged query lanes per row
    k_pool: jax.Array,  # (n_pool, bs, KV, dh) shared block pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, n_t) int32 pool ids per cache slot
    desc: jax.Array,  # (R, 4) int32 (slot, q_start, q_len, kv_len)
    *,
    interpret: bool = True,
):
    """Paged flash attention for a mixed prefill+decode batch: descriptors
    plus the block table ride scalar prefetch; K/V stream from the pool
    block by block (no HBM gather) while every lane masks causally within
    its own ``(q_start + lane, kv_len)`` span."""
    r, w, h, dh = q.shape
    bs, kv = k_pool.shape[1], k_pool.shape[2]
    n_t = block_tables.shape[1]
    g = h // kv
    scale = 1.0 / np.sqrt(dh)

    # (R, W, KV, G, dh) -> (R, KV, W*G, dh): lanes x groups flatten so one
    # block per (row, kv-head) covers the whole ragged tile
    qg = q.reshape(r, w, kv, g, dh).transpose(0, 2, 1, 3, 4).reshape(r, kv, w * g, dh)
    kt = k_pool.transpose(0, 2, 1, 3)  # (n_pool, KV, BS, dh)
    vt = v_pool.transpose(0, 2, 1, 3)
    grid = (r, kv, n_t)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # desc, block_tables
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, w * g, dh), lambda ri, ki, tj, dsc, tbl: (ri, ki, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh), lambda ri, ki, tj, dsc, tbl: (tbl[dsc[ri, 0], tj], ki, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh), lambda ri, ki, tj, dsc, tbl: (tbl[dsc[ri, 0], tj], ki, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, w * g, dh), lambda ri, ki, tj, dsc, tbl: (ri, ki, 0, 0)),
            pl.BlockSpec((1, 1, w * g, 1), lambda ri, ki, tj, dsc, tbl: (ri, ki, 0, 0)),
            pl.BlockSpec((1, 1, w * g, 1), lambda ri, ki, tj, dsc, tbl: (ri, ki, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((w * g, 1), jnp.float32),
            pltpu.VMEM((w * g, 1), jnp.float32),
            pltpu.VMEM((w * g, dh), jnp.float32),
        ],
    )
    o, m, l = pl.pallas_call(
        functools.partial(_mixed_kernel, bs=bs, scale=scale, n_t=n_t, g=g),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((r, kv, w * g, dh), jnp.float32),
            jax.ShapeDtypeStruct((r, kv, w * g, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, kv, w * g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(desc.astype(jnp.int32), block_tables.astype(jnp.int32), qg, kt, vt)
    out = o / jnp.maximum(l, 1e-30)
    out = out.reshape(r, kv, w, g, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(r, w, h, dh).astype(q.dtype)
