"""Jitted public wrapper for the unified chunked-prefill attention kernel."""
import functools

import jax

from repro.kernels.chunked_prefill.kernel import mixed_prefill_attention_pallas
from repro.kernels.chunked_prefill.ref import (  # noqa: F401  (partials re-export)
    mixed_prefill_attention_ref,
    mixed_prefill_partials,
)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def mixed_prefill_attention(q, k_pool, v_pool, block_tables, desc, use_pallas: bool = False):
    """Ragged mixed prefill/decode attention through a block table over a
    shared KV pool.  ``use_pallas=True`` streams pool blocks via
    scalar-prefetch index maps (TPU target; interpret elsewhere); the
    default gathers in XLA.

    ``desc`` is ``(B, 4)`` int32 rows ``(slot, q_start, q_len, kv_len)``.
    Three descriptor shapes cover every serving mode, all through the
    same write-then-attend contract (fresh lane K/V scatters into the
    pool before any lane attends, dead lanes ``>= q_len`` scatter to the
    trash block):

      * prefill chunk — ``q_len > 1``, ``q_start`` mid-prompt: resumes a
        chunked prompt at any boundary;
      * decode — ``q_len == 1`` at the row's next position;
      * speculative VERIFY — ``q_len == k + 1`` starting at the row's
        committed position: lane 0 carries the last committed token,
        lanes 1..k the drafter's proposals, and lane ``j``'s output
        equals a plain decode after emitting lanes ``< j``, which is
        what makes greedy accept-prefix bit-identical to 1-token decode.
    """
    if use_pallas:
        return mixed_prefill_attention_pallas(
            q, k_pool, v_pool, block_tables, desc,
            interpret=jax.default_backend() != "tpu",
        )
    return mixed_prefill_attention_ref(q, k_pool, v_pool, block_tables, desc)
