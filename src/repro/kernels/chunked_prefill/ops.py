"""Jitted public wrapper for the unified chunked-prefill attention kernel."""
import functools

import jax

from repro.kernels.chunked_prefill.kernel import mixed_prefill_attention_pallas
from repro.kernels.chunked_prefill.ref import mixed_prefill_attention_ref


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def mixed_prefill_attention(q, k_pool, v_pool, block_tables, desc, use_pallas: bool = False):
    """Ragged mixed prefill/decode attention through a block table over a
    shared KV pool.  ``use_pallas=True`` streams pool blocks via
    scalar-prefetch index maps (TPU target; interpret elsewhere); the
    default gathers in XLA."""
    if use_pallas:
        return mixed_prefill_attention_pallas(
            q, k_pool, v_pool, block_tables, desc,
            interpret=jax.default_backend() != "tpu",
        )
    return mixed_prefill_attention_ref(q, k_pool, v_pool, block_tables, desc)
