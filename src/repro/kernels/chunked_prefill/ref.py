"""Pure-jnp oracle for the unified chunked-prefill / mixed-decode kernel.

One dispatch serves any mix of rows — cold prefills, warm suffix
prefills (prefix K/V already resident in the pool), and 1-token decode
steps — described per row by ``desc[r] = (slot, q_start, q_len, kv_len)``:

* ``slot``     row in ``block_tables`` whose pool blocks hold this
               sequence's K/V (fresh tokens are scattered into the pool
               *before* attention, so the kernel only ever reads the pool)
* ``q_start``  logical position of query lane 0
* ``q_len``    number of live query lanes (lanes >= q_len output exact 0)
* ``kv_len``   total valid K/V length (= q_start + q_len for causal fill)

Lane ``j`` attends position ``kpos`` iff ``kpos <= q_start + j`` and
``kpos < kv_len`` — causal within the row's lane span, never past the
row's valid cache.  A decode row is simply ``q_len == 1``.
"""
import jax
import jax.numpy as jnp
import numpy as np


def mixed_prefill_attention_ref(q, k_pool, v_pool, block_tables, desc):
    """Oracle: gather each row's contiguous pool view, dense masked softmax.

    q:            (R, W, H, dh) — W ragged query lanes per row
    k_pool/v_pool:(n_pool, bs, KV, dh) shared block pool
    block_tables: (B, n_t) int32 pool ids per cache slot
    desc:         (R, 4) int32 rows (slot, q_start, q_len, kv_len)

    Invalid lanes (j >= q_len) produce exactly 0 — the masked softmax
    would give uniform probs over all-(-1e30) logits, so probs are zeroed
    wherever the mask is false (an exact identity for live lanes: masked
    positions already carry exp(-1e30 - m) == +0.0).
    """
    r, w, h, dh = q.shape
    bs, kv = k_pool.shape[1], k_pool.shape[2]
    tbl = block_tables[desc[:, 0]]  # (R, n_t)
    s_pad = tbl.shape[1] * bs
    k_view = k_pool[tbl].reshape(r, s_pad, kv, dh).astype(jnp.float32)
    v_view = v_pool[tbl].reshape(r, s_pad, kv, dh).astype(jnp.float32)
    qr = q.astype(jnp.float32).reshape(r, w, kv, h // kv, dh)
    logits = jnp.einsum("rwkgd,rskd->rkgws", qr, k_view) / np.sqrt(dh)
    lane = jnp.arange(w)
    kpos = jnp.arange(s_pad)
    qpos = desc[:, 1][:, None] + lane[None, :]  # (R, W)
    valid = (
        (kpos[None, None, :] <= qpos[:, :, None])
        & (kpos[None, None, :] < desc[:, 3][:, None, None])
        & (lane[None, :, None] < desc[:, 2][:, None, None])
    )  # (R, W, S)
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(valid[:, None, None], p, 0.0)
    out = jnp.einsum("rkgws,rskd->rwkgd", p, v_view)
    return out.reshape(r, w, h, dh).astype(q.dtype)


def mixed_prefill_partials(q, k_pool, v_pool, block_tables, desc, owned=None):
    """Flash-softmax partials of the mixed oracle — the per-shard half of
    the distributed dispatch.

    Same contract as ``mixed_prefill_attention_ref`` but stops before
    normalization, returning ``(o, m, l)``: un-normalized weighted values
    ``o`` (R, KV, G, W, dh), row max ``m`` and partition sum ``l`` (R,
    KV, G, W, 1), ready for ``serving/dist_decode.combine_partials``.

    ``owned`` (same leading shape as ``block_tables``, bool) marks the
    block-table entries resident on this shard; non-owned positions are
    masked out of ``valid``.  A shard owning NONE of a row's blocks (row
    affinity) contributes exact zeros — ``m = -1e30``, ``l = 0``,
    ``o = 0`` — so the cross-shard combine passes the owner's partials
    through bitwise.  ``owned=None`` means "owns everything": with one
    shard the combine then reduces to ``o / l``, the bitwise reference
    for every N-shard run.
    """
    r, w, h, dh = q.shape
    bs, kv = k_pool.shape[1], k_pool.shape[2]
    tbl = block_tables[desc[:, 0]]  # (R, n_t)
    s_pad = tbl.shape[1] * bs
    k_view = k_pool[tbl].reshape(r, s_pad, kv, dh).astype(jnp.float32)
    v_view = v_pool[tbl].reshape(r, s_pad, kv, dh).astype(jnp.float32)
    qr = q.astype(jnp.float32).reshape(r, w, kv, h // kv, dh)
    logits = jnp.einsum("rwkgd,rskd->rkgws", qr, k_view) / np.sqrt(dh)
    lane = jnp.arange(w)
    kpos = jnp.arange(s_pad)
    qpos = desc[:, 1][:, None] + lane[None, :]  # (R, W)
    valid = (
        (kpos[None, None, :] <= qpos[:, :, None])
        & (kpos[None, None, :] < desc[:, 3][:, None, None])
        & (lane[None, :, None] < desc[:, 2][:, None, None])
    )  # (R, W, S)
    if owned is not None:
        own_pos = jnp.repeat(owned[desc[:, 0]], bs, axis=1)  # (R, s_pad)
        valid = valid & own_pos[:, None, :]
    vb = valid[:, None, None]  # (R, 1, 1, W, S)
    logits = jnp.where(vb, logits, -1e30)
    m = logits.max(-1, keepdims=True)
    e = jnp.exp(logits - m)
    e = jnp.where(vb, e, 0.0)  # all-masked rows: l and o exactly 0
    l = e.sum(-1, keepdims=True)
    o = jnp.einsum("rkgws,rskd->rkgwd", e, v_view)
    return o, m, l
