"""Pure-jnp oracle for the SSD intra-chunk kernel."""
import jax.numpy as jnp


def ssd_chunk_ref(x, b, c, dt, a):
    xf = x.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    da = dt * a[None, None, :]  # (B,L,H)
    cum = jnp.cumsum(da, axis=1)
    cum_h = cum.transpose(0, 2, 1)  # (B,H,L)
    cb = jnp.einsum("bihs,bjhs->bhij", cf, bf)
    l = x.shape[1]
    mask = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.exp(jnp.where(mask, cum_h[:, :, :, None] - cum_h[:, :, None, :], -1e30))
    scores = cb * decay * dt.transpose(0, 2, 1)[:, :, None, :]
    y = jnp.einsum("bhij,bjhp->bihp", scores, xf)
    wgt = jnp.exp(cum[:, -1:, :] - cum) * dt
    st = jnp.einsum("bjh,bjhs,bjhp->bhps", wgt, bf, xf)
    dec = jnp.exp(cum[:, -1, :])
    return y, st, dec
