"""Jitted public wrapper for the SSD intra-chunk kernel."""
import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_chunk_pallas
from repro.kernels.ssd_scan.ref import ssd_chunk_ref


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def ssd_chunk(x, b, c, dt, a, use_pallas: bool = False):
    if use_pallas:
        return ssd_chunk_pallas(x, b, c, dt, a, interpret=jax.default_backend() != "tpu")
    return ssd_chunk_ref(x, b, c, dt, a)
