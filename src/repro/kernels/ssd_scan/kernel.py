"""SSD intra-chunk Pallas kernel (Mamba2 mixer hot spot).

Computes, for one chunk of length L per (batch, head):
    y_intra[i] = sum_{j<=i} (C_i·B_j) exp(cum_i - cum_j) dt_j x_j
    state_out  = sum_j exp(cum_L - cum_j) dt_j B_j (x)_j        (hd, ds)
    decay_out  = exp(cum_L)                                     scalar
so the host-level lax.scan only carries the (hd, ds) state recurrence.
Grid (B, H); the whole (L, ·) working set for one head sits in VMEM:
L=256, hd=64, ds<=128 -> ~0.5 MB.  The three L x L / L x hd contractions
run on the MXU; cumsum/exp are VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, y_ref, st_ref, dec_ref, *, l):
    x = x_ref[0, :, 0].astype(jnp.float32)  # (L, hd)
    bm = b_ref[0, :, 0].astype(jnp.float32)  # (L, ds)
    cm = c_ref[0, :, 0].astype(jnp.float32)  # (L, ds)
    dt = dt_ref[0].astype(jnp.float32)  # (L, 1)
    a = a_ref[...].astype(jnp.float32)  # (1,)

    da = dt * a  # (L,1), <= 0
    cum = jnp.cumsum(da, axis=0)  # (L,1)
    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L,L) C_i·B_j
    decay_arg = cum - cum[:, 0][None, :]  # cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    decay = jnp.exp(jnp.where(ii >= jj, decay_arg, -1e30))
    scores = cb * decay * dt[:, 0][None, :]  # (L,L)
    y_ref[0, :, 0] = jax.lax.dot(
        scores, x, preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)

    wgt = jnp.exp(cum[-1, 0] - cum) * dt  # (L,1)
    st_ref[0, 0] = jax.lax.dot_general(
        x, bm * wgt, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(st_ref.dtype)  # (hd, ds)
    dec_ref[0, 0] = jnp.exp(cum[-1, 0]).astype(dec_ref.dtype)


def ssd_chunk_pallas(x, b, c, dt, a, *, interpret: bool = True):
    """One-chunk SSD terms per (batch, head).

    x: (B, L, H, hd); b/c: (B, L, H, ds) (groups pre-broadcast);
    dt: (B, L, H) f32 post-softplus; a: (H,) f32 negative.
    Returns: y_intra (B, L, H, hd) f32, state (B, H, hd, ds) f32,
             chunk_decay (B, H) f32.
    """
    bsz, l, h, hd = x.shape
    ds = b.shape[-1]
    grid = (bsz, h)
    y, st, dec = pl.pallas_call(
        functools.partial(_kernel, l=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, 1, hd), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, l, 1, ds), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, l, 1, ds), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, l, 1), lambda bi, hi: (bi, 0, hi)),
            pl.BlockSpec((1,), lambda bi, hi: (hi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, 1, hd), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi: (bi, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, hd, ds), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, b, c, dt, a)
    return y, st, dec
