"""Pure-jnp oracle for flash attention (GQA, causal)."""
import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True):
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    qr = q.astype(jnp.float32).reshape(b, sq, kv, h // kv, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qr, k.astype(jnp.float32))
    logits = logits / np.sqrt(dh)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)
