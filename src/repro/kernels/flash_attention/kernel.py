"""Flash attention (prefill) Pallas kernel — causal + GQA.

Grid (B*H, Sq/BQ, Sk/BK), KV innermost (arbitrary).  Running (m, l, acc)
live in VMEM scratch, revisited across the KV sweep; the final normalized
block is written once on the last KV step.  GQA is handled in the k/v
index_map (query head h reads KV head h // group) so KV blocks are shared
across the group without materializing repeats in HBM.

Block defaults 256/512 keep q(BQ,dh)+k/v(BK,dh)+p(BQ,BK) comfortably in
VMEM for dh<=128 while giving the MXU 128-aligned contractions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, causal, bq, bk, scale, n_k):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # whole block is masked out iff q_block_end < k_block_start
        run = (qi + 1) * bq - 1 >= kj * bk

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0].astype(jnp.float32)  # (BQ, dh)
        k = k_ref[0].astype(jnp.float32)  # (BK, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == n_k - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, Sq, H, dh)
    k: jax.Array,  # (B, Sk, KV, dh)
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 512,
    interpret: bool = True,
):
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    group = h // kv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = 1.0 / np.sqrt(dh)

    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, dh)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, dh)

    grid = (b * h, sq // bq, sk // bk)

    def kv_map(bh, qi, kj):
        return (bh // h) * kv + (bh % h) // group, kj, 0

    out = pl.pallas_call(
        functools.partial(
            _kernel, causal=causal, bq=bq, bk=bk, scale=scale, n_k=sk // bk
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, dh), kv_map),
            pl.BlockSpec((1, bk, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
