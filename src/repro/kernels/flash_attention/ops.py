"""Jitted public wrapper for flash attention."""
import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "q_offset"))
def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0):
    del q_offset  # full-sequence prefill only; decode uses decode_attention
    return flash_attention_pallas(
        q, k, v, causal=causal, interpret=jax.default_backend() != "tpu"
    )
