"""bge-reranker-style cross encoder: the paper's aggregation model F_aggr.

Takes a (query, chunk) token pair packed into one sequence and outputs a
relevance score; the orchestrator scores all k_n x m candidates pairwise
and keeps the global top-n (paper §2.3.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.lm import _stack_specs
from repro.models.params import ParamSpec
from repro.runtime.sharding import ShardingPolicy

f32 = jnp.float32


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    block = {
        "mixer_norm": ParamSpec((d,), ("norm",), "ones"),
        "attn": L.attn_specs(cfg),
        "ffn_norm": ParamSpec((d,), ("norm",), "ones"),
        "mlp": L.mlp_specs(cfg),
    }
    return {
        "embed": L.embed_specs(cfg),
        "type_embed": ParamSpec((2, d), (None, "embed"), "normal"),
        "blocks": _stack_specs(block, cfg.n_layers),
        "final_norm": ParamSpec((d,), ("norm",), "ones"),
        "score": {"w": ParamSpec((d, 1), ("embed", None), "fan_in", fan_in_dims=(0,))},
    }


def score_pairs(cfg: ModelConfig, pol: ShardingPolicy, params, tokens, type_ids):
    """tokens: (B,S) packed [query ; chunk]; type_ids: (B,S) 0=query 1=chunk.
    Returns relevance scores (B,)."""
    h = L.embed_apply(cfg, pol, params["embed"], tokens)
    h = h + params["type_embed"].astype(h.dtype)[type_ids]
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(hh, bp):
        x = L.rmsnorm(hh, bp["mixer_norm"], cfg.norm_eps)
        hh = hh + L.attn_apply(cfg, pol, bp["attn"], x, positions, causal=False)
        x = L.rmsnorm(hh, bp["ffn_norm"], cfg.norm_eps)
        hh = hh + L.mlp_apply(cfg, pol, bp["mlp"], x)
        return hh, None

    h, _ = jax.lax.scan(body, h, params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    cls = h[:, 0, :].astype(f32)  # first-token pooling
    return (cls @ params["score"]["w"].astype(f32))[:, 0]


def rank_loss(cfg, pol, params, batch):
    """Listwise softmax ranking loss: for each query, one positive among
    n_cand candidates.  batch: tokens (B, n_cand, S), type_ids same,
    label (B,) index of the positive."""
    b, n, s = batch["tokens"].shape
    scores = score_pairs(
        cfg, pol, params,
        batch["tokens"].reshape(b * n, s),
        batch["type_ids"].reshape(b * n, s),
    ).reshape(b, n)
    logp = jax.nn.log_softmax(scores, axis=-1)
    loss = -jnp.take_along_axis(logp, batch["label"][:, None], axis=-1).mean()
    acc = (scores.argmax(-1) == batch["label"]).mean()
    return loss, {"loss": loss, "acc": acc}
