"""bge-reranker-style cross encoder: the paper's aggregation model F_aggr.

Takes a (query, chunk) token pair packed into one sequence and outputs a
relevance score; the orchestrator scores all k_n x m candidates pairwise
and keeps the global top-n (paper §2.3.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.lm import _stack_specs
from repro.models.params import ParamSpec
from repro.runtime.sharding import ShardingPolicy

f32 = jnp.float32


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    block = {
        "mixer_norm": ParamSpec((d,), ("norm",), "ones"),
        "attn": L.attn_specs(cfg),
        "ffn_norm": ParamSpec((d,), ("norm",), "ones"),
        "mlp": L.mlp_specs(cfg),
    }
    return {
        "embed": L.embed_specs(cfg),
        "type_embed": ParamSpec((2, d), (None, "embed"), "normal"),
        "blocks": _stack_specs(block, cfg.n_layers),
        "final_norm": ParamSpec((d,), ("norm",), "ones"),
        "score": {"w": ParamSpec((d, 1), ("embed", None), "fan_in", fan_in_dims=(0,))},
    }


def score_pairs(cfg: ModelConfig, pol: ShardingPolicy, params, tokens, type_ids):
    """tokens: (B,S) packed [query ; chunk]; type_ids: (B,S) 0=query 1=chunk.
    Returns relevance scores (B,)."""
    h = L.embed_apply(cfg, pol, params["embed"], tokens)
    h = h + params["type_embed"].astype(h.dtype)[type_ids]
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(hh, bp):
        x = L.rmsnorm(hh, bp["mixer_norm"], cfg.norm_eps)
        hh = hh + L.attn_apply(cfg, pol, bp["attn"], x, positions, causal=False)
        x = L.rmsnorm(hh, bp["ffn_norm"], cfg.norm_eps)
        hh = hh + L.mlp_apply(cfg, pol, bp["mlp"], x)
        return hh, None

    h, _ = jax.lax.scan(body, h, params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    cls = h[:, 0, :].astype(f32)  # first-token pooling
    return (cls @ params["score"]["w"].astype(f32))[:, 0]


def make_reranker(cfg: ModelConfig, pol: ShardingPolicy, params, *, max_len: int = 64):
    """Adapt the cross encoder to the orchestrator's reranker contract:

      (query_tokens (S,), cand_tokens (C, S)) -> (C,) scores, or the
      batched form (queries (B, S), cands (B, C, S)) -> (B, C)

    The batched form flattens all B*C (query, chunk) pairs into ONE
    ``score_pairs`` call, so a whole query batch re-ranks in a single
    forward pass (``supports_batch``, used by ``aggregate_batch``)."""
    from repro.data.tokenizer import EOS, PAD, SEP

    score = jax.jit(lambda p, t, ty: score_pairs(cfg, pol, p, t, ty))

    def _pack_pairs(q_tokens: np.ndarray, cand: np.ndarray):
        q = [int(t) for t in q_tokens if t != PAD and t != EOS]
        toks = np.full((len(cand), max_len), PAD, np.int32)
        types = np.zeros((len(cand), max_len), np.int32)
        for i, row in enumerate(cand):
            d = [int(t) for t in row if t != PAD]
            ids = (q + [SEP] + d + [EOS])[:max_len]
            toks[i, : len(ids)] = ids
            types[i, min(len(q) + 1, max_len) : len(ids)] = 1
        return toks, types

    def rerank(query_tokens: np.ndarray, cand_tokens: np.ndarray) -> np.ndarray:
        cand = np.asarray(cand_tokens)
        if cand.ndim == 3:  # (B, C, S) batch -> one flattened forward pass
            b, c, _ = cand.shape
            packed = [_pack_pairs(q, cv) for q, cv in zip(np.asarray(query_tokens), cand)]
            toks = np.concatenate([t for t, _ in packed], 0)
            types = np.concatenate([ty for _, ty in packed], 0)
            out = score(params, jnp.asarray(toks), jnp.asarray(types))
            return np.asarray(out, np.float32).reshape(b, c)
        toks, types = _pack_pairs(np.asarray(query_tokens), cand)
        return np.asarray(score(params, jnp.asarray(toks), jnp.asarray(types)), np.float32)

    rerank.supports_batch = True
    return rerank


def rank_loss(cfg, pol, params, batch):
    """Listwise softmax ranking loss: for each query, one positive among
    n_cand candidates.  batch: tokens (B, n_cand, S), type_ids same,
    label (B,) index of the positive."""
    b, n, s = batch["tokens"].shape
    scores = score_pairs(
        cfg, pol, params,
        batch["tokens"].reshape(b * n, s),
        batch["type_ids"].reshape(b * n, s),
    ).reshape(b, n)
    logp = jax.nn.log_softmax(scores, axis=-1)
    loss = -jnp.take_along_axis(logp, batch["label"][:, None], axis=-1).mean()
    acc = (scores.argmax(-1) == batch["label"]).mean()
    return loss, {"loss": loss, "acc": acc}
