"""Core transformer layers: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

Pure functions: ``*_specs(cfg)`` builds the ParamSpec subtree,
``*_apply(cfg, pol, params, ...)`` runs it.  All matmuls run in
``cfg.dtype`` (bf16) with f32 softmax/norm accumulation.

Attention impls:
  naive      materialized S_q x S_k logits (small seq, oracle)
  flash_jnp  lax.scan over KV chunks with online softmax — the dry-run /
             XLA production path (O(S·chunk) memory, exact)
  pallas     kernels/flash_attention (TPU target; validated in interpret mode)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec
from repro.runtime.sharding import ShardingPolicy

# --------------------------------------------------------------------- #
# norms / rope
# --------------------------------------------------------------------- #


def rmsnorm(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))  # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# attention cores  (q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd))
# --------------------------------------------------------------------- #


def _gqa_logits(q, k):
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    qr = q.reshape(b, sq, kv, h // kv, hd)
    return jnp.einsum(
        "bqkgd,bskd->bkgqs", qr, k, preferred_element_type=jnp.float32
    )


def _gqa_out(probs, v, out_dtype):
    b, kv, g, sq, sk = probs.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, kv * g, v.shape[-1]).astype(out_dtype)


def naive_attention(q, k, v, *, causal: bool, q_offset=0):
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = _gqa_logits(q, k) * scale  # (B,KV,G,Sq,Sk) f32
    if causal:
        qpos = q_offset + jnp.arange(q.shape[1])
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return _gqa_out(probs, v, q.dtype)


def flash_jnp_attention(q, k, v, *, causal: bool, chunk: int, q_offset=0, unroll=False):
    """Online-softmax over KV chunks (exact; O(Sq*chunk) live memory)."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    assert sk % chunk == 0, (sk, chunk)
    n = sk // chunk
    g = h // kv
    scale = 1.0 / np.sqrt(hd)
    qr = q.reshape(b, sq, kv, g, hd)
    ks = k.reshape(b, n, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    qpos = q_offset + jnp.arange(sq)

    def body(carry, kc_vc):
        m, l, acc = carry
        (kc, vc), i = kc_vc
        logits = (
            jnp.einsum("bqkgd,bskd->bkgqs", qr, kc, preferred_element_type=jnp.float32)
            * scale
        )  # (B,KV,G,Sq,chunk)
        if causal:
            kpos = i * chunk + jnp.arange(chunk)
            mask = qpos[:, None] >= kpos[None, :]
            logits = jnp.where(mask, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), ((ks, vs), jnp.arange(n)),
        unroll=n if unroll else 1,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


def attention_core(cfg: ModelConfig, q, k, v, *, causal: bool, q_offset=0):
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(q, k, v, causal=causal, q_offset=q_offset)
    if cfg.attn_impl == "flash_jnp" and k.shape[1] > cfg.attn_chunk:
        return flash_jnp_attention(
            q, k, v, causal=causal, chunk=cfg.attn_chunk, q_offset=q_offset,
            unroll=cfg.scan_unroll,
        )
    return naive_attention(q, k, v, causal=causal, q_offset=q_offset)


# --------------------------------------------------------------------- #
# attention block
# --------------------------------------------------------------------- #


def attn_specs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"), "fan_in", fan_in_dims=(0,)),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"), "fan_in", fan_in_dims=(0,)),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"), "fan_in", fan_in_dims=(0,)),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"), "fan_in", fan_in_dims=(0, 1)),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), ("norm",), "ones")
        s["k_norm"] = ParamSpec((hd,), ("norm",), "ones")
    return s


def attn_qkv(cfg: ModelConfig, pol: ShardingPolicy, p, x, positions):
    """Project + rope + qk-norm.  x: (B,S,d) -> q,k,v."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    q = pol.shard(q, "act_batch", "act_seq", "act_heads", None)
    k = pol.shard(k, "act_batch", "act_seq", "act_kv_heads", None)
    v = pol.shard(v, "act_batch", "act_seq", "act_kv_heads", None)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(cfg: ModelConfig, pol: ShardingPolicy, p, x, positions, *, causal=None):
    causal = cfg.causal if causal is None else causal
    q, k, v = attn_qkv(cfg, pol, p, x, positions)
    out = attention_core(cfg, q, k, v, causal=causal)
    out = pol.shard(out, "act_batch", "act_seq", "act_heads", None)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return pol.shard(out, "act_batch", "act_seq", "act_embed")


def attn_decode(cfg: ModelConfig, pol: ShardingPolicy, p, x, k_cache, v_cache, pos):
    """Single-token decode.  x: (B,1,d); caches: (B,S,KV,hd); pos: scalar
    write position, or (B,) per-row positions for ragged batches (each row
    writes its own cache slot and attends to its own prefix)."""
    b, s = x.shape[0], k_cache.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    positions = pos[:, None] if per_row else jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = attn_qkv(cfg, pol, p, x, positions)
    if per_row:
        slot = jax.lax.broadcasted_iota(jnp.int32, (b, s), 1) == pos[:, None]
        k_cache = jnp.where(slot[..., None, None], k_new.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(slot[..., None, None], v_new.astype(v_cache.dtype), v_cache)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    k_cache = pol.shard(k_cache, "cache_batch", "cache_seq", "cache_kv", None)
    v_cache = pol.shard(v_cache, "cache_batch", "cache_seq", "cache_kv", None)
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = _gqa_logits(q, k_cache.astype(q.dtype)) * scale  # (B,KV,G,1,S)
    kpos = jnp.arange(s)
    valid = (kpos[None, :] <= pos[:, None]).reshape(b, 1, 1, 1, s) if per_row else (kpos <= pos)
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = _gqa_out(probs, v_cache.astype(q.dtype), q.dtype)  # (B,1,H,hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, k_cache, v_cache


def _paged_attn_sharded(cfg: ModelConfig, q, k_new, v_new, k_pool, v_pool,
                        block_tables, q_start, q_len, block_size: int, mesh):
    """Distributed write-then-attend over a SHARDED block pool.

    ``k_pool``/``v_pool``: ``(n_shards, n_local + 1, block_size, KV, hd)``
    laid out ``P("data", ...)`` — each device holds its shard's blocks
    plus a per-shard trash block at local index ``n_local``.
    ``block_tables`` carries GLOBAL block ids (shard ``b // n_local``,
    local id ``b % n_local``; the global trash id ``n_shards * n_local``
    maps to every shard's local trash automatically, since its "shard"
    equals ``n_shards`` and matches nobody).

    Each shard scatters only the fresh lanes whose target block it owns
    (everything else lands in its local trash) and runs the
    ``kernels/chunked_prefill`` partials over its own table entries, with
    non-owned entries masked to exact zeros.  The allocator's row
    affinity puts ALL of a row's blocks on one shard, so the
    ``dist_decode.combine_partials`` merge passes the owner's partials
    through bitwise — an N-shard run is bit-identical to the 1-shard run
    (asserted in tests/test_sharded_serving.py).

    Returns ``(out, k_pool, v_pool)`` with ``out``: ``(B, W, H, hd)``
    (wo projection is the caller's, outside the shard_map).
    """
    from jax.sharding import PartitionSpec as P

    from repro.kernels.chunked_prefill.ref import mixed_prefill_partials
    from repro.runtime.compat import shard_map
    from repro.serving.dist_decode import combine_partials

    b, w, h, dh = q.shape
    kv = k_pool.shape[3]
    n_local = k_pool.shape[1] - 1
    s_pad = block_tables.shape[1] * block_size
    rows = jnp.arange(b)

    def body(q, k_sh, v_sh, k_new, v_new, tables, q_start, q_len):
        k_sh, v_sh = k_sh[0], v_sh[0]  # (n_local+1, bs, KV, hd)
        my = jax.lax.axis_index("data")
        owned = (tables // n_local) == my  # (B, n_t)
        loc_tbl = jnp.where(owned, tables % n_local, n_local)
        lane = jnp.arange(w)
        live = lane[None, :] < q_len[:, None]
        pos_c = jnp.minimum(q_start[:, None] + lane[None, :], s_pad - 1)
        bid_g = tables[rows[:, None], pos_c // block_size]
        mine = live & ((bid_g // n_local) == my)
        bid = jnp.where(mine, bid_g % n_local, n_local)
        off = pos_c % block_size
        k_sh = k_sh.at[bid, off].set(k_new.astype(k_sh.dtype))
        v_sh = v_sh.at[bid, off].set(v_new.astype(v_sh.dtype))
        desc = jnp.stack(
            [rows, q_start, q_len, q_start + q_len], axis=1
        ).astype(jnp.int32)
        o, m, l = mixed_prefill_partials(q, k_sh, v_sh, loc_tbl, desc, owned=owned)
        out = combine_partials(o, m, l, axis_name="data")  # (B,KV,G,W,dh)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, w, kv * (h // kv), dh)
        return out.astype(q.dtype), k_sh[None], v_sh[None]

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P("data"), P("data"), P(), P(), P(), P(), P()),
        out_specs=(P(), P("data"), P("data")),
        check_vma=False,
    )
    return fn(q, k_pool, v_pool, k_new, v_new, block_tables, q_start, q_len)


def attn_decode_paged(cfg: ModelConfig, pol: ShardingPolicy, p, x, k_pool, v_pool, pos, block_tables, block_size: int, mesh=None):
    """Single-token decode against a PAGED KV cache.

    ``k_pool``/``v_pool``: ``(n_pool, block_size, KV, hd)`` shared block
    pool (this layer's slice); ``block_tables``: ``(B, n_max_blocks)``
    int32 mapping each row's logical block ``i`` (positions ``[i*bs,
    (i+1)*bs)``) to a pool block.  ``pos`` is always per-row ``(B,)`` in
    paged mode.  The new K/V lands at ``pool[table[pos // bs], pos % bs]``.

    Attention impl follows ``cfg.attn_impl`` — the same kernels-vs-layers
    split the contiguous decode path has:
      * default (XLA): gather the ``(B, n_max_blocks * bs)`` view and run
        the masked softmax inline — identical values, shapes, and mask
        arithmetic to the contiguous ``attn_decode`` whenever
        ``n_max_blocks * bs`` equals the contiguous ``cache_len``, which
        is what makes the paged engine bit-identical to the contiguous
        baseline.  Unallocated table entries point at the engine's trash
        block: their lanes are always behind the ``kpos <= pos`` mask, so
        whatever they hold contributes exactly 0 to softmax.
      * ``attn_impl="pallas"``: ``kernels/decode_attention``'s paged
        flash-decode kernel — the scalar-prefetched block table drives
        the K/V BlockSpec index maps, so the gather never materializes in
        HBM (interpret mode off-TPU; numerically equal to the XLA path
        within flash-softmax reassociation tolerance, parity-tested in
        tests/test_models.py).
    """
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    q, k_new, v_new = attn_qkv(cfg, pol, p, x, pos[:, None])
    if k_pool.ndim == 5:
        # sharded pool (n_shards, n_local+1, bs, KV, hd): decode is the
        # W=1 case of the distributed mixed dispatch.  A free slot's
        # all-trash table matches no shard, so its (discarded) lane
        # outputs exact zeros instead of trash-block garbage
        out, k_pool, v_pool = _paged_attn_sharded(
            cfg, q, k_new, v_new, k_pool, v_pool, block_tables,
            pos, jnp.ones((b,), jnp.int32), block_size, mesh,
        )
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
        return out, k_pool, v_pool
    rows = jnp.arange(b)
    bid = block_tables[rows, pos // block_size]  # (B,) pool block per row
    off = pos % block_size
    # rows own disjoint blocks (the pool allocator guarantees it), so the
    # (bid, off) scatter targets are distinct across live rows
    k_pool = k_pool.at[bid, off].set(k_new[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[bid, off].set(v_new[:, 0].astype(v_pool.dtype))
    if cfg.attn_impl == "pallas":
        from repro.kernels.decode_attention import ops as da_ops

        out = da_ops.paged_decode_attention(
            q[:, 0], k_pool, v_pool, block_tables, pos + 1, use_pallas=True
        )[:, None]  # (B,1,H,hd)
    else:
        s_pad = block_tables.shape[1] * block_size
        k_view = k_pool[block_tables].reshape(b, s_pad, *k_pool.shape[2:])
        v_view = v_pool[block_tables].reshape(b, s_pad, *v_pool.shape[2:])
        scale = 1.0 / np.sqrt(q.shape[-1])
        logits = _gqa_logits(q, k_view.astype(q.dtype)) * scale  # (B,KV,G,1,S_pad)
        kpos = jnp.arange(s_pad)
        valid = (kpos[None, :] <= pos[:, None]).reshape(b, 1, 1, 1, s_pad)
        logits = jnp.where(valid, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = _gqa_out(probs, v_view.astype(q.dtype), q.dtype)  # (B,1,H,hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, k_pool, v_pool


def attn_mixed_paged(cfg: ModelConfig, pol: ShardingPolicy, p, x, k_pool, v_pool,
                     positions, block_tables, block_size: int, q_len, mesh=None):
    """UNIFIED mixed prefill+decode attention against a paged KV cache:
    one dispatch serves any mix of cold prefill chunks, warm suffix
    chunks riding shared prefix blocks, and 1-token decode rows.

    ``x``: ``(B, W, d)`` — W query lanes per row, of which the first
    ``q_len[b]`` are live (a decode row is ``q_len == 1``; an idle slot
    is ``q_len == 0``).  ``positions``: ``(B, W)`` absolute positions
    ``q_start[b] + lane``.  Write-then-attend: the live lanes' fresh K/V
    scatter into ``pool[table[pos // bs], pos % bs]`` FIRST (dead lanes
    target the trash block, never a neighbor's), then attention reads
    the pool alone — no fresh-K/V overlay, no HBM gather of a prefix
    view.  For a decode row this is exactly ``attn_decode_paged``'s
    scatter + mask arithmetic; for prefill lanes the pool round-trip is
    lossless at pool dtype == activation dtype, so chunked fill equals
    the dense prefill per token.  Because every row reads pool-dtype
    K/V for prefix AND fresh lanes alike, hit-vs-miss consistency holds
    at any pool dtype (the restriction the overlay path had to impose).

    Attention impl follows ``cfg.attn_impl``:
      * default (XLA): gather the padded view, mask each lane to its
        causal span ``kpos <= position`` within ``kv_len``, re-zero
        probs under the mask (exact identity for live lanes; makes dead
        lanes output exactly 0).
      * ``attn_impl="pallas"``: ``kernels/chunked_prefill``'s unified
        kernel — descriptors + block table ride scalar prefetch, pool
        blocks stream straight into VMEM (interpret mode off-TPU).

    Returns ``(o, k_pool, v_pool)`` with the fresh K/V already resident.
    """
    b, w = x.shape[0], x.shape[1]
    q, k_new, v_new = attn_qkv(cfg, pol, p, x, positions)
    if k_pool.ndim == 5:
        # sharded pool: distributed dispatch — per-shard scatter +
        # chunked-prefill partials, merged by dist_decode's combine
        out, k_pool, v_pool = _paged_attn_sharded(
            cfg, q, k_new, v_new, k_pool, v_pool, block_tables,
            positions[:, 0], q_len, block_size, mesh,
        )
        out = pol.shard(out, "act_batch", "act_seq", "act_heads", None)
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
        out = pol.shard(out, "act_batch", "act_seq", "act_embed")
        return out, k_pool, v_pool
    s_pad = block_tables.shape[1] * block_size
    lane = jnp.arange(w)
    live = lane[None, :] < q_len[:, None]  # (B, W)
    pos_c = jnp.minimum(positions, s_pad - 1)
    bid = jnp.where(
        live,
        block_tables[jnp.arange(b)[:, None], pos_c // block_size],
        k_pool.shape[0] - 1,  # trash block
    )
    off = pos_c % block_size
    # live lanes hit disjoint (bid, off) slots across rows (the allocator
    # guarantees block ownership); dead-lane collisions land in trash
    k_pool = k_pool.at[bid, off].set(k_new.astype(k_pool.dtype))
    v_pool = v_pool.at[bid, off].set(v_new.astype(v_pool.dtype))
    q_start = positions[:, 0]
    kv_len = q_start + q_len
    if cfg.attn_impl == "pallas":
        from repro.kernels.chunked_prefill import ops as cp_ops

        desc = jnp.stack(
            [jnp.arange(b), q_start, q_len, kv_len], axis=1
        ).astype(jnp.int32)
        out = cp_ops.mixed_prefill_attention(
            q, k_pool, v_pool, block_tables, desc, use_pallas=True
        )  # (B,W,H,hd)
    else:
        k_view = k_pool[block_tables].reshape(b, s_pad, *k_pool.shape[2:])
        v_view = v_pool[block_tables].reshape(b, s_pad, *v_pool.shape[2:])
        scale = 1.0 / np.sqrt(q.shape[-1])
        logits = _gqa_logits(q, k_view.astype(q.dtype)) * scale  # (B,KV,G,W,S_pad)
        kpos = jnp.arange(s_pad)
        valid = (
            (kpos[None, None, :] <= positions[..., None])
            & (kpos[None, None, :] < kv_len[:, None, None])
            & live[..., None]
        )  # (B, W, S_pad)
        logits = jnp.where(valid[:, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        probs = jnp.where(valid[:, None, None], probs, 0.0)
        out = _gqa_out(probs, v_view.astype(q.dtype), q.dtype)  # (B,W,H,hd)
    out = pol.shard(out, "act_batch", "act_seq", "act_heads", None)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    out = pol.shard(out, "act_batch", "act_seq", "act_embed")
    return out, k_pool, v_pool


# --------------------------------------------------------------------- #
# SwiGLU MLP
# --------------------------------------------------------------------- #


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wg": ParamSpec((d, f), ("embed", "mlp"), "fan_in", fan_in_dims=(0,)),
        "wu": ParamSpec((d, f), ("embed", "mlp"), "fan_in", fan_in_dims=(0,)),
        "wd": ParamSpec((f, d), ("mlp", "embed"), "fan_in", fan_in_dims=(0,)),
    }


def mlp_apply(cfg: ModelConfig, pol: ShardingPolicy, p, x):
    dt = x.dtype
    h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wu"].astype(dt))
    h = pol.shard(h, "act_batch", "act_seq", "act_ff")
    out = h @ p["wd"].astype(dt)
    return pol.shard(out, "act_batch", "act_seq", "act_embed")


# --------------------------------------------------------------------- #
# embeddings / head
# --------------------------------------------------------------------- #


def embed_specs(cfg: ModelConfig) -> dict:
    s = {"tok": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), "normal")}
    return s


def head_specs(cfg: ModelConfig) -> dict:
    if cfg.tie_embeddings:
        return {}
    return {"w": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), "fan_in", fan_in_dims=(0,))}


def embed_apply(cfg: ModelConfig, pol: ShardingPolicy, p, tokens):
    out = jnp.take(p["tok"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    return pol.shard(out, "act_batch", "act_seq", "act_embed")


def head_apply(cfg: ModelConfig, pol: ShardingPolicy, params, x):
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]["w"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.dtype(cfg.logit_dtype))
    return pol.shard(logits, "act_batch", "act_seq", "act_vocab")
