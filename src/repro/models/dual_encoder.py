"""Contriever-style dual encoder: the paper's embedding model F_emb.

Token encoder + mean pooling; trained with in-batch-negative InfoNCE
(contrastive, as Contriever).  Shared weights for query/document towers.
This is the model the paper federates with FL (core/federated.py trains it
with FedAvg / secure aggregation across providers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.lm import _stack_specs
from repro.models.params import ParamSpec
from repro.runtime.sharding import ShardingPolicy

f32 = jnp.float32


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    block = {
        "mixer_norm": ParamSpec((d,), ("norm",), "ones"),
        "attn": L.attn_specs(cfg),
        "ffn_norm": ParamSpec((d,), ("norm",), "ones"),
        "mlp": L.mlp_specs(cfg),
    }
    return {
        "embed": L.embed_specs(cfg),
        "blocks": _stack_specs(block, cfg.n_layers),
        "final_norm": ParamSpec((d,), ("norm",), "ones"),
    }


def encode(cfg: ModelConfig, pol: ShardingPolicy, params, tokens, pad_id: int = 0):
    """tokens: (B,S) -> L2-normalized embeddings (B, d)."""
    h = L.embed_apply(cfg, pol, params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(hh, bp):
        x = L.rmsnorm(hh, bp["mixer_norm"], cfg.norm_eps)
        hh = hh + L.attn_apply(cfg, pol, bp["attn"], x, positions, causal=False)
        x = L.rmsnorm(hh, bp["ffn_norm"], cfg.norm_eps)
        hh = hh + L.mlp_apply(cfg, pol, bp["mlp"], x)
        return hh, None

    h, _ = jax.lax.scan(body, h, params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    msk = (tokens != pad_id).astype(f32)[..., None]
    pooled = (h.astype(f32) * msk).sum(1) / jnp.maximum(msk.sum(1), 1.0)
    return pooled / jnp.maximum(jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)


def info_nce_loss(cfg, pol, params, batch, temperature: float = 0.05):
    """batch: query_tokens (B,S), doc_tokens (B,S) — positives aligned,
    in-batch negatives."""
    q = encode(cfg, pol, params, batch["query_tokens"])
    d = encode(cfg, pol, params, batch["doc_tokens"])
    sim = (q @ d.T) / temperature  # (B,B)
    labels = jnp.arange(q.shape[0])
    logp = jax.nn.log_softmax(sim, axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (sim.argmax(-1) == labels).mean()
    return loss, {"loss": loss, "acc": acc}
