"""Encoder-only backbone (HuBERT-xlarge) + masked-prediction objective.

Frontend stub per the assignment: ``input_specs()`` provides precomputed
frame embeddings (B, S, d_model); the CNN feature extractor is out of
scope.  Bidirectional attention, no KV cache / decode step (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamSpec
from repro.runtime.sharding import ShardingPolicy

f32 = jnp.float32


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    block = {
        "mixer_norm": ParamSpec((d,), ("norm",), "ones"),
        "attn": L.attn_specs(cfg),
        "ffn_norm": ParamSpec((d,), ("norm",), "ones"),
        "mlp": L.mlp_specs(cfg),
    }
    from repro.models.lm import _stack_specs

    return {
        "mask_embed": ParamSpec((d,), ("norm",), "normal"),
        "blocks": _stack_specs(block, cfg.n_layers),
        "final_norm": ParamSpec((d,), ("norm",), "ones"),
        "head": {"w": ParamSpec((d, cfg.vocab_size), ("embed", "vocab"), "fan_in", fan_in_dims=(0,))},
    }


def encode(cfg: ModelConfig, pol: ShardingPolicy, params, frames, mask=None):
    """frames: (B,S,d) precomputed embeddings; mask: (B,S) bool -> replace
    masked positions with the learned mask embedding (HuBERT-style)."""
    h = frames.astype(jnp.dtype(cfg.dtype))
    if mask is not None:
        h = jnp.where(mask[..., None], params["mask_embed"].astype(h.dtype), h)
    h = pol.shard(h, "act_batch", "act_seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])

    def body(carry, bp):
        hh = carry
        x = L.rmsnorm(hh, bp["mixer_norm"], cfg.norm_eps)
        hh = hh + L.attn_apply(cfg, pol, bp["attn"], x, positions, causal=False)
        x = L.rmsnorm(hh, bp["ffn_norm"], cfg.norm_eps)
        hh = hh + L.mlp_apply(cfg, pol, bp["mlp"], x)
        return hh, None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(
        body, h, params["blocks"], unroll=cfg.n_layers if cfg.scan_unroll else 1
    )
    return L.rmsnorm(h, params["final_norm"], cfg.norm_eps)


def loss_fn(cfg: ModelConfig, pol: ShardingPolicy, params, batch):
    """Masked-prediction CE over the codebook (vocab_size)."""
    h = encode(cfg, pol, params, batch["frames"], batch["mask"])
    logits = (h @ params["head"]["w"].astype(h.dtype)).astype(f32)
    logits = pol.shard(logits, "act_batch", "act_seq", "act_vocab")
    from repro.models.lm import sharded_ce

    m = batch["mask"].astype(f32)
    ce = sharded_ce(logits, batch["targets"], m)
    return ce, {"ce": ce, "tokens": m.sum()}


def embed_corpus(cfg: ModelConfig, pol: ShardingPolicy, params, frames):
    """Mean-pooled utterance embedding (provider-side audio retrieval)."""
    h = encode(cfg, pol, params, frames)
    return h.mean(axis=1)
