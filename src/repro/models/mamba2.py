"""Mamba2 / SSD (state-space duality) mixer — TPU-native chunked form.

The SSD formulation (Dao & Gu, arXiv:2405.21060) splits the sequence into
chunks of length L: the intra-chunk term is a small masked "attention"
(MXU-friendly matmuls), the inter-chunk term is a length-S/L recurrence
over (H, hd, ds) states carried by ``lax.scan``.  Decode is the O(1)
recurrent step.  All state math in f32.

Sharding: heads over `model` (B/C are per-group, replicated — the GQA
analogue), sequence/batch over `data` like attention.

Used for both the ``mamba2-1.3b`` arch and Jamba's mamba layers (DESIGN.md:
Jamba-1.5 ships Mamba-1 layers; we use the SSD formulation as the
TPU-efficient member of the same model class — recorded as an adaptation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec
from repro.runtime.sharding import ShardingPolicy


def mamba_specs(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    g, ds, h, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.conv_width
    return {
        "wz": ParamSpec((d, di), ("embed", "mlp"), "fan_in", fan_in_dims=(0,)),
        "wx": ParamSpec((d, di), ("embed", "mlp"), "fan_in", fan_in_dims=(0,)),
        "wB": ParamSpec((d, g * ds), ("embed", None), "fan_in", fan_in_dims=(0,)),
        "wC": ParamSpec((d, g * ds), ("embed", None), "fan_in", fan_in_dims=(0,)),
        "wdt": ParamSpec((d, h), ("embed", "dt"), "fan_in", fan_in_dims=(0,)),
        "conv_x": ParamSpec((w, di), ("conv", "mlp"), "fan_in", fan_in_dims=(0,)),
        "conv_B": ParamSpec((w, g * ds), ("conv", None), "fan_in", fan_in_dims=(0,)),
        "conv_C": ParamSpec((w, g * ds), ("conv", None), "fan_in", fan_in_dims=(0,)),
        "A_log": ParamSpec((h,), ("dt",), "zeros"),
        "D": ParamSpec((h,), ("dt",), "ones"),
        "dt_bias": ParamSpec((h,), ("dt",), "zeros"),
        "norm": ParamSpec((di,), ("mlp",), "ones"),
        "wo": ParamSpec((di, d), ("mlp", "embed"), "fan_in", fan_in_dims=(0,)),
    }


def _causal_conv(x, kernel, state=None):
    """Depthwise causal conv along seq.  x: (B,S,C); kernel: (W,C);
    state: (B,W-1,C) history or None (zero history).  Returns (y, new_state)."""
    w = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], w - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+W-1, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * kernel[i][None, None, :] for i in range(w)
    )
    new_state = xp[:, -(w - 1) :, :] if w > 1 else state
    return y, new_state


def _project(cfg, p, x):
    dt_ = x.dtype
    z = x @ p["wz"].astype(dt_)
    xin = x @ p["wx"].astype(dt_)
    B = x @ p["wB"].astype(dt_)
    C = x @ p["wC"].astype(dt_)
    dt_raw = x @ p["wdt"].astype(dt_)
    return z, xin, B, C, dt_raw


def _ssd_chunked(cfg: ModelConfig, xh, Bh, Ch, dt, a, init_state=None):
    """Chunked SSD.  xh: (B,S,H,hd); Bh/Ch: (B,S,G,ds); dt: (B,S,H) f32 (post-
    softplus); a: (H,) negative.  Returns (y (B,S,H,hd), final_state (B,H,hd,ds))."""
    b, s, h, hd = xh.shape
    g, ds = Bh.shape[2], Bh.shape[3]
    l = min(cfg.ssd_chunk, s)
    s_orig = s
    if s % l:  # pad: dt=0 rows decay by exp(0)=1 and contribute nothing
        pad = l - s % l
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // l
    rep = h // g

    def resh(t, feat):
        return t.reshape(b, nc, l, *feat).transpose(1, 0, 2, *range(3, 3 + len(feat)))

    xs = resh(xh, (h, hd))
    bs = resh(Bh, (g, ds))
    cs_ = resh(Ch, (g, ds))
    dts = resh(dt, (h,))

    mask = jnp.tril(jnp.ones((l, l), bool))

    def chunk_body(state, inp):
        xc, bc, cc, dtc = inp  # (B,L,H,hd), (B,L,G,ds), (B,L,G,ds), (B,L,H)
        xf = xc.astype(jnp.float32)
        da = dtc * a  # (B,L,H), <= 0
        cum = jnp.cumsum(da, axis=1)  # inclusive
        cum_h = cum.transpose(0, 2, 1)  # (B,H,L)
        # intra-chunk: scores(i,j) = (C_i·B_j) * exp(cum_i - cum_j) * dt_j, j<=i
        cb = jnp.einsum("bigs,bjgs->bgij", cc.astype(jnp.float32), bc.astype(jnp.float32))
        cb = jnp.repeat(cb, rep, axis=1)  # (B,H,L,L)
        decay_arg = cum_h[:, :, :, None] - cum_h[:, :, None, :]
        decay = jnp.exp(jnp.where(mask, decay_arg, -1e30))  # masked-safe
        scores = cb * decay * dtc.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhij,bjhp->bihp", scores, xf)
        # inter-chunk: contribution of the carried state
        ci = jnp.repeat(cc.astype(jnp.float32), rep, axis=2)  # (B,L,H,ds)
        y_inter = jnp.einsum("bihs,bhps->bihp", ci, state) * jnp.exp(cum)[..., None]
        # new state: exp(cum_L)*state + sum_j exp(cum_L - cum_j) dt_j B_j (x)_j
        wgt = jnp.exp(cum[:, -1:, :] - cum) * dtc  # (B,L,H)
        bi = jnp.repeat(bc.astype(jnp.float32), rep, axis=2)  # (B,L,H,ds)
        state_new = state * jnp.exp(cum[:, -1, :])[:, :, None, None] + jnp.einsum(
            "bjh,bjhs,bjhp->bhps", wgt, bi, xf
        )
        return state_new, (y_intra + y_inter)

    if init_state is None:
        init_state = jnp.zeros((b, h, hd, ds), jnp.float32)
    # NOTE: stays rolled even under cfg.scan_unroll — the dry-run cost
    # measurement corrects the missing (nc-1) chunks analytically
    # (launch/roofline.ssd_correction); unrolling nc=128 chunks x 7 mamba
    # layers is compile-prohibitive.
    body = jax.checkpoint(chunk_body) if cfg.remat == "block" else chunk_body
    final_state, ys = jax.lax.scan(body, init_state, (xs, bs, cs_, dts))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)[:, :s_orig]
    return y, final_state


def mamba_apply(cfg: ModelConfig, pol: ShardingPolicy, p, x, *, init=None):
    """Full-sequence forward.  x: (B,S,d).  init: optional (conv_states, ssm_state)
    for chunked prefill.  Returns (out, (conv_states, ssm_state))."""
    b, s, d = x.shape
    h, hd, g, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    z, xin, B, C, dt_raw = _project(cfg, p, x)
    cst = init[0] if init else (None, None, None)
    xin, cs_x = _causal_conv(xin, p["conv_x"].astype(xin.dtype), cst[0])
    B, cs_b = _causal_conv(B, p["conv_B"].astype(B.dtype), cst[1])
    C, cs_c = _causal_conv(C, p["conv_C"].astype(C.dtype), cst[2])
    xin, B, C = jax.nn.silu(xin), jax.nn.silu(B), jax.nn.silu(C)
    xin = pol.shard(xin, "act_batch", "act_seq", "act_ff")

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    xh = xin.reshape(b, s, h, hd)
    Bh = B.reshape(b, s, g, ds)
    Ch = C.reshape(b, s, g, ds)
    y, ssm_state = _ssd_chunked(
        cfg, xh, Bh, Ch, dt, a, init_state=init[1] if init else None
    )
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    # gated per-head RMSNorm (TP-friendly: normalizes over hd only)
    gated = y * jax.nn.silu(z.astype(jnp.float32)).reshape(b, s, h, hd)
    var = jnp.mean(gated * gated, axis=-1, keepdims=True)
    scale = p["norm"].astype(jnp.float32).reshape(h, hd)
    y = (gated * jax.lax.rsqrt(var + cfg.norm_eps) * scale).astype(x.dtype)
    out = y.reshape(b, s, cfg.d_inner) @ p["wo"].astype(x.dtype)
    return pol.shard(out, "act_batch", "act_seq", "act_embed"), ((cs_x, cs_b, cs_c), ssm_state)


def mamba_decode(cfg: ModelConfig, pol: ShardingPolicy, p, x, conv_states, ssm_state):
    """Single-token recurrent step.  x: (B,1,d); conv_states: 3x(B,W-1,C);
    ssm_state: (B,H,hd,ds) f32.  Returns (out, conv_states, ssm_state)."""
    b = x.shape[0]
    h, hd, g, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    z, xin, B, C, dt_raw = _project(cfg, p, x)
    xin, cs_x = _causal_conv(xin, p["conv_x"].astype(xin.dtype), conv_states[0])
    B, cs_b = _causal_conv(B, p["conv_B"].astype(B.dtype), conv_states[1])
    C, cs_c = _causal_conv(C, p["conv_C"].astype(C.dtype), conv_states[2])
    xin, B, C = jax.nn.silu(xin), jax.nn.silu(B), jax.nn.silu(C)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)  # (B,H)
    xh = xin.astype(jnp.float32).reshape(b, h, hd)
    Bh = jnp.repeat(B.astype(jnp.float32).reshape(b, g, ds), h // g, axis=1)  # (B,H,ds)
    Ch = jnp.repeat(C.astype(jnp.float32).reshape(b, g, ds), h // g, axis=1)
    ssm_state = ssm_state * da[:, :, None, None] + (dt[:, :, None] * xh)[..., None] * Bh[:, :, None, :]
    ssm_state = pol.shard(ssm_state, "cache_batch", "act_heads", None, None)
    y = jnp.einsum("bhps,bhs->bhp", ssm_state, Ch) + xh * p["D"].astype(jnp.float32)[None, :, None]
    gated = y * jax.nn.silu(z.astype(jnp.float32)).reshape(b, h, hd)
    var = jnp.mean(gated * gated, axis=-1, keepdims=True)
    scale = p["norm"].astype(jnp.float32).reshape(h, hd)
    y = (gated * jax.lax.rsqrt(var + cfg.norm_eps) * scale).astype(x.dtype)
    out = y.reshape(b, 1, cfg.d_inner) @ p["wo"].astype(x.dtype)
    return out, (cs_x, cs_b, cs_c), ssm_state


def mamba_reference(cfg: ModelConfig, p, x):
    """Sequential-recurrence oracle (no chunking) for tests."""
    b, s, d = x.shape
    h, hd, g, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    conv = (None, None, None)
    state = jnp.zeros((b, h, hd, ds), jnp.float32)
    outs = []
    conv = (
        jnp.zeros((b, cfg.conv_width - 1, cfg.d_inner), x.dtype),
        jnp.zeros((b, cfg.conv_width - 1, g * ds), x.dtype),
        jnp.zeros((b, cfg.conv_width - 1, g * ds), x.dtype),
    )
    pol = ShardingPolicy(rules={}, mesh=None)
    for t in range(s):
        o, conv, state = mamba_decode(cfg, pol, p, x[:, t : t + 1], conv, state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
