"""Expert-parallel Mixture-of-Experts (top-k routing, GQA-era configs).

Production path = ``masked-local EP``: tokens stay sharded over the data
axis and replicated over `model`; each model shard owns E/tp experts,
compacts the (token, expert) pairs routed to *its* experts into a fixed
capacity buffer, runs a grouped matmul (``jax.lax.ragged_dot``), scatters
back, and a single psum over `model` combines expert outputs — the same
collective a Megatron row-parallel MLP already pays.  This handles every
shape cell including decode (tokens-per-device < 1 regimes) and was
validated exactly against the dense reference (tests/test_moe.py).

An all-to-all token-resharded variant (lower collective bytes for large
T) is implemented as ``moe_apply_a2a`` — see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec
from repro.models.layers import mlp_specs, mlp_apply
from repro.runtime.compat import axis_size, shard_map
from repro.runtime.sharding import ShardingPolicy


def padded_experts(cfg: ModelConfig, tp: int) -> int:
    return int(math.ceil(cfg.n_experts / tp) * tp)


def moe_specs(cfg: ModelConfig, tp_hint: int = 16) -> dict:
    d, f = cfg.d_model, cfg.resolved_moe_d_ff
    e_pad = padded_experts(cfg, tp_hint)
    s = {
        "router": ParamSpec((d, e_pad), ("embed", "experts"), "fan_in", fan_in_dims=(0,)),
        "wg": ParamSpec((e_pad, d, f), ("experts", "expert_in", "expert_mlp"), "fan_in", fan_in_dims=(1,)),
        "wu": ParamSpec((e_pad, d, f), ("experts", "expert_in", "expert_mlp"), "fan_in", fan_in_dims=(1,)),
        "wd": ParamSpec((e_pad, f, d), ("experts", "expert_mlp", "expert_in"), "fan_in", fan_in_dims=(1,)),
    }
    if cfg.n_shared_experts:
        s["shared"] = mlp_specs(cfg, d_ff=cfg.n_shared_experts * f)
        s["shared_gate"] = ParamSpec((d, 1), ("embed", None), "fan_in", fan_in_dims=(0,))
    return s


def _route(cfg: ModelConfig, router_w, x2d):
    """Top-k routing in f32.  x2d: (T, d) -> gates (T,k), ids (T,k), probs (T,E_pad)."""
    logits = (x2d.astype(jnp.float32) @ router_w.astype(jnp.float32))
    e_pad = logits.shape[-1]
    valid = jnp.arange(e_pad) < cfg.n_experts
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.moe_top_k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)  # renormalize
    return gates, ids, probs


def _aux_loss(cfg: ModelConfig, probs, ids):
    """Switch-style load-balance loss (computed over local tokens; callers
    psum/mean across shards)."""
    e = probs.shape[-1]
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((e,), jnp.float32)
    ce = ce.at[ids.reshape(-1)].add(1.0)
    ce = ce / jnp.clip(ce.sum(), 1.0)
    return e * jnp.sum(me * ce)


def _expert_compute(wg, wu, wd, xbuf, group_sizes):
    """SwiGLU grouped matmul over capacity buffer (CAP, d)."""
    dt = xbuf.dtype
    h = jax.nn.silu(jax.lax.ragged_dot(xbuf, wg.astype(dt), group_sizes)) * jax.lax.ragged_dot(
        xbuf, wu.astype(dt), group_sizes
    )
    return jax.lax.ragged_dot(h, wd.astype(dt), group_sizes)


def _local_moe(cfg: ModelConfig, cap: int, axis_names: tuple, p, x_loc):
    """Per-device body under shard_map.  x_loc: (T_loc, d) replicated over
    `model`; p["wg"/"wu"/"wd"] are the local expert shards (E_loc, ...)."""
    tp = axis_size("model")
    my = jax.lax.axis_index("model")
    e_loc = p["wg"].shape[0]
    t_loc = x_loc.shape[0]

    gates, ids, probs = _route(cfg, p["router"], x_loc)
    flat_ids = ids.reshape(-1)
    flat_gates = gates.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(t_loc), cfg.moe_top_k)
    mine = (flat_ids // e_loc) == my
    eloc = jnp.where(mine, flat_ids % e_loc, e_loc)  # e_loc == pad bucket
    order = jnp.argsort(eloc)[:cap]
    sel_e = eloc[order]
    sel_t = tok_idx[order]
    sel_g = jnp.where(sel_e < e_loc, flat_gates[order], 0.0)
    xbuf = x_loc[sel_t]
    gs = jnp.bincount(jnp.clip(sel_e, 0, e_loc), length=e_loc + 1)[:e_loc].astype(jnp.int32)

    y = _expert_compute(p["wg"], p["wu"], p["wd"], xbuf, gs)
    out = jnp.zeros_like(x_loc).at[sel_t].add(
        (y * sel_g[:, None].astype(y.dtype)).astype(x_loc.dtype)
    )
    out = jax.lax.psum(out, "model")
    aux = jax.lax.pmean(_aux_loss(cfg, probs, ids), axis_names)
    return out, aux


def moe_apply(cfg: ModelConfig, pol: ShardingPolicy, p, x):
    """x: (B, S, d) -> (out, aux_loss).  Sharded path uses shard_map over the
    full mesh; 1-device path runs the same body inline (tp=1)."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    mesh = pol.mesh
    if (
        cfg.moe_impl == "a2a"
        and mesh is not None
        and "model" in mesh.shape
        and mesh.size > 1
        and (b * s) % mesh.size == 0
    ):
        return moe_apply_a2a(cfg, pol, p, x)
    if mesh is not None and "model" in mesh.shape and mesh.size > 1:
        tp = mesh.shape["model"]
        dp = mesh.size // tp
        batch_rule = pol.rules.get("act_batch")
        t_loc = max(b * s // dp, 1) if batch_rule else b * s
        cap = _capacity(cfg, t_loc, tp)
        tok_axes = batch_rule if batch_rule else None
        tok_spec = P(tok_axes, None)
        axis_names = tuple(mesh.axis_names)
        out, aux = shard_map(
            partial(_local_moe, cfg, cap, axis_names),
            mesh=mesh,
            in_specs=(_moe_param_specs(p), tok_spec),
            out_specs=(tok_spec, P()),
            check_vma=False,
        )(p, x2d)
    else:
        cap = _capacity(cfg, b * s, 1)
        out, aux = _local_moe_single(cfg, cap, p, x2d)
    out = out.reshape(b, s, d)
    if cfg.n_shared_experts:
        shared = mlp_apply(cfg, pol, p["shared"], x)
        gate = jax.nn.sigmoid((x @ p["shared_gate"].astype(x.dtype)).astype(jnp.float32))
        out = out + shared * gate.astype(x.dtype)
    return pol.shard(out, "act_batch", "act_seq", "act_embed"), aux


def _local_moe_single(cfg, cap, p, x2d):
    """tp=1 path without shard_map (smoke tests / CPU)."""
    t = x2d.shape[0]
    e_pad = p["router"].shape[-1]
    gates, ids, probs = _route(cfg, p["router"], x2d)
    flat_ids = ids.reshape(-1)
    flat_gates = gates.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(t), cfg.moe_top_k)
    order = jnp.argsort(flat_ids)[:cap]
    sel_e = flat_ids[order]
    sel_t = tok_idx[order]
    sel_g = flat_gates[order]
    xbuf = x2d[sel_t]
    gs = jnp.bincount(sel_e, length=e_pad).astype(jnp.int32)
    y = _expert_compute(p["wg"], p["wu"], p["wd"], xbuf, gs)
    out = jnp.zeros_like(x2d).at[sel_t].add((y * sel_g[:, None].astype(y.dtype)).astype(x2d.dtype))
    return out, _aux_loss(cfg, probs, ids)


def _capacity(cfg: ModelConfig, t_loc: int, tp: int) -> int:
    cap = int(math.ceil(t_loc * cfg.moe_top_k / tp * cfg.capacity_slack))
    cap = max(cap, cfg.moe_top_k)
    return int(math.ceil(cap / 8) * 8)


def _moe_param_specs(p):
    """shard_map in_specs for the expert params: experts over `model`."""
    specs = {}
    for k, v in p.items():
        if k in ("wg", "wu", "wd"):
            specs[k] = P("model", *([None] * (v.ndim - 1)))
        elif k == "shared":
            specs[k] = jax.tree.map(lambda _: P(), v)
        else:
            specs[k] = P(*([None] * v.ndim))
    return specs


# ------------------------------------------------------------------ #
# all-to-all expert parallelism (the optimized train-shape variant)
# ------------------------------------------------------------------ #


def _local_moe_a2a(cfg: ModelConfig, cap: int, axis_names: tuple, p, x_loc):
    """Tokens sharded over (data x model); each device routes its T_loc2
    tokens, ships them to their expert shard via all_to_all, computes the
    grouped matmul, and ships results back.  Collective bytes per device:
    2 x cap x tp x d x 2B (there + back, bf16) vs the psum variant's
    2 x T_loc x d per direction — a ~tp/(2k·slack) reduction
    (EXPERIMENTS.md §Perf cell B)."""
    tp = axis_size("model")
    my = jax.lax.axis_index("model")
    e_loc = p["wg"].shape[0]
    t_loc = x_loc.shape[0]

    gates, ids, probs = _route(cfg, p["router"], x_loc)
    flat_ids = ids.reshape(-1)
    flat_gates = gates.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(t_loc), cfg.moe_top_k)
    dest = flat_ids // e_loc  # destination shard per (token, k) pair

    # slot each pair into its destination bucket (capacity `cap` per dest)
    order = jnp.argsort(dest)  # pairs grouped by dest
    d_sorted = dest[order]
    # position within the destination group
    pos_in_dest = jnp.arange(d_sorted.size) - jnp.searchsorted(d_sorted, d_sorted, side="left")
    keep = pos_in_dest < cap
    slot = jnp.where(keep, d_sorted * cap + pos_in_dest, tp * cap)  # overflow -> dropped

    send_x = jnp.zeros((tp * cap + 1, x_loc.shape[1]), x_loc.dtype).at[slot].set(x_loc[tok_idx[order]])[:-1]
    send_e = jnp.full((tp * cap + 1,), e_loc, jnp.int32).at[slot].set(
        jnp.where(keep, flat_ids[order] % e_loc, e_loc)
    )[:-1]
    send_g = jnp.zeros((tp * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, flat_gates[order], 0.0)
    )[:-1]
    send_t = jnp.zeros((tp * cap + 1,), jnp.int32).at[slot].set(tok_idx[order])[:-1]

    # ship token payloads to their expert shard
    recv_x = jax.lax.all_to_all(send_x.reshape(tp, cap, -1), "model", 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e.reshape(tp, cap), "model", 0, 0, tiled=False)
    recv_x = recv_x.reshape(tp * cap, -1)
    recv_e = recv_e.reshape(tp * cap)

    # grouped matmul over the local experts (sorted by local expert id)
    eorder = jnp.argsort(recv_e)
    xbuf = recv_x[eorder]
    gs = jnp.bincount(jnp.clip(recv_e, 0, e_loc), length=e_loc + 1)[:e_loc].astype(jnp.int32)
    y = _expert_compute(p["wg"], p["wu"], p["wd"], xbuf, gs)
    y = jnp.zeros_like(y).at[eorder].set(y)  # un-sort

    # ship results back and combine
    back = jax.lax.all_to_all(y.reshape(tp, cap, -1), "model", 0, 0, tiled=False)
    back = back.reshape(tp * cap, -1)
    out = jnp.zeros_like(x_loc).at[send_t].add(
        (back * send_g[:, None].astype(back.dtype)).astype(x_loc.dtype)
    )
    aux = jax.lax.pmean(_aux_loss(cfg, probs, ids), axis_names)
    return out, aux


def moe_apply_a2a(cfg: ModelConfig, pol: ShardingPolicy, p, x):
    """all_to_all EP path; requires B*S divisible by dp*tp (train shapes)."""
    b, s, d = x.shape
    mesh = pol.mesh
    assert mesh is not None and "model" in mesh.shape
    tp = mesh.shape["model"]
    dp = mesh.size // tp
    assert (b * s) % (dp * tp) == 0, (b * s, dp, tp)
    t_loc2 = b * s // (dp * tp)
    cap = _capacity(cfg, t_loc2, tp)
    batch_rule = pol.rules.get("act_batch") or ()
    tok_axes = tuple(a for a in (batch_rule if isinstance(batch_rule, tuple) else (batch_rule,)) if a)
    tok_spec = P(tuple(tok_axes) + ("model",) if "model" not in tok_axes else tok_axes, None)
    x2d = x.reshape(b * s, d)
    out, aux = shard_map(
        partial(_local_moe_a2a, cfg, cap, tuple(mesh.axis_names)),
        mesh=mesh,
        in_specs=(_moe_param_specs(p), tok_spec),
        out_specs=(tok_spec, P()),
        check_vma=False,
    )(p, x2d)
    out = out.reshape(b, s, d)
    if cfg.n_shared_experts:
        shared = mlp_apply(cfg, pol, p["shared"], x)
        gate = jax.nn.sigmoid((x @ p["shared_gate"].astype(x.dtype)).astype(jnp.float32))
        out = out + shared * gate.astype(x.dtype)
    return pol.shard(out, "act_batch", "act_seq", "act_embed"), aux


# ------------------------------------------------------------------ #
# dense reference (oracle for tests)
# ------------------------------------------------------------------ #


def moe_reference(cfg: ModelConfig, p, x):
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    gates, ids, probs = _route(cfg, p["router"], x2d)
    out = jnp.zeros_like(x2d)
    for e in range(cfg.n_experts):
        w = jnp.where(ids == e, gates, 0.0).sum(-1)  # (T,)
        dt = x2d.dtype
        h = jax.nn.silu(x2d @ p["wg"][e].astype(dt)) * (x2d @ p["wu"][e].astype(dt))
        y = h @ p["wd"][e].astype(dt)
        out = out + y * w[:, None].astype(dt)
    return out.reshape(b, s, d), _aux_loss(cfg, probs, ids)
