"""Causal LM assembly: scan-over-blocks, train / prefill / decode steps.

Handles all assigned decoder families:
  dense | moe   uniform blocks (period 1)
  hybrid        Jamba-style period-8 blocks (attn 1:7, MoE every 2nd)
  ssm           all-mamba
  vlm           dense backbone + precomputed patch embeddings merged into
                the first ``n_patches`` positions (frontend stub, DESIGN §5)

Parameters for one scan block are declared once and stacked over
``n_blocks`` (leading "layers" axis) so XLA sees a single rolled loop —
essential for compile time at 40-72 layers on the 512-device dry-run.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.models.params import ParamSpec
from repro.runtime.sharding import ShardingPolicy

f32 = jnp.float32


# --------------------------------------------------------------------- #
# parameter declaration
# --------------------------------------------------------------------- #


def _position_specs(cfg: ModelConfig, i: int) -> dict:
    d = cfg.d_model
    s: dict[str, Any] = {"mixer_norm": ParamSpec((d,), ("norm",), "ones")}
    if cfg.mixer_kind(i) == "attn":
        s["attn"] = L.attn_specs(cfg)
    else:
        s["mamba"] = M.mamba_specs(cfg)
    if cfg.ffn_kind(i) == "moe":
        s["ffn_norm"] = ParamSpec((d,), ("norm",), "ones")
        s["moe"] = MOE.moe_specs(cfg)
    elif cfg.d_ff > 0:
        s["ffn_norm"] = ParamSpec((d,), ("norm",), "ones")
        s["mlp"] = L.mlp_specs(cfg)
    return s


def _stack_specs(tree, n: int, axis: str = "layers"):
    return jax.tree.map(
        lambda p: ParamSpec(
            (n,) + p.shape, (axis,) + p.axes, p.init, p.scale,
            tuple(d + 1 for d in p.fan_in_dims),
        ),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_specs(cfg: ModelConfig) -> dict:
    period = cfg.scan_period
    block = {f"pos{j}": _position_specs(cfg, j) for j in range(period)}
    specs = {
        "embed": L.embed_specs(cfg),
        "blocks": _stack_specs(block, cfg.n_blocks),
        "final_norm": ParamSpec((cfg.d_model,), ("norm",), "ones"),
    }
    specs.update({"head": h} if (h := L.head_specs(cfg)) else {})
    return specs


# --------------------------------------------------------------------- #
# block execution
# --------------------------------------------------------------------- #


def _run_position(cfg, pol, i, pp, h, positions, mode, cache_in, pos, paged=None):
    """One layer (mixer + ffn).  cache_in: per-position cache pytree or None.
    ``paged``: None (contiguous cache) or ``(block_tables, block_size)``
    (+ ``q_len`` in ``mixed`` mode) — attention then reads/writes K/V
    through the block table (non-attention state is per-slot in both
    layouts).  ``mixed`` is the unified serving mode: each row carries a
    prompt chunk or a single decode token, and the layer scatters fresh
    K/V into the pool before attending, so prompts may resume at any
    chunk boundary.  Returns (h, cache_out, aux)."""
    aux = jnp.zeros((), f32)
    x = L.rmsnorm(h, pp["mixer_norm"], cfg.norm_eps)
    cache_out = None
    if cfg.mixer_kind(i) == "attn":
        if mode == "decode" and paged is not None:
            tables, bs, mesh = paged
            o, k_c, v_c = L.attn_decode_paged(
                cfg, pol, pp["attn"], x, cache_in["k"], cache_in["v"], pos, tables, bs,
                mesh=mesh,
            )
            cache_out = {"k": k_c, "v": v_c}
        elif mode == "mixed":
            tables, bs, q_len, mesh = paged
            o, k_c, v_c = L.attn_mixed_paged(
                cfg, pol, pp["attn"], x, cache_in["k"], cache_in["v"],
                positions, tables, bs, q_len, mesh=mesh,
            )
            cache_out = {"k": k_c, "v": v_c}
        elif mode == "decode":
            o, k_c, v_c = L.attn_decode(cfg, pol, pp["attn"], x, cache_in["k"], cache_in["v"], pos)
            cache_out = {"k": k_c, "v": v_c}
        elif mode == "prefill":
            q, k, v = L.attn_qkv(cfg, pol, pp["attn"], x, positions)
            o = L.attention_core(cfg, q, k, v, causal=cfg.causal)
            o = pol.shard(o, "act_batch", "act_seq", "act_heads", None)
            o = jnp.einsum("bshk,hkd->bsd", o, pp["attn"]["wo"].astype(x.dtype))
            o = pol.shard(o, "act_batch", "act_seq", "act_embed")
            s_len = cache_in["k"].shape[1]
            k_c = jax.lax.dynamic_update_slice_in_dim(cache_in["k"], k.astype(cache_in["k"].dtype), 0, axis=1)
            v_c = jax.lax.dynamic_update_slice_in_dim(cache_in["v"], v.astype(cache_in["v"].dtype), 0, axis=1)
            cache_out = {
                "k": pol.shard(k_c, "cache_batch", "cache_seq", "cache_kv", None),
                "v": pol.shard(v_c, "cache_batch", "cache_seq", "cache_kv", None),
            }
        else:
            o = L.attn_apply(cfg, pol, pp["attn"], x, positions)
    else:
        if mode == "mixed":
            raise NotImplementedError(
                "unified mixed dispatch needs every mixer to be attention: "
                "SSM/conv state folds the whole sequence and cannot restart "
                "mid-prompt"
            )
        if mode == "decode":
            o, conv, ssm = M.mamba_decode(cfg, pol, pp["mamba"], x, cache_in["conv"], cache_in["ssm"])
            cache_out = {"conv": conv, "ssm": ssm}
        else:
            o, (conv, ssm) = M.mamba_apply(cfg, pol, pp["mamba"], x)
            if mode == "prefill":
                cache_out = {"conv": conv, "ssm": ssm}
    h = h + o
    if "ffn_norm" not in pp:  # pure-SSM blocks (mamba2) have no FFN
        return h, cache_out, aux
    x = L.rmsnorm(h, pp["ffn_norm"], cfg.norm_eps)
    if cfg.ffn_kind(i) == "moe":
        o, aux = MOE.moe_apply(cfg, pol, pp["moe"], x)
    else:
        o = L.mlp_apply(cfg, pol, pp["mlp"], x)
    return h + o, cache_out, aux


def _run_blocks(cfg, pol, params, h, positions, mode="train", cache=None, pos=0, paged=None):
    """Scan over blocks.  cache: stacked pytree (n_blocks leading) or None.
    ``paged``: see ``_run_position`` (the block table is shared across
    layers, so it rides in as a closure constant, not a scanned leaf).
    Returns (h, new_cache, aux_total)."""
    period = cfg.scan_period

    def body(carry, xs):
        hh, aux_tot = carry
        bp, cache_blk = xs
        new_cache = {}
        for j in range(period):
            c_in = cache_blk.get(f"pos{j}") if cache_blk else None
            hh, c_out, aux = _run_position(
                cfg, pol, j, bp[f"pos{j}"], hh, positions, mode, c_in, pos, paged
            )
            if c_out is not None:
                new_cache[f"pos{j}"] = c_out
            aux_tot = aux_tot + aux
        return (hh, aux_tot), (new_cache or None)

    if cfg.remat == "block" and mode == "train":
        body = jax.checkpoint(body)
    (h, aux), new_cache = jax.lax.scan(
        body,
        (h, jnp.zeros((), f32)),
        (params["blocks"], cache),
        unroll=cfg.n_blocks if cfg.scan_unroll else 1,
    )
    n_moe = sum(cfg.ffn_kind(i) == "moe" for i in range(cfg.n_layers))
    return h, new_cache, aux / max(n_moe, 1)


def _embed_inputs(cfg, pol, params, batch):
    h = L.embed_apply(cfg, pol, params["embed"], batch["tokens"])
    if cfg.frontend == "patches" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(h.dtype)
        h = jnp.concatenate([pe, h[:, pe.shape[1] :, :]], axis=1)
    return h


# --------------------------------------------------------------------- #
# public steps
# --------------------------------------------------------------------- #


def forward(cfg: ModelConfig, pol: ShardingPolicy, params, batch):
    """Full forward -> logits (B,S,V)."""
    tokens = batch["tokens"]
    h = _embed_inputs(cfg, pol, params, batch)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    h, _, aux = _run_blocks(cfg, pol, params, h, positions, mode="train")
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.head_apply(cfg, pol, params, h), aux


def sharded_ce(logits, targets, mask):
    """CE that never gathers the vocab dim: logsumexp + one-hot contraction
    both reduce over the (model-sharded) vocab axis, so GSPMD lowers them to
    (B,S)-sized allreduces instead of a (B,S,V) logits all-gather."""
    lg = logits.astype(f32)
    tgt = jnp.maximum(targets, 0)
    lse = jax.nn.logsumexp(lg, axis=-1)
    onehot = jax.nn.one_hot(tgt, lg.shape[-1], dtype=f32)
    label_logit = jnp.sum(lg * onehot, axis=-1)
    ll = label_logit - lse
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(cfg: ModelConfig, pol: ShardingPolicy, params, batch):
    """Next-token CE (+ MoE aux).  batch: tokens (B,S), targets (B,S) with
    -1 = masked."""
    logits, aux = forward(cfg, pol, params, batch)
    targets = batch["targets"]
    mask = (targets >= 0).astype(f32)
    ce = sharded_ce(logits, targets, mask)
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux, "tokens": mask.sum()}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16, abstract=False):
    """Stacked decode cache (n_blocks leading axis)."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    h, hdm, g, ds, w = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state, cfg.conv_width

    def mk(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct((cfg.n_blocks,) + shape, dt)
        return jnp.zeros((cfg.n_blocks,) + shape, dt)

    blk = {}
    for j in range(cfg.scan_period):
        if cfg.mixer_kind(j) == "attn":
            blk[f"pos{j}"] = {
                "k": mk((batch, cache_len, kv, hd), dtype),
                "v": mk((batch, cache_len, kv, hd), dtype),
            }
        else:
            blk[f"pos{j}"] = {
                "conv": tuple(
                    mk((batch, w - 1, c), dtype)
                    for c in (cfg.d_inner, g * ds, g * ds)
                ),
                "ssm": mk((batch, h, hdm, ds), f32),
            }
    return blk


def init_paged_cache(cfg: ModelConfig, n_pool_blocks: int, block_size: int, n_slots: int, dtype=jnp.bfloat16,
                     n_shards: int | None = None):
    """Paged decode cache: attention K/V live in a shared block pool
    ``(n_layer_blocks, n_pool_blocks, block_size, kv, hd)`` indexed through
    per-request block tables; SSM/conv state has no sequence axis to page,
    so those leaves keep the per-slot ``(n_layer_blocks, n_slots, ...)``
    layout of ``init_cache``.  The caller reserves one pool index as the
    trash block that unallocated table entries point at.

    ``n_shards``: sharded serving layout — pool leaves gain a leading
    shard axis ``(n_layer_blocks, n_shards, n_pool_blocks, block_size,
    kv, hd)`` (the caller passes the PER-SHARD block count incl. the
    per-shard trash as ``n_pool_blocks`` and lays the shard axis out
    ``P(None, "data", ...)``)."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    h, hdm, g, ds, w = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state, cfg.conv_width

    def mk(shape, dt):
        return jnp.zeros((cfg.n_blocks,) + shape, dt)

    pool_shape = (n_pool_blocks, block_size, kv, hd)
    if n_shards is not None:
        pool_shape = (n_shards,) + pool_shape
    blk = {}
    for j in range(cfg.scan_period):
        if cfg.mixer_kind(j) == "attn":
            blk[f"pos{j}"] = {
                "k": mk(pool_shape, dtype),
                "v": mk(pool_shape, dtype),
            }
        else:
            blk[f"pos{j}"] = {
                "conv": tuple(
                    mk((n_slots, w - 1, c), dtype)
                    for c in (cfg.d_inner, g * ds, g * ds)
                ),
                "ssm": mk((n_slots, h, hdm, ds), f32),
            }
    return blk


def paged_copy_block(cfg: ModelConfig, cache, src, dst):
    """Copy one pool block's K/V across every attention layer — the
    copy-on-write half of prefix sharing.  ``src`` holds a cached chunk
    with refcount > 1 (or parked) whose tail the new request must
    overwrite (full-prefix hit ending on a block boundary: the last
    prompt token's K/V write lands in it); the engine allocates ``dst``
    privately, copies, and repoints the request's table before the
    row's first mixed-dispatch write runs.  Per-slot (SSM/conv) leaves
    have no block axis and pass through untouched.  Sharded pool leaves
    (6-D, see ``init_paged_cache``) copy between GLOBAL ids' (shard,
    local) coordinates — prefix chains are row-affine, so src and dst
    share a shard, but the copy is correct either way."""

    def copy(leaf):
        if leaf.ndim == 6:
            n_local = leaf.shape[2] - 1
            s_src, l_src = src // n_local, src % n_local
            s_dst, l_dst = dst // n_local, dst % n_local
            return leaf.at[:, s_dst, l_dst].set(leaf[:, s_src, l_src])
        return leaf.at[:, dst].set(jnp.take(leaf, src, axis=1))

    out = {}
    for key, sub in cache.items():
        if "k" in sub:
            out[key] = {kk: copy(leaf) for kk, leaf in sub.items()}
        else:
            out[key] = sub
    return out


def mixed_step(cfg: ModelConfig, pol: ShardingPolicy, params, tokens, cache,
               block_tables, q_start, q_len, block_size: int, mesh=None):
    """UNIFIED engine step: one layer-stack pass over a mixed batch of
    prefill chunks and decode rows against the paged cache — the ONE
    dispatch the unified serving path issues per engine step, replacing
    separate prefill / decode calls.

    ``tokens``: ``(B, W)`` — each row carries ``q_len[b]`` live tokens
    starting at absolute position ``q_start[b]`` (a decode row is
    ``q_len == 1``; an idle slot is ``q_len == 0``).  Prefix positions
    below ``q_start`` must already sit in pool blocks reachable through
    ``block_tables``; each layer scatters its fresh K/V into the pool
    BEFORE attending (see ``layers.attn_mixed_paged``), so prompts may
    be chunked across steps at any boundary.  Returns ``(logits
    (B, W, V), cache)`` — the caller reads row ``b``'s next token off
    ``logits[b, q_len[b] - 1]`` when its prompt completes this step.
    """
    b, w = tokens.shape
    h = L.embed_apply(cfg, pol, params["embed"], tokens)
    q_start = jnp.asarray(q_start, jnp.int32)
    q_len = jnp.asarray(q_len, jnp.int32)
    positions = q_start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    h, cache, _ = _run_blocks(
        cfg, pol, params, h, positions, mode="mixed", cache=cache,
        paged=(block_tables, block_size, q_len, mesh),
    )
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.head_apply(cfg, pol, params, h), cache


def verify_step(cfg: ModelConfig, pol: ShardingPolicy, params, tokens, cache,
                block_tables, q_start, q_len, block_size: int, mesh=None):
    """Speculative draft-k/verify-1 target pass: score ``k + 1`` candidate
    positions per row in ONE dispatch.

    This is ``mixed_step`` run over VERIFY descriptors — each speculating
    row ``b`` carries ``(slot=b, q_start=committed position, q_len=k+1,
    kv_len=q_start+q_len)``: lane 0 is the row's last committed token,
    lanes 1..k the drafter's proposals.  Because each layer scatters the
    lane K/V into the pool BEFORE attending (write-then-attend), lane
    ``j``'s logits equal exactly what a plain 1-token decode would
    produce after emitting lanes ``< j`` — so per-lane argmaxes feed the
    engine's greedy accept-prefix and outputs stay bit-identical to
    non-speculative decode.  Rejected lanes need no device rollback: the
    engine only advances its committed position by the accepted run, and
    the next verify window re-writes every stale position before any
    lane can attend to it.  Rows with ``q_len == 1`` degenerate to plain
    decode lanes; ``q_len == 0`` rows are inert (K/V to the trash
    block).  Returns ``(logits (B, W, V), cache)``."""
    return mixed_step(
        cfg, pol, params, tokens, cache, block_tables, q_start, q_len, block_size,
        mesh=mesh,
    )


def cache_pspecs(cfg: ModelConfig, pol: ShardingPolicy):
    """PartitionSpec tree matching init_cache structure."""
    blk = {}
    for j in range(cfg.scan_period):
        if cfg.mixer_kind(j) == "attn":
            kv_spec = pol.spec(None, "cache_batch", "cache_seq", "cache_kv", None)
            blk[f"pos{j}"] = {"k": kv_spec, "v": kv_spec}
        else:
            blk[f"pos{j}"] = {
                "conv": tuple(
                    pol.spec(None, "cache_batch", None, "act_ff" if i == 0 else None)
                    for i in range(3)
                ),
                "ssm": pol.spec(None, "cache_batch", "act_heads", None, None),
            }
    return blk


def prefill(cfg: ModelConfig, pol: ShardingPolicy, params, batch, cache_len: int | None = None):
    """Process a prompt, build the decode cache.  Returns (logits, cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache_len = cache_len or s
    h = _embed_inputs(cfg, pol, params, batch)
    positions = jnp.broadcast_to(jnp.arange(s), tokens.shape)
    cache = init_cache(cfg, b, cache_len, dtype=jnp.dtype(cfg.dtype))
    h, cache, _ = _run_blocks(cfg, pol, params, h, positions, mode="prefill", cache=cache)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.head_apply(cfg, pol, params, h), cache


def decode_step(cfg: ModelConfig, pol: ShardingPolicy, params, cache, tokens, pos,
                block_tables=None, block_size: int = 0, mesh=None):
    """One decode step.  tokens: (B,1) int32; pos: scalar int32 write
    position (attention sees [0..pos]) or (B,) per-row positions for
    ragged batches.  With ``block_tables`` (``(B, n_max_blocks)`` int32,
    requires per-row ``pos`` and a paged cache from ``init_paged_cache``)
    attention K/V reads/writes go through the block table instead of a
    contiguous per-row stripe.  Returns (logits (B,1,V), cache)."""
    h = L.embed_apply(cfg, pol, params["embed"], tokens)
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos[:, None] if pos.ndim == 1 else pos, tokens.shape)
    paged = None if block_tables is None else (block_tables, block_size, mesh)
    h, cache, _ = _run_blocks(
        cfg, pol, params, h, positions, mode="decode", cache=cache, pos=pos, paged=paged
    )
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.head_apply(cfg, pol, params, h), cache


def generate(cfg, pol, params, batch, n_tokens: int, temperature: float = 0.0, key=None):
    """Greedy/sampled autoregressive generation (example drivers + e2e QA)."""
    logits, cache = prefill(cfg, pol, params, batch, cache_len=batch["tokens"].shape[1] + n_tokens)
    b = batch["tokens"].shape[0]
    prompt_len = batch["tokens"].shape[1]
    last = logits[:, -1, :]

    def pick(lg, k):
        if temperature <= 0.0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    keys = jax.random.split(key if key is not None else jax.random.PRNGKey(0), n_tokens)
    tok = pick(last, keys[0])
    out = [tok]
    for t in range(1, n_tokens):
        logits, cache = decode_step(cfg, pol, params, cache, tok[:, None], prompt_len + t - 1)
        tok = pick(logits[:, -1, :], keys[t])
        out.append(tok)
    return jnp.stack(out, axis=1)  # (B, n_tokens)
