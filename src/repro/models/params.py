"""Single-source-of-truth parameter system (no flax).

A model declares its parameters once as a pytree of ``ParamSpec`` (shape +
logical axis names + initializer).  From that one tree we derive:

  * ``init_params``      — concrete arrays (smoke tests, real training)
  * ``abstract_params``  — ShapeDtypeStructs (dry-run lowering, no allocation)
  * ``make_shardings``   — NamedShardings via logical→mesh axis rules

Logical axes (see runtime/sharding.py for the rules tables):
  layers/stack   scan dims                    -> never sharded
  vocab          embedding rows / lm head     -> tensor-parallel
  embed          d_model dims of weights      -> FSDP
  heads/kv_heads/ssm_heads                    -> tensor-parallel
  mlp            dense FFN hidden             -> tensor-parallel
  experts        MoE expert dim               -> expert-parallel
  expert_in/expert_mlp                        -> FSDP / replicated
  norm/head_dim/conv/state/dt                 -> replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | fan_in
    scale: float = 0.02
    fan_in_dims: tuple[int, ...] = ()  # dims whose product is fan-in (for "fan_in")

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def ndim(self) -> int:
        return len(self.shape)


def _leaves(tree) -> list[tuple[str, ParamSpec]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def init_params(specs, key: jax.Array, dtype=jnp.float32):
    """Materialize a ParamSpec tree into concrete arrays."""
    items = _leaves(specs)
    keys = jax.random.split(key, max(len(items), 1))
    out = {}
    for (name, spec), k in zip(items, keys):
        if spec.init == "zeros":
            v = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            v = jnp.ones(spec.shape, dtype)
        else:
            if spec.init == "fan_in":
                fan = 1
                for d in spec.fan_in_dims or range(len(spec.shape) - 1):
                    fan *= spec.shape[d]
                std = 1.0 / math.sqrt(max(fan, 1))
            else:
                std = spec.scale
            v = (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)
        out[name] = v
    return _unflatten_like(specs, [out[n] for n, _ in items])


def abstract_params(specs, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _unflatten_like(specs, values):
    treedef = jax.tree_util.tree_structure(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    return jax.tree_util.tree_unflatten(treedef, values)


def spec_to_pspec(
    spec: ParamSpec, rules: dict[str, Any], axis_sizes: dict[str, int] | None = None
) -> PartitionSpec:
    """Map logical axes -> mesh axes.  Guards: (a) never reuse a mesh axis
    within one spec; (b) with ``axis_sizes``, drop mesh axes that do not
    divide the dimension (NamedSharding requires exact divisibility —
    e.g. smollm's 15 heads / 5 kv-heads stay replicated over model=16)."""
    used: set[str] = set()
    entries = []
    for d, ax in enumerate(spec.axes):
        mesh_ax = rules.get(ax) if ax is not None else None
        if mesh_ax is None:
            entries.append(None)
            continue
        axes = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
        free = []
        fac = 1
        for a in axes:
            if a in used:
                continue
            if axis_sizes is not None:
                sz = axis_sizes.get(a, 1)
                if spec.shape[d] % (fac * sz) != 0:
                    continue
                fac *= sz
            free.append(a)
        if not free:
            entries.append(None)
            continue
        used.update(free)
        entries.append(tuple(free) if len(free) > 1 else free[0])
    return PartitionSpec(*entries)


def make_pspecs(specs, rules, axis_sizes=None):
    return jax.tree.map(
        lambda s: spec_to_pspec(s, rules, axis_sizes),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def make_shardings(specs, mesh, rules):
    axis_sizes = dict(mesh.shape)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, rules, axis_sizes)),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_bytes(specs, dtype=jnp.float32) -> int:
    total = 0
    for _, s in _leaves(specs):
        total += int(np.prod(s.shape)) * jnp.dtype(dtype).itemsize
    return total


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _leaves(specs))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )
