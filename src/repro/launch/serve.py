"""C-FedRAG serving launcher: build the federated corpus, stand up the
providers + enclave orchestrator, and answer queries.

  python -m repro.launch.serve --queries 5 --aggregation rerank
  python -m repro.launch.serve --queries 5 --generate --deadline-s 0.5
  python -m repro.launch.serve --queries 16 --stream --collect-batch 4
  python -m repro.launch.serve --queries 16 --generate --paged --block-size 32
  python -m repro.launch.serve --queries 16 --token-budget 32 --prefix-cache
  python -m repro.launch.serve --queries 16 --prefix-cache --repeat 3
  python -m repro.launch.serve --queries 16 --generate --tenants 'interactive=4:1,batch=1'
  python -m repro.launch.serve --queries 16 --draft-k 3 --token-budget 32
  python -m repro.launch.serve --queries 16 --shards 4 --block-size 8

Uses the bag embedder + lexical-overlap reranker by default (training-free
CPU path).  ``--generate`` stands up a reduced-LM ``ServeEngine`` and
routes the whole query set through ``CFedRAGSystem.serve`` — concurrent
provider fan-out, continuous-batching generation, per-request p50/p95
latency (see examples/federated_medqa.py for the trained-LM loop)."""
from __future__ import annotations

import argparse
import os
import sys

# --shards N partitions the KV pool over N devices; on a CPU host that
# means faking the device count, which only works BEFORE jax first
# imports — peek argv here, ahead of every repro/jax import below
if "--shards" in sys.argv or any(a.startswith("--shards=") for a in sys.argv):
    try:
        _i = sys.argv.index("--shards")
        _n = int(sys.argv[_i + 1])
    except (ValueError, IndexError):
        _n = next(
            (int(a.split("=", 1)[1]) for a in sys.argv if a.startswith("--shards=")),
            1,
        )
    if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={max(_n, 1)} "
            + os.environ.get("XLA_FLAGS", "")
        )

import numpy as np

from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.core.resilience import FaultSpec
from repro.data.corpus import make_federated_corpus
from repro.data.embeddings import bag_embed
from repro.data.tokenizer import HashTokenizer


def overlap_reranker(tok: HashTokenizer):
    """Lexical-overlap cross-scorer (training-free F_aggr stand-in; the
    trained cross-encoder variant lives in benchmarks/table1).

    Accepts (query (S,), candidates (C, S)) -> (C,) scores, or a whole
    batch (queries (B, S), candidates (B, C, S)) -> (B, C) — the batched
    form the orchestrator's ``aggregate_batch`` uses (``supports_batch``)."""

    def _score_row(q: set, row: np.ndarray) -> float:
        c = set(int(t) for t in row if t > 7)
        return len(q & c) / (len(q) ** 0.5 * max(len(c), 1) ** 0.5)

    def rerank(query_tokens: np.ndarray, cand_tokens: np.ndarray) -> np.ndarray:
        cand_tokens = np.asarray(cand_tokens)
        if cand_tokens.ndim == 3:  # (B, C, S) batch
            return np.stack(
                [rerank(qt, ct) for qt, ct in zip(np.asarray(query_tokens), cand_tokens)]
            )
        q = set(int(t) for t in query_tokens if t > 7)
        return np.asarray([_score_row(q, row) for row in cand_tokens], np.float32)

    rerank.supports_batch = True
    return rerank


def make_demo_engine(max_new_tokens: int = 16, paged: bool = False,
                     block_size: int = 32, pool_blocks: int | None = None,
                     max_batch: int = 4, prefix_cache: bool = False,
                     token_budget: int | None = None,
                     spill_bytes: int | None = None, draft_k: int = 0,
                     shards: int | None = None):
    """Reduced-LM ServeEngine (random-init, CPU-sized) + generator adapter
    for the scheduler-driven serving demo.  ``paged=True`` swaps the
    per-slot cache stripes for the shared block pool (``--block-size``
    tokens per block; ``--pool-blocks`` caps the HBM budget, default =
    ``max_batch`` contiguous stripes) and runs the unified chunked-prefill
    loop — ONE mixed dispatch per engine step (``token_budget`` caps its
    prefill lanes, default whole-prompt); ``prefix_cache=True`` adds the
    RESIDENT refcounted prefix index on top, so repeated context preambles
    prefill once and share blocks across serve calls; ``spill_bytes``
    bounds an optional host-RAM demotion tier under it; ``draft_k > 0``
    turns on draft-k/verify-1 speculative decoding (self-speculation —
    the demo drafter IS the target, the accept-rate ceiling; a real
    deployment passes a small ``draft_config``/``draft_params`` pair);
    ``shards`` partitions the block pool over that many mesh devices and
    runs every engine step as ONE distributed mixed dispatch —
    bit-identical to the single-shard engine (tests/test_sharded_serving)."""
    import jax

    from repro.configs import get_config, smoke_config
    from repro.models import lm as LM
    from repro.models.params import init_params
    from repro.runtime.sharding import ShardingPolicy, base_rules
    from repro.serving.engine import ServeConfig, ServeEngine, engine_generator

    cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(dtype="float32")
    params = init_params(LM.param_specs(cfg), jax.random.PRNGKey(0))
    pol = ShardingPolicy(rules=base_rules(False), mesh=None)
    engine = ServeEngine(
        cfg, pol, params,
        ServeConfig(
            max_batch=max_batch, max_prompt_len=256, max_new_tokens=max_new_tokens,
            paged=paged, block_size=block_size, n_pool_blocks=pool_blocks,
            prefix_cache=prefix_cache, token_budget=token_budget,
            spill_bytes=spill_bytes, draft_k=draft_k, shards=shards,
        ),
    )
    return engine_generator(engine)


def parse_tenant_spec(spec: str) -> tuple[dict[str, float], dict[str, int]]:
    """``--tenants 'interactive=4:1,batch=1'`` -> (weights, priorities).

    Each comma-separated entry is ``name=weight[:priority]``; weight is
    the weighted-fair admission share within a priority class, priority
    the strict admission class (higher preempts the queue)."""
    weights: dict[str, float] = {}
    prios: dict[str, int] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, eq, rest = part.partition("=")
        name = name.strip()
        if not name or not eq:
            raise ValueError(f"bad --tenants entry {part!r} (want name=weight[:priority])")
        w, _, p = rest.partition(":")
        weights[name] = float(w)
        prios[name] = int(p) if p else 0
    if not weights:
        raise ValueError(f"--tenants spec {spec!r} names no tenants")
    return weights, prios


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=5)
    ap.add_argument("--aggregation", default="rerank", choices=["embedding_rank", "rerank"])
    ap.add_argument("--n-facts", type=int, default=128)
    ap.add_argument("--m-local", type=int, default=8)
    ap.add_argument("--n-global", type=int, default=8)
    ap.add_argument("--kill-provider", type=int, default=None)
    ap.add_argument("--deadline-s", type=float, default=None, help="collect wall-clock cutoff")
    ap.add_argument(
        "--sequential-collect", action="store_true",
        help="disable concurrent provider fan-out (determinism baseline)",
    )
    ap.add_argument(
        "--generate", action="store_true",
        help="decode answers through the continuous-batching ServeEngine",
    )
    ap.add_argument(
        "--stream", action="store_true",
        help="pipelined front door: collect micro-batch N+1 overlaps decode "
        "of N, results print as each generation retires (implies --generate)",
    )
    ap.add_argument(
        "--collect-batch", type=int, default=4,
        help="micro-batch size of the --stream collector thread",
    )
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument(
        "--paged", action="store_true",
        help="paged KV cache: block-pool memory manager instead of one "
        "contiguous stripe per slot (admission becomes memory-aware)",
    )
    ap.add_argument("--block-size", type=int, default=32, help="tokens per KV block (--paged)")
    ap.add_argument(
        "--pool-blocks", type=int, default=None,
        help="KV pool size in blocks (--paged); default = max-batch contiguous stripes",
    )
    ap.add_argument("--max-batch", type=int, default=4, help="engine decode slots")
    ap.add_argument(
        "--prefix-cache", action="store_true",
        help="refcounted prefix cache on the paged pool: repeated prompt "
        "preambles (same aggregated context, retries) share KV blocks and "
        "skip their prefill (implies --paged --generate)",
    )
    ap.add_argument(
        "--token-budget", type=int, default=None, metavar="N",
        help="unified chunked prefill: one mixed prefill+decode dispatch "
        "per engine step, advancing at most N prompt tokens plus every "
        "live decode row — long prompts are spread across steps instead "
        "of stalling in-flight decodes, and dispatches stay O(1)/step "
        "(implies --paged --generate; composes with --prefix-cache)",
    )
    ap.add_argument(
        "--draft-k", type=int, default=0, metavar="K",
        help="speculative decoding: a resident drafter (self-speculation "
        "in the demo) proposes K greedy tokens per slot from its own "
        "paged pool; the target verifies all K+1 lanes in ONE mixed "
        "dispatch and greedy accept-prefix commits the matching run plus "
        "one correction token — outputs stay bit-identical to plain "
        "decode at up to K+1 tokens per target forward (implies --paged "
        "--generate; composes with --token-budget and --prefix-cache)",
    )
    ap.add_argument(
        "--shards", type=int, default=None, metavar="N",
        help="partition the paged KV pool over N mesh devices (row-affine "
        "blocks, one distributed mixed dispatch per step, bit-identical "
        "to --shards 1); on a CPU host the launcher fakes N host devices "
        "via XLA_FLAGS before jax loads (implies --paged --generate)",
    )
    ap.add_argument(
        "--repeat", type=int, default=1,
        help="serve the query set N times through ONE resident "
        "engine+index (the repeat/retry traffic a prefix cache "
        "de-duplicates; prints the per-repeat hit-rate trajectory)",
    )
    ap.add_argument(
        "--tenants", type=str, default=None, metavar="SPEC",
        help="per-tenant SLO classes, e.g. 'interactive=4:1,batch=1' "
        "(name=weight[:priority]); queries are assigned round-robin and "
        "admission is strict-priority then weighted-fair (implies "
        "--generate); per-tenant latency/prefix gauges print at the end",
    )
    ap.add_argument(
        "--fifo", action="store_true",
        help="ignore tenant weights/priorities for admission ordering "
        "(global arrival-order baseline; tenants still tagged for stats)",
    )
    ap.add_argument(
        "--spill-mb", type=float, default=None, metavar="MB",
        help="host-RAM spill tier for the prefix cache, in MiB: parked "
        "chains evicted under pool pressure demote to host memory and "
        "re-admit by upload instead of re-prefill (implies --prefix-cache)",
    )
    ap.add_argument(
        "--fault-spec", type=str, default=None, metavar="JSON",
        help='seeded fault injection on every provider, e.g. '
        '\'{"seed": 0, "p_conn": 0.1, "p_corrupt": 0.05, "p_poison": 0.05}\' '
        "(see core.resilience.FaultSpec for the full taxonomy)",
    )
    ap.add_argument(
        "--retries", type=int, default=1,
        help="collect attempts per provider per round (exponential "
        "backoff, budget deducted from --deadline-s; 1 = off)",
    )
    ap.add_argument(
        "--breaker", action=argparse.BooleanOptionalAction, default=False,
        help="per-provider circuit breakers: a provider that fails "
        "consecutive rounds is skipped (no round-trip cost) until a "
        "cooldown expires (--no-breaker to force off)",
    )
    ap.add_argument(
        "--score-gate", action="store_true",
        help="aggregator-side poisoning gate: per-provider score "
        "calibration + outlier-round quarantine",
    )
    args = ap.parse_args(argv)
    if args.spill_mb is not None:
        args.prefix_cache = True
    if (args.prefix_cache or args.token_budget is not None or args.draft_k > 0
            or args.shards is not None):
        args.paged = args.generate = True
    if args.tenants is not None:
        args.generate = True
    if args.stream:
        args.generate = True
    tenant_weights = tenant_prios = None
    if args.tenants is not None:
        tenant_weights, tenant_prios = parse_tenant_spec(args.tenants)

    corpus = make_federated_corpus(n_facts=args.n_facts, n_distractors=args.n_facts, n_queries=args.queries)
    tok = HashTokenizer()
    sys_ = CFedRAGSystem(
        corpus,
        CFedRAGConfig(
            aggregation=args.aggregation,
            m_local=args.m_local,
            n_global=args.n_global,
            deadline_s=args.deadline_s,
            concurrent_collect=False if args.sequential_collect else None,
            retries=args.retries,
            breaker=args.breaker,
            score_gate=args.score_gate,
        ),
        fault_spec=FaultSpec.from_json(args.fault_spec) if args.fault_spec else None,
        tokenizer=tok,
        reranker=overlap_reranker(tok) if args.aggregation == "rerank" else None,
        generator=make_demo_engine(
            args.max_new_tokens, paged=args.paged, block_size=args.block_size,
            pool_blocks=args.pool_blocks, max_batch=args.max_batch,
            prefix_cache=args.prefix_cache, token_budget=args.token_budget,
            spill_bytes=int(args.spill_mb * 2**20) if args.spill_mb else None,
            draft_k=args.draft_k, shards=args.shards,
        ) if args.generate else None,
    )
    if args.kill_provider is not None:
        sys_.providers[args.kill_provider].fail = True
        print(f"!! provider {args.kill_provider} marked down (quorum keeps serving)")

    texts = [q.text for q in corpus.queries[: args.queries]]
    qmeta = list(corpus.queries[: args.queries])
    tenants = priorities = None
    if tenant_weights is not None:
        names = list(tenant_weights)
        tenants = [names[i % len(names)] for i in range(len(texts))]
        priorities = [tenant_prios[t] for t in tenants]
    if args.generate:
        # warm the engine's jit paths (admit/decode-chunk) so the printed
        # per-request p50/p95 reflect serving latency, not compilation
        sys_.orchestrator.generator.engine.serve_prompts(
            [np.full((4,), 9, np.int32)], max_new_tokens=2
        )
    if args.deadline_s is not None:
        # readiness warm-up: the first collect jit-compiles the provider
        # embed path (seconds) — a deadline SLO applies to serving, not
        # to cold-start compilation
        orch = sys_.orchestrator
        orch.deadline_s = None
        orch.collect_contexts_batch(texts)
        orch.collect_contexts(texts[0])
        orch.deadline_s = args.deadline_s
    # --repeat loops over ONE resident system: the engine, block pool, and
    # prefix index survive across rounds, so round 2+ re-serves every
    # query against a warm index (guaranteed preamble hits) — the
    # per-repeat trajectory below is the CLI-visible proof
    results: list = []
    meta_all: list = []
    for rep in range(max(1, args.repeat)):
        if args.stream:
            # pipelined: results arrive in retire order while later
            # micro-batches are still collecting; print the stream live,
            # then report per-query below in submission order
            res = [None] * len(texts)
            for qidx, out in sys_.serve_stream(
                texts, max_new_tokens=args.max_new_tokens,
                collect_batch=args.collect_batch, tenants=tenants,
                priorities=priorities, tenant_weights=tenant_weights,
                fifo=args.fifo,
            ):
                res[qidx] = out
                print(
                    f"  [stream] q{qidx} retired: status={out['status']} "
                    f"lat={out['latency_s'] * 1e3:.1f}ms (collect->finish)"
                )
        elif args.generate:
            res = sys_.serve(
                texts, max_new_tokens=args.max_new_tokens, tenants=tenants,
                priorities=priorities, tenant_weights=tenant_weights,
                fifo=args.fifo,
            )
        else:
            res = [sys_.orchestrator.answer(t) for t in texts]
        results.extend(res)
        meta_all.extend(qmeta)
        if args.repeat > 1 and args.generate:
            st = getattr(sys_, "last_serve_stats", {})
            print(
                f"repeat {rep + 1}/{args.repeat}: prefix hits "
                f"{st.get('prefix_hits', 0)}/{st.get('prefix_lookups', 0)} "
                f"({st.get('prefix_hit_rate', 0.0):.0%}), "
                f"{st.get('prefill_tokens_saved', 0)} prefill tokens saved "
                "this round"
            )
    for q, res in zip(meta_all, results):
        if res.get("degraded"):
            print(
                f"Q: {q.text!r:45s} DEGRADED ({res['error']}) — "
                "flagged result, stream/batch kept serving"
            )
            continue
        ids = list(res["context"]["chunk_ids"])
        hit = q.gold_chunk_id in ids
        extra = ""
        if "answer_tokens" in res:
            extra = f" answer_toks={len(res['answer_tokens'])} lat={res['latency_s'] * 1e3:.1f}ms"
        print(
            f"Q: {q.text!r:45s} gold_chunk={q.gold_chunk_id:4d} "
            f"hit@{args.n_global}={'Y' if hit else 'n'} "
            f"providers={res['n_providers']} candidates={res['context']['n_candidates']}"
            + extra
        )
    if args.generate:
        lats = sorted(r["latency_s"] for r in results if r.get("latency_s") is not None)
        if lats:
            p50 = lats[len(lats) // 2]
            p95 = lats[min(len(lats) - 1, int(len(lats) * 0.95))]
            print(f"\ngeneration latency: p50={p50 * 1e3:.1f}ms p95={p95 * 1e3:.1f}ms")
        st = getattr(sys_, "last_serve_stats", {})
        if "min_free_slots" in st:
            slots = sys_.orchestrator.generator.engine.scfg.max_batch
            line = (
                f"memory headroom: peak {slots - st['min_free_slots']}/{slots} slots "
                f"(backlog peak {st['peak_backlog']})"
            )
            if "min_free_blocks" in st:
                line += (
                    f", KV blocks {st['free_blocks']} free now / "
                    f"{st['min_free_blocks']} at peak ({args.block_size} tok/block)"
                )
            if args.shards is not None:
                line += f" over {args.shards} pool shard(s)"
            print(line)
            if args.draft_k > 0 and "draft_free_blocks" in st:
                print(
                    f"drafter pool: {st['draft_free_blocks']} blocks free now / "
                    f"{st['min_draft_free_blocks']} at peak"
                )
        if "engine_steps" in st and st["engine_steps"]:
            print(
                f"dispatches: {st['admit_dispatches']} admit + "
                f"{st['decode_dispatches']} decode + "
                f"{st['mixed_dispatches']} mixed over {st['engine_steps']} "
                f"engine steps ({st['dispatches_per_step']:.2f}/step)"
            )
        if "spec_tokens_per_round" in st:
            print(
                f"speculation: {st['spec_tokens_per_round']:.2f} tokens/round "
                f"at accept rate {st.get('spec_accept_rate', 0.0):.0%} "
                f"(draft_k={args.draft_k}), "
                f"{st['dispatches_per_spec_round']:.2f} dispatches/spec round "
                f"over {st['spec_rounds']} rounds"
            )
        if "prefix_lookups" in st:
            print(
                f"prefix cache: {st['prefix_hits']}/{st['prefix_lookups']} hits "
                f"({st.get('prefix_hit_rate', 0.0):.0%}), "
                f"{st['prefill_tokens_saved']}/{st['prefill_tokens']} prefill tokens "
                f"saved ({st.get('prefill_saved_frac', 0.0):.0%}), "
                f"{st['prefix_shared_blocks']} blocks shared by reference, "
                f"{st['prefix_cached_blocks']} chunks cached "
                f"({st.get('reclaimable_blocks', 0)} reclaimable)"
            )
        if "spilled_blocks" in st:
            print(
                f"spill tier: {st['spilled_blocks']} chunks on host "
                f"({st['spill_bytes_used'] / 2**20:.2f} MiB), "
                f"{st['spill_demotions']} demotions / "
                f"{st['spill_readmits']} re-admits this window"
            )
        for name, ts in sorted(st.get("tenants", {}).items()):
            line = (
                f"tenant {name}: {ts['n_done']} done, {ts['n_expired']} expired, "
                f"{ts.get('n_admitted', 0)} admitted, {ts['tokens_out']} tokens out"
            )
            if "p95_s" in ts:
                line += f", p50={ts['p50_s'] * 1e3:.1f}ms p95={ts['p95_s'] * 1e3:.1f}ms"
            if ts.get("prefix_lookups") and args.prefix_cache:
                line += f", prefix hit rate {ts.get('prefix_hit_rate', 0.0):.0%}"
            print(line)
    fed = sys_.orchestrator.federation_stats()
    tot = fed["totals"]
    if tot["attempts"]:
        print(
            f"federation: {tot['successes']}/{tot['attempts']} round-trips ok, "
            f"{tot['retries']} retries, {tot['skips']} breaker skips "
            f"({tot['breakers_open']} breakers open), "
            f"{tot['rechannels']} channel re-establishes, "
            f"faults conn={tot['faults']['conn']} timeout={tot['faults']['timeout']} "
            f"integrity={tot['faults']['integrity']}, "
            f"{tot['quarantined']} rounds quarantined by the score gate"
        )
        flaky = {
            pid: d for pid, d in fed["providers"].items()
            if d["attempts"] != d["successes"] or d["skips"] or d["quarantined"]
        }
        for pid, d in sorted(flaky.items()):
            print(
                f"  provider {pid}: {d['successes']}/{d['attempts']} ok, "
                f"{d['retries']} retries, {d['skips']} skips, "
                f"breaker={d['breaker'] or 'off'}, faults={d['faults']}"
                + (f", injected={d['injected']}" if "injected" in d else "")
            )
    stats = sys_.eval_retrieval(args.queries)
    print(f"\nrecall@{args.n_global}: {stats['recall_at_n']:.3f}  mrr: {stats['mrr']:.3f}")


if __name__ == "__main__":
    main()
