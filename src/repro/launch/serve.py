"""C-FedRAG serving launcher: build the federated corpus, stand up the
providers + enclave orchestrator, and answer queries.

  python -m repro.launch.serve --queries 5 --aggregation rerank

Uses the bag embedder + lexical-overlap reranker by default (training-free
CPU path); pass --generator-ckpt to decode answers with a trained reduced
LM (see examples/federated_medqa.py for the full train->serve loop)."""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus
from repro.data.embeddings import bag_embed
from repro.data.tokenizer import HashTokenizer


def overlap_reranker(tok: HashTokenizer):
    """Lexical-overlap cross-scorer (training-free F_aggr stand-in; the
    trained cross-encoder variant lives in benchmarks/table1).

    Accepts (query (S,), candidates (C, S)) -> (C,) scores, or a whole
    batch (queries (B, S), candidates (B, C, S)) -> (B, C) — the batched
    form the orchestrator's ``aggregate_batch`` uses (``supports_batch``)."""

    def _score_row(q: set, row: np.ndarray) -> float:
        c = set(int(t) for t in row if t > 7)
        return len(q & c) / (len(q) ** 0.5 * max(len(c), 1) ** 0.5)

    def rerank(query_tokens: np.ndarray, cand_tokens: np.ndarray) -> np.ndarray:
        cand_tokens = np.asarray(cand_tokens)
        if cand_tokens.ndim == 3:  # (B, C, S) batch
            return np.stack(
                [rerank(qt, ct) for qt, ct in zip(np.asarray(query_tokens), cand_tokens)]
            )
        q = set(int(t) for t in query_tokens if t > 7)
        return np.asarray([_score_row(q, row) for row in cand_tokens], np.float32)

    rerank.supports_batch = True
    return rerank


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=5)
    ap.add_argument("--aggregation", default="rerank", choices=["embedding_rank", "rerank"])
    ap.add_argument("--n-facts", type=int, default=128)
    ap.add_argument("--m-local", type=int, default=8)
    ap.add_argument("--n-global", type=int, default=8)
    ap.add_argument("--kill-provider", type=int, default=None)
    args = ap.parse_args(argv)

    corpus = make_federated_corpus(n_facts=args.n_facts, n_distractors=args.n_facts, n_queries=args.queries)
    tok = HashTokenizer()
    sys_ = CFedRAGSystem(
        corpus,
        CFedRAGConfig(aggregation=args.aggregation, m_local=args.m_local, n_global=args.n_global),
        tokenizer=tok,
        reranker=overlap_reranker(tok) if args.aggregation == "rerank" else None,
    )
    if args.kill_provider is not None:
        sys_.providers[args.kill_provider].fail = True
        print(f"!! provider {args.kill_provider} marked down (quorum keeps serving)")

    for q in corpus.queries[: args.queries]:
        res = sys_.orchestrator.answer(q.text)
        ids = list(res["context"]["chunk_ids"])
        hit = q.gold_chunk_id in ids
        print(
            f"Q: {q.text!r:45s} gold_chunk={q.gold_chunk_id:4d} "
            f"hit@{args.n_global}={'Y' if hit else 'n'} "
            f"providers={res['n_providers']} candidates={res['context']['n_candidates']}"
        )
    stats = sys_.eval_retrieval(args.queries)
    print(f"\nrecall@{args.n_global}: {stats['recall_at_n']:.3f}  mrr: {stats['mrr']:.3f}")


if __name__ == "__main__":
    main()
