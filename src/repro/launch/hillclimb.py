import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimbing on the three selected cells (EXPERIMENTS.md §Perf).

Each iteration is an explicit hypothesis -> change -> re-lower -> validate
cycle; every run is a full dryrun_cell with the lever applied, so the
before/after numbers come from the same measurement pipeline as the
baseline table.

  cell A  qwen3-4b x decode_32k   (serving path; paper's F_inf decode)
  cell B  qwen2-moe-a2.7b x train_4k  (most collective-bound: MoE EP)
  cell C  smollm-360m x train_4k  (worst roofline fraction: unshardeable TP)
"""
import argparse  # noqa: E402
import json  # noqa: E402

from repro.launch.dryrun import dryrun_cell  # noqa: E402

PURE_DP_PATCH = {
    # small models whose heads don't divide TP: use the model axis as extra
    # data parallelism (DDP, replicated weights) instead of wasting it.
    "act_batch": ("data", "model"),
    "embed": None, "heads": None, "kv_heads": None, "mlp": None,
    "vocab": ("data", "model"),
    "act_heads": None, "act_kv_heads": None, "act_ff": None, "act_vocab": None,
    "dt": None, "ssm_heads": None, "experts": None, "expert_in": None,
    "cache_batch": ("data", "model"), "cache_kv": None,
}


def run_cell(tag, **kw):
    r = dryrun_cell(**kw)
    r["tag"] = tag
    keep = (
        "tag arch shape mesh status compute_s memory_s collective_s dominant "
        "step_bound_s useful_flops_frac mfu_bound bytes_raw dus_bytes "
        "hlo_flops hlo_bytes collective_bytes collective_detail".split()
    )
    slim = {k: r.get(k) for k in keep}
    slim["mem_per_dev_gib"] = r["memory_analysis"]["peak_bytes_per_device"] / 2**30 if r["status"] == "ok" else None
    return slim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=["A", "B", "C"])
    ap.add_argument("--out", required=True)
    args = ap.parse_args()
    runs = []

    if args.cell == "A":
        # baseline
        runs.append(run_cell("A0-baseline", arch="qwen3-4b", shape_name="decode_32k", mesh_kind="single"))
        # A1: kv-head replication 8 -> 16 (math-identical weight duplication;
        # hypothesis: cache + K/V reads stop being replicated over model=16,
        # memory term / ~8, cache mem/dev / ~8 at 2x logical cache)
        runs.append(run_cell("A1-kv-replicate-16", arch="qwen3-4b", shape_name="decode_32k",
                             mesh_kind="single", cfg_overrides={"n_kv_heads": 16}))
        # A2: + donate cache (in-place KV update; hypothesis: removes the
        # dus copy-on-write — dus_bytes drop out of the memory term)
        runs.append(run_cell("A2-kv16+donate", arch="qwen3-4b", shape_name="decode_32k",
                             mesh_kind="single", cfg_overrides={"n_kv_heads": 16},
                             decode_donate=True))
    elif args.cell == "B":
        runs.append(run_cell("B0-baseline", arch="qwen2-moe-a2.7b", shape_name="train_4k", mesh_kind="single"))
        # B1: all-to-all EP (hypothesis: psum moves 2xT_loc x d per direction
        # over model; a2a moves only the routed tokens cap*tp*d ~ k*slack/tp
        # of that -> collective term drops several x)
        runs.append(run_cell("B1-a2a-EP", arch="qwen2-moe-a2.7b", shape_name="train_4k",
                             mesh_kind="single", cfg_overrides={"moe_impl": "a2a"}))
        # B2: a2a + tighter capacity (slack 1.5 -> 1.25: buffer + flops trim)
        runs.append(run_cell("B2-a2a+slack1.25", arch="qwen2-moe-a2.7b", shape_name="train_4k",
                             mesh_kind="single",
                             cfg_overrides={"moe_impl": "a2a", "capacity_slack": 1.25}))
    else:
        runs.append(run_cell("C0-baseline", arch="smollm-360m", shape_name="train_4k", mesh_kind="single"))
        # C1: pure-DP resharding (hypothesis: 15 heads / 5 kv can't use TP;
        # batch over (data x model) removes the 16x redundant compute ->
        # compute & memory terms / ~16; grads all-reduce over 256 instead
        # of 16 adds collective bytes)
        runs.append(run_cell("C1-pure-DP", arch="smollm-360m", shape_name="train_4k",
                             mesh_kind="single", rules_patch=PURE_DP_PATCH))
    with open(args.out, "w") as f:
        json.dump(runs, f, indent=1, default=str)
    for r in runs:
        if r["status"] != "ok":
            print(r["tag"], r["status"])
            continue
        print(
            f"{r['tag']:22s} compute={r['compute_s']*1e3:9.2f}ms memory={r['memory_s']*1e3:9.2f}ms "
            f"coll={r['collective_s']*1e3:8.2f}ms bound={r['step_bound_s']*1e3:9.2f}ms "
            f"dominant={r['dominant']:10s} mfu={r['mfu_bound']:.4f} mem/dev={r['mem_per_dev_gib']:.1f}GiB"
        )


if __name__ == "__main__":
    main()
