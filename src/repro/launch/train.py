"""Training launcher.

Real-hardware entry point (and CPU-scale driver for the e2e examples):
  python -m repro.launch.train --arch qwen3-0.6b --reduced --steps 200 \\
      --batch 8 --seq 256 --ckpt-dir /tmp/ck --resume auto

--reduced swaps in the smoke-scale config of the same family so the
driver runs on CPU; on a TPU pod the full config + production mesh is
selected automatically (mesh axes collapse to the device count)."""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import SHAPES, get_config, smoke_config
from repro.data.pipeline import LMBatchStream
from repro.launch.mesh import make_smoke_mesh
from repro.optim.optimizers import cosine_schedule, get_optimizer
from repro.runtime.sharding import make_policy
from repro.runtime.train_loop import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--opt", default="adamw")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = smoke_config(cfg)
    n_dev = len(jax.devices())
    mesh = make_smoke_mesh((n_dev, 1)) if n_dev > 1 else None
    pol = make_policy(mesh, shape_kind="train", global_batch=args.batch, seq_len=args.seq)

    stream = LMBatchStream(args.batch, args.seq, cfg.vocab_size)
    opt = get_optimizer(args.opt)
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        fail_at_step=args.fail_at,
    )
    trainer = Trainer(cfg, pol, opt, stream, tcfg, lr_fn=cosine_schedule(args.lr, 20, args.steps))
    params, _ = trainer.run(resume=args.resume)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(trainer.metrics_log, f, indent=1)
    last = trainer.metrics_log[-1] if trainer.metrics_log else {}
    print(f"final: {last}")
    return params, trainer


if __name__ == "__main__":
    main()
