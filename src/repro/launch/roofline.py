"""Roofline-term extraction from compiled dry-run artifacts.

compute  = HLO_FLOPs   / (chips * 197 TFLOP/s bf16)
memory   = HLO_bytes   / (chips * 819 GB/s HBM)
collect. = coll_bytes  / (chips * 49.5 GB/s ICI)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the (SPMD-partitioned, per-
device) HLO and sum OPERAND sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, then multiply by the
device count to get the global number (cost_analysis flops are likewise
per-device-module x n_devices handled in ``normalize``; empirically
jax cost_analysis on a sharded module reports per-device numbers).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*)$")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_convert_bytes(hlo_text: str) -> int:
    """Total (input+output) bytes of standalone `convert` instructions.

    The CPU backend materializes bf16<->f32 converts around every dot
    (bf16 matmuls are emulated); on TPU these converts either don't exist
    (native bf16 MXU operands) or fuse into the producer/consumer fusion
    (no extra HBM pass).  The roofline memory term subtracts them:
    corrected = measured - convert_in_out_bytes.  Raw and corrected are
    both reported in EXPERIMENTS.md."""
    total = 0
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*\bconvert\(", line)
        if not m:
            continue
        out_dt, dims = m.group(1), m.group(2)
        if out_dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(",") if dims else []:
            n *= int(d)
        in_bytes = n * (2 if out_dt == "f32" else 4)  # partner dtype approx
        total += n * _DTYPE_BYTES[out_dt] + in_bytes
    return total


def parse_dus_bytes(hlo_text: str) -> int:
    """Bytes written by dynamic-update-slice ops on large buffers — the
    KV-cache copy-on-write cost that buffer DONATION removes in serving."""
    total = 0
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*\bdynamic-update-slice", line)
        if not m:
            continue
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(",") if dims else []:
            n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind OPERAND bytes from (per-device) HLO text."""
    # index: instruction name -> result bytes
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1).lstrip("%"), m.group(2)
        # result type = everything before the op name token; cheap approx:
        # take the type prefix up to the first " op(" occurrence
        op_split = re.split(r"\s[a-z0-9\-]+\(", rest, maxsplit=1)
        sizes[name] = _type_bytes(op_split[0])

    out = {k: 0 for k in COLLECTIVES}
    out["collective_count"] = 0
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        rest = m.group(2)
        opm = re.search(r"\s((?:all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?)\(([^)]*)\)", rest)
        if not opm:
            continue
        kind = opm.group(1).replace("-start", "")
        operands = [o.strip().lstrip("%") for o in opm.group(2).split(",")]
        b = 0
        for o in operands:
            o = o.split(" ")[0]
            if o in sizes:
                b += sizes[o]
            else:
                # inline-typed operand, e.g. "bf16[8,128]{1,0} %x"
                b += _type_bytes(o)
        out[kind] += b
        out["collective_count"] += 1
    return out


# ---------------- hardware model (TPU v5e) ----------------
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 49.5e9  # B/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float  # global
    hlo_bytes: float  # global
    collective_bytes: float  # global
    collective_detail: dict
    model_flops: float
    memory_per_device: int  # peak from memory_analysis

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.n_chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.n_chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.n_chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def mfu_bound(self) -> float:
        """Roofline fraction: model-useful FLOP/s at the step bound vs peak."""
        return self.model_flops / (self.n_chips * PEAK_FLOPS * max(self.step_bound_s, 1e-12))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            step_bound_s=self.step_bound_s,
            useful_flops_frac=self.useful_flops_frac,
            mfu_bound=self.mfu_bound,
        )
        return d


def ssd_correction(cfg, shape) -> dict:
    """Analytic cost of the (nc-1) SSD chunks the measurement compiles do
    not count (the intra-chunk scan stays rolled; XLA counts its body once).

    Per chunk per (batch, head), f32:
      flops_fwd ~ 2L^2(ds+hd) [CB^T + scores@X] + 6L^2 [decay/mask/scale]
                  + 6L*hd*ds  [state update + inter-chunk output]
      bytes_fwd ~ 28 L^2      [cb/decay/scores materialized, ~7 f32 passes]
    Train multiplies by ~3 (remat fwd + bwd)."""
    if cfg.ssm_state == 0 or shape.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    n_mamba = sum(1 for i in range(cfg.n_layers) if cfg.mixer_kind(i) == "mamba")
    if n_mamba == 0:
        return {"flops": 0.0, "bytes": 0.0}
    l = min(cfg.ssd_chunk, shape.seq_len)
    nc = (shape.seq_len + l - 1) // l
    b, h = shape.global_batch, cfg.ssm_heads
    ds, hd = cfg.ssm_state, cfg.ssm_head_dim
    mult = 3.0 if shape.kind == "train" else 1.0
    per_chunk_flops = 2 * l * l * (ds + hd) + 6 * l * l + 6 * l * hd * ds
    per_chunk_bytes = 28.0 * l * l
    scale = b * h * n_mamba * (nc - 1) * mult
    return {"flops": per_chunk_flops * scale, "bytes": per_chunk_bytes * scale}


def model_flops_for(cfg, shape) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference) + attention term."""
    n_active = cfg.param_count(active=True)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens, mult = b * s, 6
    elif shape.kind == "prefill":
        tokens, mult = b * s, 2
    else:  # decode: one token per sequence
        tokens, mult = b * 1, 2
    flops = mult * n_active * tokens
    # attention score/value FLOPs (not in 6ND):
    hd = cfg.resolved_head_dim
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.mixer_kind(i) == "attn")
    if shape.kind == "train":
        # fwd attn = 2 matmuls x 2*B*(S^2/2)*H*hd; train ~ 3x fwd
        flops += 3 * (2 * 2 * b * (s * s // 2) * cfg.n_heads * hd) * n_attn
    elif shape.kind == "prefill":
        flops += 2 * b * (s * s // 2) * cfg.n_heads * hd * 2 * n_attn
    else:
        flops += 2 * b * s * cfg.n_heads * hd * 2 * n_attn
    # SSD state-math term (the attention-equivalent for mamba mixers)
    n_mamba = sum(1 for i in range(cfg.n_layers) if cfg.mixer_kind(i) == "mamba")
    if n_mamba and cfg.ssm_state:
        h2, ds, hd2 = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        if shape.kind == "decode":
            flops += 2 * b * h2 * hd2 * ds * 2 * n_mamba
        else:
            l = min(cfg.ssd_chunk, s)
            nc = (s + l - 1) // l
            per = 2 * l * l * (ds + hd2) + 6 * l * hd2 * ds
            mult = 3 if shape.kind == "train" else 1
            flops += per * b * h2 * nc * n_mamba * mult
    return float(flops)
