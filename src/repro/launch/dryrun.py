import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell,
prove the sharding config is coherent, and extract the roofline terms.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json

The dry-run lowers the PURE-JNP model path (kernels are opaque custom
calls to XLA cost analysis — DESIGN.md §4 kernel policy) with the same
shardings as production.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, all_configs, applicable, get_config  # noqa: E402
from repro.launch.inputs import cache_specs, input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import (  # noqa: E402
    Roofline,
    model_flops_for,
    parse_collective_bytes,
    parse_convert_bytes,
    parse_dus_bytes,
    ssd_correction,
)
from repro.models import encoder as ENC  # noqa: E402
from repro.models import lm as LM  # noqa: E402
from repro.models.params import abstract_params, make_pspecs  # noqa: E402
from repro.optim.optimizers import get_optimizer  # noqa: E402
from repro.runtime.sharding import make_policy  # noqa: E402
from repro.runtime.steps import make_decode_step, make_prefill_step, make_train_step  # noqa: E402


def _attach(tree_abs, tree_pspec, mesh):
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=NamedSharding(mesh, s)),
        tree_abs,
        tree_pspec,
    )


def _opt_pspecs(opt_name: str, specs, rules, axis_sizes):
    """Optimizer-state PartitionSpecs derived from the param logical axes."""
    from repro.models.params import ParamSpec, spec_to_pspec

    def p_spec(s):
        return spec_to_pspec(s, rules, axis_sizes)

    def drop_last(s):
        return spec_to_pspec(ParamSpec(s.shape[:-1], s.axes[:-1], s.init), rules, axis_sizes)

    def drop_2nd_last(s):
        return spec_to_pspec(
            ParamSpec(s.shape[:-2] + s.shape[-1:], s.axes[:-2] + s.axes[-1:], s.init),
            rules,
            axis_sizes,
        )

    from jax.sharding import PartitionSpec as P

    is_spec = lambda x: isinstance(x, ParamSpec)
    if opt_name == "adamw":
        return {
            "mu": jax.tree.map(p_spec, specs, is_leaf=is_spec),
            "nu": jax.tree.map(p_spec, specs, is_leaf=is_spec),
            "count": P(),
        }
    if opt_name == "adafactor":
        def fac(s):
            if s.ndim >= 2 and s.shape[-1] >= 128 and s.shape[-2] >= 128:
                return {"vr": drop_last(s), "vc": drop_2nd_last(s)}
            return {"v": p_spec(s)}

        class _NS:  # tiny shim so tree.map sees ParamSpec leaves
            pass

        return {
            "v": jax.tree.map(fac, specs, is_leaf=is_spec),
            "count": P(),
        }
    raise ValueError(opt_name)


def _lower_cell(cfg, shape, mesh, pol, opt_name, decode_donate=False, grad_rs=False):
    """lower+compile one step for one cfg; returns compiled."""
    specs_fn = ENC.param_specs if cfg.family == "encoder" else LM.param_specs
    specs = specs_fn(cfg)
    axis_sizes = dict(mesh.shape)
    pspecs = make_pspecs(specs, pol.rules, axis_sizes)
    params_abs = _attach(abstract_params(specs), pspecs, mesh)
    batch_abs = input_specs(cfg, shape, pol)
    with mesh:
        if shape.kind == "train":
            opt = get_optimizer(opt_name)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            opt_abs = _attach(opt_abs, _opt_pspecs(opt_name, specs, pol.rules, axis_sizes), mesh)
            step_fn = make_train_step(cfg, pol, opt, grad_pspecs=pspecs if grad_rs else None)
            lowered = jax.jit(step_fn).lower(
                params_abs, opt_abs, batch_abs, jax.ShapeDtypeStruct((), jnp.int32)
            )
        elif shape.kind == "prefill":
            step_fn = make_prefill_step(cfg, pol)
            lowered = jax.jit(step_fn).lower(params_abs, batch_abs)
        else:  # decode
            cache_abs = cache_specs(cfg, shape, pol)
            step_fn = make_decode_step(cfg, pol)
            # donate_argnums=(1,) aliases the KV cache update in place —
            # the production serving configuration (no copy-on-write)
            jitted = jax.jit(step_fn, donate_argnums=(1,) if decode_donate else ())
            lowered = jitted.lower(
                params_abs, cache_abs, batch_abs["tokens"], jax.ShapeDtypeStruct((), jnp.int32)
            )
        return lowered.compile()


def _measure(compiled, n_chips):
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    raw = float(cost.get("bytes accessed", 0.0))
    conv = float(parse_convert_bytes(hlo))
    return {
        "flops": float(cost.get("flops", 0.0)) * n_chips,
        # corrected: standalone converts fuse away on TPU (roofline.py)
        "bytes": max(raw - conv, raw * 0.25) * n_chips,
        "bytes_raw": raw * n_chips,
        "dus_bytes": float(parse_dus_bytes(hlo)) * n_chips,
        "coll": float(sum(v for k, v in coll.items() if k != "collective_count")) * n_chips,
        "detail": coll,
    }


def dryrun_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    opt_name: str | None = None,
    verbose: bool = True,
    measure: bool = True,
    cfg_overrides: dict | None = None,
    rules_patch: dict | None = None,
    decode_donate: bool = False,
    grad_rs: bool = False,
):
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.with_overrides(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "status": "skip", "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    pol = make_policy(
        mesh,
        multi_pod=(mesh_kind == "multi"),
        shape_kind=shape.kind,
        global_batch=shape.global_batch,
        seq_len=shape.seq_len,
        long_context=shape.name == "long_500k",
    )
    if rules_patch:
        pol.rules.update(rules_patch)
    # big models need the factored optimizer to fit (DESIGN.md §4)
    if opt_name is None:
        big = cfg.param_count(False) + cfg.embedding_params() > 20e9
        opt_name = "adafactor" if big else "adamw"

    # 1) FULL rolled-scan compile: proves the sharding config + memory analysis
    t0 = time.monotonic()
    compiled = _lower_cell(cfg, shape, mesh, pol, opt_name, decode_donate, grad_rs)
    compile_s = time.monotonic() - t0
    mem = compiled.memory_analysis()

    # 2) cost measurement: XLA cost_analysis counts while-loop bodies ONCE, so
    # the rolled numbers undercount the layer scan.  Compile unrolled 1-block
    # and 2-block variants; body = m2 - m1, outside = m1 - body;
    # total = outside + n_blocks * body (scan blocks are homogeneous).
    period = cfg.scan_period
    if measure:
        # raise the flash chunk so the unrolled inner scan stays small
        # (<=8 steps); total attention flops/bytes are chunk-invariant.
        meas_chunk = max(cfg.attn_chunk, shape.seq_len // 8)
        cfg1 = cfg.with_overrides(n_layers=period, scan_unroll=True, attn_chunk=meas_chunk)
        cfg2 = cfg.with_overrides(n_layers=2 * period, scan_unroll=True, attn_chunk=meas_chunk)
        m1 = _measure(_lower_cell(cfg1, shape, mesh, pol, opt_name, decode_donate, grad_rs), n_chips)
        m2 = _measure(_lower_cell(cfg2, shape, mesh, pol, opt_name, decode_donate, grad_rs), n_chips)
        keys = ("flops", "bytes", "bytes_raw", "dus_bytes", "coll")
        body = {k: m2[k] - m1[k] for k in keys}
        totals = {k: max(m1[k] - body[k], 0.0) + cfg.n_blocks * body[k] for k in keys}
        ssd = ssd_correction(cfg, shape)  # rolled SSD chunks (see roofline.py)
        totals["flops"] += ssd["flops"]
        totals["bytes"] += ssd["bytes"]
        coll_detail = {
            k: (m2["detail"][k] - m1["detail"][k]) * cfg.n_blocks
            + max(2 * m1["detail"][k] - m2["detail"][k], 0)
            for k in m1["detail"]
        }
    else:
        m = _measure(compiled, n_chips)
        totals = {k: m[k] for k in ("flops", "bytes", "bytes_raw", "dus_bytes", "coll")}
        coll_detail = m["detail"]

    rl = Roofline(
        arch=arch,
        shape=shape_name,
        mesh=mesh_kind,
        n_chips=n_chips,
        hlo_flops=totals["flops"],
        hlo_bytes=totals["bytes"],
        collective_bytes=totals["coll"],
        collective_detail=coll_detail,
        model_flops=model_flops_for(cfg, shape),
        memory_per_device=int(getattr(mem, "temp_size_in_bytes", 0) + getattr(mem, "argument_size_in_bytes", 0)),
    )
    out = {
        "status": "ok",
        "compile_s": compile_s,
        "bytes_raw": totals.get("bytes_raw", totals["bytes"]),
        "dus_bytes": totals.get("dus_bytes", 0.0),
        "opt": opt_name if shape.kind == "train" else None,
        "memory_analysis": {
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "peak_bytes_per_device": int(
                getattr(mem, "temp_size_in_bytes", 0) + getattr(mem, "argument_size_in_bytes", 0)
            ),
        },
        **rl.to_dict(),
    }
    if verbose:
        print(
            f"[{arch} x {shape_name} x {mesh_kind}] compile={compile_s:.1f}s "
            f"flops={out['hlo_flops']:.3e} bytes={out['hlo_bytes']:.3e} "
            f"coll={out['collective_bytes']:.3e} dominant={out['dominant']} "
            f"bound={out['step_bound_s']*1e3:.2f}ms mfu_bound={out['mfu_bound']:.3f} "
            f"useful={out['useful_flops_frac']:.2f} "
            f"mem/dev={out['memory_analysis']['peak_bytes_per_device']/2**30:.2f}GiB"
        )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="all assigned (arch x shape) cells")
    ap.add_argument("--opt", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        from repro.configs import ASSIGNED_ARCHS

        cells = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        for mk in meshes:
            try:
                # roofline measurement is single-pod (the table's scope);
                # multi-pod cells prove compile + record memory analysis.
                results.append(
                    dryrun_cell(arch, shape, mk, args.opt, measure=(mk == "single"))
                )
            except Exception as e:  # a failing cell is a bug: record it loudly
                traceback.print_exc()
                results.append(
                    {"arch": arch, "shape": shape, "mesh": mk, "status": "FAIL", "error": str(e)[:500]}
                )
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, default=str)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\ndone: {n_ok} ok, {n_skip} documented skips, {n_fail} FAILURES")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
