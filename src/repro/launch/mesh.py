"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before the first jax device query.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices but only {len(devices)} present; "
            "the dry-run launcher must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import"
        )
    if len(devices) == need:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    # device superset (e.g. single-pod mesh inside the 512-device dry-run
    # process): take the first pod's worth.
    arr = np.array(devices[:need]).reshape(shape)
    return Mesh(arr, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    arr = np.array(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(arr, axes, axis_types=(AxisType.Auto,) * len(axes))
