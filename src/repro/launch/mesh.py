"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before the first jax device query.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.runtime.compat import make_mesh, make_topology_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices but only {len(devices)} present; "
            "the dry-run launcher must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import"
        )
    if len(devices) == need:
        return make_topology_mesh(shape, axes)  # topology-aware ordering
    # device superset (e.g. single-pod mesh inside the 512-device dry-run
    # process): take the first pod's worth.
    return make_mesh(np.array(devices[:need]).reshape(shape), axes)


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    arr = np.array(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return make_mesh(arr, axes)
