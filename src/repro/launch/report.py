"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSON."""
from __future__ import annotations

import argparse
import json


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.2f}ms"


def fmt_b(x):
    for unit, f in (("PB", 2**50), ("TB", 2**40), ("GB", 2**30), ("MB", 2**20)):
        if x >= f:
            return f"{x/f:.2f}{unit}"
    return f"{x:.0f}B"


def roofline_table(results, mesh="single"):
    rows = [r for r in results if r.get("mesh") == mesh and r["status"] == "ok"]
    out = [
        "| arch | shape | compute | memory | collective | dominant | bound | "
        "MODEL_FLOPs/HLO | mfu_bound | mem/dev | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        fix = suggest_fix(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** | {fmt_s(r['step_bound_s'])} "
            f"| {r['useful_flops_frac']:.2f} | {r['mfu_bound']:.3f} "
            f"| {fmt_b(r['memory_analysis']['peak_bytes_per_device'])} | {fix} |"
        )
    return "\n".join(out)


def suggest_fix(r) -> str:
    d = r["dominant"]
    if d == "memory":
        return "fuse attention/SSD softmax chain into Pallas kernel (VMEM-resident)"
    if d == "collective":
        det = r.get("collective_detail", {})
        big = max((k for k in det if k != "collective_count"), key=lambda k: det[k], default="all-reduce")
        return f"cut {big} bytes: bf16 collectives / a2a EP / kv-replicated TP"
    return "increase per-chip work (larger per-device batch) or reduce redundant compute"


def skip_table(results):
    rows = [r for r in results if r["status"] == "skip" and r["mesh"] == "single"]
    out = ["| arch | shape | reason |", "|---|---|---|"]
    for r in rows:
        out.append(f"| {r['arch']} | {r['shape']} | {r['reason']} |")
    return "\n".join(out)


def dryrun_table(results):
    out = [
        "| arch | shape | mesh | compile | peak mem/device | fits 16G v5e |",
        "|---|---|---|---|---|---|",
    ]
    for r in results:
        if r["status"] != "ok":
            continue
        mem = r["memory_analysis"]["peak_bytes_per_device"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']:.1f}s "
            f"| {fmt_b(mem)} | {'yes' if mem < 16*2**30 else 'NO'} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("json")
    ap.add_argument("--section", default="roofline", choices=["roofline", "dryrun", "skips"])
    args = ap.parse_args()
    results = json.load(open(args.json))
    if args.section == "roofline":
        print(roofline_table(results))
    elif args.section == "dryrun":
        print(dryrun_table(results))
    else:
        print(skip_table(results))


if __name__ == "__main__":
    main()
