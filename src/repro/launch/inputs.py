"""ShapeDtypeStruct input stand-ins per (arch x shape) cell — shardable,
weak-type-correct, zero allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm as LM
from repro.runtime.sharding import ShardingPolicy


def _sds(shape, dtype, pol: ShardingPolicy, *axes):
    sharding = None
    if pol.mesh is not None:
        sharding = NamedSharding(pol.mesh, pol.spec(*axes, shape=shape))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _filter_pspec(pspec, shape, sizes):
    """Drop mesh axes that don't divide the dim (NamedSharding divisibility)."""
    from jax.sharding import PartitionSpec as P

    entries = []
    for d, e in enumerate(pspec):
        if e is None:
            entries.append(None)
            continue
        cand = (e,) if isinstance(e, str) else tuple(e)
        keep, fac = [], 1
        for a in cand:
            sz = sizes.get(a, 1)
            if shape[d] % (fac * sz) == 0:
                keep.append(a)
                fac *= sz
        entries.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    entries += [None] * (len(shape) - len(entries))
    return P(*entries)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, pol: ShardingPolicy) -> dict:
    """Batch pytree of ShapeDtypeStructs for the step kind."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.family == "encoder":
            return {
                "frames": _sds((b, s, cfg.d_model), jnp.bfloat16, pol, "act_batch", "act_seq", "act_embed"),
                "mask": _sds((b, s), jnp.bool_, pol, "act_batch", "act_seq"),
                "targets": _sds((b, s), jnp.int32, pol, "act_batch", "act_seq"),
            }
        batch = {
            "tokens": _sds((b, s), jnp.int32, pol, "act_batch", "act_seq"),
            "targets": _sds((b, s), jnp.int32, pol, "act_batch", "act_seq"),
        }
        if cfg.frontend == "patches":
            batch["patch_embeds"] = _sds(
                (b, cfg.n_patches, cfg.d_model), jnp.bfloat16, pol, "act_batch", None, "act_embed"
            )
        return batch
    if shape.kind == "prefill":
        if cfg.family == "encoder":
            return {"frames": _sds((b, s, cfg.d_model), jnp.bfloat16, pol, "act_batch", "act_seq", "act_embed")}
        batch = {"tokens": _sds((b, s), jnp.int32, pol, "act_batch", "act_seq")}
        if cfg.frontend == "patches":
            batch["patch_embeds"] = _sds(
                (b, cfg.n_patches, cfg.d_model), jnp.bfloat16, pol, "act_batch", None, "act_embed"
            )
        return batch
    # decode: one new token against a seq_len cache
    return {"tokens": _sds((b, 1), jnp.int32, pol, "act_batch", None)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, pol: ShardingPolicy):
    """Abstract decode cache with its shardings."""
    abstract = LM.init_cache(
        cfg, shape.global_batch, shape.seq_len, dtype=jnp.bfloat16, abstract=True
    )
    pspecs = LM.cache_pspecs(cfg, pol)
    if pol.mesh is None:
        return abstract
    sizes = dict(pol.mesh.shape)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(
            a.shape, a.dtype,
            sharding=NamedSharding(pol.mesh, _filter_pspec(s, a.shape, sizes)),
        ),
        abstract,
        pspecs,
    )
