"""CFedRAGSystem — end-to-end wiring of Algorithm 1.

Builds providers from a FederatedCorpus (paper topology: 2 sites x 2
corpora), an in-enclave orchestrator with the chosen aggregation model,
and model-backed reranker/generator callables.  Used by the Table 1
benchmark, the examples, and the integration tests.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filters import MaxChunksFilter, ProvenanceStripFilter
from repro.core.orchestrator import Orchestrator
from repro.core.provider import DataProvider
from repro.core.resilience import (
    BreakerPolicy,
    FaultSpec,
    FaultyProvider,
    QuorumNotMet,
    RetryPolicy,
    ScoreGate,
)
from repro.data.corpus import FederatedCorpus
from repro.data.embeddings import bag_embed
from repro.data.tokenizer import HashTokenizer


@dataclasses.dataclass
class CFedRAGConfig:
    m_local: int = 8  # paper §3.2: top-8 per site
    n_global: int = 8  # paper §3.3: final context window of 8
    aggregation: str = "rerank"
    split_by: str = "site"  # site (paper: 2 providers) | corpus (4 providers)
    embed_dim: int = 256
    chunk_max_len: int = 40
    quorum: int = 1
    deadline_s: float | None = None  # wall-clock collect cutoff (Alg. 1 k_n <= k)
    concurrent_collect: bool | None = None  # None -> auto (transport-aware)
    use_pallas: bool = False
    # federation resilience (core/resilience.py); defaults keep the
    # legacy bit-identical single-shot path
    retries: int = 1  # collect attempts per provider per round (1 = off)
    retry_backoff_s: float = 0.02  # base of the exponential backoff
    breaker: bool = False  # per-provider circuit breakers
    breaker_threshold: int = 2  # consecutive failed rounds to open
    breaker_cooldown_s: float = 1.0  # open -> half-open probe delay
    score_gate: bool = False  # aggregator-side poisoning gate


def _serve_result(req, prompt, context, n_providers: int, answer=None) -> dict:
    """One per-query result dict — the single definition the bit-parity
    contract between ``serve`` and ``serve_stream`` hangs on."""
    out = {
        "context": context,
        "n_providers": n_providers,
        "prompt": prompt,
        "status": req.status,
        "latency_s": req.latency_s,
    }
    if req.status == "done":
        out["answer_tokens"] = answer
        if req.truncated:  # cut short by KV-pool OOM, not EOS/budget
            out["truncated"] = True
    return out


def _degraded_result(err: QuorumNotMet) -> dict:
    """Per-query result for a micro-batch whose collect missed quorum:
    flagged degraded (mirroring the ``truncated`` convention — degraded,
    never silent, never fatal to the rest of the stream) instead of
    propagating the exception and killing every other micro-batch."""
    return {
        "context": None,
        "n_providers": err.arrived,
        "prompt": None,
        "status": "degraded",
        "degraded": True,
        "error": str(err),
        "latency_s": None,
    }


class CFedRAGSystem:
    def __init__(
        self,
        corpus: FederatedCorpus,
        cfg: CFedRAGConfig | None = None,
        tokenizer: HashTokenizer | None = None,
        embed_fn: Callable | None = None,
        reranker: Callable | None = None,
        generator: Callable | None = None,
        fault_spec: FaultSpec | None = None,
    ):
        self.cfg = cfg or CFedRAGConfig()
        self.corpus = corpus
        self.tok = tokenizer or HashTokenizer()
        self.embed_fn = embed_fn or (
            lambda toks: bag_embed(jnp.asarray(toks), dim=self.cfg.embed_dim)
        )
        groups: dict[object, list] = {}
        for c in corpus.chunks:
            key = c.site if self.cfg.split_by == "site" else c.corpus
            groups.setdefault(key, []).append(c)
        self.providers = [
            DataProvider(
                provider_id=i,
                chunks=chunks,
                embed_fn=self.embed_fn,
                tokenizer=self.tok,
                chunk_max_len=self.cfg.chunk_max_len,
                filters=[MaxChunksFilter(self.cfg.m_local), ProvenanceStripFilter()],
                use_pallas=self.cfg.use_pallas,
            )
            for i, (_, chunks) in enumerate(sorted(groups.items(), key=lambda kv: str(kv[0])))
        ]
        for p in self.providers:
            p.build_index()
        if fault_spec is not None:
            # the fault-injection harness wraps every provider; the
            # wrapper proxies everything but handle_request, so the
            # orchestrator (channels, rpc_lock, delay_s) is none the wiser
            self.providers = [FaultyProvider(p, fault_spec) for p in self.providers]
        self.orchestrator = Orchestrator(
            self.providers,
            self.tok,
            aggregation=self.cfg.aggregation,
            reranker=reranker,
            generator=generator,
            m_local=self.cfg.m_local,
            n_global=self.cfg.n_global,
            quorum=self.cfg.quorum,
            deadline_s=self.cfg.deadline_s,
            concurrent_collect=self.cfg.concurrent_collect,
            retry=RetryPolicy(
                max_attempts=self.cfg.retries, backoff_s=self.cfg.retry_backoff_s
            )
            if self.cfg.retries > 1
            else None,
            breaker=BreakerPolicy(
                fail_threshold=self.cfg.breaker_threshold,
                cooldown_s=self.cfg.breaker_cooldown_s,
            )
            if self.cfg.breaker
            else None,
            score_gate=ScoreGate() if self.cfg.score_gate else None,
        )

    # ---- serving entry points ----
    def answer_batch(self, query_texts: list[str]) -> list[dict]:
        """Batched Algorithm 1: one sealed request per provider per batch."""
        return self.orchestrator.answer_batch(query_texts)

    def serve(
        self,
        query_texts: list[str],
        *,
        max_new_tokens: int | list[int] | None = None,
        gen_deadline_s: float | list[float | None] | None = None,
        tenants: str | list[str] | None = None,
        priorities: int | list[int] | None = None,
        tenant_weights: dict[str, float] | None = None,
        fifo: bool = False,
    ) -> list[dict]:
        """Scheduler-driven Algorithm 1: concurrent provider fan-out for
        collect, one batched aggregation pass, then generation through the
        engine's continuous-batching slot pool (when the generator is an
        ``engine_generator``) so ragged generations retire early and free
        their slot.  Per-request generation budgets/deadlines flow through
        to the scheduler; each result carries its ``latency_s``
        (submit -> finish) so callers can report p50/p95.

        Tenant SLO classes: ``tenants``/``priorities`` tag each query with
        its tenant and admission class (scalar or per-query list), and
        ``tenant_weights`` sets the weighted-fair admission shares
        (``fifo=True`` forces the global-arrival-order baseline).
        Per-tenant latency/prefix gauges land in
        ``last_serve_stats["tenants"]``.  Falls back to ``answer_batch``
        semantics when no engine-backed generator is wired."""
        queries = list(query_texts)
        if not queries:
            return []
        orch = self.orchestrator
        engine = getattr(orch.generator, "engine", None)
        continuous = getattr(orch.generator, "mode", "continuous") == "continuous"
        if orch.generator is None or engine is None or not continuous:
            # no engine-backed generator (or a lockstep determinism
            # baseline was wired in): keep answer_batch semantics
            try:
                return self.answer_batch(queries)
            except QuorumNotMet as e:
                self.last_serve_stats = {"federation": orch.federation_stats()}
                return [_degraded_result(e) for _ in queries]
        from repro.serving.scheduler import Scheduler

        try:
            responses = orch.collect_contexts_batch(queries)
        except QuorumNotMet as e:
            self.last_serve_stats = {"federation": orch.federation_stats()}
            return [_degraded_result(e) for _ in queries]
        contexts = orch.aggregate_batch(queries, responses)
        # build prompts at the engine's true window so grammar-aware
        # truncation happens here — the engine's blind tail-slice to
        # max_prompt_len must never be what cuts an overflowing prompt.
        # build_prompt's layout is prefix-stable (context preamble first,
        # fixed query reserve), so when the engine runs the paged prefix
        # cache, same-context siblings and retries in this batch share
        # their preamble KV blocks instead of re-prefilling them
        width = engine.scfg.max_prompt_len
        prompts = [orch.build_prompt(q, c, max_len=width) for q, c in zip(queries, contexts)]
        sched = Scheduler(tenant_weights=tenant_weights, fifo=fifo)
        # scalar-or-list broadcast (with length validation) lives in
        # submit_many, shared by every serve entry point
        rids = sched.submit_many(
            prompts, max_new_tokens, gen_deadline_s,
            tenants=tenants, priorities=priorities,
        )
        answers = engine.serve(sched)
        # latency percentiles + engine occupancy gauges (free slots / free
        # KV blocks) + the federation health ledger for callers that
        # report memory headroom / provider health
        self.last_serve_stats = sched.latency_stats()
        self.last_serve_stats["federation"] = orch.federation_stats()
        return [
            _serve_result(sched.results[rid], prompt, ctx, len(responses), answers.get(rid))
            for rid, prompt, ctx in zip(rids, prompts, contexts)
        ]

    def serve_stream(
        self,
        query_texts: list[str],
        *,
        max_new_tokens: int | list[int] | None = None,
        gen_deadline_s: float | list[float | None] | None = None,
        collect_batch: int = 8,
        tenants: str | list[str] | None = None,
        priorities: int | list[int] | None = None,
        tenant_weights: dict[str, float] | None = None,
        fifo: bool = False,
    ):
        """Pipelined (double-buffered) front door: a collector thread runs
        ``collect_contexts_batch``/``aggregate_batch`` for micro-batch N+1
        while the engine decodes micro-batch N, submitting prompts into
        the live scheduler as they become ready; results are yielded as
        ``(query_index, result_dict)`` the moment each generation retires
        (retire order, not submission order).  Scheduler backpressure
        bounds the collector to one micro-batch of run-ahead, and yielded
        requests drop their prompt/context/answer buffers, so resident
        payload memory stays O(collect_batch) however long the query list
        is (only per-request timestamps are kept for latency stats).

        Per-query dicts are bit-identical to ``serve`` on the same inputs
        (collect/aggregate are per-query, slot decode is slot-independent)
        — only ``latency_s`` differs in *meaning*: it now covers the whole
        collect -> finish span of the query's micro-batch, not just
        generation, because requests are stamped with the micro-batch's
        collect start time.  Without an engine-backed continuous generator
        the phase-barrier ``serve`` runs instead and its results are
        yielded in order."""
        queries = list(query_texts)
        if not queries:
            return
        orch = self.orchestrator
        engine = getattr(orch.generator, "engine", None)
        continuous = getattr(orch.generator, "mode", "continuous") == "continuous"
        if orch.generator is None or engine is None or not continuous:
            for i, out in enumerate(
                self.serve(
                    queries, max_new_tokens=max_new_tokens,
                    gen_deadline_s=gen_deadline_s, tenants=tenants,
                    priorities=priorities, tenant_weights=tenant_weights,
                    fifo=fifo,
                )
            ):
                yield i, out
            return
        from repro.serving.scheduler import Scheduler, _broadcast

        n = len(queries)
        budgets = _broadcast(max_new_tokens, n, "max_new_tokens")
        deadlines = _broadcast(gen_deadline_s, n, "gen_deadline_s")
        tenant_l = _broadcast(tenants if tenants is not None else "default", n, "tenants")
        prio_l = _broadcast(priorities if priorities is not None else 0, n, "priorities")
        collect_batch = max(1, int(collect_batch))
        width = engine.scfg.max_prompt_len
        sched = Scheduler(tenant_weights=tenant_weights, fifo=fifo)
        info: dict[int, tuple] = {}  # qidx -> (prompt, context, n_providers)
        degraded: dict[int, dict] = {}  # qidx -> quorum-degraded result
        collect_err: list[BaseException] = []
        stop = threading.Event()  # consumer-gone signal for the collector

        def collector():
            try:
                for start in range(0, n, collect_batch):
                    # double-buffer backpressure: collect micro-batch N+1
                    # only while at most one micro-batch of work is still
                    # non-terminal, so a fast collector holds O(collect
                    # batch) prompts, not the whole workload.  The wait is
                    # condition-driven; the coarse timeout exists only so
                    # an abandoned stream (stop set, no more retires to
                    # wake the condition) unblocks promptly
                    while not stop.is_set() and not sched.wait_backlog_below(
                        2 * collect_batch, timeout=0.5
                    ):
                        pass
                    if stop.is_set():
                        return
                    chunk = queries[start : start + collect_batch]
                    t0 = time.monotonic()
                    try:
                        responses = orch.collect_contexts_batch(chunk)
                    except QuorumNotMet as e:
                        # this micro-batch degrades; the stream survives
                        for j in range(len(chunk)):
                            degraded[start + j] = _degraded_result(e)
                        continue
                    contexts = orch.aggregate_batch(chunk, responses)
                    prompts = [
                        orch.build_prompt(q, c, max_len=width)
                        for q, c in zip(chunk, contexts)
                    ]
                    idxs = list(range(start, start + len(chunk)))
                    # publish metadata BEFORE submitting: the engine may
                    # retire a request the instant it is queued
                    for j, qidx in enumerate(idxs):
                        info[qidx] = (prompts[j], contexts[j], len(responses))
                    sched.submit_many(
                        prompts,
                        [budgets[i] for i in idxs],
                        [deadlines[i] for i in idxs],
                        tags=idxs,
                        t0=t0,
                        tenants=[tenant_l[i] for i in idxs],
                        priorities=[prio_l[i] for i in idxs],
                    )
            except BaseException as e:  # surfaced to the consumer below
                collect_err.append(e)
            finally:
                sched.close()  # handshake: engine drains and exits

        producer = threading.Thread(target=collector, daemon=True)
        producer.start()
        try:
            for rid, ans in engine.serve_stream(sched):
                req = sched.results[rid]
                qidx = req.tag
                prompt, context, n_providers = info.pop(qidx)
                req.tokens = req.answer = None  # keep timestamps, drop payloads
                yield qidx, _serve_result(req, prompt, context, n_providers, ans)
            # expired requests never reach the engine; report them too so
            # every submitted query yields exactly one result
            for req in list(sched.results.values()):
                if req.status != "expired":
                    continue
                prompt, context, n_providers = info.pop(req.tag)
                req.tokens = None
                yield req.tag, _serve_result(req, prompt, context, n_providers)
            # quorum-degraded micro-batches were never submitted either;
            # their flagged results complete the one-result-per-query
            # contract (mirrors the expired convention above)
            for qidx in sorted(degraded):
                yield qidx, degraded[qidx]
        finally:
            # an abandoned stream must not leave the collector blocked on
            # backpressure: signal it down, then wait it out
            stop.set()
            producer.join()
            self.last_serve_stats = sched.latency_stats()
            self.last_serve_stats["federation"] = orch.federation_stats()
        if collect_err:
            raise collect_err[0]

    # ---- evaluation (Table 1 protocol on synthetic provenance) ----
    def eval_retrieval(self, n_queries: int | None = None, batch_size: int = 32) -> dict:
        """recall@n of the gold chunk in the final context window.

        Queries run through the batched pipeline (``batch_size`` per sealed
        round-trip); results are identical to the sequential path."""
        queries = self.corpus.queries[:n_queries] if n_queries else self.corpus.queries
        hits = 0
        per_corpus: dict = {}
        mrr = 0.0
        for i in range(0, len(queries), batch_size):
            chunk = queries[i : i + batch_size]
            results = self.orchestrator.answer_batch([q.text for q in chunk])
            for q, res in zip(chunk, results):
                ids = list(res["context"]["chunk_ids"])
                hit = q.gold_chunk_id in ids
                hits += hit
                if hit:
                    mrr += 1.0 / (ids.index(q.gold_chunk_id) + 1)
                stats = per_corpus.setdefault(q.corpus, [0, 0])
                stats[0] += hit
                stats[1] += 1
        n = len(queries)
        return {
            "recall_at_n": hits / n,
            "mrr": mrr / n,
            "n_queries": n,
            "per_corpus": {c: h / t for c, (h, t) in per_corpus.items()},
        }


def single_silo_system(corpus: FederatedCorpus, corpus_name: str, cfg: CFedRAGConfig | None = None, **kw):
    """Vanilla-RAG baseline on one corpus only (Table 1 MedRag(X) rows)."""
    sub = FederatedCorpus(
        chunks=corpus.corpus_chunks(corpus_name), queries=corpus.queries
    )
    c = dataclasses.replace(cfg or CFedRAGConfig(), split_by="corpus", aggregation="embedding_rank")
    return CFedRAGSystem(sub, c, **kw)


def centralized_system(corpus: FederatedCorpus, cfg: CFedRAGConfig | None = None, **kw):
    """Centralized MedRag(MedCorp) baseline: all corpora in one index —
    every chunk is remapped to one site, so the site split yields a single
    provider holding everything."""
    c = dataclasses.replace(cfg or CFedRAGConfig(), split_by="site")
    merged = FederatedCorpus(
        chunks=[dataclasses.replace(ch, site=0) for ch in corpus.chunks],
        queries=corpus.queries,
    )
    return CFedRAGSystem(merged, c, **kw)
