"""CFedRAGSystem — end-to-end wiring of Algorithm 1.

Builds providers from a FederatedCorpus (paper topology: 2 sites x 2
corpora), an in-enclave orchestrator with the chosen aggregation model,
and model-backed reranker/generator callables.  Used by the Table 1
benchmark, the examples, and the integration tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filters import MaxChunksFilter, ProvenanceStripFilter
from repro.core.orchestrator import Orchestrator
from repro.core.provider import DataProvider
from repro.data.corpus import FederatedCorpus
from repro.data.embeddings import bag_embed
from repro.data.tokenizer import HashTokenizer


@dataclasses.dataclass
class CFedRAGConfig:
    m_local: int = 8  # paper §3.2: top-8 per site
    n_global: int = 8  # paper §3.3: final context window of 8
    aggregation: str = "rerank"
    split_by: str = "site"  # site (paper: 2 providers) | corpus (4 providers)
    embed_dim: int = 256
    chunk_max_len: int = 40
    quorum: int = 1
    deadline_s: float | None = None  # wall-clock collect cutoff (Alg. 1 k_n <= k)
    concurrent_collect: bool | None = None  # None -> auto (transport-aware)
    use_pallas: bool = False


class CFedRAGSystem:
    def __init__(
        self,
        corpus: FederatedCorpus,
        cfg: CFedRAGConfig | None = None,
        tokenizer: HashTokenizer | None = None,
        embed_fn: Callable | None = None,
        reranker: Callable | None = None,
        generator: Callable | None = None,
    ):
        self.cfg = cfg or CFedRAGConfig()
        self.corpus = corpus
        self.tok = tokenizer or HashTokenizer()
        self.embed_fn = embed_fn or (
            lambda toks: bag_embed(jnp.asarray(toks), dim=self.cfg.embed_dim)
        )
        groups: dict[object, list] = {}
        for c in corpus.chunks:
            key = c.site if self.cfg.split_by == "site" else c.corpus
            groups.setdefault(key, []).append(c)
        self.providers = [
            DataProvider(
                provider_id=i,
                chunks=chunks,
                embed_fn=self.embed_fn,
                tokenizer=self.tok,
                chunk_max_len=self.cfg.chunk_max_len,
                filters=[MaxChunksFilter(self.cfg.m_local), ProvenanceStripFilter()],
                use_pallas=self.cfg.use_pallas,
            )
            for i, (_, chunks) in enumerate(sorted(groups.items(), key=lambda kv: str(kv[0])))
        ]
        for p in self.providers:
            p.build_index()
        self.orchestrator = Orchestrator(
            self.providers,
            self.tok,
            aggregation=self.cfg.aggregation,
            reranker=reranker,
            generator=generator,
            m_local=self.cfg.m_local,
            n_global=self.cfg.n_global,
            quorum=self.cfg.quorum,
            deadline_s=self.cfg.deadline_s,
            concurrent_collect=self.cfg.concurrent_collect,
        )

    # ---- serving entry points ----
    def answer_batch(self, query_texts: list[str]) -> list[dict]:
        """Batched Algorithm 1: one sealed request per provider per batch."""
        return self.orchestrator.answer_batch(query_texts)

    def serve(
        self,
        query_texts: list[str],
        *,
        max_new_tokens: int | list[int] | None = None,
        gen_deadline_s: float | list[float | None] | None = None,
    ) -> list[dict]:
        """Scheduler-driven Algorithm 1: concurrent provider fan-out for
        collect, one batched aggregation pass, then generation through the
        engine's continuous-batching slot pool (when the generator is an
        ``engine_generator``) so ragged generations retire early and free
        their slot.  Per-request generation budgets/deadlines flow through
        to the scheduler; each result carries its ``latency_s``
        (submit -> finish) so callers can report p50/p95.  Falls back to
        ``answer_batch`` semantics when no engine-backed generator is
        wired."""
        queries = list(query_texts)
        if not queries:
            return []
        orch = self.orchestrator
        engine = getattr(orch.generator, "engine", None)
        continuous = getattr(orch.generator, "mode", "continuous") == "continuous"
        if orch.generator is None or engine is None or not continuous:
            # no engine-backed generator (or a lockstep determinism
            # baseline was wired in): keep answer_batch semantics
            return self.answer_batch(queries)
        from repro.serving.scheduler import Scheduler

        responses = orch.collect_contexts_batch(queries)
        contexts = orch.aggregate_batch(queries, responses)
        outs = [{"context": c, "n_providers": len(responses)} for c in contexts]
        prompts = [orch.build_prompt(q, c) for q, c in zip(queries, contexts)]
        sched = Scheduler()
        rids = sched.submit_many(
            prompts,
            max_new_tokens,
            gen_deadline_s if isinstance(gen_deadline_s, (list, tuple)) else [gen_deadline_s] * len(queries),
        )
        answers = engine.serve(sched)
        for out, prompt, rid in zip(outs, prompts, rids):
            req = sched.results[rid]
            out["prompt"] = prompt
            out["status"] = req.status
            out["latency_s"] = req.latency_s
            if req.status == "done":
                out["answer_tokens"] = answers[rid]
        return outs

    # ---- evaluation (Table 1 protocol on synthetic provenance) ----
    def eval_retrieval(self, n_queries: int | None = None, batch_size: int = 32) -> dict:
        """recall@n of the gold chunk in the final context window.

        Queries run through the batched pipeline (``batch_size`` per sealed
        round-trip); results are identical to the sequential path."""
        queries = self.corpus.queries[:n_queries] if n_queries else self.corpus.queries
        hits = 0
        per_corpus: dict = {}
        mrr = 0.0
        for i in range(0, len(queries), batch_size):
            chunk = queries[i : i + batch_size]
            results = self.orchestrator.answer_batch([q.text for q in chunk])
            for q, res in zip(chunk, results):
                ids = list(res["context"]["chunk_ids"])
                hit = q.gold_chunk_id in ids
                hits += hit
                if hit:
                    mrr += 1.0 / (ids.index(q.gold_chunk_id) + 1)
                stats = per_corpus.setdefault(q.corpus, [0, 0])
                stats[0] += hit
                stats[1] += 1
        n = len(queries)
        return {
            "recall_at_n": hits / n,
            "mrr": mrr / n,
            "n_queries": n,
            "per_corpus": {c: h / t for c, (h, t) in per_corpus.items()},
        }


def single_silo_system(corpus: FederatedCorpus, corpus_name: str, cfg: CFedRAGConfig | None = None, **kw):
    """Vanilla-RAG baseline on one corpus only (Table 1 MedRag(X) rows)."""
    sub = FederatedCorpus(
        chunks=corpus.corpus_chunks(corpus_name), queries=corpus.queries
    )
    c = dataclasses.replace(cfg or CFedRAGConfig(), split_by="corpus", aggregation="embedding_rank")
    return CFedRAGSystem(sub, c, **kw)


def centralized_system(corpus: FederatedCorpus, cfg: CFedRAGConfig | None = None, **kw):
    """Centralized MedRag(MedCorp) baseline: all corpora in one index —
    every chunk is remapped to one site, so the site split yields a single
    provider holding everything."""
    c = dataclasses.replace(cfg or CFedRAGConfig(), split_by="site")
    merged = FederatedCorpus(
        chunks=[dataclasses.replace(ch, site=0) for ch in corpus.chunks],
        queries=corpus.queries,
    )
    return CFedRAGSystem(merged, c, **kw)
