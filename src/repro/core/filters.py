"""NVFlare-style task data / result filters (paper §2.3.1 "Data Privacy").

Filters are composable transforms applied to payloads on both ends of a
channel: the provider filters what leaves its boundary; the orchestrator
filters what enters the enclave.  Each filter sees a payload dict and
returns a (possibly modified) payload dict.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

Payload = dict


class Filter:
    name = "filter"

    def __call__(self, payload: Payload) -> Payload:  # pragma: no cover
        raise NotImplementedError


@dataclasses.dataclass
class MaxChunksFilter(Filter):
    """Cap how many chunks a provider will ever return (policy control)."""

    max_chunks: int
    name = "max_chunks"

    def __call__(self, payload: Payload) -> Payload:
        if "chunk_tokens" in payload:
            payload = dict(payload)
            # truncate the candidate axis: last-but-one for (.., m, S)
            # chunk tokens, last for (.., m) scores/ids — works for both
            # single-query and (B, ...) batched payloads
            payload["chunk_tokens"] = payload["chunk_tokens"][..., : self.max_chunks, :]
            for k in ("scores", "chunk_ids"):
                if k in payload:
                    payload[k] = payload[k][..., : self.max_chunks]
        return payload


@dataclasses.dataclass
class ScoreQuantizeFilter(Filter):
    """Coarsen scores before they leave the provider (reduces what a curious
    orchestrator can infer about the local corpus distribution)."""

    decimals: int = 2
    name = "score_quantize"

    def __call__(self, payload: Payload) -> Payload:
        if "scores" in payload:
            payload = dict(payload)
            payload["scores"] = np.round(payload["scores"], self.decimals)
        return payload


@dataclasses.dataclass
class DPNoiseFilter(Filter):
    """Gaussian-mechanism noise on embedding payloads (paper §4.3 mentions
    differential privacy as a candidate PET for federated embedding flows)."""

    sigma: float = 0.01
    seed: int = 0
    name = "dp_noise"

    def __call__(self, payload: Payload) -> Payload:
        if "embeddings" in payload:
            payload = dict(payload)
            rng = np.random.default_rng(self.seed)
            e = payload["embeddings"]
            payload["embeddings"] = e + rng.normal(0, self.sigma, e.shape).astype(e.dtype)
        return payload


@dataclasses.dataclass
class ProvenanceStripFilter(Filter):
    """Remove provider-internal identifiers before chunks leave the site."""

    keep: tuple = ("chunk_tokens", "scores", "chunk_ids", "provider")
    name = "provenance_strip"

    def __call__(self, payload: Payload) -> Payload:
        return {k: v for k, v in payload.items() if k in self.keep}


def apply_filters(filters: list[Filter], payload: Payload) -> Payload:
    for f in filters:
        payload = f(payload)
    return payload
