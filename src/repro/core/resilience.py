"""Federation resilience layer — fault injection, retry/backoff, circuit
breakers, and the aggregator-side poisoning gate for the collect path.

The paper's premise is federation across organizational trust boundaries
(paper §2.3: the orchestrator talks to every data provider over attested
mTLS channels; §4.1: providers are independent parties that may fail,
lag, or misbehave).  Algorithm 1's ``k_n <= k`` semantics already tolerate
*absent* providers; this module adds the rest of the threat model:

  * :class:`FaultSpec` / :class:`FaultyProvider` — a deterministic
    (seeded) fault-injection harness standing in for the real-world
    failure modes of a provider WAN link and a tampering/compromised
    site: connection failures, transport timeouts, RTT jitter, sealed
    payload corruption (→ AEAD ``IntegrityError`` at the orchestrator,
    §2.3.1 integrity), replayed nonces (→ replay detection, §2.3.1), and
    outlier/poisoned relevance scores (the retrieval-side poisoning
    attack of the RAG security literature: a malicious provider inflates
    its scores so its chunks dominate context selection).
  * :class:`RetryPolicy` — bounded per-provider retries with exponential
    backoff; the backoff budget is deducted from the live collect
    ``deadline_s`` so retries can never stretch the SLO.
  * :class:`CircuitBreaker` (+ :class:`BreakerPolicy`) — per-provider
    closed/open/half-open breaker: a provider that keeps failing whole
    rounds is skipped (no round-trip cost) until a cooldown expires,
    then probed with a single half-open attempt.  Flapping providers
    stop costing a full RTT (plus retries) every round; collect degrades
    to the healthy quorum.
  * :class:`ScoreGate` — aggregator-side poisoning defense (§4.1 "only
    authorized codes", extended to authorized *behavior*): per-provider
    score calibration (z-score against the provider's own running score
    distribution, making provider-local embedding spaces comparable) and
    an outlier gate that quarantines a provider's round when its scores
    are anomalous against its own history.  Provenance tags
    (``providers`` + ``gated`` metadata) flow into ``aggregate`` /
    ``build_prompt`` so a downstream consumer can audit what was kept.
  * :class:`ProviderHealth` / ``Orchestrator.federation_stats()`` —
    per-provider attempts, retries, breaker state, faults by type, and
    drop/quarantine counts, surfaced through
    ``CFedRAGSystem.last_serve_stats`` and ``launch/serve.py``.

Invariant: with no faults injected, retries off, and the gate off, the
collect path is bit-identical to the un-hardened one (asserted in
tests/test_resilience.py) — resilience is pure overlay, never a silent
behavior change.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time

import numpy as np


class QuorumNotMet(RuntimeError):
    """Typed quorum failure: fewer providers answered than ``quorum``
    requires.  Subclasses RuntimeError so legacy ``except RuntimeError``
    / ``match="quorum"`` call sites keep working; carries the counts so
    the serving layer can report a *degraded* result instead of dying."""

    def __init__(self, arrived: int, required: int):
        super().__init__(
            f"quorum not met: {arrived}/{required} providers answered"
        )
        self.arrived = arrived
        self.required = required


# --------------------------------------------------------------------- #
# fault injection
# --------------------------------------------------------------------- #

_FAULT_KINDS = ("conn", "timeout", "delay", "corrupt", "replay", "poison")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Deterministic fault schedule for one (or many) providers.

    Per sealed request exactly one fault kind is drawn from a seeded RNG
    (cumulative ranges over one uniform draw, so the schedule is
    reproducible across machines and independent of wall-clock):

      * ``p_conn``    — raise ``ConnectionError`` (link down)
      * ``p_timeout`` — raise ``TimeoutError`` (transport gave up)
      * ``p_delay``   — sleep a jitter in [0, ``delay_jitter_s``] then
                        answer normally (WAN jitter)
      * ``p_corrupt`` — answer, then flip a ciphertext byte (tampered /
                        corrupted sealed payload → ``IntegrityError``)
      * ``p_replay``  — answer, but return the PREVIOUS sealed response
                        (stale nonce → replay detection)
      * ``p_poison``  — answer with inflated outlier scores (retrieval
                        poisoning; the :class:`ScoreGate` target)

    ``fault_latency_s`` models the detection cost of conn/timeout faults
    (a failed connect still burns a timeout before it raises) — without
    it, a dead provider would be *cheaper* than a healthy one and a
    breaker could never win wall-clock."""

    seed: int = 0
    p_conn: float = 0.0
    p_timeout: float = 0.0
    p_delay: float = 0.0
    delay_jitter_s: float = 0.0
    p_corrupt: float = 0.0
    p_replay: float = 0.0
    p_poison: float = 0.0
    poison_scale: float = 50.0
    fault_latency_s: float = 0.0

    def rates(self) -> dict[str, float]:
        return {
            "conn": self.p_conn,
            "timeout": self.p_timeout,
            "delay": self.p_delay,
            "corrupt": self.p_corrupt,
            "replay": self.p_replay,
            "poison": self.p_poison,
        }

    @property
    def total_rate(self) -> float:
        return sum(self.rates().values())

    def __post_init__(self):
        if self.total_rate > 1.0:
            raise ValueError(f"fault rates sum to {self.total_rate} > 1")

    def rng_for(self, provider_id: int) -> np.random.Generator:
        # per-provider stream: the schedule of provider i never depends
        # on how many requests provider j handled (thread-arrival order
        # in the concurrent fan-out must not perturb the schedule)
        return np.random.default_rng((self.seed, int(provider_id)))

    @staticmethod
    def from_json(blob: str | dict) -> "FaultSpec":
        """Build from a JSON object string (the ``--fault-spec`` CLI
        surface), e.g. ``'{"seed": 0, "p_conn": 0.1, "p_corrupt": 0.05}'``."""
        d = json.loads(blob) if isinstance(blob, str) else dict(blob)
        unknown = set(d) - {f.name for f in dataclasses.fields(FaultSpec)}
        if unknown:
            raise ValueError(f"unknown FaultSpec field(s): {sorted(unknown)}")
        return FaultSpec(**d)


class FaultyProvider:
    """Deterministic fault-injection wrapper around a ``DataProvider``.

    Transparent proxy: every attribute read/write not owned by the
    wrapper forwards to the inner provider, so the orchestrator's
    channel establishment (``p.channel = ...``, ``p._orch_channel``),
    ``rpc_lock`` serialization, and ``delay_s`` transport hints all keep
    working — only ``handle_request`` is intercepted.  This replaces the
    blunt ``DataProvider.fail`` boolean (kept for back-compat) with the
    full fault taxonomy of :class:`FaultSpec`; ``faults`` counts every
    injection by kind so a harness can reconcile injected-vs-observed.

    Calls on one provider are serialized by the orchestrator's per-
    provider ``rpc_lock``, so the per-provider RNG stream makes the
    schedule reproducible regardless of fan-out interleaving."""

    _OWN = frozenset({"inner", "spec", "faults", "calls", "_rng", "_last_response"})

    def __init__(self, inner, spec: FaultSpec):
        object.__setattr__(self, "inner", inner)
        object.__setattr__(self, "spec", spec)
        object.__setattr__(self, "_rng", spec.rng_for(inner.provider_id))
        object.__setattr__(self, "faults", {k: 0 for k in _FAULT_KINDS})
        object.__setattr__(self, "calls", 0)
        object.__setattr__(self, "_last_response", None)

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def __setattr__(self, name, value):
        if name in self._OWN:
            object.__setattr__(self, name, value)
        else:
            setattr(self.inner, name, value)

    # ---- fault schedule ----
    def _draw(self) -> tuple[str | None, float]:
        """One fault decision per request: (kind, jitter_s).  A single
        uniform draw selects the kind (cumulative ranges keep marginal
        rates exact); a second draw sizes the jitter only when a delay
        fault fired, so the stream stays deterministic."""
        u = float(self._rng.random())
        edge = 0.0
        for kind, p in self.spec.rates().items():
            edge += p
            if u < edge:
                jitter = (
                    float(self._rng.random()) * self.spec.delay_jitter_s
                    if kind == "delay"
                    else 0.0
                )
                return kind, jitter
        return None, 0.0

    def _poisoned_response(self, nonce: bytes, sealed: bytes):
        """Handle the request like the inner provider would, but inflate
        the relevance scores before sealing: the channel is intact (the
        provider *is* the attacker), the content is poisoned — exactly
        the retrieval-side attack the aggregator's ScoreGate must catch."""
        from repro.core.provider import pack, unpack  # local: avoid cycle

        inner = self.inner
        inner.n_requests += 1
        if inner.delay_s:
            time.sleep(inner.delay_s)
        req = unpack(inner.channel.open(nonce, sealed))
        out = dict(inner.retrieve(req["query_tokens"], int(req["m"])))
        scores = np.asarray(out["scores"], np.float32)
        out["scores"] = scores + np.float32(self.spec.poison_scale)
        return inner.channel.seal(pack(out))

    def handle_request(self, nonce: bytes, sealed: bytes):
        self.calls += 1
        kind, jitter = self._draw()
        if kind == "conn":
            self.faults["conn"] += 1
            if self.spec.fault_latency_s:
                time.sleep(self.spec.fault_latency_s)
            raise ConnectionError(
                f"provider {self.inner.provider_id} injected connection failure"
            )
        if kind == "timeout":
            self.faults["timeout"] += 1
            if self.spec.fault_latency_s:
                time.sleep(self.spec.fault_latency_s)
            raise TimeoutError(
                f"provider {self.inner.provider_id} injected timeout"
            )
        if kind == "delay":
            self.faults["delay"] += 1
            if jitter:
                time.sleep(jitter)
            resp = self.inner.handle_request(nonce, sealed)
        elif kind == "poison":
            self.faults["poison"] += 1
            resp = self._poisoned_response(nonce, sealed)
        else:
            resp = self.inner.handle_request(nonce, sealed)
        if kind == "corrupt":
            self.faults["corrupt"] += 1
            r_nonce, r_sealed = resp
            tampered = bytearray(r_sealed)
            tampered[len(tampered) // 2] ^= 0xFF
            return r_nonce, bytes(tampered)
        if (
            kind == "replay"
            and self._last_response is not None
            and self._last_response[0] is self.inner.channel
        ):
            # re-send the previous round's sealed response: its nonce is
            # behind the orchestrator's receive sequence -> IntegrityError.
            # Only counted while the channel that sealed it is still live:
            # after a re-establish the receive sequence resets and the old
            # message would verify again — a replay that cannot be
            # detected is not a detectable injection, so it is not drawn.
            self.faults["replay"] += 1
            return self._last_response[1]
        self._last_response = (self.inner.channel, resp)
        return resp


# --------------------------------------------------------------------- #
# retry / breaker
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded per-provider retry with exponential backoff.

    ``max_attempts`` counts total round-trips (1 == retries disabled —
    the exact legacy single-shot path).  The backoff before attempt
    ``n+1`` is ``backoff_s * backoff_mult**n``; the orchestrator deducts
    it from the remaining ``deadline_s`` budget and stops retrying when
    the SLO cannot afford another attempt."""

    max_attempts: int = 3
    backoff_s: float = 0.02
    backoff_mult: float = 2.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")

    def backoff(self, prior_attempts: int) -> float:
        return self.backoff_s * self.backoff_mult ** max(0, prior_attempts - 1)


@dataclasses.dataclass(frozen=True)
class BreakerPolicy:
    """Per-federation breaker parameters (one CircuitBreaker is minted
    per provider): ``fail_threshold`` consecutive failed *rounds* open
    the breaker, ``cooldown_s`` later one half-open probe is allowed."""

    fail_threshold: int = 2
    cooldown_s: float = 1.0


class CircuitBreaker:
    """Closed / open / half-open breaker for one provider.

    closed:    requests flow; ``fail_threshold`` consecutive failed
               rounds trip it open.
    open:      requests are skipped (no round-trip, no retry cost) until
               ``cooldown_s`` has elapsed.
    half-open: exactly one probe round is allowed through; success
               closes the breaker, failure re-opens it (fresh cooldown).

    Thread-safe: ``allow``/``record_*`` may race across the concurrent
    fan-out of overlapping collects."""

    def __init__(self, policy: BreakerPolicy, clock=time.monotonic):
        self.policy = policy
        self._clock = clock
        self._lock = threading.Lock()
        self._state = "closed"
        self._consecutive_failures = 0
        self._opened_at = 0.0
        self._probe_inflight = False
        self.trips = 0  # observability: how often it opened

    @property
    def state(self) -> str:
        with self._lock:
            if (
                self._state == "open"
                and self._clock() - self._opened_at >= self.policy.cooldown_s
            ):
                return "half-open"  # next allow() will admit the probe
            return self._state

    def allow(self) -> bool:
        with self._lock:
            if self._state == "closed":
                return True
            if self._state == "open":
                if self._clock() - self._opened_at < self.policy.cooldown_s:
                    return False
                self._state = "half-open"
                self._probe_inflight = True
                return True
            # half-open: only the single probe may be in flight
            if self._probe_inflight:
                return False
            self._probe_inflight = True
            return True

    def record_success(self):
        with self._lock:
            self._state = "closed"
            self._consecutive_failures = 0
            self._probe_inflight = False

    def record_failure(self):
        with self._lock:
            self._consecutive_failures += 1
            if self._state == "half-open":
                self._trip()
            elif (
                self._state == "closed"
                and self._consecutive_failures >= self.policy.fail_threshold
            ):
                self._trip()

    def _trip(self):
        self._state = "open"
        self._opened_at = self._clock()
        self._probe_inflight = False
        self.trips += 1


# --------------------------------------------------------------------- #
# per-provider health ledger
# --------------------------------------------------------------------- #


@dataclasses.dataclass
class ProviderHealth:
    """Everything the orchestrator observed about one provider: attempts
    dispatched, successes, retries, breaker skips, channel re-
    establishments, score-gate quarantines, and faults by type."""

    attempts: int = 0
    successes: int = 0
    retries: int = 0
    skips: int = 0  # rounds not dispatched because the breaker was open
    rechannels: int = 0  # channel self-heals after IntegrityError
    quarantined: int = 0  # rounds dropped by the score gate
    dropped_chunks: int = 0  # chunks removed by quarantine
    faults: dict = dataclasses.field(
        default_factory=lambda: {"conn": 0, "timeout": 0, "integrity": 0}
    )
    breaker: CircuitBreaker | None = None

    def record_fault(self, exc: BaseException):
        if isinstance(exc, ConnectionError):
            self.faults["conn"] += 1
        elif isinstance(exc, TimeoutError):
            self.faults["timeout"] += 1
        else:
            self.faults["integrity"] += 1

    def as_dict(self) -> dict:
        d = {
            "attempts": self.attempts,
            "successes": self.successes,
            "retries": self.retries,
            "skips": self.skips,
            "rechannels": self.rechannels,
            "quarantined": self.quarantined,
            "dropped_chunks": self.dropped_chunks,
            "faults": dict(self.faults),
            "breaker": self.breaker.state if self.breaker else None,
            "breaker_trips": self.breaker.trips if self.breaker else 0,
        }
        return d


# --------------------------------------------------------------------- #
# aggregator-side poisoning gate
# --------------------------------------------------------------------- #


class ScoreGate:
    """Per-provider score calibration + outlier quarantine.

    Providers may run *different* embedding models (paper §2.3.4: each
    site vectorizes with its embedding model of choice), so raw score
    scales are not comparable across providers — and a malicious
    provider can exploit exactly that by inflating its scores to
    dominate ``aggregate``'s top-n cut.  The gate keeps a running
    per-provider score distribution (Welford mean/variance over every
    score the provider has ever returned) and, per round:

      1. **outlier gate** — if the round's max z-score against the
         provider's OWN history exceeds ``z_max`` (history permitting:
         at least ``min_history`` scores), the provider's whole round is
         quarantined (chunks dropped, counted) and the anomalous scores
         are NOT folded into the history — poisoning must not be able to
         shift its own baseline.
      2. **calibration** — surviving scores are z-scored against the
         provider's distribution, so cross-provider ranking compares
         "how unusual is this match for THIS provider" instead of raw
         cosines from incompatible spaces.

    Opt-in: the gate changes ranking inputs, so it is off by default and
    the ungated path stays bit-identical.  Thread-safe (one lock; the
    concurrent fan-out aggregates on one thread today, but overlapping
    ``serve_stream`` collectors may not)."""

    def __init__(self, z_max: float = 6.0, min_history: int = 16):
        self.z_max = z_max
        self.min_history = min_history
        self._lock = threading.Lock()
        self._stats: dict[int, tuple[int, float, float]] = {}  # pid -> (n, mean, M2)

    def _mean_std(self, pid: int) -> tuple[int, float, float]:
        n, mean, m2 = self._stats.get(pid, (0, 0.0, 0.0))
        std = (m2 / (n - 1)) ** 0.5 if n > 1 else 0.0
        return n, mean, std

    def _fold(self, pid: int, scores: np.ndarray):
        n, mean, m2 = self._stats.get(pid, (0, 0.0, 0.0))
        for x in scores.ravel():
            n += 1
            d = float(x) - mean
            mean += d / n
            m2 += d * (float(x) - mean)
        self._stats[pid] = (n, mean, m2)

    def admit(self, pid: int, scores: np.ndarray) -> tuple[bool, np.ndarray]:
        """Gate one provider's round.  Returns ``(keep, calibrated)``:
        ``keep=False`` quarantines the round (calibrated is the raw
        input, unused); ``keep=True`` returns z-scored ``calibrated``
        (identity when history is still too thin to calibrate)."""
        scores = np.asarray(scores, np.float32)
        with self._lock:
            n, mean, std = self._mean_std(pid)
            if n >= self.min_history and std > 0.0:
                z = (scores - mean) / std
                if float(np.max(np.abs(z))) > self.z_max:
                    return False, scores  # quarantine; history unpolluted
                self._fold(pid, scores)
                return True, ((scores - mean) / std).astype(np.float32)
            # cold start: observe only, rank on raw scores
            self._fold(pid, scores)
            return True, scores

    def snapshot(self) -> dict[int, dict]:
        with self._lock:
            return {
                pid: {"n": n, "mean": mean, "std": self._mean_std(pid)[2]}
                for pid, (n, mean, _) in self._stats.items()
            }
