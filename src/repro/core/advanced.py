"""Advanced federated-flow variations (paper §2.2 / §4.4-4.5).

The paper's basic setup broadcasts to all providers and generates with one
LLM, but §2.2 explicitly describes the richer flow:

  * "instead of blindly broadcasting to everyone, a selective process can
    be added to only query the most relevant data providers according to
    the global knowledge of query-provider compatibility"
    -> ProviderSelector: per-provider corpus centroids (coarse, privacy-
       preserving sketches shared at enrollment) + top-p routing.
  * "before sending the query to a data provider, the query can be
    pre-processed (rewriting, expansion, etc.) in a personalized fashion"
    -> QueryRewriter: per-provider token expansion from a provider-supplied
       synonym/expansion map (filtered, so no raw corpus leaves the site).
  * "a routing model can orchestrate the answer inference by sending the
    augmented query to the most relevant LLMs, and produce the final
    answer by aggregating the responses from them" (§4.4 "internet of
    agents") -> AnswerFusion: score-weighted answer voting across
    multiple generator endpoints.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.provider import DataProvider
from repro.data.tokenizer import HashTokenizer


class ProviderSelector:
    """Query-provider compatibility routing from enrollment-time corpus
    centroids (a k-dim sketch per provider — far coarser than any chunk)."""

    def __init__(self, providers: Sequence[DataProvider], embed_fn: Callable, n_centroids: int = 4):
        self.embed_fn = embed_fn
        self.centroids: dict[int, np.ndarray] = {}
        for p in providers:
            assert p.embeddings is not None, "build_index first"
            embs = p.embeddings
            # k-means-lite: seed with strided picks, one refinement pass
            idx = np.linspace(0, len(embs) - 1, n_centroids).astype(int)
            cents = embs[idx].copy()
            assign = np.argmax(embs @ cents.T, axis=1)
            for c in range(n_centroids):
                members = embs[assign == c]
                if len(members):
                    cents[c] = members.mean(0)
            cents /= np.maximum(np.linalg.norm(cents, axis=1, keepdims=True), 1e-9)
            self.centroids[p.provider_id] = cents

    def select(self, query_tokens: np.ndarray, providers: Sequence[DataProvider], top_p: int) -> list[DataProvider]:
        q = np.asarray(self.embed_fn(query_tokens[None, :]))[0]
        scored = []
        for p in providers:
            c = self.centroids[p.provider_id]
            scored.append((float((c @ q).max()), p))
        scored.sort(key=lambda t: -t[0])
        return [p for _, p in scored[: max(top_p, 1)]]


class QueryRewriter:
    """Per-provider query expansion: each provider publishes a (filtered)
    token-expansion map at enrollment; the orchestrator expands the query
    with provider-specific related tokens before dispatch."""

    def __init__(self, expansion_maps: dict[int, dict[int, list[int]]], max_extra: int = 4):
        self.maps = expansion_maps
        self.max_extra = max_extra

    def rewrite(self, query_tokens: np.ndarray, provider_id: int) -> np.ndarray:
        m = self.maps.get(provider_id, {})
        extra: list[int] = []
        for t in query_tokens:
            extra.extend(m.get(int(t), []))
            if len(extra) >= self.max_extra:
                break
        if not extra:
            return query_tokens
        out = np.concatenate([query_tokens, np.asarray(extra[: self.max_extra], np.int32)])
        return out


@dataclasses.dataclass
class GeneratorEndpoint:
    name: str
    generate: Callable  # (prompt_tokens (1,S)) -> (1,T) answer tokens
    domains: tuple = ()  # corpus names this expert specializes in


class AnswerFusion:
    """Multi-LLM answer inference (paper §4.4): route the augmented query to
    the most relevant expert generators and fuse their answers by
    context-affinity-weighted voting."""

    def __init__(self, endpoints: Sequence[GeneratorEndpoint], top_m: int = 2):
        self.endpoints = list(endpoints)
        self.top_m = top_m

    def route(self, context: dict) -> list[GeneratorEndpoint]:
        """Rank endpoints by how much of the context window comes from their
        specialty corpora (provider ids double as corpus tags here)."""
        provs = [int(x) for x in context.get("providers", [])]
        scored = []
        for e in self.endpoints:
            affinity = sum(provs.count(d) for d in e.domains) if e.domains else 0.5
            scored.append((affinity, e))
        scored.sort(key=lambda t: -t[0])
        return [e for _, e in scored[: self.top_m]]

    def answer(self, prompt_tokens: np.ndarray, context: dict) -> dict:
        chosen = self.route(context)
        votes: dict[int, float] = {}
        per_model = {}
        for rank, e in enumerate(chosen):
            ans = np.asarray(e.generate(prompt_tokens))[0]
            tok = int(ans[0])
            votes[tok] = votes.get(tok, 0.0) + 1.0 / (rank + 1)
            per_model[e.name] = ans
        best = max(votes, key=votes.get)
        return {"answer_token": best, "votes": votes, "per_model": per_model,
                "models": [e.name for e in chosen]}


def build_expansion_maps(
    providers: Sequence[DataProvider], tokenizer: HashTokenizer, max_pairs: int = 64
) -> dict[int, dict[int, list[int]]]:
    """Derive per-provider co-occurrence expansions from each provider's own
    chunks (computed provider-side; only the token-id map is shared)."""
    maps: dict[int, dict[int, list[int]]] = {}
    for p in providers:
        co: dict[int, list[int]] = {}
        for row in p.chunk_tokens[: max_pairs]:
            toks = [int(t) for t in row if t > 7]
            for a, b in zip(toks, toks[1:]):
                co.setdefault(a, [])
                if b not in co[a] and len(co[a]) < 3:
                    co[a].append(b)
        maps[p.provider_id] = co
    return maps
