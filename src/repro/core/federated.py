"""Federated training of the embedding/re-ranking models (paper §2.2)
with cryptographic secure aggregation.

* ``fedavg``: weighted model averaging.  With one local step and equal
  weights this is exactly a data-parallel gradient mean — which is why the
  multi-pod mesh's `pod` axis (pure DP) implements the paper's federation
  topology in-device (DESIGN.md §3); this module is the *host-level*
  counterpart for genuinely separate sites.

* ``SecureAggregator``: Bonawitz-style pairwise-mask secure aggregation in
  exact fixed-point modular arithmetic (masks derived from attested DH
  pair keys; the server sees only masked updates, masks cancel in the
  sum).  Cancellation is exact (integer mod 2^62), so FL results are
  bit-identical with/without masking (tests/test_federated.py).

* ``federated_train_embedder``: FedAvg rounds of InfoNCE on each
  provider's local (query, doc) pairs -> a shared Contriever-style
  F_emb, optionally personalized (local head fine-tune) per provider.
"""
from __future__ import annotations

import hashlib
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.confidential import Enclave, hkdf

_Q = 1 << 62  # modulus
_SCALE = 1 << 24  # fixed-point scale


def fedavg(client_params: Sequence, weights: Sequence[float] | None = None):
    w = np.asarray(weights if weights is not None else [1.0] * len(client_params), np.float64)
    w = w / w.sum()
    return jax.tree.map(
        lambda *xs: sum(wi * x for wi, x in zip(w, xs)).astype(xs[0].dtype),
        *client_params,
    )


# ------------------------------------------------------------------ #
# secure aggregation
# ------------------------------------------------------------------ #


def _encode(x: np.ndarray) -> np.ndarray:
    fp = np.round(np.asarray(x, np.float64) * _SCALE).astype(np.int64)
    return np.mod(fp, _Q).astype(np.uint64)


def _decode(x: np.ndarray, n_clients: int) -> np.ndarray:
    v = x.astype(np.int64)
    v = np.where(v > _Q // 2, v - _Q, v)  # centered representative
    return (v / _SCALE).astype(np.float64)


def _pair_mask(key: bytes, round_id: int, size: int) -> np.ndarray:
    seed = hkdf(key, b"mask-round:%d" % round_id, 32)
    rng = np.random.default_rng(np.frombuffer(seed, np.uint64))
    return rng.integers(0, _Q, size=size, dtype=np.uint64)


class SecureAggregator:
    """Pairwise-cancelling-mask aggregation over attested DH pair keys."""

    def __init__(self, enclaves: Sequence[Enclave]):
        self.enclaves = list(enclaves)
        n = len(enclaves)
        self.pair_keys = {}
        for i in range(n):
            for j in range(i + 1, n):
                k = enclaves[i].shared_key(enclaves[j].dh_public, b"secure-agg")
                self.pair_keys[(i, j)] = k

    def mask_update(self, client: int, flat: np.ndarray, round_id: int) -> np.ndarray:
        """Client-side: fixed-point encode + add pairwise masks."""
        enc = _encode(flat)
        for (i, j), key in self.pair_keys.items():
            if client not in (i, j):
                continue
            m = _pair_mask(key, round_id, flat.size)
            if client == i:
                enc = np.mod(enc + m, _Q).astype(np.uint64)
            else:
                enc = np.mod(enc - m, _Q).astype(np.uint64)
        return enc

    def aggregate(self, masked: Sequence[np.ndarray]) -> np.ndarray:
        """Server-side: modular sum — masks cancel exactly."""
        total = np.zeros_like(masked[0])
        for m in masked:
            total = np.mod(total + m, _Q).astype(np.uint64)
        return _decode(total, len(masked))


def secure_fedavg(
    client_updates: Sequence,  # pytrees of np/jnp arrays (deltas or grads)
    aggregator: SecureAggregator,
    round_id: int,
) -> object:
    """Secure-aggregated MEAN of client update pytrees."""
    n = len(client_updates)
    flats = []
    treedef = None
    for c, upd in enumerate(client_updates):
        leaves, treedef = jax.tree.flatten(upd)
        sizes = [x.size for x in leaves]
        flat = np.concatenate([np.asarray(x, np.float64).ravel() for x in leaves])
        flats.append(aggregator.mask_update(c, flat, round_id))
    total = aggregator.aggregate(flats) / n
    out_leaves = []
    off = 0
    leaves0 = jax.tree.leaves(client_updates[0])
    for x in leaves0:
        out_leaves.append(total[off : off + x.size].reshape(x.shape).astype(np.asarray(x).dtype))
        off += x.size
    return jax.tree.unflatten(treedef, out_leaves)


# ------------------------------------------------------------------ #
# federated embedder training (FedAvg over providers)
# ------------------------------------------------------------------ #


def federated_train_embedder(
    init_params,
    client_batch_fns: Sequence[Callable[[int], dict]],  # round -> local batch
    grad_fn: Callable,  # (params, batch) -> (loss, grads)
    apply_update: Callable,  # (params, mean_grads) -> params
    n_rounds: int,
    secure: bool = True,
    local_steps: int = 1,
):
    """Returns (global params, per-round history).  ``secure=True`` routes
    the update exchange through SecureAggregator."""
    params = init_params
    enclaves = [Enclave(f"fl-client-{i}") for i in range(len(client_batch_fns))]
    agg = SecureAggregator(enclaves) if secure else None
    history = []
    for r in range(n_rounds):
        updates, losses = [], []
        for c, batch_fn in enumerate(client_batch_fns):
            local = params
            for _ in range(local_steps):
                loss, grads = grad_fn(local, batch_fn(r))
                local = apply_update(local, grads)
            delta = jax.tree.map(lambda a, b: np.asarray(a) - np.asarray(b), local, params)
            updates.append(delta)
            losses.append(float(loss))
        if secure:
            mean_delta = secure_fedavg(updates, agg, r)
        else:
            mean_delta = jax.tree.map(
                lambda *xs: sum(np.asarray(x, np.float64) for x in xs) / len(xs), *updates
            )
        params = jax.tree.map(
            lambda p, d: (np.asarray(p, np.float64) + d).astype(np.asarray(p).dtype),
            params,
            mean_delta,
        )
        history.append({"round": r, "mean_loss": float(np.mean(losses))})
    return params, history
