"""Confidential Computing simulation (paper §2.3.3) — stdlib-crypto only.

Models the trust primitives of a confidential VM / TEE deployment:

  * **Measurement**: SHA-256 over the enclave's code identity.
  * **Attestation**: an HMAC "quote" over (measurement, nonce, pubkey) by a
    simulated hardware root key; verifiers check the quote against an
    expected-measurement policy before releasing any data (the paper's
    "only authorized codes are running").
  * **Session keys**: finite-field Diffie-Hellman (RFC 3526 group 14)
    bound into the attestation quote, then HKDF-SHA256 to directional keys.
  * **AEAD channel**: encrypt-then-MAC (HMAC-SHA256 counter-mode keystream
    + HMAC tag over aad|nonce|ct) with per-message sequence numbers for
    replay protection — the mTLS stand-in for provider<->orchestrator
    links (paper §2.3.1).

This is a *simulation of the trust topology*, not a production cipher
suite; TPU devices sit inside the enclave boundary (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import hashlib
import hmac
import secrets

import numpy as np

# RFC 3526 MODP group 14 (2048-bit)
_P_HEX = (
    "FFFFFFFFFFFFFFFFC90FDAA22168C234C4C6628B80DC1CD129024E088A67CC74"
    "020BBEA63B139B22514A08798E3404DDEF9519B3CD3A431B302B0A6DF25F1437"
    "4FE1356D6D51C245E485B576625E7EC6F44C42E9A637ED6B0BFF5CB6F406B7ED"
    "EE386BFB5A899FA5AE9F24117C4B1FE649286651ECE45B3DC2007CB8A163BF05"
    "98DA48361C55D39A69163FA8FD24CF5F83655D23DCA3AD961C62F356208552BB"
    "9ED529077096966D670C354E4ABC9804F1746C08CA18217C32905E462E36CE3B"
    "E39E772C180E86039B2783A2EC07A28FB5C55DF06F4C52C9DE2BCBF695581718"
    "3995497CEA956AE515D2261898FA051015728E5A8AACAA68FFFFFFFFFFFFFFFF"
)
DH_P = int(_P_HEX, 16)
DH_G = 2

# simulated hardware root of trust (burned-in key, known to the "vendor")
_HW_ROOT_KEY = bytes.fromhex(
    "8f4a1e2b3c4d5e6f708192a3b4c5d6e7f8091a2b3c4d5e6f708192a3b4c5d6e7"
)


def measure(code_identity: str) -> bytes:
    return hashlib.sha256(code_identity.encode()).digest()


def hkdf(key_material: bytes, info: bytes, length: int = 32, salt: bytes = b"") -> bytes:
    prk = hmac.new(salt or b"\x00" * 32, key_material, hashlib.sha256).digest()
    out, t, i = b"", b"", 1
    while len(out) < length:
        t = hmac.new(prk, t + info + bytes([i]), hashlib.sha256).digest()
        out += t
        i += 1
    return out[:length]


def _keystream(key: bytes, nonce: bytes, n: int) -> bytes:
    # hmac.digest is the C one-shot path — same bytes as
    # hmac.new(...).digest(), ~5x faster on the many-block payloads the
    # batched retrieval path seals
    blocks = [
        hmac.digest(key, nonce + ctr.to_bytes(8, "little"), "sha256")
        for ctr in range((n + 31) // 32)
    ]
    return b"".join(blocks)[:n]


def _xor(data: bytes, ks: bytes) -> bytes:
    """Vectorized XOR — the seal/open hot path for batched (B, m, S)
    retrieval payloads, where a per-byte python loop would dominate."""
    return np.bitwise_xor(
        np.frombuffer(data, np.uint8), np.frombuffer(ks, np.uint8)
    ).tobytes()


def aead_seal(key: bytes, nonce: bytes, plaintext: bytes, aad: bytes = b"") -> bytes:
    enc_key = hkdf(key, b"enc")
    mac_key = hkdf(key, b"mac")
    ct = _xor(plaintext, _keystream(enc_key, nonce, len(plaintext)))
    tag = hmac.new(mac_key, aad + nonce + ct, hashlib.sha256).digest()
    return ct + tag


def aead_open(key: bytes, nonce: bytes, sealed: bytes, aad: bytes = b"") -> bytes:
    ct, tag = sealed[:-32], sealed[-32:]
    mac_key = hkdf(key, b"mac")
    expect = hmac.new(mac_key, aad + nonce + ct, hashlib.sha256).digest()
    if not hmac.compare_digest(tag, expect):
        raise IntegrityError("AEAD tag mismatch")
    enc_key = hkdf(key, b"enc")
    return _xor(ct, _keystream(enc_key, nonce, len(ct)))


class IntegrityError(Exception):
    pass


class AttestationError(Exception):
    pass


@dataclasses.dataclass(frozen=True)
class AttestationReport:
    measurement: bytes
    nonce: bytes
    dh_public: int
    quote: bytes  # HMAC by the hardware root key

    def payload(self) -> bytes:
        return self.measurement + self.nonce + self.dh_public.to_bytes(256, "big")


class Enclave:
    """A party running inside a (simulated) TEE."""

    def __init__(self, code_identity: str):
        self.code_identity = code_identity
        self.measurement = measure(code_identity)
        self._dh_secret = secrets.randbelow(DH_P - 2) + 2
        self.dh_public = pow(DH_G, self._dh_secret, DH_P)

    def attest(self, nonce: bytes) -> AttestationReport:
        body = self.measurement + nonce + self.dh_public.to_bytes(256, "big")
        quote = hmac.new(_HW_ROOT_KEY, body, hashlib.sha256).digest()
        return AttestationReport(self.measurement, nonce, self.dh_public, quote)

    def shared_key(self, peer_public: int, context: bytes) -> bytes:
        secret = pow(peer_public, self._dh_secret, DH_P)
        return hkdf(secret.to_bytes(256, "big"), context)


def verify_report(report: AttestationReport, expected_measurement: bytes, nonce: bytes):
    if report.nonce != nonce:
        raise AttestationError("stale attestation nonce (replay?)")
    if report.measurement != expected_measurement:
        raise AttestationError("measurement mismatch: unauthorized code")
    expect = hmac.new(_HW_ROOT_KEY, report.payload(), hashlib.sha256).digest()
    if not hmac.compare_digest(report.quote, expect):
        raise AttestationError("invalid quote signature")


class SecureChannel:
    """Attested, AEAD-protected, replay-safe duplex channel (mTLS stand-in).

    Built by ``establish()``: both sides exchange nonces + attestation
    reports, verify each other's measurement against policy (mutual auth,
    like the paper's two-way X.509 verification), then derive directional
    keys from the DH secret."""

    def __init__(self, key_send: bytes, key_recv: bytes):
        self._ks, self._kr = key_send, key_recv
        self._seq_send = 0
        self._seq_recv = 0

    @staticmethod
    def establish(me: Enclave, peer: Enclave, expected_peer_measurement: bytes):
        nonce = secrets.token_bytes(16)
        report = peer.attest(nonce)
        verify_report(report, expected_peer_measurement, nonce)
        secret = me.shared_key(report.dh_public, b"cfedrag-session")
        low, high = sorted([me.measurement, peer.measurement])
        k1 = hkdf(secret, b"dir:" + low)
        k2 = hkdf(secret, b"dir:" + high)
        if me.measurement == low:
            return SecureChannel(k1, k2)
        return SecureChannel(k2, k1)

    def seal(self, payload: bytes, aad: bytes = b"") -> tuple[bytes, bytes]:
        nonce = self._seq_send.to_bytes(12, "little")
        self._seq_send += 1
        return nonce, aead_seal(self._ks, nonce, payload, aad)

    def open(self, nonce: bytes, sealed: bytes, aad: bytes = b"") -> bytes:
        if int.from_bytes(nonce, "little") < self._seq_recv:
            raise IntegrityError("replayed message")
        self._seq_recv = int.from_bytes(nonce, "little") + 1
        return aead_open(self._kr, nonce, sealed, aad)
