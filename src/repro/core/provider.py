"""Data provider (paper §2.3.4): standardized retrieval API behind an
attested channel.

Each provider owns its corpus shard, vectorizes it once with its embedding
model of choice (off-the-shelf bag embedder or an FL-trained dual
encoder), and answers ``retrieve`` requests with its local top-m — raw
chunks never leave except as filtered, AEAD-sealed responses to an
attested orchestrator.  Providers never talk to each other and never
receive inbound connections except via the orchestrator channel (paper
§4.1).

The ``fail`` flag is the blunt always-down switch (kept for the quorum
tests and the ``--kill-provider`` CLI); the full fault taxonomy —
seeded connection failures, timeouts, jitter, payload corruption,
replayed nonces, poisoned scores — lives in
``core.resilience.FaultyProvider``, which wraps a provider without it
noticing.
"""
from __future__ import annotations

import io
import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.confidential import Enclave, SecureChannel
from repro.core.filters import Filter, apply_filters
from repro.data.corpus import Chunk
from repro.data.tokenizer import HashTokenizer
from repro.kernels.retrieval_topk.ops import retrieval_topk


def pack(payload: dict) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **payload)
    return buf.getvalue()


def unpack(raw: bytes) -> dict:
    with np.load(io.BytesIO(raw), allow_pickle=False) as z:
        return {k: z[k] for k in z.files}


class DataProvider:
    def __init__(
        self,
        provider_id: int,
        chunks: Sequence[Chunk],
        embed_fn: Callable,  # (tokens (N,S) int32) -> (N,D) f32 unit-norm
        tokenizer: HashTokenizer,
        chunk_max_len: int = 40,
        filters: list[Filter] | None = None,
        use_pallas: bool = False,
        fail: bool = False,
        delay_s: float = 0.0,
    ):
        self.provider_id = provider_id
        self.chunks = list(chunks)
        self.embed_fn = embed_fn
        self.tok = tokenizer
        self.filters = filters or []
        self.use_pallas = use_pallas
        self.fail = fail
        self.delay_s = delay_s
        self.enclave = Enclave(f"cfedrag-provider-v1:{provider_id}")
        self.chunk_tokens = np.stack(
            [tokenizer.encode(c.text, max_len=chunk_max_len) for c in self.chunks]
        )
        self._chunk_id_arr = np.asarray([c.chunk_id for c in self.chunks], np.int64)
        self.embeddings: np.ndarray | None = None
        self.channel: SecureChannel | None = None
        self.n_requests = 0  # sealed requests handled (observability/tests)
        # serializes sealed round-trips: the orchestrator's concurrent
        # fan-out must never interleave two rounds' channel sequence
        # numbers on the same provider (e.g. an abandoned straggler
        # finishing while the next collect is already in flight)
        self.rpc_lock = threading.Lock()

    # ---- lifecycle ----
    def build_index(self, batch: int = 512):
        outs = []
        for i in range(0, len(self.chunk_tokens), batch):
            outs.append(np.asarray(self.embed_fn(self.chunk_tokens[i : i + batch])))
        self.embeddings = np.concatenate(outs, 0)

    def list_products(self) -> dict:
        corpora = sorted({c.corpus for c in self.chunks})
        return {
            "provider": self.provider_id,
            "products": corpora,
            "n_chunks": len(self.chunks),
        }

    # ---- retrieval API (sealed request/response) ----
    def handle_request(self, nonce: bytes, sealed: bytes) -> tuple[bytes, bytes]:
        """Sealed {query_tokens, m} -> sealed {scores, chunk_ids, chunk_tokens}.

        ``query_tokens`` may be a single (S,) query or a (B, S) batch; the
        response arrays carry the matching leading shape."""
        self.n_requests += 1
        if self.fail:
            raise ConnectionError(f"provider {self.provider_id} down")
        if self.delay_s:
            time.sleep(self.delay_s)
        assert self.channel is not None, "no established channel"
        req = unpack(self.channel.open(nonce, sealed))
        out = self.retrieve(req["query_tokens"], int(req["m"]))
        return self.channel.seal(pack(out))

    def retrieve(self, query_tokens: np.ndarray, m: int) -> dict:
        """Local top-m.  query_tokens: (S,) -> {scores (m,), chunk_ids (m,),
        chunk_tokens (m, S_c)}; or batched (B, S) -> (B, m, ...) — the whole
        batch is embedded and scored in one kernel call."""
        assert self.embeddings is not None, "index not built"
        q = np.asarray(query_tokens)
        single = q.ndim == 1
        if single:
            q = q[None, :]
        q_emb = np.asarray(self.embed_fn(q))  # (B, D)
        m_eff = min(m, len(self.chunks))
        scores, idx = retrieval_topk(
            q_emb, self.embeddings, m_eff, use_pallas=self.use_pallas
        )
        scores, idx = np.asarray(scores), np.asarray(idx)  # (B, m)
        if single:
            scores, idx = scores[0], idx[0]
        payload = {
            "provider": np.int32(self.provider_id),
            "scores": scores,
            "chunk_ids": self._chunk_id_arr[idx],
            "chunk_tokens": self.chunk_tokens[idx],
        }
        return apply_filters(self.filters, payload)
