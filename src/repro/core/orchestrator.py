"""Orchestrator (paper §2.3.2, Algorithm 1) — runs inside the CC enclave.

Flow per query:
  1. select k_n <= k providers (all by default; compatibility selector opt-in)
  2. broadcast the sealed query over attested channels
  3. collect local top-m responses under a deadline/quorum (straggler
     mitigation is *native* to Algorithm 1's k_n <= k semantics)
  4. aggregate inside the enclave:
       embedding_rank  merge by provider-reported scores
       rerank          cross-encoder F_aggr over all candidates (paper's
                       bge-reranker-base role), keep global top-n
  5. build the augmented prompt and run F_inf (generation LLM) in-enclave

Every step also runs batched (``answer_batch``): one sealed request per
provider carries the whole (B, S) query block, aggregation re-ranks the
(B, C, S) candidate block in one pass, and generation goes through the
generator's ``generate_batch`` hook when present — identical results to
B sequential ``answer`` calls at a fraction of the per-query overhead.

Dispatch is **transport-aware**: when providers have real round-trip
latency (``delay_s``, standing in for remote RTT) or a ``deadline_s``
SLO is set, step 2-3 fans the sealed request out to all selected
providers at once (one thread-pool future per provider), so collect
wall-clock is the *max* of provider round-trips instead of the sum,
``deadline_s`` is a true wall-clock cutoff (whatever arrived by then is
aggregated, stragglers are abandoned), and the quorum check runs against
the arrivals at the deadline.  For colocated in-process providers with
sub-millisecond round-trips the sequential loop is kept — thread handoff
would cost more than the overlap buys.  Responses are re-ordered by
provider position before aggregation, so results are bit-identical
between the two dispatchers whenever every provider responds in time;
``concurrent_collect=True/False`` forces either path (False is the
determinism baseline).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.confidential import Enclave, SecureChannel
from repro.core.provider import DataProvider, pack, unpack
from repro.data.tokenizer import ANS, BOS, CTX, EOS, PAD, QRY, SEP, HashTokenizer


class Orchestrator:
    def __init__(
        self,
        providers: Sequence[DataProvider],
        tokenizer: HashTokenizer,
        *,
        aggregation: str = "rerank",  # embedding_rank | rerank
        reranker: Callable | None = None,  # (query_tokens, cand_tokens (C,S)) -> (C,) scores
        generator: Callable | None = None,  # (prompt_tokens (1,S)) -> (1,T) answer tokens
        m_local: int = 8,
        n_global: int = 8,
        quorum: int = 1,
        deadline_s: float | None = None,
        selector=None,  # core.advanced.ProviderSelector (paper §2.2 routing)
        selector_top_p: int = 0,  # 0 -> broadcast to all (paper's basic setup)
        rewriter=None,  # core.advanced.QueryRewriter (per-provider expansion)
        concurrent_collect: bool | None = None,  # None -> auto (transport-aware)
        query_reserve: int = 32,  # prompt tail allowance (see build_prompt)
    ):
        self.providers = list(providers)
        self.tok = tokenizer
        self.aggregation = aggregation
        self.reranker = reranker
        self.generator = generator
        self.m_local, self.n_global = m_local, n_global
        self.quorum = quorum
        self.deadline_s = deadline_s
        self.selector = selector
        self.selector_top_p = selector_top_p
        self.rewriter = rewriter
        self.concurrent_collect = concurrent_collect
        self.query_reserve = query_reserve
        self.enclave = Enclave("cfedrag-orchestrator-v1")
        self._establish_channels()

    def _establish_channels(self):
        """Mutual attestation with every provider (paper §2.3.1 mTLS): each
        side verifies the other's measurement before deriving session keys
        (directional keys agree because both are derived from the same
        static-DH secret with measurement-ordered labels)."""
        for p in self.providers:
            ch = SecureChannel.establish(self.enclave, p.enclave, p.enclave.measurement)
            p.channel = SecureChannel.establish(p.enclave, self.enclave, self.enclave.measurement)
            setattr(p, "_orch_channel", ch)

    def select_providers(self, query_text: str) -> list[DataProvider]:
        if self.selector is not None and self.selector_top_p:
            q_tokens = self.tok.encode(query_text, max_len=24)
            return self.selector.select(q_tokens, self.providers, self.selector_top_p)
        return self.providers  # broadcast policy (paper's basic setup)

    # ------------------------------------------------------------------ #
    def _roundtrip(self, p, tokens_for) -> dict:
        """One sealed request/response exchange with provider ``p``.  The
        per-provider lock serializes overlapping rounds (an abandoned
        straggler from a previous collect must not interleave its channel
        sequence numbers with the current round)."""
        with p.rpc_lock:
            ch = getattr(p, "_orch_channel")
            nonce, sealed = ch.seal(
                pack({"query_tokens": tokens_for(p), "m": np.int64(self.m_local)})
            )
            r_nonce, r_sealed = p.handle_request(nonce, sealed)
            return unpack(ch.open(r_nonce, r_sealed))

    def _quorum_check(self, responses: list[dict]) -> list[dict]:
        if len(responses) < self.quorum:
            raise RuntimeError(
                f"quorum not met: {len(responses)}/{self.quorum} providers answered"
            )
        return responses

    def _use_concurrent(self, providers) -> bool:
        """Transport-aware dispatch policy: fan out when overlap can pay
        (providers with real round-trip latency) or when wall-clock
        deadline semantics are requested; else the sequential loop wins
        (in-process round-trips are GIL-bound, so threads only add
        handoff cost).  ``concurrent_collect`` forces either path."""
        if len(providers) <= 1:
            return False
        if self.concurrent_collect is not None:
            return self.concurrent_collect
        return self.deadline_s is not None or any(
            getattr(p, "delay_s", 0.0) for p in providers
        )

    def _collect(self, providers, tokens_for) -> list[dict]:
        """Shared steps 2-3 dispatch: sealed round-trip per provider under
        the deadline, straggler tolerance, quorum check.
        ``tokens_for(provider)`` builds the query token payload.

        The ``deadline_s`` clock is anchored HERE, before any dispatch
        work (payload building, thread spawning), so the SLO bounds the
        whole collect step — not just the wait after setup."""
        t0 = time.monotonic()
        if self._use_concurrent(providers):
            return self._collect_concurrent(providers, tokens_for, t0)
        return self._collect_sequential(providers, tokens_for, t0)

    def _collect_sequential(self, providers, tokens_for, t0: float) -> list[dict]:
        """Sequential loop — the in-process fast path and the determinism
        baseline (``concurrent_collect=False``): latency is the SUM of
        provider round-trips and the deadline only fires between calls."""
        responses = []
        for p in providers:
            if self.deadline_s is not None and time.monotonic() - t0 > self.deadline_s:
                break  # deadline: proceed with what we have (k_n <= k)
            try:
                responses.append(self._roundtrip(p, tokens_for))
            except (ConnectionError, TimeoutError):
                continue  # straggler/failed provider: tolerated by quorum
        return self._quorum_check(responses)

    def _collect_concurrent(self, providers, tokens_for, t0: float) -> list[dict]:
        """Concurrent fan-out: every provider round-trip runs in its own
        future, so collect wall-clock tracks the slowest *responding*
        provider (max, not sum).  ``deadline_s`` is a hard wall-clock
        cutoff: whatever completed by then is returned (quorum permitting)
        and stragglers are abandoned mid-flight — Algorithm 1's k_n <= k
        straggler tolerance with real overlap.  Completed responses are
        re-ordered by provider position so aggregation stays bit-identical
        to the sequential path when everyone answers in time.

        Workers are daemon threads on purpose: an abandoned straggler
        (a hung provider past the deadline) must never block interpreter
        exit — the deadline SLO bounds process lifetime too."""
        results: dict[int, dict] = {}
        unexpected: list[BaseException] = []
        n_finished = [0]
        cond = threading.Condition()

        def worker(i, p):
            resp = None
            try:
                resp = self._roundtrip(p, tokens_for)
            except (ConnectionError, TimeoutError):
                pass  # failed provider: tolerated by quorum
            except BaseException as e:  # real bugs must surface, not vanish
                with cond:
                    unexpected.append(e)
                    n_finished[0] += 1
                    cond.notify_all()
                return
            with cond:
                if resp is not None:
                    results[i] = resp
                n_finished[0] += 1
                cond.notify_all()

        for i, p in enumerate(providers):
            threading.Thread(target=worker, args=(i, p), daemon=True).start()
        # the SLO clock started at _collect entry (``t0``), so only the
        # REMAINING budget is spent waiting — spawning one thread per
        # provider must not extend the effective deadline.  The predicate
        # also wakes on an unexpected worker exception: with no deadline
        # and a hung straggler, waiting for n_finished alone would park
        # the raise below forever.
        timeout = None
        if self.deadline_s is not None:
            timeout = max(0.0, self.deadline_s - (time.monotonic() - t0))
        with cond:
            cond.wait_for(
                lambda: bool(unexpected) or n_finished[0] >= len(providers),
                timeout=timeout,
            )
            if unexpected:
                raise unexpected[0]
            responses = [results[i] for i in sorted(results)]
        return self._quorum_check(responses)

    def collect_contexts(self, query_text: str) -> list[dict]:
        """Steps 1-3: dispatch + quorum collection."""
        base_tokens = self.tok.encode(query_text, max_len=24)

        def tokens_for(p):
            if self.rewriter is not None:  # personalized expansion (§2.2)
                return self.rewriter.rewrite(base_tokens, p.provider_id)
            return base_tokens

        return self._collect(self.select_providers(query_text), tokens_for)

    def collect_contexts_batch(self, queries: Sequence[str]) -> list[dict]:
        """Steps 1-3 for a query batch: ONE sealed request per provider
        carries all (B, S) query tokens; each response holds (B, m)
        scores/ids and (B, m, S_c) chunk tokens.  Sealing/serialization
        round-trips drop from B*P to P and every provider embeds the whole
        batch in one kernel call.  Broadcast-only: selector routing is
        per-query, so routed setups must use the sequential path (as
        ``answer_batch`` does automatically)."""
        if self.selector is not None and self.selector_top_p:
            raise ValueError(
                "collect_contexts_batch broadcasts to all providers; "
                "selector routing requires the per-query collect_contexts path"
            )
        base = [self.tok.encode(q, max_len=24) for q in queries]

        def tokens_for(p):
            rows = base
            if self.rewriter is not None:  # personalized expansion (§2.2)
                rows = [self.rewriter.rewrite(r, p.provider_id) for r in base]
            width = max(len(r) for r in rows)
            return np.stack(
                [np.pad(r, (0, width - len(r))) for r in rows]
            ).astype(np.int32)  # PAD tail; the embedder masks PAD

        return self._collect(self.providers, tokens_for)

    def aggregate(self, query_text: str, responses: list[dict]) -> dict:
        """Step 4: in-enclave context aggregation (global re-rank)."""
        all_tokens = np.concatenate([r["chunk_tokens"] for r in responses], 0)
        all_ids = np.concatenate([r["chunk_ids"] for r in responses], 0)
        all_scores = np.concatenate([r["scores"] for r in responses], 0)
        providers = np.concatenate(
            [np.full(len(r["chunk_ids"]), int(r["provider"])) for r in responses]
        )
        if self.aggregation == "rerank" and self.reranker is not None:
            q_tokens = self.tok.encode(query_text, max_len=24)
            rank_scores = np.asarray(self.reranker(q_tokens, all_tokens))
        else:
            rank_scores = all_scores
        n = min(self.n_global, len(all_ids))
        order = np.argsort(-rank_scores)[:n]
        return {
            "chunk_tokens": all_tokens[order],
            "chunk_ids": all_ids[order],
            "scores": rank_scores[order],
            "providers": providers[order],
            "n_candidates": len(all_ids),
        }

    def aggregate_batch(self, queries: Sequence[str], responses: list[dict]) -> list[dict]:
        """Step 4 over a batch: one re-rank pass over the (B, C, S)
        candidate block when the reranker supports batching, else per-row.
        Produces per-query context dicts identical to ``aggregate``."""
        all_tokens = np.concatenate([r["chunk_tokens"] for r in responses], 1)  # (B, C, S)
        all_ids = np.concatenate([r["chunk_ids"] for r in responses], 1)  # (B, C)
        all_scores = np.concatenate([r["scores"] for r in responses], 1)
        providers = np.concatenate(
            [
                np.full(r["chunk_ids"].shape, int(r["provider"]))
                for r in responses
            ],
            1,
        )
        if self.aggregation == "rerank" and self.reranker is not None:
            q_tok = np.stack([self.tok.encode(q, max_len=24) for q in queries])
            if getattr(self.reranker, "supports_batch", False):
                rank_scores = np.asarray(self.reranker(q_tok, all_tokens))
            else:
                rank_scores = np.stack(
                    [np.asarray(self.reranker(q_tok[b], all_tokens[b])) for b in range(len(queries))]
                )
        else:
            rank_scores = all_scores
        n = min(self.n_global, all_ids.shape[1])
        outs = []
        for b in range(len(queries)):
            order = np.argsort(-rank_scores[b])[:n]
            outs.append(
                {
                    "chunk_tokens": all_tokens[b][order],
                    "chunk_ids": all_ids[b][order],
                    "scores": rank_scores[b][order],
                    "providers": providers[b][order],
                    "n_candidates": all_ids.shape[1],
                }
            )
        return outs

    def build_prompt(self, query_text: str, context: dict, max_len: int = 512) -> np.ndarray:
        """[BOS] CTX chunk1 SEP chunk2 ... QRY query ANS — a STABLE
        shared-prefix layout.

        The context preamble comes first and is a pure function of the
        context and ``max_len``: the chunk budget reserves a FIXED query
        allowance (``query_reserve``, not the query's own length), so two
        queries served against the same aggregated context produce
        byte-identical prompts up to and including the ``QRY`` marker —
        exactly the prefix the paged engine's refcounted prefix cache
        shares block-for-block across micro-batch siblings and retries
        (``ServeConfig.prefix_cache``).  Truncation cuts from the TAIL:
        overflow drops whole lowest-ranked chunks first, and only the
        query itself is tail-truncated into whatever space remains (at
        least the reserve, so structural markers always survive).

        Overflow never breaks the grammar: dropping whole chunks keeps
        the ``BOS/CTX/QRY/query/ANS`` skeleton intact, where a blind
        ``ids[-max_len:]`` would slice off ``BOS``/``CTX`` and could
        bisect a chunk."""
        query = [int(t) for t in self.tok.encode(query_text, bos=False) if t not in (PAD, EOS)]
        n_markers = 4  # BOS, CTX, QRY, ANS
        # fixed reserve: chunk inclusion must not depend on the query, or
        # same-context siblings diverge before QRY and never share blocks
        reserve = min(self.query_reserve, max(0, (max_len - n_markers) // 2))
        chunk_budget = max_len - n_markers - reserve
        ids = [BOS, CTX]
        for row in context["chunk_tokens"]:
            chunk = [int(t) for t in row if t not in (PAD, BOS, EOS)]
            if len(chunk) + 1 > chunk_budget:  # +1: trailing SEP
                break  # ranked order: everything after is lower-scored
            ids += chunk
            ids.append(SEP)
            chunk_budget -= len(chunk) + 1
        ids.append(QRY)
        ids += query[: max(0, max_len - len(ids) - 1)]  # tail cut, ANS always fits
        ids.append(ANS)
        return np.asarray(ids, np.int32)[None, :]

    def _prompt_max_len(self) -> int:
        """Generator-advertised prompt window (``max_prompt_len`` on an
        engine adapter), so grammar-aware truncation in ``build_prompt``
        happens at the width the generator will actually consume."""
        return int(getattr(self.generator, "max_prompt_len", None) or 512)

    def answer(self, query_text: str) -> dict:
        responses = self.collect_contexts(query_text)
        context = self.aggregate(query_text, responses)
        out = {
            "context": context,
            "n_providers": len(responses),
        }
        if self.generator is not None:
            prompt = self.build_prompt(query_text, context, max_len=self._prompt_max_len())
            out["answer_tokens"] = np.asarray(self.generator(prompt))[0]
            out["prompt"] = prompt
        return out

    def answer_batch(self, queries: Sequence[str]) -> list[dict]:
        """Algorithm 1 over a query batch: one sealed round-trip per
        provider for the whole batch, batched aggregation, and (when the
        generator exposes ``generate_batch``) batched decoding.  Returns
        per-query result dicts identical to ``answer``."""
        queries = list(queries)
        if not queries:
            return []
        if self.selector is not None and self.selector_top_p:
            # per-query routing can hit different provider subsets; keep
            # Algorithm 1 semantics by falling back to the sequential path
            return [self.answer(q) for q in queries]
        responses = self.collect_contexts_batch(queries)
        contexts = self.aggregate_batch(queries, responses)
        outs = [
            {"context": ctx, "n_providers": len(responses)} for ctx in contexts
        ]
        if self.generator is not None:
            width = self._prompt_max_len()
            prompts = [self.build_prompt(q, ctx, max_len=width) for q, ctx in zip(queries, contexts)]
            gen_batch = getattr(self.generator, "generate_batch", None)
            if gen_batch is not None:
                answers = gen_batch(prompts)
            else:
                answers = [np.asarray(self.generator(p))[0] for p in prompts]
            for out, prompt, ans in zip(outs, prompts, answers):
                out["answer_tokens"] = np.asarray(ans).ravel()
                out["prompt"] = prompt
        return outs
