"""Orchestrator (paper §2.3.2, Algorithm 1) — runs inside the CC enclave.

Flow per query:
  1. select k_n <= k providers (all by default; compatibility selector opt-in)
  2. broadcast the sealed query over attested channels
  3. collect local top-m responses under a deadline/quorum (straggler
     mitigation is *native* to Algorithm 1's k_n <= k semantics)
  4. aggregate inside the enclave:
       embedding_rank  merge by provider-reported scores
       rerank          cross-encoder F_aggr over all candidates (paper's
                       bge-reranker-base role), keep global top-n
  5. build the augmented prompt and run F_inf (generation LLM) in-enclave

Every step also runs batched (``answer_batch``): one sealed request per
provider carries the whole (B, S) query block, aggregation re-ranks the
(B, C, S) candidate block in one pass, and generation goes through the
generator's ``generate_batch`` hook when present — identical results to
B sequential ``answer`` calls at a fraction of the per-query overhead.

Dispatch is **transport-aware**: when providers have real round-trip
latency (``delay_s``, standing in for remote RTT) or a ``deadline_s``
SLO is set, step 2-3 fans the sealed request out to all selected
providers at once (one thread-pool future per provider), so collect
wall-clock is the *max* of provider round-trips instead of the sum,
``deadline_s`` is a true wall-clock cutoff (whatever arrived by then is
aggregated, stragglers are abandoned), and the quorum check runs against
the arrivals at the deadline.  For colocated in-process providers with
sub-millisecond round-trips the sequential loop is kept — thread handoff
would cost more than the overlap buys.  Responses are re-ordered by
provider position before aggregation, so results are bit-identical
between the two dispatchers whenever every provider responds in time;
``concurrent_collect=True/False`` forces either path (False is the
determinism baseline).

Dispatch is also **resilient** (core/resilience.py): per-provider
retry/backoff (budget deducted from the live deadline), circuit breakers
that skip flapping providers, channel self-healing on ``IntegrityError``
(one re-attest + re-establish before a round counts as failed), an
opt-in aggregator-side poisoning gate (per-provider score calibration +
outlier quarantine), and a ``federation_stats()`` health ledger.  All of
it is overlay: with retries off / breaker off / gate off and no faults
firing, collect results are bit-identical to the plain path.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.confidential import Enclave, IntegrityError, SecureChannel
from repro.core.provider import DataProvider, pack, unpack
from repro.core.resilience import (
    BreakerPolicy,
    CircuitBreaker,
    ProviderHealth,
    QuorumNotMet,
    RetryPolicy,
    ScoreGate,
)
from repro.data.tokenizer import ANS, BOS, CTX, EOS, PAD, QRY, SEP, HashTokenizer

# the faults one provider may raise without failing the round: absorbed
# by quorum (Algorithm 1's k_n <= k), counted in the health ledger.  An
# IntegrityError (tampered/corrupted/replayed sealed payload) is a
# per-provider fault exactly like a dead link — it must never crash the
# whole round.
_TOLERATED_FAULTS = (ConnectionError, TimeoutError, IntegrityError)


class Orchestrator:
    def __init__(
        self,
        providers: Sequence[DataProvider],
        tokenizer: HashTokenizer,
        *,
        aggregation: str = "rerank",  # embedding_rank | rerank
        reranker: Callable | None = None,  # (query_tokens, cand_tokens (C,S)) -> (C,) scores
        generator: Callable | None = None,  # (prompt_tokens (1,S)) -> (1,T) answer tokens
        m_local: int = 8,
        n_global: int = 8,
        quorum: int = 1,
        deadline_s: float | None = None,
        selector=None,  # core.advanced.ProviderSelector (paper §2.2 routing)
        selector_top_p: int = 0,  # 0 -> broadcast to all (paper's basic setup)
        rewriter=None,  # core.advanced.QueryRewriter (per-provider expansion)
        concurrent_collect: bool | None = None,  # None -> auto (transport-aware)
        query_reserve: int = 32,  # prompt tail allowance (see build_prompt)
        retry: RetryPolicy | None = None,  # None -> single-shot (legacy path)
        breaker: BreakerPolicy | None = None,  # None -> no circuit breakers
        score_gate: ScoreGate | None = None,  # None -> raw provider scores
    ):
        self.providers = list(providers)
        self.tok = tokenizer
        self.aggregation = aggregation
        self.reranker = reranker
        self.generator = generator
        self.m_local, self.n_global = m_local, n_global
        self.quorum = quorum
        self.deadline_s = deadline_s
        self.selector = selector
        self.selector_top_p = selector_top_p
        self.rewriter = rewriter
        self.concurrent_collect = concurrent_collect
        self.query_reserve = query_reserve
        self.retry = retry
        self.breaker_policy = breaker
        self.score_gate = score_gate
        # per-provider health ledger (attempts/retries/faults/breaker/...)
        self._health: dict[int, ProviderHealth] = {
            int(p.provider_id): ProviderHealth(
                breaker=CircuitBreaker(breaker) if breaker is not None else None
            )
            for p in self.providers
        }
        self.enclave = Enclave("cfedrag-orchestrator-v1")
        self._establish_channels()

    def _establish_channels(self):
        """Mutual attestation with every provider (paper §2.3.1 mTLS): each
        side verifies the other's measurement before deriving session keys
        (directional keys agree because both are derived from the same
        static-DH secret with measurement-ordered labels)."""
        for p in self.providers:
            self._establish_channel(p)

    def _establish_channel(self, p):
        ch = SecureChannel.establish(self.enclave, p.enclave, p.enclave.measurement)
        p.channel = SecureChannel.establish(p.enclave, self.enclave, self.enclave.measurement)
        setattr(p, "_orch_channel", ch)

    def select_providers(self, query_text: str) -> list[DataProvider]:
        if self.selector is not None and self.selector_top_p:
            q_tokens = self.tok.encode(query_text, max_len=24)
            return self.selector.select(q_tokens, self.providers, self.selector_top_p)
        return self.providers  # broadcast policy (paper's basic setup)

    def query_routes(self, queries: Sequence[str]) -> list[list[DataProvider]] | None:
        """Per-query provider subsets in SELECTOR ORDER (score-descending
        — the order the sequential path collects and aggregates in, which
        the rank tie-break depends on).  ``None`` when the selector is
        off: broadcast to all."""
        if self.selector is None or not self.selector_top_p:
            return None
        return [
            self.selector.select(
                self.tok.encode(q, max_len=24), self.providers, self.selector_top_p
            )
            for q in queries
        ]

    # ------------------------------------------------------------------ #
    def _roundtrip(self, p, tokens_for) -> dict:
        """One sealed request/response exchange with provider ``p``.  The
        per-provider lock serializes overlapping rounds (an abandoned
        straggler from a previous collect must not interleave its channel
        sequence numbers with the current round)."""
        with p.rpc_lock:
            ch = getattr(p, "_orch_channel")
            nonce, sealed = ch.seal(
                pack({"query_tokens": tokens_for(p), "m": np.int64(self.m_local)})
            )
            r_nonce, r_sealed = p.handle_request(nonce, sealed)
            return unpack(ch.open(r_nonce, r_sealed))

    def _quorum_check(self, responses: list[dict]) -> list[dict]:
        if len(responses) < self.quorum:
            raise QuorumNotMet(len(responses), self.quorum)
        return responses

    def _health_for(self, p) -> ProviderHealth:
        pid = int(p.provider_id)
        h = self._health.get(pid)
        if h is None:  # provider added after construction
            h = self._health[pid] = ProviderHealth(
                breaker=CircuitBreaker(self.breaker_policy)
                if self.breaker_policy is not None
                else None
            )
        return h

    def _heal_channel(self, p, tokens_for) -> dict | None:
        """Channel self-heal: an ``IntegrityError`` (tampered payload,
        replayed nonce, sequence desync) may mean the session state is
        wedged rather than the provider hostile — re-attest and
        re-establish the provider's SecureChannel ONCE, then retry the
        exchange once, before the round counts as failed.  Re-
        establishment runs attestation from scratch, so a provider whose
        code identity changed still fails closed (AttestationError is
        not tolerated)."""
        h = self._health_for(p)
        h.rechannels += 1
        with p.rpc_lock:  # never re-key mid-roundtrip of another round
            self._establish_channel(p)
        try:
            h.attempts += 1
            return self._roundtrip(p, tokens_for)
        except _TOLERATED_FAULTS as e:
            h.record_fault(e)
            return None

    def _exchange(self, p, tokens_for, t0: float) -> dict | None:
        """One resilient provider exchange: breaker gate, bounded retries
        with exponential backoff (the backoff budget comes OUT of the
        remaining ``deadline_s``), channel self-heal on IntegrityError.
        Returns the response dict, or None when the provider failed the
        whole round (tolerated — quorum decides downstream).  With
        ``retry=None`` and ``breaker=None`` this is exactly one
        ``_roundtrip`` plus fault accounting — the legacy path."""
        h = self._health_for(p)
        br = h.breaker
        if br is not None and not br.allow():
            h.skips += 1
            return None
        attempts = self.retry.max_attempts if self.retry is not None else 1
        resp = None
        for attempt in range(attempts):
            if attempt:
                backoff = self.retry.backoff(attempt)
                if self.deadline_s is not None:
                    remaining = self.deadline_s - (time.monotonic() - t0)
                    if remaining <= backoff:
                        break  # SLO cannot afford another attempt
                h.retries += 1
                if backoff:
                    time.sleep(backoff)
            h.attempts += 1
            try:
                resp = self._roundtrip(p, tokens_for)
            except IntegrityError as e:
                h.record_fault(e)
                resp = self._heal_channel(p, tokens_for)
                if resp is not None:
                    break
            except _TOLERATED_FAULTS as e:
                h.record_fault(e)
            else:
                break
        if resp is None:
            if br is not None:
                br.record_failure()  # one failure per failed ROUND
            return None
        if br is not None:
            br.record_success()
        h.successes += 1
        return resp

    def federation_stats(self) -> dict:
        """Per-provider health ledger + federation totals: attempts,
        retries, breaker state/trips, faults by type, skip/quarantine
        counts — and, for fault-injection harness runs, the wrapper's
        injected-fault counters so a benchmark can reconcile every
        injected fault against an observed one."""
        per: dict[int, dict] = {}
        for p in self.providers:
            d = self._health_for(p).as_dict()
            injected = getattr(p, "faults", None)
            if isinstance(injected, dict):
                d["injected"] = dict(injected)
            per[int(p.provider_id)] = d
        totals = {
            k: sum(d[k] for d in per.values())
            for k in ("attempts", "successes", "retries", "skips", "rechannels",
                      "quarantined", "dropped_chunks")
        }
        totals["faults"] = {
            k: sum(d["faults"][k] for d in per.values())
            for k in ("conn", "timeout", "integrity")
        }
        totals["breakers_open"] = sum(
            1 for d in per.values() if d["breaker"] not in (None, "closed")
        )
        if self.score_gate is not None:
            totals["score_gate"] = self.score_gate.snapshot()
        return {"providers": per, "totals": totals}

    def _use_concurrent(self, providers) -> bool:
        """Transport-aware dispatch policy: fan out when overlap can pay
        (providers with real round-trip latency) or when wall-clock
        deadline semantics are requested; else the sequential loop wins
        (in-process round-trips are GIL-bound, so threads only add
        handoff cost).  ``concurrent_collect`` forces either path."""
        if len(providers) <= 1:
            return False
        if self.concurrent_collect is not None:
            return self.concurrent_collect
        return self.deadline_s is not None or any(
            getattr(p, "delay_s", 0.0) for p in providers
        )

    def _collect(self, providers, tokens_for) -> list[dict]:
        """Shared steps 2-3 dispatch: sealed round-trip per provider under
        the deadline, straggler tolerance, quorum check.
        ``tokens_for(provider)`` builds the query token payload.

        The ``deadline_s`` clock is anchored HERE, before any dispatch
        work (payload building, thread spawning), so the SLO bounds the
        whole collect step — not just the wait after setup."""
        t0 = time.monotonic()
        if self._use_concurrent(providers):
            return self._collect_concurrent(providers, tokens_for, t0)
        return self._collect_sequential(providers, tokens_for, t0)

    def _collect_sequential(self, providers, tokens_for, t0: float) -> list[dict]:
        """Sequential loop — the in-process fast path and the determinism
        baseline (``concurrent_collect=False``): latency is the SUM of
        provider round-trips and the deadline only fires between calls.
        Per-provider faults (dead link, timeout, tampered payload) are
        absorbed by ``_exchange`` and left to the quorum check."""
        responses = []
        for p in providers:
            if self.deadline_s is not None and time.monotonic() - t0 > self.deadline_s:
                break  # deadline: proceed with what we have (k_n <= k)
            resp = self._exchange(p, tokens_for, t0)
            if resp is not None:
                responses.append(resp)
        return self._quorum_check(responses)

    def _collect_concurrent(self, providers, tokens_for, t0: float) -> list[dict]:
        """Concurrent fan-out: every provider round-trip runs in its own
        future, so collect wall-clock tracks the slowest *responding*
        provider (max, not sum).  ``deadline_s`` is a hard wall-clock
        cutoff: whatever completed by then is returned (quorum permitting)
        and stragglers are abandoned mid-flight — Algorithm 1's k_n <= k
        straggler tolerance with real overlap.  Completed responses are
        re-ordered by provider position so aggregation stays bit-identical
        to the sequential path when everyone answers in time.

        Workers are daemon threads on purpose: an abandoned straggler
        (a hung provider past the deadline) must never block interpreter
        exit — the deadline SLO bounds process lifetime too."""
        results: dict[int, dict] = {}
        unexpected: list[BaseException] = []
        n_finished = [0]
        cond = threading.Condition()

        def worker(i, p):
            resp = None
            try:
                # expected faults (dead link, timeout, tampered payload)
                # are absorbed inside _exchange -> None; quorum decides
                resp = self._exchange(p, tokens_for, t0)
            except BaseException as e:  # real bugs must surface, not vanish
                with cond:
                    unexpected.append(e)
                    n_finished[0] += 1
                    cond.notify_all()
                return
            with cond:
                if resp is not None:
                    results[i] = resp
                n_finished[0] += 1
                cond.notify_all()

        for i, p in enumerate(providers):
            threading.Thread(target=worker, args=(i, p), daemon=True).start()
        # the SLO clock started at _collect entry (``t0``), so only the
        # REMAINING budget is spent waiting — spawning one thread per
        # provider must not extend the effective deadline.  The predicate
        # also wakes on an unexpected worker exception: with no deadline
        # and a hung straggler, waiting for n_finished alone would park
        # the raise below forever.
        timeout = None
        if self.deadline_s is not None:
            timeout = max(0.0, self.deadline_s - (time.monotonic() - t0))
        with cond:
            cond.wait_for(
                lambda: bool(unexpected) or n_finished[0] >= len(providers),
                timeout=timeout,
            )
            if unexpected:
                raise unexpected[0]
            responses = [results[i] for i in sorted(results)]
        return self._quorum_check(responses)

    def collect_contexts(self, query_text: str) -> list[dict]:
        """Steps 1-3: dispatch + quorum collection."""
        base_tokens = self.tok.encode(query_text, max_len=24)

        def tokens_for(p):
            if self.rewriter is not None:  # personalized expansion (§2.2)
                return self.rewriter.rewrite(base_tokens, p.provider_id)
            return base_tokens

        return self._collect(self.select_providers(query_text), tokens_for)

    def collect_contexts_batch(
        self, queries: Sequence[str], *, routes: list[list[DataProvider]] | None = None
    ) -> list[dict]:
        """Steps 1-3 for a query batch: ONE sealed request per provider
        carries all (B, S) query tokens; each response holds (B, m)
        scores/ids and (B, m, S_c) chunk tokens.  Sealing/serialization
        round-trips drop from B*P to P and every provider embeds the whole
        batch in one kernel call.

        Selector routing (``selector_top_p > 0``) rides the same fan-out
        ragged: only providers selected by at least one query receive a
        request, and within a selected provider's (B, S) block the rows of
        queries that did NOT route to it are masked to all-PAD (the
        embedder masks PAD, and the response rows of masked queries are
        discarded at aggregation).  ``routes`` lets a caller that already
        computed ``query_routes`` pass them in instead of re-embedding."""
        queries = list(queries)
        base = [self.tok.encode(q, max_len=24) for q in queries]
        if routes is None:
            routes = self.query_routes(queries)
        if routes is None:
            fan, mine_of = self.providers, None
        else:
            mine_of = {}  # provider id -> query rows routed to it
            for b, sub in enumerate(routes):
                for p in sub:
                    mine_of.setdefault(int(p.provider_id), set()).add(b)
            fan = [p for p in self.providers if int(p.provider_id) in mine_of]

        def tokens_for(p):
            rows = base
            if self.rewriter is not None:  # personalized expansion (§2.2)
                rows = [self.rewriter.rewrite(r, p.provider_id) for r in base]
            width = max(len(r) for r in rows)
            if mine_of is not None:
                mine = mine_of[int(p.provider_id)]
                rows = [
                    r if b in mine else np.full((width,), PAD, np.int32)
                    for b, r in enumerate(rows)
                ]
            return np.stack(
                [np.pad(r, (0, width - len(r))) for r in rows]
            ).astype(np.int32)  # PAD tail; the embedder masks PAD

        return self._collect(fan, tokens_for)

    def _gate_responses(self, responses: list[dict]) -> tuple[list[dict], dict | None]:
        """Aggregator-side poisoning gate (opt-in, ``score_gate``): each
        provider's round is z-checked against that provider's OWN running
        score distribution — anomalous rounds are quarantined (their
        chunks never reach ranking), surviving scores are calibrated to
        per-provider z-scores so incompatible embedding spaces become
        comparable.  Returns (kept responses, provenance meta).  If the
        gate would quarantine EVERY provider the raw rounds are kept
        instead: the defense assumes an honest majority, and dropping
        the whole federation on a global distribution shift would turn
        the gate itself into a denial of service."""
        if self.score_gate is None or not responses:
            return responses, None
        kept, quarantined = [], []
        for r in responses:
            pid = int(r["provider"])
            keep, calibrated = self.score_gate.admit(pid, r["scores"])
            if keep:
                r = dict(r)
                r["scores"] = calibrated
                kept.append(r)
            else:
                quarantined.append((pid, int(np.asarray(r["chunk_ids"]).size)))
        if not kept:
            return responses, {"quarantined": [], "calibrated": False}
        for pid, n_chunks in quarantined:
            h = self._health.get(pid)
            if h is not None:
                h.quarantined += 1
                h.dropped_chunks += n_chunks
        return kept, {
            "quarantined": [pid for pid, _ in quarantined],
            "calibrated": True,
        }

    def aggregate(self, query_text: str, responses: list[dict]) -> dict:
        """Step 4: in-enclave context aggregation (global re-rank).  With
        a ``score_gate``, poisoned/outlier provider rounds are quarantined
        first and surviving scores calibrated; the context dict carries
        the provenance (``providers`` per chunk + ``gated`` round meta)."""
        responses, gated = self._gate_responses(responses)
        all_tokens = np.concatenate([r["chunk_tokens"] for r in responses], 0)
        all_ids = np.concatenate([r["chunk_ids"] for r in responses], 0)
        all_scores = np.concatenate([r["scores"] for r in responses], 0)
        providers = np.concatenate(
            [np.full(len(r["chunk_ids"]), int(r["provider"])) for r in responses]
        )
        if self.aggregation == "rerank" and self.reranker is not None:
            q_tokens = self.tok.encode(query_text, max_len=24)
            rank_scores = np.asarray(self.reranker(q_tokens, all_tokens))
        else:
            rank_scores = all_scores
        n = min(self.n_global, len(all_ids))
        order = np.argsort(-rank_scores)[:n]
        out = {
            "chunk_tokens": all_tokens[order],
            "chunk_ids": all_ids[order],
            "scores": rank_scores[order],
            "providers": providers[order],
            "n_candidates": len(all_ids),
        }
        if gated is not None:
            out["gated"] = gated
        return out

    def aggregate_batch(self, queries: Sequence[str], responses: list[dict]) -> list[dict]:
        """Step 4 over a batch: one re-rank pass over the (B, C, S)
        candidate block when the reranker supports batching, else per-row.
        Produces per-query context dicts identical to ``aggregate``."""
        responses, gated = self._gate_responses(responses)
        all_tokens = np.concatenate([r["chunk_tokens"] for r in responses], 1)  # (B, C, S)
        all_ids = np.concatenate([r["chunk_ids"] for r in responses], 1)  # (B, C)
        all_scores = np.concatenate([r["scores"] for r in responses], 1)
        providers = np.concatenate(
            [
                np.full(r["chunk_ids"].shape, int(r["provider"]))
                for r in responses
            ],
            1,
        )
        if self.aggregation == "rerank" and self.reranker is not None:
            q_tok = np.stack([self.tok.encode(q, max_len=24) for q in queries])
            if getattr(self.reranker, "supports_batch", False):
                rank_scores = np.asarray(self.reranker(q_tok, all_tokens))
            else:
                rank_scores = np.stack(
                    [np.asarray(self.reranker(q_tok[b], all_tokens[b])) for b in range(len(queries))]
                )
        else:
            rank_scores = all_scores
        n = min(self.n_global, all_ids.shape[1])
        outs = []
        for b in range(len(queries)):
            order = np.argsort(-rank_scores[b])[:n]
            ctx = {
                "chunk_tokens": all_tokens[b][order],
                "chunk_ids": all_ids[b][order],
                "scores": rank_scores[b][order],
                "providers": providers[b][order],
                "n_candidates": all_ids.shape[1],
            }
            if gated is not None:
                ctx["gated"] = gated
            outs.append(ctx)
        return outs

    def build_prompt(self, query_text: str, context: dict, max_len: int = 512) -> np.ndarray:
        """[BOS] CTX chunk1 SEP chunk2 ... QRY query ANS — a STABLE
        shared-prefix layout.

        The context preamble comes first and is a pure function of the
        context and ``max_len``: the chunk budget reserves a FIXED query
        allowance (``query_reserve``, not the query's own length), so two
        queries served against the same aggregated context produce
        byte-identical prompts up to and including the ``QRY`` marker —
        exactly the prefix the paged engine's refcounted prefix cache
        shares block-for-block across micro-batch siblings and retries
        (``ServeConfig.prefix_cache``).  Truncation cuts from the TAIL:
        overflow drops whole lowest-ranked chunks first, and only the
        query itself is tail-truncated into whatever space remains (at
        least the reserve, so structural markers always survive).

        Overflow never breaks the grammar: dropping whole chunks keeps
        the ``BOS/CTX/QRY/query/ANS`` skeleton intact, where a blind
        ``ids[-max_len:]`` would slice off ``BOS``/``CTX`` and could
        bisect a chunk."""
        query = [int(t) for t in self.tok.encode(query_text, bos=False) if t not in (PAD, EOS)]
        n_markers = 4  # BOS, CTX, QRY, ANS
        # fixed reserve: chunk inclusion must not depend on the query, or
        # same-context siblings diverge before QRY and never share blocks
        reserve = min(self.query_reserve, max(0, (max_len - n_markers) // 2))
        chunk_budget = max_len - n_markers - reserve
        ids = [BOS, CTX]
        for row in context["chunk_tokens"]:
            chunk = [int(t) for t in row if t not in (PAD, BOS, EOS)]
            if len(chunk) + 1 > chunk_budget:  # +1: trailing SEP
                break  # ranked order: everything after is lower-scored
            ids += chunk
            ids.append(SEP)
            chunk_budget -= len(chunk) + 1
        ids.append(QRY)
        ids += query[: max(0, max_len - len(ids) - 1)]  # tail cut, ANS always fits
        ids.append(ANS)
        return np.asarray(ids, np.int32)[None, :]

    def _prompt_max_len(self) -> int:
        """Generator-advertised prompt window (``max_prompt_len`` on an
        engine adapter), so grammar-aware truncation in ``build_prompt``
        happens at the width the generator will actually consume."""
        return int(getattr(self.generator, "max_prompt_len", None) or 512)

    def answer(self, query_text: str) -> dict:
        responses = self.collect_contexts(query_text)
        context = self.aggregate(query_text, responses)
        out = {
            "context": context,
            "n_providers": len(responses),
        }
        if self.generator is not None:
            prompt = self.build_prompt(query_text, context, max_len=self._prompt_max_len())
            out["answer_tokens"] = np.asarray(self.generator(prompt))[0]
            out["prompt"] = prompt
        return out

    @staticmethod
    def _response_row(r: dict, b: int) -> dict:
        """Row ``b`` of a provider's batched response, shaped exactly like
        the sequential per-query response (m,) / (m, S_c)."""
        return {
            "provider": r["provider"],
            "scores": np.asarray(r["scores"])[b],
            "chunk_ids": np.asarray(r["chunk_ids"])[b],
            "chunk_tokens": np.asarray(r["chunk_tokens"])[b],
        }

    def _aggregate_routed(
        self, queries: Sequence[str], responses: list[dict], routes
    ) -> list[dict]:
        """Step 4 under selector routing: per query, slice out the rows of
        ITS providers in selector order (the order the sequential path
        concatenates in — rank tie-breaks depend on it), quorum-check the
        routed subset, and aggregate exactly like ``aggregate`` does.
        Returns (per-query contexts, per-query responding-provider
        counts)."""
        by_pid = {int(r["provider"]): r for r in responses}
        outs, n_prov = [], []
        for b, q in enumerate(queries):
            rs = [
                self._response_row(by_pid[int(p.provider_id)], b)
                for p in routes[b]
                if int(p.provider_id) in by_pid
            ]
            self._quorum_check(rs)
            outs.append(self.aggregate(q, rs))
            n_prov.append(len(rs))
        return outs, n_prov

    def answer_batch(self, queries: Sequence[str]) -> list[dict]:
        """Algorithm 1 over a query batch: one sealed round-trip per
        provider for the whole batch (selector-routed setups fan out
        ragged — only selected providers, non-selected query rows PAD-
        masked), batched aggregation, and (when the generator exposes
        ``generate_batch``) batched decoding.  Returns per-query result
        dicts identical to ``answer``."""
        queries = list(queries)
        if not queries:
            return []
        routes = self.query_routes(queries)
        responses = self.collect_contexts_batch(queries, routes=routes)
        if routes is None:
            contexts = self.aggregate_batch(queries, responses)
            n_prov = [len(responses)] * len(queries)
        else:
            contexts, n_prov = self._aggregate_routed(queries, responses, routes)
        outs = [
            {"context": ctx, "n_providers": n}
            for ctx, n in zip(contexts, n_prov)
        ]
        if self.generator is not None:
            width = self._prompt_max_len()
            prompts = [self.build_prompt(q, ctx, max_len=width) for q, ctx in zip(queries, contexts)]
            gen_batch = getattr(self.generator, "generate_batch", None)
            if gen_batch is not None:
                answers = gen_batch(prompts)
            else:
                answers = [np.asarray(self.generator(p))[0] for p in prompts]
            for out, prompt, ans in zip(outs, prompts, answers):
                out["answer_tokens"] = np.asarray(ans).ravel()
                out["prompt"] = prompt
        return outs
