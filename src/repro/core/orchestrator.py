"""Orchestrator (paper §2.3.2, Algorithm 1) — runs inside the CC enclave.

Flow per query:
  1. select k_n <= k providers (all by default; compatibility selector opt-in)
  2. broadcast the sealed query over attested channels
  3. collect local top-m responses under a deadline/quorum (straggler
     mitigation is *native* to Algorithm 1's k_n <= k semantics)
  4. aggregate inside the enclave:
       embedding_rank  merge by provider-reported scores
       rerank          cross-encoder F_aggr over all candidates (paper's
                       bge-reranker-base role), keep global top-n
  5. build the augmented prompt and run F_inf (generation LLM) in-enclave
"""
from __future__ import annotations

import secrets
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.confidential import Enclave, SecureChannel
from repro.core.provider import DataProvider, pack, unpack
from repro.data.tokenizer import ANS, BOS, CTX, EOS, PAD, QRY, SEP, HashTokenizer


class Orchestrator:
    def __init__(
        self,
        providers: Sequence[DataProvider],
        tokenizer: HashTokenizer,
        *,
        aggregation: str = "rerank",  # embedding_rank | rerank
        reranker: Callable | None = None,  # (query_tokens, cand_tokens (C,S)) -> (C,) scores
        generator: Callable | None = None,  # (prompt_tokens (1,S)) -> (1,T) answer tokens
        m_local: int = 8,
        n_global: int = 8,
        quorum: int = 1,
        deadline_s: float | None = None,
        selector=None,  # core.advanced.ProviderSelector (paper §2.2 routing)
        selector_top_p: int = 0,  # 0 -> broadcast to all (paper's basic setup)
        rewriter=None,  # core.advanced.QueryRewriter (per-provider expansion)
    ):
        self.providers = list(providers)
        self.tok = tokenizer
        self.aggregation = aggregation
        self.reranker = reranker
        self.generator = generator
        self.m_local, self.n_global = m_local, n_global
        self.quorum = quorum
        self.deadline_s = deadline_s
        self.selector = selector
        self.selector_top_p = selector_top_p
        self.rewriter = rewriter
        self.enclave = Enclave("cfedrag-orchestrator-v1")
        self._establish_channels()

    def _establish_channels(self):
        """Mutual attestation with every provider (paper §2.3.1 mTLS): each
        side verifies the other's measurement before deriving session keys
        (directional keys agree because both are derived from the same
        static-DH secret with measurement-ordered labels)."""
        for p in self.providers:
            ch = SecureChannel.establish(self.enclave, p.enclave, p.enclave.measurement)
            p.channel = SecureChannel.establish(p.enclave, self.enclave, self.enclave.measurement)
            setattr(p, "_orch_channel", ch)

    def select_providers(self, query_text: str) -> list[DataProvider]:
        if self.selector is not None and self.selector_top_p:
            q_tokens = self.tok.encode(query_text, max_len=24)
            return self.selector.select(q_tokens, self.providers, self.selector_top_p)
        return self.providers  # broadcast policy (paper's basic setup)

    # ------------------------------------------------------------------ #
    def collect_contexts(self, query_text: str) -> list[dict]:
        """Steps 1-3: dispatch + quorum collection."""
        base_tokens = self.tok.encode(query_text, max_len=24)
        responses = []
        t0 = time.monotonic()
        for p in self.select_providers(query_text):
            if self.deadline_s is not None and time.monotonic() - t0 > self.deadline_s:
                break  # deadline: proceed with what we have (k_n <= k)
            q_tokens = base_tokens
            if self.rewriter is not None:  # personalized expansion (§2.2)
                q_tokens = self.rewriter.rewrite(base_tokens, p.provider_id)
            try:
                ch = getattr(p, "_orch_channel")
                nonce, sealed = ch.seal(pack({"query_tokens": q_tokens, "m": np.int64(self.m_local)}))
                r_nonce, r_sealed = p.handle_request(nonce, sealed)
                responses.append(unpack(ch.open(r_nonce, r_sealed)))
            except (ConnectionError, TimeoutError):
                continue  # straggler/failed provider: tolerated by quorum
        if len(responses) < self.quorum:
            raise RuntimeError(
                f"quorum not met: {len(responses)}/{self.quorum} providers answered"
            )
        return responses

    def aggregate(self, query_text: str, responses: list[dict]) -> dict:
        """Step 4: in-enclave context aggregation (global re-rank)."""
        all_tokens = np.concatenate([r["chunk_tokens"] for r in responses], 0)
        all_ids = np.concatenate([r["chunk_ids"] for r in responses], 0)
        all_scores = np.concatenate([r["scores"] for r in responses], 0)
        providers = np.concatenate(
            [np.full(len(r["chunk_ids"]), int(r["provider"])) for r in responses]
        )
        if self.aggregation == "rerank" and self.reranker is not None:
            q_tokens = self.tok.encode(query_text, max_len=24)
            rank_scores = np.asarray(self.reranker(q_tokens, all_tokens))
        else:
            rank_scores = all_scores
        n = min(self.n_global, len(all_ids))
        order = np.argsort(-rank_scores)[:n]
        return {
            "chunk_tokens": all_tokens[order],
            "chunk_ids": all_ids[order],
            "scores": rank_scores[order],
            "providers": providers[order],
            "n_candidates": len(all_ids),
        }

    def build_prompt(self, query_text: str, context: dict, max_len: int = 512) -> np.ndarray:
        """[BOS] CTX chunk1 SEP chunk2 ... QRY query ANS"""
        ids = [BOS, CTX]
        for row in context["chunk_tokens"]:
            ids += [int(t) for t in row if t not in (PAD, BOS, EOS)]
            ids.append(SEP)
        ids.append(QRY)
        ids += [int(t) for t in self.tok.encode(query_text, bos=False) if t not in (PAD, EOS)]
        ids.append(ANS)
        ids = ids[-max_len:]
        return np.asarray(ids, np.int32)[None, :]

    def answer(self, query_text: str) -> dict:
        responses = self.collect_contexts(query_text)
        context = self.aggregate(query_text, responses)
        out = {
            "context": context,
            "n_providers": len(responses),
        }
        if self.generator is not None:
            prompt = self.build_prompt(query_text, context)
            out["answer_tokens"] = np.asarray(self.generator(prompt))[0]
            out["prompt"] = prompt
        return out
