"""In-mesh federated retrieval: the device-level realization of Alg. 1
steps 2-4 when providers are mesh slices (DESIGN.md §3 table).

The corpus is sharded over the provider axis (= `data`); each shard runs
local MIPS top-k (Pallas kernel on TPU), then ONLY the (score, global_id)
candidate tuples — k values per query per provider, never raw chunks —
cross the shard boundary via all_gather, exactly mirroring the paper's
"providers return m candidates, orchestrator merges" flow.  A quorum mask
zeroes out failed/straggling providers at the combine, so serving degrades
gracefully (k_n <= k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.retrieval_topk.ref import retrieval_topk_ref
from repro.runtime.compat import shard_map


def local_topk(q_emb, corpus_shard, m, use_pallas: bool = False):
    if use_pallas:
        from repro.kernels.retrieval_topk.kernel import retrieval_topk_pallas

        # interpret mode is auto-selected from the backend inside the kernel
        return retrieval_topk_pallas(q_emb, corpus_shard, m)
    return retrieval_topk_ref(q_emb, corpus_shard, m)


def federated_topk(
    q_emb: jax.Array,  # (Q, D) replicated
    corpus: jax.Array,  # (N_total, D) sharded over the provider axis
    m_local: int,
    n_global: int,
    mesh: Mesh | None = None,
    provider_axis: str = "data",
    alive: jax.Array | None = None,  # (n_providers,) bool quorum mask
    use_pallas: bool = False,
):
    """Returns (scores (Q, n_global), global_idx (Q, n_global), provider (Q, n_global))."""
    if mesh is None or provider_axis not in getattr(mesh, "shape", {}):
        s, i = local_topk(q_emb, corpus, n_global, use_pallas)
        return s, i, jnp.zeros_like(i)

    n_prov = mesh.shape[provider_axis]
    n_total = corpus.shape[0]
    n_loc = n_total // n_prov
    if alive is None:
        alive = jnp.ones((n_prov,), bool)

    def shard_fn(q, c_loc, alive_):
        pid = jax.lax.axis_index(provider_axis)
        s, i = local_topk(q, c_loc, m_local, use_pallas)  # (Q, m) local ids
        s = jnp.where(alive_[pid], s, -jnp.inf)  # straggler/failure mask
        gid = i + pid * n_loc
        # only (score, id) tuples cross the provider boundary:
        s_all = jax.lax.all_gather(s, provider_axis, axis=0)  # (P, Q, m)
        g_all = jax.lax.all_gather(gid, provider_axis, axis=0)
        p_all = jax.lax.all_gather(jnp.full_like(gid, pid), provider_axis, axis=0)
        q_n = q.shape[0]
        s_flat = s_all.transpose(1, 0, 2).reshape(q_n, -1)
        g_flat = g_all.transpose(1, 0, 2).reshape(q_n, -1)
        p_flat = p_all.transpose(1, 0, 2).reshape(q_n, -1)
        top_s, pos = jax.lax.top_k(s_flat, n_global)
        top_g = jnp.take_along_axis(g_flat, pos, axis=-1)
        top_p = jnp.take_along_axis(p_flat, pos, axis=-1)
        return top_s, top_g, top_p

    other_axes = [a for a in mesh.axis_names if a != provider_axis]
    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(), P(provider_axis, None), P()),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )
    return fn(q_emb, corpus, alive)


@functools.partial(jax.jit, static_argnames=("m_local", "n_global", "provider_axis", "use_pallas"))
def federated_topk_jit(q_emb, corpus, m_local, n_global, mesh=None, provider_axis="data", alive=None, use_pallas=False):
    return federated_topk(q_emb, corpus, m_local, n_global, mesh, provider_axis, alive, use_pallas)
