"""Contriever-like dual encoder — the paper's embedding model F_emb (§2.3.4).
Vocab matches the synthetic tokenizer used by the MedRAG-analog benchmark."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="contriever-110m", family="encoder",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=8192, causal=False,
)
