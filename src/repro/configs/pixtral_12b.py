"""pixtral-12b [vlm] — mistral-nemo backbone + patch-embedding frontend stub
(input_specs provides precomputed patch embeddings) [hf:mistralai/Pixtral-12B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    frontend="patches", n_patches=64,
    rope_theta=1_000_000.0,
)
