"""Model / shape configuration system.

One ``ModelConfig`` per architecture (the 10 assigned + the paper's own
retrieval trio).  Configs are frozen dataclasses — pure data, no jax import
side effects.  ``ShapeConfig`` describes the (seq_len, global_batch, step
kind) cells from the assignment; ``applicable()`` encodes the documented
skips (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str = "dense"  # dense | moe | hybrid | ssm | encoder | vlm

    # --- backbone ---
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    n_kv_heads: int = 12
    head_dim: int = 0  # 0 -> d_model // n_heads
    d_ff: int = 3072
    vocab_size: int = 32000
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    causal: bool = True

    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden (0 -> d_ff)
    n_shared_experts: int = 0
    moe_every: int = 1  # MoE on layers where i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_slack: float = 1.5
    router_aux_weight: float = 0.01
    moe_impl: str = "psum"  # psum (masked-local EP) | a2a (token-resharded EP)

    # --- hybrid / ssm mixers ---
    attn_every: int = 1  # attention on layers where i % attn_every == attn_offset
    attn_offset: int = 0  # (ssm family: attn_every=0 -> no attention anywhere)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 256

    # --- modality frontend stubs (DESIGN.md §5) ---
    frontend: str = "none"  # none | frames | patches
    n_patches: int = 0  # vlm: precomputed patch embeds replacing first N positions

    # --- compute policy ---
    attn_impl: str = "flash_jnp"  # naive | flash_jnp | pallas
    attn_chunk: int = 1024
    remat: str = "block"  # none | block
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    logit_dtype: str = "float32"
    bf16_grads: bool = False  # bf16 gradient sync (f32 master update)
    scan_unroll: bool = False  # unroll all scans (dry-run cost measurement:
    # XLA cost_analysis counts while-loop bodies ONCE, so roofline
    # measurement compiles must be loop-free; see launch/dryrun.py)

    # ------------------------------------------------------------------ #
    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # --- per-layer structure ----------------------------------------- #
    def mixer_kind(self, i: int) -> str:
        if self.family == "ssm":
            return "mamba"
        if self.family == "hybrid":
            return "attn" if (i % self.attn_every) == self.attn_offset else "mamba"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        if self.n_experts > 0 and (i % self.moe_every) == self.moe_offset:
            return "moe"
        return "dense"

    @property
    def scan_period(self) -> int:
        """Smallest period such that layer structure repeats; we scan over
        n_layers // period blocks of `period` explicit positions."""
        p = 1
        if self.family == "hybrid":
            p = math.lcm(p, self.attn_every)
        if self.n_experts > 0 and self.moe_every > 1:
            p = math.lcm(p, self.moe_every)
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        return p

    @property
    def n_blocks(self) -> int:
        return self.n_layers // self.scan_period

    # --- parameter counting (MODEL_FLOPS denominators) ---------------- #
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        p = self.d_model * hd * (self.n_heads + 2 * self.n_kv_heads)  # qkv
        p += self.n_heads * hd * self.d_model  # o
        if self.qk_norm:
            p += 2 * hd
        return p

    def _mamba_params(self) -> int:
        di, ds, g, h = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_heads
        p = self.d_model * di * 2  # z, x projections
        p += self.d_model * (2 * g * ds)  # B, C
        p += self.d_model * h  # dt
        p += (di + 2 * g * ds) * self.conv_width  # depthwise conv
        p += 3 * h  # A_log, D, dt_bias
        p += di  # gated norm scale
        p += di * self.d_model  # out proj
        return p

    def _dense_ffn_params(self) -> int:
        return 3 * self.d_model * self.d_ff

    def _moe_ffn_params(self, active: bool) -> int:
        e = self.moe_top_k if active else self.n_experts
        p = 3 * self.d_model * self.resolved_moe_d_ff * e
        p += self.d_model * self.n_experts  # router
        if self.n_shared_experts:
            p += 3 * self.d_model * (self.n_shared_experts * self.resolved_moe_d_ff)
        return p

    def param_count(self, active: bool = False) -> int:
        """Total (or activated, for MoE) parameter count, excluding embeddings
        for the 6ND convention denominator; embeddings reported separately."""
        total = 0
        for i in range(self.n_layers):
            total += (
                self._attn_params()
                if self.mixer_kind(i) == "attn"
                else self._mamba_params()
            )
            if self.family != "encoder" or True:
                total += (
                    self._moe_ffn_params(active)
                    if self.ffn_kind(i) == "moe"
                    else self._dense_ffn_params()
                )
            total += 2 * self.d_model  # norms
        total += self.d_model  # final norm
        return total

    def embedding_params(self) -> int:
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings and self.family != "encoder":
            n *= 2
        return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per DESIGN.md §5."""
    if cfg.family == "encoder" and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k needs sub-quadratic attention (ssm/hybrid only)"
    return True, ""


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    period = cfg.scan_period
    return cfg.with_overrides(
        n_layers=period * 2 if period > 1 else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 8),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16,
        n_patches=min(cfg.n_patches, 4) if cfg.n_patches else 0,
        attn_impl="naive",
        attn_chunk=64,
        ssd_chunk=16,
        remat="none",
    )
