"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7, MoE 16e top-2 every 2nd layer
[arXiv:2403.19887].  SSD-form mamba layers (DESIGN.md adaptation note)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    n_experts=16, moe_top_k=2, moe_d_ff=24576, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=0,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_groups=8, conv_width=4,
    rope_theta=10_000.0,
)
