"""hubert-xlarge [audio] — encoder-only; frame-embedding frontend stub
(input_specs provides precomputed frames).  No decode shapes (DESIGN §5)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504, causal=False, frontend="frames",
)
