"""command-r-plus-104b [dense] — GQA, no-bias, tied embeddings [hf:CohereForAI]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000, tie_embeddings=True,
    rope_theta=75_000_000.0,
)
