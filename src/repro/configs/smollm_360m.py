"""smollm-360m [dense] — llama-arch small; 15 heads (GSPMD pads over TP=16)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49152, tie_embeddings=True,
    rope_theta=10_000.0,
)
