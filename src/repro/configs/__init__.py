"""Architecture registry: --arch <id> resolution."""
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, applicable, smoke_config

_MODULES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "dbrx-132b": "dbrx_132b",
    "command-r-plus-104b": "command_r_plus_104b",
    "qwen3-4b": "qwen3_4b",
    "qwen3-0.6b": "qwen3_0_6b",
    "smollm-360m": "smollm_360m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-1.3b": "mamba2_1_3b",
    "pixtral-12b": "pixtral_12b",
    "contriever-110m": "contriever_110m",
    "bge-reranker-base": "bge_reranker_base",
    "llama3-8b": "llama3_8b",
}

ASSIGNED_ARCHS = list(_MODULES)[:10]
PAPER_ARCHS = list(_MODULES)[10:]


def get_config(name: str) -> ModelConfig:
    import importlib

    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {n: get_config(n) for n in _MODULES}
