"""bge-reranker-base-like cross encoder — the paper's aggregation model F_aggr
(§2.3.2): pairwise (query, chunk) relevance scoring."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="bge-reranker-base", family="encoder",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=8192, causal=False,
)
