"""JAX version-compatibility shims.

The codebase targets the current jax API (``jax.shard_map``,
``Mesh(..., axis_types=...)``); deployment containers may pin an older
release where those live under different names.  All mesh/shard_map
construction goes through here so version drift is absorbed in one place.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` when present, else the experimental spelling
    (where ``check_vma`` was called ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def axis_size(name: str):
    """``jax.lax.axis_size`` (new jax) or a psum-of-ones fallback, usable
    inside shard_map/pmap bodies."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def make_mesh(devices, axes) -> Mesh:
    """Mesh over an explicit device array, with AxisType.Auto where the
    installed jax understands ``axis_types``."""
    arr = np.asarray(devices)
    try:
        from jax.sharding import AxisType

        return Mesh(arr, axes, axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return Mesh(arr, axes)


def make_topology_mesh(shape, axes) -> Mesh:
    """``jax.make_mesh`` (topology-aware device ordering on real TPU
    slices) with the axis_types kwarg when supported, falling back to an
    explicit enumeration-order Mesh on older jax."""
    try:
        from jax.sharding import AxisType

        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError, AttributeError):
        pass
    try:
        return jax.make_mesh(shape, axes)
    except (AttributeError, TypeError):
        need = int(np.prod(np.asarray(shape)))
        return make_mesh(np.array(jax.devices()[:need]).reshape(shape), axes)
