"""Step builders: the jit-able (train | prefill | decode) callables per
architecture family, with optimizer fused into train_step (so dry-run
memory analysis includes optimizer state — the number that actually
gates large-model feasibility)."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encoder as ENC
from repro.models import lm as LM
from repro.optim.optimizers import Optimizer
from repro.runtime.sharding import ShardingPolicy


def model_loss_fn(cfg: ModelConfig):
    if cfg.family == "encoder":
        return ENC.loss_fn
    return LM.loss_fn


def make_train_step(
    cfg: ModelConfig,
    pol: ShardingPolicy,
    opt: Optimizer,
    lr_fn=None,
    grad_pspecs=None,
):
    """grad_pspecs: optional tree of PartitionSpecs (same tree as params).
    Constraining gradients to the parameter sharding makes GSPMD emit
    reduce-scatter instead of a full-replica all-reduce (ZeRO-2 gradient
    sharding) — a ~dp-fold cut of the gradient-sync bytes
    (EXPERIMENTS.md §Perf, iteration B4)."""
    loss_fn = model_loss_fn(cfg)
    lr_fn = lr_fn or (lambda step: 3e-4)
    bf16_grads = getattr(cfg, "bf16_grads", False)

    def train_step(params, opt_state, batch, step):
        if bf16_grads:
            # mixed-precision sync: differentiate the bf16 shadow -> bf16
            # gradients cross the network, f32 master update (§Perf B5)
            from repro.models.params import cast_tree

            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, pol, p, batch), has_aux=True
            )(cast_tree(params, jnp.bfloat16))
        else:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, pol, p, batch), has_aux=True
            )(params)
        if grad_pspecs is not None and pol.mesh is not None:
            from jax.sharding import NamedSharding

            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(
                    g, NamedSharding(pol.mesh, s)
                ),
                grads,
                grad_pspecs,
            )
        new_params, new_state, gnorm = opt.update(grads, opt_state, params, lr_fn(step))
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr_fn(step))
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, pol: ShardingPolicy):
    if cfg.family == "encoder":
        def encode_step(params, batch):
            return ENC.encode(cfg, pol, params, batch["frames"])

        return encode_step

    def prefill_step(params, batch):
        logits, cache = LM.prefill(cfg, pol, params, batch)
        return logits[:, -1:, :], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, pol: ShardingPolicy):
    def decode_step(params, cache, tokens, pos):
        return LM.decode_step(cfg, pol, params, cache, tokens, pos)

    return decode_step
