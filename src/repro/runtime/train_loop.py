"""Fault-tolerant training loop: checkpoint/restart, straggler accounting,
simulated-failure injection for the restart tests.

The loop is deliberately dumb-robust (the MaxText philosophy): every state
that matters — params, optimizer, data-iterator, step — round-trips
through CheckpointManager, and `run()` can be killed at any step and
relaunched with resume="auto" to continue bit-exactly (tests/
test_checkpoint.py asserts loss-trajectory equality)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.models.params import init_params, make_shardings
from repro.optim.optimizers import Optimizer
from repro.runtime.sharding import ShardingPolicy
from repro.runtime.steps import make_train_step


class SimulatedFailure(Exception):
    """Injected node failure (tests / chaos drills)."""


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_warn_factor: float = 2.0  # warn if a step takes 2x the median
    fail_at_step: int | None = None  # inject SimulatedFailure (tests)


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        pol: ShardingPolicy,
        opt: Optimizer,
        data_stream,
        tcfg: TrainerConfig,
        lr_fn: Callable | None = None,
        param_specs_fn=None,
    ):
        from repro.models import lm as LM
        from repro.models import encoder as ENC

        self.cfg, self.pol, self.opt, self.tcfg = cfg, pol, opt, tcfg
        self.stream = data_stream
        specs_fn = param_specs_fn or (
            ENC.param_specs if cfg.family == "encoder" else LM.param_specs
        )
        self.specs = specs_fn(cfg)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        self.train_step = jax.jit(make_train_step(cfg, pol, opt, lr_fn), donate_argnums=(0, 1))
        self.step_times: list[float] = []
        self.metrics_log: list[dict] = []

    def init_state(self, seed: int = 0):
        params = init_params(self.specs, jax.random.PRNGKey(seed))
        return params, self.opt.init(params)

    def run(self, resume: str = "auto", seed: int = 0):
        start_step = 0
        if resume == "auto" and self.ckpt.latest_step() is not None:
            params, opt_state = self.init_state(seed)
            (params, opt_state), extra, start_step = self.ckpt.restore(
                (params, opt_state)
            )
            self.stream.load_state_dict(extra["stream"])
            start_step += 1
        else:
            params, opt_state = self.init_state(seed)

        for step in range(start_step, self.tcfg.total_steps):
            if self.tcfg.fail_at_step is not None and step == self.tcfg.fail_at_step:
                # persist nothing beyond the last checkpoint: a real node loss
                raise SimulatedFailure(f"node lost at step {step}")
            t0 = time.monotonic()
            batch = {k: jax.numpy.asarray(v) for k, v in self.stream.next().items()}
            params, opt_state, metrics = self.train_step(
                params, opt_state, batch, jax.numpy.asarray(step)
            )
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            self.step_times.append(dt)
            med = float(np.median(self.step_times[-20:]))
            if dt > self.tcfg.straggler_warn_factor * med and len(self.step_times) > 5:
                metrics["straggler"] = dt / med  # logged; scheduler hook point
            metrics["step"] = step
            self.metrics_log.append(metrics)
            if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == self.tcfg.total_steps:
                self.ckpt.save(
                    step, (params, opt_state), extra={"stream": self.stream.state_dict()}
                )
        self.ckpt.wait()
        return params, opt_state
