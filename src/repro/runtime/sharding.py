"""Logical-axis -> mesh-axis rules (t5x/MaxText style) + activation helpers.

Two weight-sharding regimes:
  * single-pod (data=16, model=16):  2-D sharding — `embed`-type dims FSDP
    over `data`, heads/mlp/vocab/experts TP over `model`.
  * multi-pod (pod=2, data=16, model=16): the `pod` axis is pure DP
    (weights replicated across pods; batch sharded over (pod, data)).
    This matches the paper's federation topology: each pod is a "site",
    only gradient aggregates cross the pod boundary (FedAvg-equivalent,
    optionally secure-aggregated / compressed — optim/compression.py).

Activation logical axes:
  act_batch    batch dim of activations           -> (pod,)data
  act_seq      sequence dim                       -> None (SP variants opt-in)
  act_heads    per-head activation dim            -> model
  act_vocab    logits vocab dim                   -> model
  cache_batch / cache_kv / cache_seq              -> shape-dependent (below)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def base_rules(multi_pod: bool) -> dict[str, Any]:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        # weights
        "layers": None,
        "stack": None,
        "vocab": "model",
        "embed": "data",
        "heads": "model",
        "kv_heads": "model",
        "ssm_heads": "model",
        "mlp": "model",
        "experts": "model",
        "expert_in": "data",
        "expert_mlp": None,
        "head_dim": None,
        "norm": None,
        "conv": None,
        "state": None,
        "dt": "model",
        # activations
        "act_batch": batch,
        "act_seq": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_embed": None,
        "act_vocab": "model",
        "act_ff": "model",
        # kv / ssm cache (defaults; overridden per shape)
        "cache_batch": batch,
        "cache_kv": "model",
        "cache_seq": None,
    }


@dataclasses.dataclass
class ShardingPolicy:
    """Resolved rules for one (arch, shape, mesh) cell."""

    rules: dict[str, Any]
    mesh: Mesh | None = None

    def spec(self, *axes: str | None, shape: tuple | None = None) -> PartitionSpec:
        sizes = dict(self.mesh.shape) if self.mesh is not None else {}
        used: set[str] = set()
        entries = []
        for d, ax in enumerate(axes):
            m = self.rules.get(ax) if ax is not None else None
            if m is None:
                entries.append(None)
                continue
            cand = (m,) if isinstance(m, str) else tuple(m)
            free = []
            fac = 1
            for a in cand:
                if a in used:
                    continue
                if shape is not None and sizes:
                    sz = sizes.get(a, 1)
                    if shape[d] % (fac * sz) != 0:
                        continue
                    fac *= sz
                free.append(a)
            if not free:
                entries.append(None)
                continue
            used.update(free)
            entries.append(tuple(free) if len(free) > 1 else free[0])
        return PartitionSpec(*entries)

    def shard(self, x, *axes: str | None):
        """with_sharding_constraint if a mesh is active, else identity."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*axes, shape=x.shape))
        )


def make_policy(
    mesh: Mesh | None,
    *,
    multi_pod: bool = False,
    shape_kind: str = "train",
    global_batch: int = 0,
    seq_len: int = 0,
    long_context: bool = False,
) -> ShardingPolicy:
    rules = base_rules(multi_pod)
    if mesh is not None:
        dp = 1
        for ax in ("pod", "data"):
            if ax in mesh.shape:
                dp *= mesh.shape[ax]
        # batch too small to shard over the full DP extent -> keep replicated
        if global_batch and global_batch < dp:
            rules["act_batch"] = None
            rules["cache_batch"] = None
            if long_context or seq_len >= 1 << 17:
                # long-context decode: shard the KV cache over `data` instead
                rules["cache_seq"] = "data"
                rules["act_seq"] = "data"
    return ShardingPolicy(rules=rules, mesh=mesh)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PartitionSpec())
