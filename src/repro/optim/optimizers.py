"""Optimizers (pure functions, no optax): AdamW, Adafactor, SGD-momentum.

Adafactor's factored second moment is what lets the 104B/132B/398B archs
fit the single-pod memory budget (EXPERIMENTS.md §Dry-run) — full-Adam
state for jamba-398b alone would exceed v5e HBM at 256 chips.

State trees mirror the param tree so the same sharding rules apply
(optimizer state is ZeRO-sharded exactly like its parameter).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (new_params, new_state)
    name: str = "opt"


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(f32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), grads), g


def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, clip_norm=1.0) -> Optimizer:
    def init(params):
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, f32), params),
            "nu": jax.tree.map(lambda p: jnp.zeros_like(p, f32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        c = state["count"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(f32), state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(f32)), state["nu"], grads)
        bc1 = 1 - b1 ** c.astype(f32)
        bc2 = 1 - b2 ** c.astype(f32)

        def upd(p, m, v):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return (p.astype(f32) - lr * (step + weight_decay * p.astype(f32))).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "count": c}, gnorm

    return Optimizer(init, update, "adamw")


def adafactor(eps=1e-30, clip_norm=1.0, weight_decay=0.0, min_dim_factored=128) -> Optimizer:
    """Factored second moment for >=2D params whose trailing dims are large;
    no first moment (memory ~ O(rows+cols) per matrix)."""

    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored and p.shape[-2] >= min_dim_factored

    def init(params):
        def mk(p):
            if factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], f32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], f32),
                }
            return {"v": jnp.zeros_like(p, f32)}

        return {
            "v": jax.tree.map(mk, params, is_leaf=lambda x: hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        c = state["count"] + 1
        decay = 1.0 - (c.astype(f32) + 1.0) ** -0.8

        def upd(p, g, v):
            g = g.astype(f32)
            g2 = jnp.square(g) + eps
            if "vr" in v:
                vr = decay * v["vr"] + (1 - decay) * g2.mean(-1)
                vc = decay * v["vc"] + (1 - decay) * g2.mean(-2)
                denom = (
                    vr[..., None]
                    * vc[..., None, :]
                    / jnp.maximum(vr.mean(-1)[..., None, None], eps)
                )
                step = g * jax.lax.rsqrt(denom + eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": decay * v["v"] + (1 - decay) * g2}
                step = g * jax.lax.rsqrt(nv["v"] + eps)
            # Adafactor update clipping (RMS<=1)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + eps)
            step = step / jnp.maximum(1.0, rms)
            newp = p.astype(f32) - lr * (step + weight_decay * p.astype(f32))
            return newp.astype(p.dtype), nv

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_params, {"v": new_v, "count": c}, gnorm

    return Optimizer(init, update, "adafactor")


def sgdm(momentum=0.9, clip_norm=1.0) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, f32), params)}

    def update(grads, state, params, lr):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(f32), state["mu"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(f32) - lr * m).astype(p.dtype), params, mu
        )
        return new_params, {"mu": mu}, gnorm

    return Optimizer(init, update, "sgdm")


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}[name](**kw)


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, f32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr
