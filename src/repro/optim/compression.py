"""Cross-pod gradient compression: int8 quantization + error feedback.

The multi-pod mesh all-reduces gradients over the `pod` axis (the
FedAvg-equivalent site boundary, slowest links).  Compressing that
exchange 4x (bf16->int8 per-tensor-scale) with an error-feedback buffer
(residual added back next step, so the quantization bias vanishes) is the
standard trick for WAN/DCN federation — exactly the paper's deployment
regime.  Used by runtime/train_loop when `compress_pod_grads=True`;
correctness (EF convergence) covered in tests/test_optim.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(f32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(f32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(f32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)


def compress_with_ef(grads, ef_state):
    """Returns (quantized tree of (q, scale), new_ef placeholder-corrected)."""

    def one(g, e):
        target = g.astype(f32) + e
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s)
        return (q, s), target - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_ef = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return comp, new_ef


def decompress(comp):
    return jax.tree.map(
        lambda qs: dequantize_int8(*qs),
        comp,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
    )
