"""Host data pipeline: deterministic, checkpointable iterators + device
placement with the mesh batch sharding.

``LMBatchStream`` serves next-token-prediction batches from a synthetic
token source (or packed corpus text); iterator state is just (seed, step)
so checkpoint/restart resumes the exact stream (tests/test_checkpoint.py).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.data.tokenizer import HashTokenizer


@dataclasses.dataclass
class StreamState:
    seed: int
    step: int


class LMBatchStream:
    """Deterministic synthetic LM stream.  Mixes (a) random token spans and
    (b) retrieval-style "context + query -> answer copy" sequences so a small
    model trained on it learns the copy/grounding behaviour RAG needs."""

    def __init__(
        self,
        batch: int,
        seq_len: int,
        vocab_size: int,
        seed: int = 0,
        copy_task_frac: float = 0.5,
        markov: bool = True,
        tokenizer: HashTokenizer | None = None,
    ):
        self.batch, self.seq_len, self.vocab = batch, seq_len, vocab_size
        self.state = StreamState(seed=seed, step=0)
        self.copy_frac = copy_task_frac
        self.markov = markov and vocab_size <= 8192  # table is vocab^2
        self._cum_p: np.ndarray | None = None
        self.tok = tokenizer or HashTokenizer(vocab_size)

    def _markov_row(self, rng: np.random.Generator) -> np.ndarray:
        """Sample from a fixed random bigram language (seed-determined
        256x256-ish transition table): learnable structure whose achievable
        CE is bounded by model capacity — the Table-2 ablation signal."""
        if self._cum_p is None:
            rng0 = np.random.default_rng(self.state.seed + 99991)
            logits = rng0.normal(size=(self.vocab, self.vocab)) * 2.0
            p = np.exp(logits - logits.max(1, keepdims=True))
            p /= p.sum(1, keepdims=True)
            self._cum_p = p.cumsum(1)
        toks = np.empty(self.seq_len + 1, np.int64)
        toks[0] = rng.integers(8, self.vocab)
        u = rng.random(self.seq_len)
        for t in range(self.seq_len):
            toks[t + 1] = min(np.searchsorted(self._cum_p[toks[t]], u[t]), self.vocab - 1)
        return toks.astype(np.int32)

    def _copy_example(self, rng: np.random.Generator) -> np.ndarray:
        """[CTX] w.. SEP val w.. [QRY] ANS -> val: fetch the token after the
        (fixed) SEP marker from context — the minimal retrieval-grounding
        behaviour (find the relevant span, extract the answer), learnable in
        a few hundred steps unlike full induction-copy."""
        from repro.data.tokenizer import ANS, BOS, CTX, EOS, QRY, SEP

        s = self.seq_len + 1
        n_ctx = int(rng.integers(s // 4, s // 2))
        ctx = rng.integers(8, self.vocab, size=n_ctx)
        key_pos = int(rng.integers(1, n_ctx - 2))
        ctx[key_pos] = SEP  # fixed marker
        val_tok = int(ctx[key_pos + 1])
        seq = [BOS, CTX, *ctx.tolist(), QRY, ANS, val_tok, EOS]
        seq = seq[:s] + [0] * max(0, s - len(seq))
        return np.asarray(seq, np.int32)

    def next(self) -> dict[str, np.ndarray]:
        from repro.data.tokenizer import QRY

        rng = np.random.default_rng((self.state.seed, self.state.step))
        self.state.step += 1
        rows, masks = [], []
        for i in range(self.batch):
            if rng.random() < self.copy_frac:
                from repro.data.tokenizer import ANS

                row = self._copy_example(rng)
                # supervise exactly the grounded-answer position (the token
                # predicted at ANS): filler/PAD positions would otherwise
                # dominate the gradient and drown the copy signal
                m = np.zeros(self.seq_len, bool)
                apos = np.where(row[:-1] == ANS)[0]
                if len(apos):
                    m[apos[0]] = True
                masks.append(m)
                rows.append(row)
            elif self.markov:
                rows.append(self._markov_row(rng))
                masks.append(np.ones(self.seq_len, bool))
            else:
                rows.append(rng.integers(8, self.vocab, size=self.seq_len + 1).astype(np.int32))
                masks.append(np.ones(self.seq_len, bool))
        arr = np.stack(rows)
        targets = arr[:, 1:].copy()
        targets[~np.stack(masks)] = -1
        return {"tokens": arr[:, :-1], "targets": targets}

    # --- checkpointable iterator state ---
    def state_dict(self) -> dict:
        return dataclasses.asdict(self.state)

    def load_state_dict(self, d: dict):
        self.state = StreamState(**d)


def shard_batch(batch: dict, mesh, batch_spec):
    """Place a host batch onto the mesh with the activation batch sharding."""
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    out = {}
    for k, v in batch.items():
        spec = batch_spec if v.ndim >= 1 else None
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
