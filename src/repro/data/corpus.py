"""Synthetic corpus + QA generator with known ground-truth provenance.

Stand-in for MedRAG/MIRAGE (unavailable offline, DESIGN.md §2).  Mirrors
the paper's experimental topology: 4 corpora ("pubmed", "wikipedia",
"statpearls", "textbooks") distributed across 2 sites; each query's gold
evidence lives in exactly one corpus, with corpus-skewed query mixes so a
single silo cannot answer everything (the Table 1 mechanism).

Facts are ``entity attribute value`` triples; chunks embed the fact inside
topic-correlated distractor words; queries ask ``what is <attribute> of
<entity>``.  Every chunk records (corpus, site, gold query ids).
"""
from __future__ import annotations

import dataclasses

import numpy as np

CORPORA = ("pubmed", "wikipedia", "statpearls", "textbooks")
SITE_OF = {"pubmed": 0, "wikipedia": 0, "statpearls": 1, "textbooks": 1}
# query-topic mix: pubmed dominates (as in Table 1 where MedRag(PubMed)
# nearly matches MedRag(MedCorp))
CORPUS_WEIGHTS = (0.55, 0.15, 0.15, 0.15)


@dataclasses.dataclass
class Chunk:
    text: str
    corpus: str
    site: int
    chunk_id: int
    fact_id: int  # -1 for distractor-only chunks


@dataclasses.dataclass
class Query:
    text: str
    answer: str
    gold_chunk_id: int
    corpus: str
    query_id: int


@dataclasses.dataclass
class FederatedCorpus:
    chunks: list[Chunk]
    queries: list[Query]

    def site_chunks(self, site: int) -> list[Chunk]:
        return [c for c in self.chunks if c.site == site]

    def corpus_chunks(self, corpus: str) -> list[Chunk]:
        return [c for c in self.chunks if c.corpus == corpus]


def _words(rng: np.random.Generator, pool: list[str], n: int) -> str:
    return " ".join(rng.choice(pool, size=n))


def make_federated_corpus(
    n_facts: int = 256,
    n_distractors: int = 256,
    n_queries: int = 200,
    chunk_len_words: int = 24,
    seed: int = 0,
) -> FederatedCorpus:
    rng = np.random.default_rng(seed)
    topics = {
        c: [f"{c}word{i}" for i in range(200)] for c in CORPORA
    }
    attrs = [f"attr{i}" for i in range(32)]
    chunks: list[Chunk] = []
    queries: list[Query] = []

    # facts, assigned to corpora by the skewed mix
    fact_corpus = rng.choice(len(CORPORA), size=n_facts, p=CORPUS_WEIGHTS)
    for f in range(n_facts):
        corpus = CORPORA[fact_corpus[f]]
        ent, attr = f"entity{f}", attrs[rng.integers(len(attrs))]
        val = f"value{f}x{rng.integers(10_000)}"
        filler = _words(rng, topics[corpus], chunk_len_words - 6)
        text = f"{filler} {ent} {attr} is {val} ."
        chunks.append(Chunk(text, corpus, SITE_OF[corpus], len(chunks), f))
        if len(queries) < n_queries:
            queries.append(
                Query(
                    text=f"what is {attr} of {ent}",
                    answer=val,
                    gold_chunk_id=len(chunks) - 1,
                    corpus=corpus,
                    query_id=len(queries),
                )
            )
    # distractors
    for _ in range(n_distractors):
        corpus = CORPORA[rng.integers(len(CORPORA))]
        text = _words(rng, topics[corpus], chunk_len_words)
        chunks.append(Chunk(text, corpus, SITE_OF[corpus], len(chunks), -1))

    rng.shuffle(queries)
    return FederatedCorpus(chunks=chunks, queries=queries)
