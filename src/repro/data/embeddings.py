"""Training-free bag-of-words hash embedder.

Deterministic per-token Gaussian vectors (PRNG keyed by token id), mean-
pooled and L2-normalized: lexical-overlap similarity.  Serves as (a) the
"off-the-shelf embedding model" baseline the paper contrasts with
FL-trained embedders and (b) a fast oracle for retrieval tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.data.tokenizer import PAD


@functools.partial(jax.jit, static_argnames=("dim", "seed"))
def bag_embed(tokens: jax.Array, dim: int = 256, seed: int = 17):
    """tokens: (N, S) int32 -> (N, dim) f32, unit norm."""
    table_key = jax.random.PRNGKey(seed)
    # per-token embedding generated on the fly from the token id
    def tok_vec(tid):
        k = jax.random.fold_in(table_key, tid)
        return jax.random.normal(k, (dim,), jnp.float32)

    vecs = jax.vmap(jax.vmap(tok_vec))(tokens)  # (N,S,dim)
    mask = (tokens != PAD).astype(jnp.float32)[..., None]
    pooled = (vecs * mask).sum(1) / jnp.maximum(mask.sum(1), 1.0)
    return pooled / jnp.maximum(jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)
