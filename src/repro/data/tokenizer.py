"""Deterministic hash tokenizer (offline stand-in for the paper's HF
tokenizers).  Stable across processes (no PYTHONHASHSEED dependence)."""
from __future__ import annotations

import hashlib

import numpy as np

PAD, BOS, EOS, SEP, MASK, QRY, CTX, ANS = 0, 1, 2, 3, 4, 5, 6, 7
N_SPECIAL = 8


class HashTokenizer:
    def __init__(self, vocab_size: int = 8192):
        assert vocab_size > N_SPECIAL
        self.vocab_size = vocab_size

    def token(self, word: str) -> int:
        h = hashlib.blake2s(word.lower().encode(), digest_size=4).digest()
        return int.from_bytes(h, "little") % (self.vocab_size - N_SPECIAL) + N_SPECIAL

    def encode(self, text: str, max_len: int | None = None, bos: bool = True) -> np.ndarray:
        ids = [BOS] if bos else []
        ids += [self.token(w) for w in text.split()]
        ids.append(EOS)
        if max_len is not None:
            ids = ids[:max_len] + [PAD] * max(0, max_len - len(ids))
        return np.asarray(ids, np.int32)

    def encode_pair(self, query: str, doc: str, max_len: int):
        """[BOS] query [SEP] doc [EOS] + type ids (cross-encoder input)."""
        q = [BOS] + [self.token(w) for w in query.split()] + [SEP]
        d = [self.token(w) for w in doc.split()] + [EOS]
        ids = (q + d)[:max_len]
        types = ([0] * len(q) + [1] * len(d))[:max_len]
        pad = max_len - len(ids)
        return (
            np.asarray(ids + [PAD] * pad, np.int32),
            np.asarray(types + [0] * pad, np.int32),
        )
