"""Paged KV-cache block pool: host-side memory manager for the serving engine.

The contiguous engine layout reserves one ``max_prompt_len +
max_new_tokens`` cache stripe per slot, so a 12-token query pays the same
HBM as the longest allowed prompt and the admitted batch size is pinned to
the number of physical stripes.  The paged layout chops the cache into
fixed-size **token blocks** (``block_size`` positions each) held in one
shared pool; each request owns an ordered **block table** mapping its
logical positions ``[i * block_size, (i + 1) * block_size)`` to pool block
``table[i]``.  Admission allocates just enough blocks to cover the prompt,
decode grows the table one block at a time at chunk boundaries, and retire
returns every block to the pool — so concurrency is bounded by *actual*
tokens resident, not by worst-case stripes.

This module is deliberately host-only and jax-free: the pool hands out
integer block ids; the engine owns the device arrays those ids index
(``models/lm.init_paged_cache`` leaves shaped ``(n_layers, n_pool,
block_size, ...)``) and the device copy of the block tables.

Contracts:
  * ``alloc(n)`` is all-or-nothing: it returns ``n`` block ids or raises
    ``BlockPoolOOM`` without allocating anything (``try_alloc`` returns
    ``None`` instead) — a half-admitted request can never leak blocks.
  * ``free`` rejects double-frees and foreign ids loudly: a double-free
    means two requests believe they own the same block, which is cache
    corruption, not a recoverable condition.
  * Allocation order is deterministic (LIFO free list) so paged serving
    replays are reproducible run to run.
"""
from __future__ import annotations


class BlockPoolOOM(RuntimeError):
    """Raised by ``alloc`` when the pool cannot satisfy a request."""


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of blocks needed to hold ``n_tokens`` positions (>= 1)."""
    return max(1, -(-int(n_tokens) // block_size))


class BlockPool:
    """Fixed pool of ``n_blocks`` token blocks with a LIFO free list."""

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks <= 0 or block_size <= 0:
            raise ValueError(f"need positive pool dims, got {n_blocks}x{block_size}")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        # LIFO: block 0 is handed out first, and a just-freed block is the
        # next one reused (cache-friendly and deterministic)
        self._free = list(range(self.n_blocks - 1, -1, -1))
        self._owned: set[int] = set()

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return len(self._owned)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` blocks; all-or-nothing (raises BlockPoolOOM)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise BlockPoolOOM(f"need {n} blocks, {len(self._free)} free")
        ids = [self._free.pop() for _ in range(n)]
        self._owned.update(ids)
        return ids

    def try_alloc(self, n: int) -> list[int] | None:
        """Like ``alloc`` but returns None on OOM (the chunk-boundary grow
        path treats OOM as an early-retire signal, not an error)."""
        return self.alloc(n) if self.can_alloc(n) else None

    def free(self, ids) -> None:
        """Return blocks to the pool.  Double-free / foreign ids raise:
        either means two requests think they own the same block."""
        ids = list(ids)
        bad = [b for b in ids if b not in self._owned]
        if bad:
            raise ValueError(f"free of unowned block(s) {bad}")
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate ids in free: {ids}")
        for b in ids:
            self._owned.remove(b)
        # reversed: freeing [a, b] then allocating 2 returns [a, b] again
        self._free.extend(reversed(ids))

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"BlockPool(n_blocks={self.n_blocks}, block_size={self.block_size}, "
            f"free={self.free_blocks})"
        )


class BlockTable:
    """Per-request ordered list of pool block ids.

    ``ids[i]`` backs logical token positions ``[i*bs, (i+1)*bs)``.  The
    table grows via ``extend`` at decode-chunk boundaries and releases
    everything via ``release`` at retire; ``n_tokens_capacity`` is the
    highest position count the table can currently hold.
    """

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self.ids: list[int] = []

    @property
    def n_blocks(self) -> int:
        return len(self.ids)

    @property
    def n_tokens_capacity(self) -> int:
        return len(self.ids) * self.pool.block_size

    def extend_to(self, n_tokens: int) -> bool:
        """Grow to cover ``n_tokens`` positions.  Returns False on OOM
        (nothing allocated) — the caller's early-retire signal."""
        need = blocks_for(n_tokens, self.pool.block_size) - len(self.ids)
        if need <= 0:
            return True
        got = self.pool.try_alloc(need)
        if got is None:
            return False
        self.ids.extend(got)
        return True

    def release(self) -> None:
        if self.ids:
            self.pool.free(self.ids)
            self.ids = []
