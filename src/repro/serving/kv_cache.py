"""Paged KV-cache block pool: host-side memory manager for the serving engine.

The contiguous engine layout reserves one ``max_prompt_len +
max_new_tokens`` cache stripe per slot, so a 12-token query pays the same
HBM as the longest allowed prompt and the admitted batch size is pinned to
the number of physical stripes.  The paged layout chops the cache into
fixed-size **token blocks** (``block_size`` positions each) held in one
shared pool; each request owns an ordered **block table** mapping its
logical positions ``[i * block_size, (i + 1) * block_size)`` to pool block
``table[i]``.  Admission allocates just enough blocks to cover the prompt,
decode grows the table one block at a time at chunk boundaries, and retire
returns every block to the pool — so concurrency is bounded by *actual*
tokens resident, not by worst-case stripes.

Blocks are **refcounted** so prompt prefixes can be shared: ``alloc``
hands a block out at refcount 1, ``share`` increments (a second request's
table now points at the same physical block), and ``free`` decrements —
a block is recycled (or parked, see below) only when its count reaches
zero.  The C-FedRAG front door builds every prompt as ``[BOS] CTX
<aggregated chunks> QRY <query> ANS`` with the context preamble first, so
micro-batch siblings and retries repeat the expensive prefix verbatim;
two block tables pointing at one immutable prompt block de-duplicate both
the HBM and the prefill FLOPs that computed it.

``PrefixIndex`` is the lookup structure on top: a hash-chain trie over
``block_size``-token chunks of prompt token ids.  Each cached chunk is
one trie node keyed by ``(parent, chunk tokens)`` holding the pool block
with that chunk's K/V.  ``lookup`` walks the trie for the longest cached
prefix; when a request retires, its cached blocks drop to refcount zero
and are **parked** — contents preserved, reclaimable — rather than
recycled, and an LRU sweep evicts parked leaves when the pool is under
pressure (``BlockPool.alloc`` asks its registered ``evictor`` to recycle
parked blocks before declaring OOM).

This module is deliberately host-only and jax-free: the pool hands out
integer block ids; the engine owns the device arrays those ids index
(``models/lm.init_paged_cache`` leaves shaped ``(n_layers, n_pool,
block_size, ...)``) and the device copy of the block tables.

Contracts / invariants (property-tested in tests/test_kv_cache.py):
  * ``alloc(n)`` is all-or-nothing: it returns ``n`` block ids or raises
    ``BlockPoolOOM`` without allocating anything (``try_alloc`` returns
    ``None`` instead) — a half-admitted request can never leak blocks.
    Under pool pressure it first asks the registered evictor to recycle
    parked (zero-ref cached) blocks, LRU-first.
  * Refcounts are never negative: ``free`` of a block that is not owned
    (refcount >= 1) raises loudly — a double-free means two requests
    believe they own the same block, which is cache corruption, not a
    recoverable condition.  ``share`` requires an owned block.
  * A block is in exactly one state: free, owned (refcount >= 1), or
    parked (refcount == 0, cached contents preserved, reclaimable).
    Zero-ref blocks are always reclaimable — either on the free list or
    parked where the evictor can reach them.
  * Eviction never touches a block with refcount > 0: only parked blocks
    are recycled, and only trie leaves (a cached chunk is evicted before
    the parent chunk its hash chains on, so every surviving chain stays
    reachable from the root).
  * Allocation order is deterministic (LIFO free list, FIFO eviction by
    LRU stamp) so paged serving replays are reproducible run to run.
  * Shared prompt blocks are immutable: the engine only writes positions
    ``>= start`` of a request whose blocks below ``start`` are shared,
    and copy-on-writes the boundary block when a full-prefix hit would
    otherwise write position ``L - 1`` into a block it does not own
    exclusively (see ``PrefixIndex.plan``).
"""
from __future__ import annotations

from typing import Any


class BlockPoolOOM(RuntimeError):
    """Raised by ``alloc`` when the pool cannot satisfy a request."""


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of blocks needed to hold ``n_tokens`` positions (>= 1)."""
    return max(1, -(-int(n_tokens) // block_size))


class BlockPool:
    """Fixed pool of ``n_blocks`` refcounted token blocks.

    States: **free** (on the LIFO free list), **owned** (refcount >= 1,
    at least one block table points at it), **parked** (refcount == 0
    but contents preserved for prefix reuse; recycled by the registered
    ``evictor`` under pressure).  Without a registered evictor (plain
    paged serving, no prefix cache) blocks never park and the pool
    degenerates to the PR-4 alloc/free manager.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks <= 0 or block_size <= 0:
            raise ValueError(f"need positive pool dims, got {n_blocks}x{block_size}")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        # LIFO: block 0 is handed out first, and a just-freed block is the
        # next one reused (cache-friendly and deterministic)
        self._free = list(range(self.n_blocks - 1, -1, -1))
        self._ref: dict[int, int] = {}  # owned blocks -> refcount >= 1
        self._parked: set[int] = set()  # zero-ref cached blocks (reclaimable)
        self._cached: set[int] = set()  # blocks a PrefixIndex holds (owned or parked)
        self.evictor: Any = None  # PrefixIndex registers itself here

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return len(self._ref)

    @property
    def reclaimable_blocks(self) -> int:
        """Parked blocks: zero-ref cached prefixes the evictor can recycle."""
        return len(self._parked)

    def refcount(self, b: int) -> int:
        return self._ref.get(b, 0)

    def is_parked(self, b: int) -> bool:
        return b in self._parked

    def can_alloc(self, n: int) -> bool:
        """Could ``alloc(n)`` succeed?  Counts parked blocks only when an
        evictor is registered to actually reclaim them."""
        avail = len(self._free) + (len(self._parked) if self.evictor is not None else 0)
        return n <= avail

    def _make_room(self, n: int) -> None:
        while len(self._free) < n and self.evictor is not None:
            if not self.evictor.evict_one():
                break

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` blocks at refcount 1; all-or-nothing (raises
        BlockPoolOOM).  Under pressure, parked prefix blocks are evicted
        LRU-first before giving up."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        self._make_room(n)
        if n > len(self._free):
            raise BlockPoolOOM(
                f"need {n} blocks, {len(self._free)} free "
                f"(+{len(self._parked)} parked)"
            )
        ids = [self._free.pop() for _ in range(n)]
        for b in ids:
            self._ref[b] = 1
        return ids

    def try_alloc(self, n: int) -> list[int] | None:
        """Like ``alloc`` but returns None on OOM (the chunk-boundary grow
        path treats OOM as an early-retire signal, not an error)."""
        return self.alloc(n) if self.can_alloc(n) else None

    def share(self, ids) -> None:
        """Increment the refcount of owned blocks: a second table now
        points at the same physical block.  Parked blocks must be
        ``reactivate``d instead (0 -> 1 is a state change, not a share)."""
        ids = list(ids)
        bad = [b for b in ids if b not in self._ref]
        if bad:
            raise ValueError(f"share of unowned block(s) {bad}")
        for b in ids:
            self._ref[b] += 1

    def reactivate(self, ids) -> None:
        """Parked -> owned at refcount 1: a prefix-cache hit on a block
        whose last owner already retired."""
        ids = list(ids)
        bad = [b for b in ids if b not in self._parked]
        if bad:
            raise ValueError(f"reactivate of non-parked block(s) {bad}")
        for b in ids:
            self._parked.remove(b)
            self._ref[b] = 1

    def free(self, ids) -> None:
        """Decrement refcounts; a block reaching zero is parked if a
        prefix index holds it (contents stay reclaimable) and recycled to
        the free list otherwise.  Unowned ids raise: a double-free means
        two requests think they own the same block."""
        ids = list(ids)
        bad = [b for b in ids if b not in self._ref]
        if bad:
            raise ValueError(f"free of unowned block(s) {bad}")
        counts: dict[int, int] = {}
        for b in ids:
            counts[b] = counts.get(b, 0) + 1
        over = [b for b, c in counts.items() if c > self._ref[b]]
        if over:
            raise ValueError(f"free decrements below zero for block(s) {over}")
        recycled = []
        for b in ids:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                if b in self._cached:
                    self._parked.add(b)
                else:
                    recycled.append(b)
        # reversed: freeing [a, b] then allocating 2 returns [a, b] again
        self._free.extend(reversed(recycled))

    # ---- prefix-index hooks ----
    def mark_cached(self, b: int) -> None:
        if b not in self._ref and b not in self._parked:
            raise ValueError(f"mark_cached of free block {b}")
        self._cached.add(b)

    def recycle_parked(self, b: int) -> None:
        """Eviction endpoint: a parked block loses its cached contents and
        returns to the free list.  Refuses owned blocks — eviction must
        never touch refcount > 0."""
        if b not in self._parked:
            raise ValueError(f"recycle_parked of non-parked block {b}")
        self._parked.remove(b)
        self._cached.discard(b)
        self._free.append(b)

    def unmark_cached(self, b: int) -> None:
        """Drop the prefix-index claim on a block whose cached chunk was
        never (or will never be) materialized — the rollback half of
        ``PrefixIndex.invalidate``.  An owned block simply loses its
        park-on-free destiny; a block already parked has no owner left to
        reach it, so it returns straight to the free list."""
        self._cached.discard(b)
        if b in self._parked:
            self._parked.remove(b)
            self._free.append(b)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"BlockPool(n_blocks={self.n_blocks}, block_size={self.block_size}, "
            f"free={self.free_blocks}, parked={len(self._parked)})"
        )


class BlockTable:
    """Per-request ordered list of pool block ids.

    ``ids[i]`` backs logical token positions ``[i*bs, (i+1)*bs)``.  The
    table grows via ``extend`` at decode-chunk boundaries and releases
    everything via ``release`` at retire (a release is a refcount
    decrement: shared prefix blocks survive under their other owners or
    park in the prefix index); ``n_tokens_capacity`` is the highest
    position count the table can currently hold.
    """

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self.ids: list[int] = []

    @property
    def n_blocks(self) -> int:
        return len(self.ids)

    @property
    def n_tokens_capacity(self) -> int:
        return len(self.ids) * self.pool.block_size

    def extend_to(self, n_tokens: int) -> bool:
        """Grow to cover ``n_tokens`` positions.  Returns False on OOM
        (nothing allocated) — the caller's early-retire signal."""
        need = blocks_for(n_tokens, self.pool.block_size) - len(self.ids)
        if need <= 0:
            return True
        got = self.pool.try_alloc(need)
        if got is None:
            return False
        self.ids.extend(got)
        return True

    def adopt(self, ids) -> None:
        """Seed the table with already-accounted blocks (shared prefix
        chain + freshly alloc'd suffix blocks, in logical order)."""
        assert not self.ids, "adopt into a non-empty table"
        self.ids = list(ids)

    def release(self) -> None:
        if self.ids:
            self.pool.free(self.ids)
            self.ids = []


class _Node:
    """One cached chunk: trie node keyed by its chunk tokens under its
    parent, holding the pool block with the chunk's K/V."""

    __slots__ = ("chunk", "block", "parent", "children", "stamp")

    def __init__(self, chunk: tuple, block: int, parent: "_Node | None", stamp: int):
        self.chunk = chunk
        self.block = block
        self.parent = parent
        self.children: dict[tuple, _Node] = {}
        self.stamp = stamp


class PrefixPlan:
    """Admission plan for one prompt: what to share, copy, and allocate.

    ``shared``: cached blocks adopted by reference (refcount +1 each).
    ``cow_src``: cached block to copy-on-write, or None.  Set exactly when
    the cache holds the *entire* prompt and the prompt ends on a block
    boundary: the suffix is then the single last prompt token (we still
    need its logits for the first decode token) and its K/V write at
    position ``L - 1`` would mutate the shared boundary block — so that
    block is duplicated into a private copy first.
    ``n_fresh``: private blocks to allocate beyond shared + COW copy
    (suffix prompt blocks + the first decode block), i.e.
    ``blocks_for(L + 1) - len(shared) - (1 if cow)``.
    ``start``: first prompt position the engine must actually prefill;
    positions ``< start`` ride in shared blocks.
    """

    __slots__ = ("tokens", "nodes", "shared", "cow_src", "n_fresh", "start", "n_tokens")

    def __init__(self, tokens, nodes, shared, cow_src, n_fresh, start, n_tokens):
        self.tokens = tokens
        self.nodes = nodes  # matched trie nodes, root-first
        self.shared = shared  # block ids shared by reference
        self.cow_src = cow_src  # block id to copy, or None
        self.n_fresh = n_fresh
        self.start = start
        self.n_tokens = n_tokens  # L (prompt length within the window)


class PrefixIndex:
    """Hash-chain trie over ``block_size``-token chunks of prompt ids.

    Registers itself as the pool's evictor: under allocation pressure the
    least-recently-used parked *leaf* chunk is evicted (leaf-first keeps
    every surviving chain reachable), its block recycled.  Lookup walks
    the trie chunk by chunk for the longest cached prefix; ``plan`` turns
    a lookup into an admission plan (shared chain, optional COW boundary
    copy, fresh-block count) and checks feasibility against the pool
    without mutating anything.
    """

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self.block_size = pool.block_size
        self._root = _Node((), -1, None, 0)
        self._node_of_block: dict[int, _Node] = {}
        self._clock = 0
        pool.evictor = self

    # ---- observability ----
    @property
    def n_cached_blocks(self) -> int:
        return len(self._node_of_block)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    @staticmethod
    def _chunks(tokens, bs: int):
        L = len(tokens)
        for i in range(L // bs):
            yield tuple(int(t) for t in tokens[i * bs : (i + 1) * bs])

    def lookup(self, tokens) -> list[_Node]:
        """Longest cached prefix: matched trie nodes, root-first."""
        node, out = self._root, []
        for chunk in self._chunks(tokens, self.block_size):
            nxt = node.children.get(chunk)
            if nxt is None:
                break
            out.append(nxt)
            node = nxt
        return out

    def plan(self, tokens, n_reserve_tokens: int | None = None) -> PrefixPlan | None:
        """Admission plan for ``tokens`` (already window-truncated), or
        None when the pool cannot cover it even after evicting every
        parked block not needed by the plan itself.  Pure: nothing is
        shared, allocated, or evicted until ``commit``.

        ``n_reserve_tokens`` defaults to ``len(tokens) + 1`` — prompt
        plus the first decode token, exactly what the PR-4 admission gate
        reserves so same-pass admits can never starve each other."""
        L = len(tokens)
        n_total = blocks_for(
            L + 1 if n_reserve_tokens is None else n_reserve_tokens, self.block_size
        )
        nodes = self.lookup(tokens)
        matched = len(nodes) * self.block_size
        if matched == L and nodes:
            # full-prefix hit ending on a block boundary: recompute only
            # the last prompt token (its logits seed decode) and COW the
            # boundary block its K/V write would otherwise mutate
            start, shared_nodes, cow = L - 1, nodes[:-1], nodes[-1]
        else:
            start, shared_nodes, cow = matched, nodes, None
        shared = [n.block for n in shared_nodes]
        n_fresh = n_total - len(shared) - (1 if cow is not None else 0)
        # feasibility: fresh + COW copy must come from free blocks plus
        # parked blocks OUTSIDE the plan's own chain (evicting a block we
        # are about to share/copy would be self-defeating)
        pinned = {n.block for n in nodes}
        reclaimable = sum(1 for b in self.pool._parked if b not in pinned)
        need = n_fresh + (1 if cow is not None else 0)
        if need > self.pool.free_blocks + reclaimable:
            return None
        return PrefixPlan(tokens, nodes, shared, None if cow is None else cow.block,
                          n_fresh, start, L)

    def commit(self, plan: PrefixPlan) -> tuple[list[int], int | None]:
        """Execute a plan: acquire the shared chain (share / reactivate),
        allocate the COW copy and fresh blocks (evicting parked blocks
        under pressure — the chain is pinned first, so eviction can never
        touch it), and register the prompt chunks this request will
        compute.  Returns ``(table_ids, cow_dst)``: the request's block
        table in logical order, and the private copy destination the
        engine must fill from ``plan.cow_src`` on device (None when no
        COW).

        When ``cow_dst`` is not None, ``plan.cow_src`` is returned STILL
        PINNED (refcount +1): the caller must ``pool.free([cow_src])``
        only after dispatching the device copy.  Unpinning earlier would
        let a later same-pass commit under pool pressure evict and
        re-allocate the source before the copy reads it."""
        pool, stamp = self.pool, self._tick()
        for n in plan.nodes:
            n.stamp = stamp  # LRU touch on every matched chunk
        # 1. pin the shared chain before any allocation can evict it
        for b in plan.shared:
            if pool.is_parked(b):
                pool.reactivate([b])
            else:
                pool.share([b])
        cow = plan.cow_src is not None
        if cow:
            # pin the source so allocation pressure cannot evict it before
            # the engine's device copy reads it (eviction never touches
            # refcount >= 1).  The pin survives commit — the caller
            # releases it after dispatching the copy
            if pool.is_parked(plan.cow_src):
                pool.reactivate([plan.cow_src])
            else:
                pool.share([plan.cow_src])
        try:
            got = pool.alloc(plan.n_fresh + (1 if cow else 0))
        except BlockPoolOOM:
            # plan() said feasible and the consumer is single-threaded,
            # so this means the caller raced the pool — unwind loudly
            if cow:
                pool.free([plan.cow_src])
            if plan.shared:
                pool.free(plan.shared)
            raise
        cow_dst = got[0] if cow else None
        fresh = got[1:] if cow else got
        table = plan.shared + ([cow_dst] if cow_dst is not None else []) + fresh
        # 2. register the full prompt chunks this request computes (the
        # COW copy stays private: its original chunk is already cached)
        node = plan.nodes[-1] if plan.nodes else self._root
        chunks = list(self._chunks(plan.tokens, self.block_size))
        for i in range(len(plan.nodes), len(chunks)):
            node = self._insert_child(node, chunks[i], table[i], stamp)
        return table, cow_dst

    def _insert_child(self, parent: _Node, chunk: tuple, block: int, stamp: int) -> _Node:
        assert chunk not in parent.children, "duplicate chunk insert"
        node = _Node(chunk, block, parent, stamp)
        parent.children[chunk] = node
        self._node_of_block[block] = node
        self.pool.mark_cached(block)
        return node

    def invalidate(self, block_ids) -> None:
        """Unregister chunks that were committed but never materialized —
        the rollback path when an admission is force-done (dependency
        deadlock) before its prefill ran.  Leaf-first, like eviction, so
        every surviving chain stays root-reachable; a chunk whose children
        are NOT in the same invalidation set would orphan a live chain
        and raises instead (callers force-done whole dependent groups, so
        descendants of an invalidated chunk are always invalidated too).
        Blocks stay owned by the caller's table — ``unmark_cached`` only
        removes the park-on-free claim, so the subsequent table release
        recycles them as plain blocks."""
        todo = [b for b in block_ids if b in self._node_of_block]
        while todo:
            progressed = False
            for b in list(todo):
                node = self._node_of_block[b]
                if node.children:
                    continue  # interior: wait for its chunks to go first
                del node.parent.children[node.chunk]
                del self._node_of_block[b]
                self.pool.unmark_cached(b)
                todo.remove(b)
                progressed = True
            if not progressed:
                raise ValueError(
                    f"invalidate of chunk(s) with live cached children: {todo}"
                )

    # ---- eviction (BlockPool.evictor protocol) ----
    def evict_one(self) -> bool:
        """Recycle the LRU parked leaf chunk.  Returns False when nothing
        is evictable (every cached block is owned or has cached
        children)."""
        victim: _Node | None = None
        for b in self.pool._parked:
            node = self._node_of_block.get(b)
            if node is None or node.children:
                continue  # not ours / interior chunk: children chain on it
            if victim is None or node.stamp < victim.stamp:
                victim = node
        if victim is None:
            return False
        del victim.parent.children[victim.chunk]
        del self._node_of_block[victim.block]
        self.pool.recycle_parked(victim.block)
        return True
