"""Paged KV-cache block pool: host-side memory manager for the serving engine.

The contiguous engine layout reserves one ``max_prompt_len +
max_new_tokens`` cache stripe per slot, so a 12-token query pays the same
HBM as the longest allowed prompt and the admitted batch size is pinned to
the number of physical stripes.  The paged layout chops the cache into
fixed-size **token blocks** (``block_size`` positions each) held in one
shared pool; each request owns an ordered **block table** mapping its
logical positions ``[i * block_size, (i + 1) * block_size)`` to pool block
``table[i]``.  Admission allocates just enough blocks to cover the prompt,
decode grows the table one block at a time at chunk boundaries, and retire
returns every block to the pool — so concurrency is bounded by *actual*
tokens resident, not by worst-case stripes.

Blocks are **refcounted** so prompt prefixes can be shared: ``alloc``
hands a block out at refcount 1, ``share`` increments (a second request's
table now points at the same physical block), and ``free`` decrements —
a block is recycled (or parked, see below) only when its count reaches
zero.  The C-FedRAG front door builds every prompt as ``[BOS] CTX
<aggregated chunks> QRY <query> ANS`` with the context preamble first, so
micro-batch siblings and retries repeat the expensive prefix verbatim;
two block tables pointing at one immutable prompt block de-duplicate both
the HBM and the prefill FLOPs that computed it.

``PrefixIndex`` is the lookup structure on top: a hash-chain trie over
``block_size``-token chunks of prompt token ids.  Each cached chunk is
one trie node keyed by ``(parent, chunk tokens)``.  On a resident engine
the index (and the pool) survive across serve calls, so the cache is
**tiered**:

  * **device tier**: the node holds a pool block (``node.block`` is an
    int) with the chunk's K/V in HBM.  Zero-ref device blocks **park**
    (contents preserved, reclaimable).
  * **host tier** (optional, ``HostBlockStore``): under pool pressure a
    parked chunk is *demoted* instead of discarded — its K/V payload is
    fetched to host RAM (``fetch_block`` callback, engine-provided) and
    the device block is recycled; the node stays in the trie with
    ``node.block is None``.  A later prefix hit **re-admits** the chunk:
    ``commit`` allocates a fresh device block, repoints the node, and
    returns the host payload for the engine to ``device_put`` — the
    chunk's K/V is never recomputed.  The store is byte-bounded; over
    budget it drops LRU spilled *leaves* (then the chunk really is gone
    and costs a re-prefill like a plain eviction).

**Leaf-first chain integrity across the tier boundary**: demotion (like
eviction) only takes a node whose children are all already spilled, and
host-side drops only take spilled nodes with no children — so along any
root-to-leaf chain the device-resident nodes form a prefix, the spilled
nodes a contiguous middle, and nothing cached is ever orphaned from the
root.  Re-admission restores whole matched chains in root-first order,
preserving the same shape.

This module is deliberately host-only and jax-free: the pool hands out
integer block ids and the store holds opaque payloads; the engine owns
the device arrays those ids index (``models/lm.init_paged_cache`` leaves
shaped ``(n_layers, n_pool, block_size, ...)``), performs the
device->host fetch at demotion and the host->device upload at
re-admission, and keeps the device copy of the block tables.

**Sharded pools** (``BlockPool(n_blocks, bs, n_shards=N)``): global
block ids partition into N contiguous per-shard ranges of ``n_blocks //
N`` each (``shard_of(b) = b // n_local``), matching device pool leaves
laid out ``(n_layers, n_shards, n_local + 1, block_size, kv, hd)`` and
sharded ``P(None, "data", ...)`` — each device holds exactly its own
shard's blocks plus a per-shard trash block at local index ``n_local``.
The allocator stays a single host-side global authority; allocation is
shard-local and **row-affine**: a ``BlockTable`` pins its shard on first
alloc, a ``PrefixIndex`` chain records its shard at insert and keeps it
across demotion, so every request's whole KV chain (and its cached
prefixes) lives on exactly one shard.  The spill tier stays keyed by
global block id / trie node; re-admission allocates on the recorded
owning shard so the engine's ``device_put`` lands the payload back on
the same device.  Row affinity is what lets the distributed mixed
dispatch mask non-owner shards to exact zeros and combine partials
bit-identically to a single-shard run (see ``serving/dist_decode.py``).

Contracts / invariants (property-tested in tests/test_kv_cache.py):
  * ``alloc(n)`` is all-or-nothing: it returns ``n`` block ids or raises
    ``BlockPoolOOM`` without allocating anything (``try_alloc`` returns
    ``None`` instead) — a half-admitted request can never leak blocks.
    Under pool pressure it first asks the registered evictor to demote
    (or, with no spill store, recycle) parked blocks, LRU-first.
  * Refcounts are never negative: ``free`` of a block that is not owned
    (refcount >= 1) raises loudly — a double-free means two requests
    believe they own the same block, which is cache corruption, not a
    recoverable condition.  ``share`` requires an owned block.
  * A device block is in exactly one state: free, owned (refcount >= 1),
    or parked (refcount == 0, cached contents preserved, reclaimable);
    a cached *chunk* is in exactly one tier: device-backed (its node
    holds a block in one of those states) or spilled (payload in the
    host store, ``node.block is None``).  The store's ``used_bytes``
    never exceeds ``max_bytes``.
  * Eviction/demotion never touches a block with refcount > 0, and only
    takes chunks whose children are already off-device (leaf-first), so
    every surviving chain stays reachable from the root.
  * Allocation order is deterministic (LIFO free list, FIFO
    eviction/demotion by LRU stamp) so paged serving replays are
    reproducible run to run.
  * Shared prompt blocks are immutable: the engine only writes positions
    ``>= start`` of a request whose blocks below ``start`` are shared,
    and copy-on-writes the boundary block when a full-prefix hit would
    otherwise write position ``L - 1`` into a block it does not own
    exclusively (see ``PrefixIndex.plan``).  A spilled boundary chunk
    needs no device copy at all: its payload uploads straight into the
    request's private block.
"""
from __future__ import annotations

from typing import Any, Callable


class BlockPoolOOM(RuntimeError):
    """Raised by ``alloc`` when the pool cannot satisfy a request."""


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of blocks needed to hold ``n_tokens`` positions (>= 1)."""
    return max(1, -(-int(n_tokens) // block_size))


class BlockPool:
    """Fixed pool of ``n_blocks`` refcounted token blocks.

    States: **free** (on the LIFO free list), **owned** (refcount >= 1,
    at least one block table points at it), **parked** (refcount == 0
    but contents preserved for prefix reuse; demoted or recycled by the
    registered ``evictor`` under pressure).  Without a registered
    evictor (plain paged serving, no prefix cache) blocks never park and
    the pool degenerates to the PR-4 alloc/free manager.

    **Sharded pools** (``n_shards > 1``): global block ids partition into
    ``n_shards`` contiguous ranges of ``n_blocks // n_shards`` ids each;
    block ``b`` lives on shard ``b // (n_blocks // n_shards)``.  The
    allocator stays a single host-side authority, but every allocation is
    shard-local (one LIFO free list per shard) so a request's whole block
    table lands on ONE shard — the row-affinity contract the distributed
    mixed dispatch's exact-zero masking depends on.  ``alloc`` with no
    explicit shard picks the shard with the most headroom (ties break
    low), and ``can_alloc`` answers "could some single shard hold n".
    With ``n_shards == 1`` every path reduces bit-for-bit to the
    unsharded allocator (same LIFO order, same eviction order).
    """

    def __init__(self, n_blocks: int, block_size: int, n_shards: int = 1):
        if n_blocks <= 0 or block_size <= 0:
            raise ValueError(f"need positive pool dims, got {n_blocks}x{block_size}")
        if n_shards <= 0 or n_blocks % n_shards:
            raise ValueError(
                f"n_blocks={n_blocks} must divide evenly over n_shards={n_shards}"
            )
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self.n_shards = int(n_shards)
        self._n_local = self.n_blocks // self.n_shards
        # LIFO per shard: the shard's lowest block id is handed out first,
        # and a just-freed block is the next one reused (cache-friendly
        # and deterministic); with one shard this is the classic flat list
        self._frees = [
            list(range((s + 1) * self._n_local - 1, s * self._n_local - 1, -1))
            for s in range(self.n_shards)
        ]
        self._ref: dict[int, int] = {}  # owned blocks -> refcount >= 1
        self._parked: set[int] = set()  # zero-ref cached blocks (reclaimable)
        self._cached: set[int] = set()  # blocks a PrefixIndex holds (owned or parked)
        self.evictor: Any = None  # PrefixIndex registers itself here

    @property
    def _free(self) -> list[int]:
        """Flat view of every free block id (read-only; shard lists are
        authoritative)."""
        return [b for fl in self._frees for b in fl]

    @property
    def free_blocks(self) -> int:
        return sum(len(fl) for fl in self._frees)

    @property
    def free_blocks_by_shard(self) -> list[int]:
        return [len(fl) for fl in self._frees]

    def shard_of(self, b: int) -> int:
        """Owning shard of block ``b`` (its global id's range)."""
        return int(b) // self._n_local

    def _parked_on(self, shard: int) -> int:
        return sum(1 for b in self._parked if b // self._n_local == shard)

    @property
    def used_blocks(self) -> int:
        return len(self._ref)

    @property
    def reclaimable_blocks(self) -> int:
        """Parked blocks: zero-ref cached prefixes the evictor can recycle."""
        return len(self._parked)

    def refcount(self, b: int) -> int:
        return self._ref.get(b, 0)

    def is_parked(self, b: int) -> bool:
        return b in self._parked

    def _headroom(self, shard: int) -> int:
        return len(self._frees[shard]) + (
            self._parked_on(shard) if self.evictor is not None else 0
        )

    def pick_shard(self, n: int) -> int:
        """Shard with the most headroom (free + reclaimable-parked); ties
        break toward the lowest shard id for deterministic replays."""
        return max(range(self.n_shards), key=lambda s: (self._headroom(s), -s))

    def can_alloc(self, n: int, shard: int | None = None) -> bool:
        """Could ``alloc(n)`` succeed?  Counts parked blocks only when an
        evictor is registered to actually reclaim them.  All ``n`` blocks
        must come from ONE shard (row affinity); ``shard=None`` asks
        whether the best shard could hold them."""
        if shard is None:
            shard = self.pick_shard(n)
        return n <= self._headroom(shard)

    def _make_room(self, n: int, shard: int) -> None:
        while len(self._frees[shard]) < n and self.evictor is not None:
            if not self.evictor.evict_one(shard=shard):
                break

    def alloc(self, n: int, shard: int | None = None) -> list[int]:
        """Take ``n`` blocks at refcount 1 from one shard; all-or-nothing
        (raises BlockPoolOOM).  Under pressure, parked prefix blocks *on
        that shard* are demoted to the host tier (or evicted outright)
        LRU-first before giving up.  ``shard=None`` picks the shard with
        the most headroom."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if shard is None:
            shard = self.pick_shard(n)
        self._make_room(n, shard)
        fl = self._frees[shard]
        if n > len(fl):
            raise BlockPoolOOM(
                f"need {n} blocks on shard {shard}, {len(fl)} free "
                f"(+{self._parked_on(shard)} parked)"
            )
        ids = [fl.pop() for _ in range(n)]
        for b in ids:
            self._ref[b] = 1
        return ids

    def try_alloc(self, n: int, shard: int | None = None) -> list[int] | None:
        """Like ``alloc`` but returns None on OOM (the chunk-boundary grow
        path treats OOM as an early-retire signal, not an error)."""
        if shard is None and self.n_shards > 1:
            shard = self.pick_shard(n)
        return self.alloc(n, shard=shard) if self.can_alloc(n, shard=shard) else None

    def share(self, ids) -> None:
        """Increment the refcount of owned blocks: a second table now
        points at the same physical block.  Parked blocks must be
        ``reactivate``d instead (0 -> 1 is a state change, not a share)."""
        ids = list(ids)
        bad = [b for b in ids if b not in self._ref]
        if bad:
            raise ValueError(f"share of unowned block(s) {bad}")
        for b in ids:
            self._ref[b] += 1

    def reactivate(self, ids) -> None:
        """Parked -> owned at refcount 1: a prefix-cache hit on a block
        whose last owner already retired."""
        ids = list(ids)
        bad = [b for b in ids if b not in self._parked]
        if bad:
            raise ValueError(f"reactivate of non-parked block(s) {bad}")
        for b in ids:
            self._parked.remove(b)
            self._ref[b] = 1

    def free(self, ids) -> None:
        """Decrement refcounts; a block reaching zero is parked if a
        prefix index holds it (contents stay reclaimable) and recycled to
        the free list otherwise.  Unowned ids raise: a double-free means
        two requests think they own the same block."""
        ids = list(ids)
        bad = [b for b in ids if b not in self._ref]
        if bad:
            raise ValueError(f"free of unowned block(s) {bad}")
        counts: dict[int, int] = {}
        for b in ids:
            counts[b] = counts.get(b, 0) + 1
        over = [b for b, c in counts.items() if c > self._ref[b]]
        if over:
            raise ValueError(f"free decrements below zero for block(s) {over}")
        recycled = []
        for b in ids:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                if b in self._cached:
                    self._parked.add(b)
                else:
                    recycled.append(b)
        # reversed: freeing [a, b] then allocating 2 returns [a, b] again;
        # each block returns to its owning shard's list
        for b in reversed(recycled):
            self._frees[self.shard_of(b)].append(b)

    # ---- prefix-index hooks ----
    def mark_cached(self, b: int) -> None:
        if b not in self._ref and b not in self._parked:
            raise ValueError(f"mark_cached of free block {b}")
        self._cached.add(b)

    def recycle_parked(self, b: int) -> None:
        """Eviction/demotion endpoint: a parked block's device contents
        are released and the block returns to the free list.  Refuses
        owned blocks — eviction must never touch refcount > 0."""
        if b not in self._parked:
            raise ValueError(f"recycle_parked of non-parked block {b}")
        self._parked.remove(b)
        self._cached.discard(b)
        self._frees[self.shard_of(b)].append(b)

    def unmark_cached(self, b: int) -> None:
        """Drop the prefix-index claim on a block whose cached chunk was
        never (or will never be) materialized — the rollback half of
        ``PrefixIndex.invalidate``.  An owned block simply loses its
        park-on-free destiny; a block already parked has no owner left to
        reach it, so it returns straight to the free list."""
        self._cached.discard(b)
        if b in self._parked:
            self._parked.remove(b)
            self._frees[self.shard_of(b)].append(b)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"BlockPool(n_blocks={self.n_blocks}, block_size={self.block_size}, "
            f"free={self.free_blocks}, parked={len(self._parked)})"
        )


class BlockTable:
    """Per-request ordered list of pool block ids.

    ``ids[i]`` backs logical token positions ``[i*bs, (i+1)*bs)``.  The
    table grows via ``extend`` at decode-chunk boundaries and releases
    everything via ``release`` at retire (a release is a refcount
    decrement: shared prefix blocks survive under their other owners or
    park in the prefix index); ``n_tokens_capacity`` is the highest
    position count the table can currently hold.

    On a sharded pool the first allocation pins the table's ``shard``
    (the pool's pick); every later grow allocates on the same shard, so
    a request's entire KV chain is resident on one shard — the
    row-affinity invariant behind the distributed dispatch's bit-parity.
    """

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self.ids: list[int] = []
        self.shard: int | None = None

    @property
    def n_blocks(self) -> int:
        return len(self.ids)

    @property
    def n_tokens_capacity(self) -> int:
        return len(self.ids) * self.pool.block_size

    def extend_to(self, n_tokens: int) -> bool:
        """Grow to cover ``n_tokens`` positions.  Returns False on OOM
        (nothing allocated) — the caller's early-retire signal."""
        need = blocks_for(n_tokens, self.pool.block_size) - len(self.ids)
        if need <= 0:
            return True
        got = self.pool.try_alloc(need, shard=self.shard)
        if got is None:
            return False
        self.ids.extend(got)
        self.shard = self.pool.shard_of(self.ids[0])
        return True

    def adopt(self, ids) -> None:
        """Seed the table with already-accounted blocks (shared prefix
        chain + freshly alloc'd suffix blocks, in logical order)."""
        assert not self.ids, "adopt into a non-empty table"
        self.ids = list(ids)
        self.shard = self.pool.shard_of(self.ids[0]) if self.ids else None

    def release(self) -> None:
        if self.ids:
            self.pool.free(self.ids)
            self.ids = []
        self.shard = None


class HostBlockStore:
    """Bounded host-RAM tier for demoted prefix-cache chunks.

    Holds opaque per-chunk payloads (whatever the engine's
    ``fetch_block`` produced — this module never looks inside) under a
    hard ``max_bytes`` budget.  ``put`` makes room by asking its
    registered ``evictor`` (the ``PrefixIndex``) to drop LRU spilled
    leaves; if the budget still cannot fit the payload, ``put`` returns
    False and the caller falls back to a plain eviction.  Host-only and
    jax-free, like the pool.
    """

    def __init__(self, max_bytes: int):
        if max_bytes <= 0:
            raise ValueError(f"HostBlockStore needs a positive byte budget, got {max_bytes}")
        self.max_bytes = int(max_bytes)
        self.used_bytes = 0
        self._entries: dict[Any, tuple[Any, int]] = {}
        self.evictor: Any = None  # PrefixIndex registers itself here
        # lifetime counters (observability)
        self.n_puts = 0
        self.n_drops = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    def put(self, key, payload, nbytes: int) -> bool:
        """Store ``payload`` under ``key``; True on success.  Makes room
        by dropping LRU spilled leaves via the evictor; refuses (False,
        nothing stored) if the payload cannot fit the budget at all."""
        nbytes = int(nbytes)
        if key in self._entries:
            raise ValueError(f"duplicate spill key {key!r}")
        if nbytes > self.max_bytes:
            return False
        while self.used_bytes + nbytes > self.max_bytes:
            if self.evictor is None or not self.evictor.drop_one_spilled():
                return False
        self._entries[key] = (payload, nbytes)
        self.used_bytes += nbytes
        self.n_puts += 1
        return True

    def peek(self, key):
        """Payload for ``key`` without removing it (COW-from-host reads
        the chunk's content but leaves the spilled entry authoritative)."""
        return self._entries[key][0]

    def pop(self, key):
        """Remove and return the payload for ``key`` (re-admission moves
        the chunk back to the device tier)."""
        payload, nbytes = self._entries.pop(key)
        self.used_bytes -= nbytes
        return payload

    def drop(self, key) -> None:
        """Discard an entry (store-pressure eviction bookkeeping)."""
        self.pop(key)
        self.n_drops += 1

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"HostBlockStore(entries={len(self._entries)}, "
            f"used={self.used_bytes}/{self.max_bytes}B)"
        )


class _Node:
    """One cached chunk: trie node keyed by its chunk tokens under its
    parent.  Device-backed (``block`` is a pool id) or spilled
    (``block is None``; payload lives in the host store keyed by this
    node).  ``shard`` is the owning shard recorded when the chunk was
    first cached; it survives demotion (``block is None`` keeps the
    coordinate) so re-admission can ``device_put`` the payload back onto
    the same shard's pool slice."""

    __slots__ = ("chunk", "block", "parent", "children", "stamp", "shard")

    def __init__(self, chunk: tuple, block: int | None, parent: "_Node | None", stamp: int,
                 shard: int = 0):
        self.chunk = chunk
        self.block = block
        self.parent = parent
        self.children: dict[tuple, _Node] = {}
        self.stamp = stamp
        self.shard = shard


class PrefixPlan:
    """Admission plan for one prompt: what to share, re-admit, copy, and
    allocate.

    ``shared``: cached device blocks adopted by reference (refcount +1
    each).
    ``readmit``: spilled chain nodes to bring back to the device tier —
    each gets a fresh block at ``commit`` and its host payload is
    returned for the engine to upload.
    ``cow_src``: cached device block to copy-on-write, or None.  Set
    exactly when the cache holds the *entire* prompt on device and the
    prompt ends on a block boundary: the suffix is then the single last
    prompt token (we still need its logits for the first decode token)
    and its K/V write at position ``L - 1`` would mutate the shared
    boundary block — so that block is duplicated into a private copy
    first.  When the boundary chunk is *spilled* instead
    (``host_cow``), no device copy exists or is needed: the host payload
    uploads straight into the request's private block and the spilled
    entry stays authoritative.
    ``n_fresh``: private blocks to allocate beyond shared + re-admitted +
    COW copy (suffix prompt blocks + the first decode block).
    ``start``: first prompt position the engine must actually prefill;
    positions ``< start`` ride in shared/re-admitted blocks.
    ``uploads``: filled by ``commit`` — ``(payload, block)`` pairs the
    engine must ``device_put`` before the row's first dispatch.
    """

    __slots__ = ("tokens", "nodes", "shared", "readmit", "cow_node", "cow_src",
                 "host_cow", "n_fresh", "start", "n_tokens", "uploads", "shard")

    def __init__(self, tokens, nodes, shared, readmit, cow_node, n_fresh, start, n_tokens,
                 shard: int = 0):
        self.tokens = tokens
        self.nodes = nodes  # matched trie nodes, root-first
        self.shared = shared  # device block ids shared by reference
        self.readmit = readmit  # spilled chain nodes needing fresh blocks
        self.cow_node = cow_node  # boundary node for a full-prefix hit, or None
        self.cow_src = None if cow_node is None else cow_node.block  # device id or None
        self.host_cow = cow_node is not None and cow_node.block is None
        self.n_fresh = n_fresh
        self.start = start
        self.n_tokens = n_tokens  # L (prompt length within the window)
        self.uploads: list[tuple[Any, int]] = []
        self.shard = shard  # every block in this plan lives here


class PrefixIndex:
    """Hash-chain trie over ``block_size``-token chunks of prompt ids.

    Registers itself as the pool's evictor: under allocation pressure the
    least-recently-used parked chunk whose children are already
    off-device is *demoted* to the host tier (``spill_store`` +
    ``fetch_block`` set) or evicted outright, its device block recycled.
    Lookup walks the trie chunk by chunk for the longest cached prefix
    across both tiers; ``plan`` turns a lookup into an admission plan
    (shared device chain, spilled chunks to re-admit, optional COW
    boundary copy, fresh-block count) and checks feasibility against the
    pool without mutating anything.  The index survives the serve loop
    that populated it — a resident engine re-uses it across calls.
    """

    def __init__(self, pool: BlockPool, spill_store: HostBlockStore | None = None,
                 fetch_block: Callable[[int], tuple[Any, int]] | None = None):
        self.pool = pool
        self.block_size = pool.block_size
        self._root = _Node((), -1, None, 0)
        self._node_of_block: dict[int, _Node] = {}
        self._spilled: set[_Node] = set()
        self._clock = 0
        pool.evictor = self
        self.spill_store = spill_store
        self.fetch_block = fetch_block
        if spill_store is not None:
            if fetch_block is None:
                raise ValueError("spill_store needs a fetch_block callback to demote")
            spill_store.evictor = self
        # commit-in-progress protection: chain nodes about to re-admit
        # must not be dropped by store pressure mid-commit
        self._pinned_spilled: set[_Node] = set()
        # lifetime tier-traffic counters (engine reports deltas per pass)
        self.n_demotions = 0
        self.n_readmits = 0

    # ---- observability ----
    @property
    def n_cached_blocks(self) -> int:
        """Device-tier cached chunks."""
        return len(self._node_of_block)

    @property
    def n_spilled(self) -> int:
        """Host-tier cached chunks."""
        return len(self._spilled)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    @staticmethod
    def _chunks(tokens, bs: int):
        L = len(tokens)
        for i in range(L // bs):
            yield tuple(int(t) for t in tokens[i * bs : (i + 1) * bs])

    def lookup(self, tokens) -> list[_Node]:
        """Longest cached prefix: matched trie nodes, root-first.  The
        chain may cross the tier boundary — device-backed nodes first,
        then spilled ones (demotion is leaf-first, so device nodes always
        form a prefix of the chain)."""
        node, out = self._root, []
        for chunk in self._chunks(tokens, self.block_size):
            nxt = node.children.get(chunk)
            if nxt is None:
                break
            out.append(nxt)
            node = nxt
        return out

    def plan(self, tokens, n_reserve_tokens: int | None = None) -> PrefixPlan | None:
        """Admission plan for ``tokens`` (already window-truncated), or
        None when the pool cannot cover it even after evicting every
        parked block not needed by the plan itself.  Pure: nothing is
        shared, allocated, re-admitted, or evicted until ``commit``.

        ``n_reserve_tokens`` defaults to ``len(tokens) + 1`` — prompt
        plus the first decode token, exactly what the admission gate
        reserves so same-pass admits can never starve each other."""
        L = len(tokens)
        n_total = blocks_for(
            L + 1 if n_reserve_tokens is None else n_reserve_tokens, self.block_size
        )
        nodes = self.lookup(tokens)
        matched = len(nodes) * self.block_size
        if matched == L and nodes:
            # full-prefix hit ending on a block boundary: recompute only
            # the last prompt token (its logits seed decode) and COW the
            # boundary block its K/V write would otherwise mutate
            start, chain, cow = L - 1, nodes[:-1], nodes[-1]
        else:
            start, chain, cow = matched, nodes, None
        shared = [n.block for n in chain if n.block is not None]
        readmit = [n for n in chain if n.block is None]
        n_fresh = n_total - len(chain) - (1 if cow is not None else 0)
        need = n_fresh + len(readmit) + (1 if cow is not None else 0)
        # row affinity: a matched chain pins the plan to the chain's
        # recorded shard (re-admitted chunks go back where they lived);
        # a cold miss goes to the shard with the most headroom
        shard = nodes[0].shard if nodes else self.pool.pick_shard(need)
        # feasibility: fresh + re-admitted + COW copy must come from free
        # blocks plus parked blocks ON THE PLAN'S SHARD and OUTSIDE the
        # plan's own device chain (evicting a block we are about to
        # share/copy is self-defeating)
        pinned = {n.block for n in nodes if n.block is not None}
        reclaimable = sum(
            1 for b in self.pool._parked
            if b not in pinned and self.pool.shard_of(b) == shard
        )
        if need > self.pool.free_blocks_by_shard[shard] + reclaimable:
            return None
        return PrefixPlan(tokens, nodes, shared, readmit, cow, n_fresh, start, L,
                          shard=shard)

    def commit(self, plan: PrefixPlan) -> tuple[list[int], int | None]:
        """Execute a plan: acquire the shared device chain (share /
        reactivate), re-admit spilled chain chunks (fresh block each,
        host payload queued on ``plan.uploads`` for the engine's
        device_put), allocate the COW copy and fresh blocks (demoting or
        evicting parked blocks under pressure — the chain is pinned
        first, so eviction can never touch it), and register the prompt
        chunks this request will compute.  Returns ``(table_ids,
        cow_dst)``: the request's block table in logical order, and the
        private boundary-copy destination (None when no COW is needed).

        When ``cow_dst`` is not None AND ``plan.cow_src`` is a device
        block, the source is returned STILL PINNED (refcount +1): the
        caller must ``pool.free([cow_src])`` only after dispatching the
        device copy.  A *spilled* boundary chunk (``plan.host_cow``)
        needs no device copy — its payload rides ``plan.uploads`` into
        the private block directly and nothing stays pinned."""
        pool, stamp = self.pool, self._tick()
        for n in plan.nodes:
            n.stamp = stamp  # LRU touch on every matched chunk, both tiers
        # 1. pin the device chain before any allocation can evict it;
        #    pin the spilled chain against store-pressure drops mid-commit
        for b in plan.shared:
            if pool.is_parked(b):
                pool.reactivate([b])
            else:
                pool.share([b])
        cow = plan.cow_node is not None
        dev_cow = cow and not plan.host_cow
        if dev_cow:
            # pin the source so allocation pressure cannot evict it before
            # the engine's device copy reads it (eviction never touches
            # refcount >= 1).  The pin survives commit — the caller
            # releases it after dispatching the copy
            if pool.is_parked(plan.cow_src):
                pool.reactivate([plan.cow_src])
            else:
                pool.share([plan.cow_src])
        self._pinned_spilled = set(plan.readmit)
        if plan.host_cow:
            self._pinned_spilled.add(plan.cow_node)
        try:
            got = pool.alloc(
                plan.n_fresh + len(plan.readmit) + (1 if cow else 0),
                shard=plan.shard,
            )
        except BlockPoolOOM:
            # plan() said feasible and the consumer is single-threaded,
            # so this means the caller raced the pool — unwind loudly
            if dev_cow:
                pool.free([plan.cow_src])
            if plan.shared:
                pool.free(plan.shared)
            raise
        finally:
            self._pinned_spilled = set()
        k = 0
        plan.uploads = []
        # 2. re-admit spilled chain chunks in root-first order: fresh
        #    device block, table repoint, payload queued for upload.  The
        #    block is owned (refcount 1) by this request and cached — on
        #    retire it parks again like any device-tier chunk
        for node in plan.readmit:
            b = got[k]
            k += 1
            node.block = b
            self._spilled.discard(node)
            self._node_of_block[b] = node
            pool.mark_cached(b)
            plan.uploads.append((self.spill_store.pop(node), b))
            self.n_readmits += 1
        cow_dst = None
        if cow:
            cow_dst = got[k]
            k += 1
            if plan.host_cow:
                # boundary content comes from the host tier: upload into
                # the private block, spilled entry stays authoritative
                plan.uploads.append((self.spill_store.peek(plan.cow_node), cow_dst))
                self.n_readmits += 1
        fresh = got[k:]
        chain = plan.nodes[:-1] if cow else plan.nodes
        table = [n.block for n in chain] + ([cow_dst] if cow_dst is not None else []) + fresh
        # 3. register the full prompt chunks this request computes (the
        # COW copy stays private: its original chunk is already cached)
        node = plan.nodes[-1] if plan.nodes else self._root
        chunks = list(self._chunks(plan.tokens, self.block_size))
        for i in range(len(plan.nodes), len(chunks)):
            node = self._insert_child(node, chunks[i], table[i], stamp)
        return table, cow_dst

    def _insert_child(self, parent: _Node, chunk: tuple, block: int, stamp: int) -> _Node:
        assert chunk not in parent.children, "duplicate chunk insert"
        node = _Node(chunk, block, parent, stamp, shard=self.pool.shard_of(block))
        parent.children[chunk] = node
        self._node_of_block[block] = node
        self.pool.mark_cached(block)
        return node

    def invalidate(self, block_ids) -> None:
        """Unregister chunks that were committed but never materialized —
        the rollback path when an admission is force-done (dependency
        deadlock) or its serve loop is abandoned before its prefill ran.
        Leaf-first, like eviction, so every surviving chain stays
        root-reachable; a chunk whose children are NOT in the same
        invalidation set would orphan a live chain and raises instead
        (callers force-done whole dependent groups, so descendants of an
        invalidated chunk are always invalidated too).  Blocks stay owned
        by the caller's table — ``unmark_cached`` only removes the
        park-on-free claim, so the subsequent table release recycles them
        as plain blocks."""
        todo = [b for b in block_ids if b in self._node_of_block]
        while todo:
            progressed = False
            for b in list(todo):
                node = self._node_of_block[b]
                if node.children:
                    continue  # interior: wait for its chunks to go first
                del node.parent.children[node.chunk]
                del self._node_of_block[b]
                self.pool.unmark_cached(b)
                todo.remove(b)
                progressed = True
            if not progressed:
                raise ValueError(
                    f"invalidate of chunk(s) with live cached children: {todo}"
                )

    # ---- eviction / demotion (BlockPool.evictor protocol) ----
    def _demotable(self, node: _Node) -> bool:
        """Leaf-first across the tier boundary: a chunk may leave the
        device tier only once every child is already off-device."""
        return all(c.block is None for c in node.children.values())

    def _drop_spilled_subtree(self, node: _Node) -> None:
        """Remove every spilled descendant of ``node`` from the trie and
        the store (deepest-first) — the hard-eviction path when a chunk
        with spilled children must leave the trie entirely."""
        for child in list(node.children.values()):
            self._drop_spilled_subtree(child)
            self.spill_store.drop(child)
            self._spilled.discard(child)
            del node.children[child.chunk]

    def evict_one(self, shard: int | None = None) -> bool:
        """Free one device block from the cache, LRU-first among parked
        chunks whose children are already off-device.  With a spill
        store the chunk is *demoted* (payload fetched to host, node
        repointed off-device); without one — or when the store cannot fit
        it — the chunk (and any spilled subtree chaining on it) is
        dropped outright.  ``shard`` restricts victims to that shard's
        blocks (shard-local allocation pressure must free shard-local
        blocks).  Returns False when nothing is reclaimable."""
        cands: list[_Node] = []
        for b in self.pool._parked:
            if shard is not None and self.pool.shard_of(b) != shard:
                continue
            node = self._node_of_block.get(b)
            if node is None or not self._demotable(node):
                continue
            cands.append(node)
        cands.sort(key=lambda n: n.stamp)
        for victim in cands:
            b = victim.block
            if self.spill_store is not None:
                payload, nbytes = self.fetch_block(b)
                if self.spill_store.put(victim, payload, nbytes):
                    victim.block = None
                    self._spilled.add(victim)
                    del self._node_of_block[b]
                    self.pool.recycle_parked(b)
                    self.n_demotions += 1
                    return True
                # the store cannot hold this chunk: fall through to a
                # plain eviction (its spilled subtree must go with it)
            if victim.children:
                if self.spill_store is None:
                    continue  # interior chunk with off-device children: skip
                self._drop_spilled_subtree(victim)
            del victim.parent.children[victim.chunk]
            del self._node_of_block[b]
            self.pool.recycle_parked(b)
            return True
        return False

    # ---- host-store pressure (HostBlockStore.evictor protocol) ----
    def drop_one_spilled(self) -> bool:
        """Drop the LRU spilled *leaf* from the host tier (then the chunk
        is really gone and costs a re-prefill, like a plain eviction).
        Chunks pinned by an in-progress ``commit`` are never dropped."""
        victim: _Node | None = None
        for node in self._spilled:
            if node.children or node in self._pinned_spilled:
                continue
            if victim is None or node.stamp < victim.stamp:
                victim = node
        if victim is None:
            return False
        self.spill_store.drop(victim)
        self._spilled.discard(victim)
        del victim.parent.children[victim.chunk]
        return True
