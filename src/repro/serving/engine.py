"""RAG serving engine: a resident, multi-tenant continuous-batching core
over a slot pool with a contiguous- or paged-KV cache.

Request flow (paper Fig. 2/3 in serving form):
  query -> federated retrieval (core.retrieval / orchestrator)
        -> enclave re-rank -> prompt build -> slot prefill -> decode chunks

Serving modes (all share the slot-state contract):

  * **Lock-step** (``step_batch``): drain the queue in fixed ``max_batch``
    chunks, one packed prefill + one fused decode ``while_loop`` per
    chunk.  Kept as the deterministic baseline the continuous path is
    parity-tested (and benchmarked) against.  Always contiguous.
  * **Continuous, contiguous** (``serve_stream`` with ``paged=False``): a
    fixed pool of ``max_batch`` decode slots over per-slot cache stripes.
    Finished rows (EOS or per-request budget) retire and free their slot;
    the ``Scheduler`` admits queued requests into free slots — bucketed
    into power-of-2 groups so ``k`` waiting requests cost ``O(log k)``
    fused prefill+scatter dispatches instead of ``k`` — while the other
    slots keep decoding.  Decode runs in fused chunks of at most
    ``sched_chunk`` steps with ONE host sync per chunk.  This path is the
    second parity oracle next to lock-step.
  * **Continuous, paged** (``paged=True``): ALWAYS the **unified chunked
    prefill** loop (``_serve_unified``).  Every engine step issues ONE
    ``_mixed_rows`` call over per-row ``(q_start, q_len)`` descriptors —
    prompt tokens are chunked across steps (at most ``token_budget``
    query lanes per step, shared with the 1-lane decode rows), so a long
    prompt arrival never stalls in-flight decodes behind a monolithic
    prefill, and the dispatch count per step is O(1) regardless of how
    many requests are admitting.  The kernel underneath
    (``kernels/chunked_prefill``) reads prefix K/V straight from the
    block pool, so the prefix cache works with ``attn_impl="pallas"``,
    prompts longer than ``attn_chunk``, and non-f32 caches — cold and
    warm rows both attend through the pool, making hit-vs-miss parity
    structural.  (The legacy dense+suffix admission pipeline and its
    dependency-wave machinery were retired once this path reached
    bit-parity everywhere; the lock-step and contiguous engines are the
    surviving oracles.)

**Resident state.**  A paged engine is a long-lived service: the device
cache, ``BlockPool``, per-slot ``BlockTable``s, and the ``PrefixIndex``
are created lazily on first use and survive across ``serve`` /
``serve_stream`` calls, so a repeated system preamble is a prefix HIT on
the second call — no re-prefill.  ``reset_cache()`` drops everything for
an explicitly cold start.  With ``ServeConfig.spill_bytes`` set the
prefix cache is **tiered**: parked chains evicted under pool pressure
*demote* their K/V to a bounded host-RAM ``HostBlockStore`` and come
back via ``device_put`` + table repoint instead of re-prefill (see
``serving/kv_cache``).

**Tenants.**  Admission order is the scheduler's: per-tenant SLO classes
(priority preempts the *queue*, weighted-fair within a class, FIFO
within a tenant).  The engine never preempts a running slot — an
admitted request decodes to EOS/budget/OOM on its own terms — and
reports per-tenant admission + prefix gauges back through
``Scheduler.record_tenant_admit``.

Degradation contract (terminal, flagged, neighbors unharmed):
  * ``truncated`` — force-retired on KV-pool OOM at a growth boundary;
    the answer is a prefix of what the budget allowed.
  * ``deadlocked`` — force-retired empty when an admission waits on
    cached chunks no in-flight fill will materialize
    (``AdmissionDeadlock`` from the ``pending_blocks`` resolver;
    unreachable with commit-ordered deps, but degrading beats wedging).
  * ``expired`` — dropped by the scheduler at its admission deadline.
  * ``degraded`` (pipeline-level, ``core/pipeline``) — a federation
    round that missed quorum; the serving layers above still answer.

Cache layouts (``ServeConfig.paged`` selects; bit-identical for the
same admission order):

  * **Contiguous** (default): every cache leaf is ``(n_layer_blocks, B,
    cache_len, ...)`` — one ``max_prompt_len + max_new_tokens`` stripe
    per slot.  Simple, but a short query pays worst-case HBM and
    ``max_batch`` is pinned to physical stripes.
  * **Paged** (``paged=True``): attention K/V live in one shared pool of
    ``n_pool_blocks`` fixed-size token blocks — leaves ``(n_layer_blocks,
    n_pool_blocks + 1, block_size, kv, hd)`` (the ``+1`` is a trash block
    that unallocated table entries point at) — indexed through per-slot
    block tables ``(B, cache_len_padded / block_size)``.  A
    ``serving/kv_cache.BlockPool`` allocates blocks at admission
    (``ceil(prompt_len / block_size)``), grows tables incrementally at
    decode-chunk boundaries, and frees them at retire.  Admission is
    memory-aware: a request is only popped while free blocks cover its
    prompt + first decode token, so ``max_batch`` slots can exceed the
    contiguous stripe count for short-prompt traffic at the same HBM; a
    request that cannot get a block at a chunk boundary is force-retired
    with what it already emitted (its neighbors are never corrupted).
    Requires an all-attention model (SSM/conv state folds the whole
    sequence and cannot resume a chunked prompt).

Both paths pack prompts left-aligned (PAD tail) and decode each row from
its OWN cache position (per-row ``lengths``), so ragged batches never
attend to PAD key/values; rows that hit EOS are masked to PAD for the
rest of their stay in the batch (post-EOS logits are never emitted).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS, PAD
from repro.models import lm as LM
from repro.runtime.sharding import ShardingPolicy
from repro.serving.kv_cache import (
    BlockPool,
    BlockTable,
    HostBlockStore,
    PrefixIndex,
    blocks_for,
)
from repro.serving.scheduler import Request, Scheduler


class AdmissionDeadlock(RuntimeError):
    """Prefix-cache admission dependency resolution stalled: some admitted
    rows wait on cached chunks that no in-flight fill is going to
    materialize.  With deps derived from ``PrefixIndex.commit`` order this
    is unreachable (an admit can only depend on chunks registered by an
    EARLIER admit, so the wait graph is acyclic), but a hang here would
    wedge the whole serve loop — so instead of asserting, the resolver
    raises with whatever DID resolve plus the stuck slots, and the engine
    force-retires the latter with an empty, ``deadlocked``-flagged
    result."""

    def __init__(self, waves: list, stuck: list):
        super().__init__(
            f"admission dependency resolution stalled: {len(stuck)} row(s) wait "
            f"on cached chunks no in-flight fill writes (cyclic prefix deps?)"
        )
        self.waves = waves
        self.stuck = stuck


def resolve_fill_deps(fill_deps: dict[int, frozenset], pending) -> list[int]:
    """Runnable in-flight fills given the ``pending_blocks`` key set.

    ``fill_deps`` maps slot -> the cached-chunk blocks its shared chain /
    COW source reads; ``pending`` is the set of blocks some in-flight
    fill has registered but not yet materialized.  A fill is runnable
    once none of its deps are still pending.  Raises
    :class:`AdmissionDeadlock` (carrying the stuck slots) when fills
    exist but none can run — the engine's cue to force-retire them as
    ``deadlocked`` instead of spinning forever."""
    pending = set(pending)
    runnable = [i for i, deps in sorted(fill_deps.items()) if not (deps & pending)]
    if fill_deps and not runnable:
        raise AdmissionDeadlock([], sorted(fill_deps))
    return runnable


def accept_prefix(draft, target, *, q_len=None, rem=None, done=None, eos=EOS):
    """Greedy draft-k/verify-1 acceptance: per row, the committed run is
    the longest common prefix of ``draft`` and the target's per-lane
    argmaxes PLUS exactly one target-sourced correction token.

    ``draft``: ``(B, k)`` drafter proposals; ``target``: ``(B, k + 1)``
    target argmaxes where lane ``j`` is the target's next token after the
    row has emitted ``target[:j]`` (valid only while ``draft[:j] ==
    target[:j]`` — the causal verify dispatch guarantees this).  Lane
    ``j`` commits iff every draft before it matched, no earlier
    committed lane was EOS (plain decode stops after emitting EOS), and
    the optional clips hold: ``q_len`` (live verify lanes this round),
    ``rem`` (per-row remaining token budget), ``done``.  All clip masks
    are prefix-monotone, so the committed lanes are a contiguous run
    ``target[:n_emit]`` — bit-identical to what plain greedy decode
    would emit one token at a time.

    Returns ``(n_emit, can_emit)``: committed token count ``(B,)`` and
    the per-lane commit mask ``(B, k + 1)``."""
    d = jnp.asarray(draft)
    t = jnp.asarray(target)
    b, k = d.shape
    j = jnp.arange(k + 1)
    one = jnp.ones((b, 1), jnp.int32)
    ok = jnp.cumprod(
        jnp.concatenate([one, (d == t[:, :k]).astype(jnp.int32)], axis=1), axis=1
    ).astype(bool)
    no_eos = jnp.cumprod(
        jnp.concatenate([one, (t[:, :k] != eos).astype(jnp.int32)], axis=1), axis=1
    ).astype(bool)
    can = ok & no_eos
    if q_len is not None:
        can = can & (j[None, :] < jnp.asarray(q_len)[:, None])
    if rem is not None:
        can = can & (j[None, :] < jnp.asarray(rem)[:, None])
    if done is not None:
        can = can & ~jnp.asarray(done)[:, None]
    return can.sum(axis=1).astype(jnp.int32), can


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8  # decode slots (continuous) / chunk size (lock-step)
    max_prompt_len: int = 512
    max_new_tokens: int = 16  # hard cap; per-request budgets clamp to this
    temperature: float = 0.0
    sched_chunk: int = 8  # max fused decode steps between scheduler runs
    paged: bool = False  # paged KV cache (block pool) vs contiguous stripes
    block_size: int = 32  # tokens per KV block (paged mode)
    # pool size in blocks; None -> the HBM of max_batch contiguous stripes,
    # so paged-vs-contiguous comparisons at the default are equal-memory
    n_pool_blocks: int | None = None
    # refcounted prefix cache on the paged pool: admission looks up the
    # longest cached prompt prefix (block-granular hash-chain), shares
    # those blocks into the new request's table, and prefills only the
    # suffix; retired prompt blocks park in an LRU index for reuse.  The
    # index is RESIDENT: it survives across serve calls on this engine
    prefix_cache: bool = False
    # unified chunked prefill query-lane cap per engine step (paged-only;
    # paged engines always run the unified mixed-dispatch loop).  None
    # defaults to max_prompt_len — i.e. a whole prompt may prefill in one
    # step; smaller budgets chunk prompts across steps so arrivals never
    # stall in-flight decodes
    token_budget: int | None = None
    # host-RAM spill tier for the prefix cache, in bytes (requires
    # prefix_cache): parked chains evicted under pool pressure demote
    # their K/V to host memory and re-admit by upload instead of
    # re-prefill.  None disables tiering (eviction discards)
    spill_bytes: int | None = None
    # speculative decoding (draft-k / verify-1, paged-only): a resident
    # drafter model proposes ``draft_k`` greedy tokens per decode slot
    # each round; the target model scores all ``draft_k + 1`` positions
    # in its ONE mixed dispatch (each speculating row becomes a
    # ``(slot, q_start, q_len=k+1, kv_len)`` verify descriptor) and
    # commits the longest matching prefix plus one corrected token.
    # Greedy accept-prefix keeps outputs BIT-identical to plain decode;
    # 0 disables speculation entirely (the engine runs today's path
    # byte-for-byte)
    draft_k: int = 0
    # drafter architecture + params.  None defaults to the target model
    # (self-speculation — useful for parity tests; every draft accepted).
    # A real deployment points these at a small config (e.g.
    # ``configs/smollm_360m``) sharing the target's vocab
    draft_config: ModelConfig | None = None
    draft_params: object | None = None
    # sharded paged serving (paged-only): partition the KV block pool
    # over ``shards`` devices on a "data" mesh axis — pool leaves become
    # ``(n_layer_blocks, shards, n_pool_blocks/shards + 1, bs, kv, hd)``
    # laid out ``P(None, "data", ...)`` and every engine step runs the
    # DISTRIBUTED mixed dispatch (per-shard scatter + partials, merged by
    # ``dist_decode.combine_partials``).  Allocation is row-affine (a
    # request's whole chain on one shard), which makes ``shards=N``
    # bit-identical to ``shards=1`` for the same admission order.
    # ``None`` (default) keeps the single-device unsharded path
    # byte-for-byte; note ``shards=1`` runs the sharded machinery (the
    # bitwise reference for N > 1) and differs from ``None`` only by
    # flash-partials reassociation
    shards: int | None = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, pol: ShardingPolicy, params, scfg: ServeConfig):
        self.cfg, self.pol, self.params, self.scfg = cfg, pol, params, scfg
        cache_len = scfg.max_prompt_len + scfg.max_new_tokens
        self._cache_len = cache_len
        # paged geometry: the logical cache length rounds up to a block
        # multiple so a block table addresses exactly the same number of
        # key positions as a contiguous stripe (bit-parity needs equal
        # lane counts through the masked softmax)
        bs = scfg.block_size
        self._blocks_per_slot = blocks_for(cache_len, bs)
        self._cache_len_padded = self._blocks_per_slot * bs
        if scfg.paged:
            n_pool = (
                scfg.n_pool_blocks
                if scfg.n_pool_blocks is not None
                else scfg.max_batch * self._blocks_per_slot
            )
            if n_pool < self._blocks_per_slot:
                raise ValueError(
                    f"n_pool_blocks={n_pool} cannot hold one max-size request "
                    f"({self._blocks_per_slot} blocks of {bs})"
                )
            self._n_pool_blocks = n_pool
            self._trash_block = n_pool  # extra pool index for masked writes
        # sharded pool geometry + mesh (built once, a closure constant of
        # every jitted step so shard_map never retraces on it)
        self._shards = scfg.shards
        self._mesh = None
        if scfg.shards is not None:
            if not scfg.paged:
                raise ValueError(
                    "shards (sharded paged serving) requires paged=True: only "
                    "the block pool partitions over the mesh"
                )
            if scfg.shards < 1:
                raise ValueError(f"shards={scfg.shards} must be >= 1")
            if self._n_pool_blocks % scfg.shards:
                raise ValueError(
                    f"n_pool_blocks={self._n_pool_blocks} must divide evenly "
                    f"over shards={scfg.shards}"
                )
            self._n_local = self._n_pool_blocks // scfg.shards
            if self._n_local < self._blocks_per_slot:
                raise ValueError(
                    f"per-shard pool ({self._n_local} blocks) cannot hold one "
                    f"max-size request ({self._blocks_per_slot} blocks): "
                    "allocation is row-affine, a request never spans shards"
                )
            devs = jax.devices()
            if len(devs) < scfg.shards:
                raise ValueError(
                    f"shards={scfg.shards} needs that many devices, have "
                    f"{len(devs)} (CPU: set XLA_FLAGS="
                    "--xla_force_host_platform_device_count before importing jax)"
                )
            from repro.runtime import compat

            self._mesh = compat.make_mesh(
                np.array(devs[: scfg.shards]), ("data",)
            )
        if scfg.prefix_cache and not scfg.paged:
            raise ValueError(
                "prefix_cache=True requires paged=True: block tables are "
                "what make prompt prefixes shareable"
            )
        if scfg.spill_bytes is not None:
            if not scfg.prefix_cache:
                raise ValueError(
                    "spill_bytes (host spill tier) requires prefix_cache=True: "
                    "only cached prefix chains are demotable"
                )
            if scfg.spill_bytes < 1:
                raise ValueError(f"spill_bytes={scfg.spill_bytes} must be >= 1")
        if scfg.token_budget is not None:
            if scfg.token_budget < 1:
                raise ValueError(f"token_budget={scfg.token_budget} must be >= 1")
            if not scfg.paged:
                raise ValueError(
                    "token_budget (unified chunked prefill) requires "
                    "paged=True: mixed dispatches read and write K/V "
                    "through the shared block pool"
                )
        if scfg.paged and any(cfg.mixer_kind(i) != "attn" for i in range(cfg.n_layers)):
            raise ValueError(
                "paged serving runs the unified chunked-prefill path, which "
                "requires an all-attention model: SSM/conv state folds the "
                "whole sequence and cannot resume a chunked prompt"
            )
        # paged -> unified: the mixed-dispatch loop is the only paged path
        self._unified = scfg.paged
        self._token_budget = (
            scfg.token_budget if scfg.token_budget is not None else scfg.max_prompt_len
        )
        if scfg.draft_k < 0:
            raise ValueError(f"draft_k={scfg.draft_k} must be >= 0")
        if scfg.draft_k > 0:
            if not scfg.paged:
                raise ValueError(
                    "draft_k (speculative decoding) requires paged=True: the "
                    "verify dispatch reads and writes K/V through the shared "
                    "block pool"
                )
            if self._token_budget < scfg.draft_k + 1:
                raise ValueError(
                    f"token_budget={self._token_budget} cannot fit one verify "
                    f"descriptor of q_len={scfg.draft_k + 1} (draft_k + 1)"
                )
            if scfg.draft_config is not None and scfg.draft_params is None:
                raise ValueError(
                    "draft_config without draft_params: a drafter with its "
                    "own architecture needs its own weights"
                )
            dcfg = scfg.draft_config if scfg.draft_config is not None else cfg
            if dcfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"drafter vocab_size={dcfg.vocab_size} != target "
                    f"vocab_size={cfg.vocab_size}: greedy accept-prefix "
                    "compares token ids across the two models"
                )
            if any(dcfg.mixer_kind(i) != "attn" for i in range(dcfg.n_layers)):
                raise ValueError(
                    "draft_config must be all-attention: the drafter decodes "
                    "through its own paged pool"
                )
            self._draft_cfg = dcfg
            self._draft_params = (
                scfg.draft_params if scfg.draft_params is not None else params
            )
        t_cap = scfg.max_new_tokens
        # dispatch observability: fused admit prefills (bucketed admission
        # benchmark), fused decode chunks, and unified mixed steps — the
        # O(1)-dispatch-per-step regression gauges
        self.admit_dispatches = 0
        self.admit_rows_total = 0
        self.decode_dispatches = 0
        self.mixed_dispatches = 0
        # prefix-cache observability (engine lifetime; serve passes report
        # window deltas AND these totals into the scheduler each pass)
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefill_tokens_total = 0
        self.prefill_tokens_saved = 0
        self.prefix_shared_total = 0  # blocks adopted by reference (cumulative)
        # speculative-decoding observability (engine lifetime): one
        # drafter dispatch + one verify dispatch per spec round is the
        # O(2)-dispatch bound CI guards; accept rate and tokens/step
        # derive from the proposed/accepted/emitted tallies
        self.draft_dispatches = 0
        self.draft_fill_dispatches = 0  # drafter prefill-only (admission cost)
        self.spec_rounds = 0
        self.spec_tokens_proposed = 0
        self.spec_tokens_accepted = 0
        self.spec_tokens_emitted = 0
        # resident paged state: created lazily on first paged serve and
        # reused by every later call (reset_cache() drops it)
        self._pool: BlockPool | None = None
        self._row_tables: list[BlockTable] | None = None
        self._tables_h: np.ndarray | None = None
        self._cache = None
        self._index: PrefixIndex | None = None
        self._spill_store: HostBlockStore | None = None
        # drafter resident state (draft_k > 0): a second, independent
        # BlockPool + per-slot tables + paged cache for the drafter —
        # same block geometry as the target pool, sized by the drafter's
        # (smaller) layer stack.  No prefix index: the drafter re-prefills
        # every prompt in full through its own chunked fill lanes
        self._draft_pool: BlockPool | None = None
        self._draft_row_tables: list[BlockTable] | None = None
        self._draft_tables_h: np.ndarray | None = None
        self._draft_cache = None
        self._serving = False

        def prefill_fn(params, tokens, lengths, cache_len=cache_len):
            logits, cache = LM.prefill(cfg, pol, params, {"tokens": tokens}, cache_len=cache_len)
            # logits at each row's true last prompt position -> first token
            last = jnp.take_along_axis(logits, (lengths - 1)[:, None, None], axis=1)[:, 0, :]
            return jnp.argmax(last, -1).astype(jnp.int32), cache

        def decode_loop(params, cache, first_tok, lengths):
            """Device-resident greedy decode: runs until every row has
            emitted EOS or max_new_tokens, with no host round-trips.
            Rows that are already done emit PAD (never fresh argmax)."""
            b = first_tok.shape[0]
            t_max = scfg.max_new_tokens
            out = jnp.zeros((b, t_max), jnp.int32).at[:, 0].set(first_tok)
            state = (jnp.int32(1), cache, first_tok, first_tok == EOS, out)

            def cond(st):
                t, _, _, done, _ = st
                return (t < t_max) & ~jnp.all(done)

            def body(st):
                t, cache, cur, done, out = st
                logits, cache = LM.decode_step(
                    cfg, pol, params, cache, cur[:, None], lengths + t - 1
                )
                nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
                nxt = jnp.where(done, PAD, nxt)  # finished rows stay PAD
                out = out.at[:, t].set(nxt)
                return (t + 1, cache, nxt, done | (nxt == EOS), out)

            t, _, _, _, out = jax.lax.while_loop(cond, body, state)
            return out, t

        def admit_rows(params, cache, cur, lengths, emitted, done, budget, out,
                       rows_tokens, slot_ids, row_lens, b_new):
            """Prefill ``g`` requests and scatter them into contiguous
            cache stripes ``slot_ids`` in a single fused call.  The
            bucketed admission path dispatches waiting requests in
            power-of-2 groups, so the jit trace count is bounded at
            log2(max_batch) group shapes and ``k`` queued requests cost
            O(log k) dispatches, not k."""
            first, row_cache = prefill_fn(params, rows_tokens, row_lens)
            cache = jax.tree.map(
                lambda c, rc: c.at[:, slot_ids].set(rc), cache, row_cache
            )
            g = rows_tokens.shape[0]
            cur = cur.at[slot_ids].set(first)
            lengths = lengths.at[slot_ids].set(row_lens)
            emitted = emitted.at[slot_ids].set(1)
            budget = budget.at[slot_ids].set(b_new)
            out = out.at[slot_ids].set(
                jnp.zeros((g, t_cap + 1), jnp.int32).at[:, 0].set(first)
            )
            done = done.at[slot_ids].set((first == EOS) | (b_new <= 1))
            return cache, cur, lengths, emitted, done, budget, out

        def cow_copy(cache, src, dst):
            return LM.paged_copy_block(cfg, cache, src, dst)

        def is_pool_leaf(leaf):
            # pool-indexed K/V leaves: (n_layer_blocks, n_pool + 1, bs, ...)
            # unsharded, (n_layer_blocks, shards, n_local + 1, bs, ...) sharded
            if not scfg.paged:
                return False
            if self._shards is not None:
                return (
                    leaf.ndim >= 4
                    and leaf.shape[1] == self._shards
                    and leaf.shape[2] == self._n_local + 1
                    and leaf.shape[3] == bs
                )
            return (
                leaf.ndim >= 3
                and leaf.shape[1] == self._n_pool_blocks + 1
                and leaf.shape[2] == bs
            )

        self._is_pool_leaf = is_pool_leaf

        def upload_block(cache, payload, b):
            """Re-admission upload: host-tier K/V payload (one array per
            pool leaf, in ``jax.tree.leaves`` order) lands in pool block
            ``b``.  One trace total — every block has the same shape.  On
            a sharded pool the GLOBAL id resolves to (shard, local), so
            the payload lands on the chunk's recorded owning shard."""
            leaves, treedef = jax.tree.flatten(cache)
            out, j = [], 0
            for leaf in leaves:
                if is_pool_leaf(leaf):
                    if self._shards is not None:
                        s, l = b // self._n_local, b % self._n_local
                        out.append(leaf.at[:, s, l].set(payload[j].astype(leaf.dtype)))
                    else:
                        out.append(leaf.at[:, b].set(payload[j].astype(leaf.dtype)))
                    j += 1
                else:
                    out.append(leaf)
            return jax.tree.unflatten(treedef, out)

        def mixed_rows(params, cache, cur, lengths, emitted, done, budget, out,
                       tok, q_start_h, q_len, is_decode, row_len, b_new, tables):
            """ONE unified engine step: every row — mid-prompt fill, fill
            completion, or 1-token decode — advances through a single
            ``LM.mixed_step`` dispatch driven by per-row ``(q_start,
            q_len)`` descriptors.  Decode rows (``is_decode``) read their
            token from ``cur`` at position ``lengths + emitted - 1`` —
            exactly the ``decode_chunk`` hot loop for one step, so the
            emitted/done/out updates below are bit-compatible with it.
            Fill rows write their prompt chunk's K/V into the pool and
            only touch slot state on the chunk that REACHES ``row_len``
            (``completes``): the final logits lane seeds the slot exactly
            like ``admit_rows``.  Rows with ``q_len == 0`` (budget-starved
            this step) are inert: their lanes score into the trash block
            and no state updates."""
            b = scfg.max_batch
            rows = jnp.arange(b)
            q_start = jnp.where(is_decode, lengths + emitted - 1, q_start_h)
            tok = tok.at[:, 0].set(jnp.where(is_decode, cur, tok[:, 0]))
            logits, cache = LM.mixed_step(
                cfg, pol, params, tok, cache, tables, q_start, q_len, bs,
                mesh=self._mesh,
            )
            last = jnp.take_along_axis(
                logits, jnp.maximum(q_len - 1, 0)[:, None, None], axis=1
            )[:, 0, :]
            nxt = jnp.argmax(last, -1).astype(jnp.int32)
            completes = (~is_decode) & (q_len > 0) & (q_start + q_len >= row_len)
            emit_dec = is_decode & (q_len > 0) & ~done
            # decode lane: token lands at the row's own emitted offset
            idx = jnp.minimum(emitted, t_cap)
            out = out.at[rows, idx].set(jnp.where(emit_dec, nxt, out[rows, idx]))
            # fill completion: seed the slot like admit_rows does
            seeded = jnp.zeros((b, t_cap + 1), jnp.int32).at[:, 0].set(nxt)
            out = jnp.where(completes[:, None], seeded, out)
            cur = jnp.where(completes | emit_dec, nxt, cur)
            lengths = jnp.where(completes, row_len, lengths)
            budget = jnp.where(completes, b_new, budget)
            emitted = jnp.where(completes, 1, emitted + emit_dec)
            done = jnp.where(
                completes,
                (nxt == EOS) | (b_new <= 1),
                done | (emit_dec & ((nxt == EOS) | (emitted >= budget))),
            )
            return cache, cur, lengths, emitted, done, budget, out

        kd = scfg.draft_k

        def spec_mixed_rows(params, cache, cur, lengths, emitted, done, budget, out,
                            tok, q_start_h, q_len, is_spec, drafts, row_len, b_new,
                            tables):
            """ONE unified engine step in speculative mode: fill chunks
            advance exactly as in ``mixed_rows``, while each speculating
            row (``is_spec``) becomes a VERIFY descriptor ``(slot,
            q_start = lengths + emitted - 1, q_len <= draft_k + 1,
            kv_len)``: lane 0 carries the row's last committed token
            ``cur``, lanes 1..q_len-1 carry the drafter's proposals.  The
            target's per-lane argmaxes are what plain greedy decode would
            emit one token at a time, so ``accept_prefix`` commits the
            longest matching run plus one corrected token — bit-identical
            outputs, > 1 token per dispatch.

            Rollback is positional, not a device copy: only ``emitted``
            advances (by ``n_emit``), so rejected lanes' K/V sit BEYOND
            the committed position.  The next round's verify window
            starts at the new ``q_start`` and re-writes every stale
            position before any lane attends to it (the kernel's
            write-then-attend contract), so a rejection can never leak
            state; q_len-masked dead lanes scatter to the trash block as
            always."""
            b = scfg.max_batch
            rows = jnp.arange(b)
            q_start = jnp.where(is_spec, lengths + emitted - 1, q_start_h)
            tok = tok.at[:, 0].set(jnp.where(is_spec, cur, tok[:, 0]))
            tok = tok.at[:, 1 : kd + 1].set(
                jnp.where(is_spec[:, None], drafts, tok[:, 1 : kd + 1])
            )
            logits, cache = LM.verify_step(
                cfg, pol, params, tok, cache, tables, q_start, q_len, bs,
                mesh=self._mesh,
            )
            # fill rows: next token off the chunk's last live lane
            last = jnp.take_along_axis(
                logits, jnp.maximum(q_len - 1, 0)[:, None, None], axis=1
            )[:, 0, :]
            nxt = jnp.argmax(last, -1).astype(jnp.int32)
            completes = (~is_spec) & (q_len > 0) & (q_start + q_len >= row_len)
            # spec rows: per-lane targets + greedy accept-prefix
            tgt = jnp.argmax(logits[:, : kd + 1, :], -1).astype(jnp.int32)
            n_emit, can = accept_prefix(
                drafts, tgt, q_len=q_len, rem=budget - emitted, done=done
            )
            n_emit = jnp.where(is_spec, n_emit, 0)
            can = can & is_spec[:, None]
            # committed run lands at the row's own emitted offsets (the
            # decode_chunk ragged-merge pattern); clamped lanes rewrite
            # the spare t_cap column with its own value
            j = jnp.arange(kd + 1)
            idx = jnp.minimum(emitted[:, None] + j[None, :], t_cap)
            keep = out[rows[:, None], idx]
            out = out.at[rows[:, None], idx].set(jnp.where(can, tgt, keep))
            # fill completion seeds the slot exactly like admit_rows
            seeded = jnp.zeros((b, t_cap + 1), jnp.int32).at[:, 0].set(nxt)
            out = jnp.where(completes[:, None], seeded, out)
            last_emit = jnp.take_along_axis(
                tgt, jnp.maximum(n_emit - 1, 0)[:, None], axis=1
            )[:, 0]
            cur = jnp.where(n_emit > 0, last_emit, cur)
            cur = jnp.where(completes, nxt, cur)
            lengths = jnp.where(completes, row_len, lengths)
            budget = jnp.where(completes, b_new, budget)
            emitted = jnp.where(completes, 1, emitted + n_emit)
            done = jnp.where(
                completes,
                (nxt == EOS) | (b_new <= 1),
                done | ((n_emit > 0) & ((last_emit == EOS) | (emitted >= budget))),
            )
            return cache, cur, lengths, emitted, done, budget, out

        def make_draft_rows(with_fill: bool):
            dcfg = getattr(self, "_draft_cfg", cfg)

            def draft_body(dparams, dcache, cur, dec_pos, d_dec_tables):
                # k greedy drafter steps — ONE host dispatch; each step
                # writes the fed token's K/V then attends, so a stale
                # (rejected) position is always re-written before read.
                # The loop rides mixed_step (q_len=1 lanes), the SAME
                # kernel path the target verifies through: under
                # self-speculation the proposal at a position is then the
                # identical computation to the target's verify lane, so
                # near-tied argmaxes cannot flip between the two models
                # (accept rate hits the drafter-quality ceiling instead
                # of fp-noise)
                one = jnp.ones((scfg.max_batch,), jnp.int32)

                def body(t, st):
                    tok, dc, c = st
                    logits, dc = LM.mixed_step(
                        dcfg, pol, dparams, tok[:, None], dc, d_dec_tables,
                        dec_pos + t, one, bs, mesh=self._mesh,
                    )
                    nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
                    return nxt, dc, c.at[:, t].set(nxt)

                c = jnp.zeros((scfg.max_batch, max(kd, 1)), jnp.int32)
                last, dcache, c = jax.lax.fori_loop(0, kd, body, (cur, dcache, c))
                # write the k-th proposal's K/V too (logits discarded): a
                # full accept advances the committed position PAST it, and
                # an unwritten hole there would corrupt every later draft
                # for the row — write-then-attend must cover all k
                # proposed positions, not just the k-1 the loop feeds
                _, dcache = LM.mixed_step(
                    dcfg, pol, dparams, last[:, None], dcache, d_dec_tables,
                    dec_pos + kd, one, bs, mesh=self._mesh,
                )
                return c, dcache

            if not with_fill:
                return draft_body

            def draft_rows(dparams, dcache, d_tok, d_q_start, d_q_len,
                           cur, dec_pos, d_tables, d_dec_tables):
                """Drafter fill chunks + k draft steps fused into ONE
                dispatch: rows still streaming their prompt into the
                drafter pool advance through a mixed step (q_len == 0
                rows are inert), then every drafter-ready row proposes
                ``draft_k`` greedy tokens.  Rows excluded from drafting
                this round arrive with an all-trash ``d_dec_tables``
                row, so their draft-loop writes land in the trash
                block."""
                _, dcache = LM.mixed_step(
                    dcfg, pol, dparams, d_tok, dcache, d_tables,
                    d_q_start, d_q_len, bs, mesh=self._mesh,
                )
                return draft_body(dparams, dcache, cur, dec_pos, d_dec_tables)

            return draft_rows

        def make_decode_chunk(paged: bool):
            def decode_chunk(params, cache, cur, lengths, emitted, done, budget, out,
                             n_steps, tables=None):
                """Fused decode of up to ``n_steps`` tokens across all
                slots.  Per-slot write offsets (``emitted``) make
                retire/admit cheap: a slot's output row is always its own
                [0, emitted) prefix.  The inner loop writes a dense
                (B, chunk) buffer by step index — exactly the lock-step
                hot loop — and the ragged merge into the per-slot offsets
                happens ONCE per chunk, so continuous batching adds no
                per-token bookkeeping to the decode path.  In paged mode
                every K/V read/write goes through ``tables``; the host
                guarantees each live row's table covers the chunk before
                dispatch (rows it could not grow arrive force-done)."""
                b = scfg.max_batch
                rows = jnp.arange(b)
                chunk = jnp.zeros((b, scfg.sched_chunk), jnp.int32)
                emitted0 = emitted

                def cond(st):
                    t = st[0]
                    return (t < n_steps) & ~jnp.all(st[4])

                def body(st):
                    t, cache, cur, emitted, done, chunk = st
                    if paged:
                        logits, cache = LM.decode_step(
                            cfg, pol, params, cache, cur[:, None],
                            lengths + emitted - 1, block_tables=tables, block_size=bs,
                            mesh=self._mesh,
                        )
                    else:
                        logits, cache = LM.decode_step(
                            cfg, pol, params, cache, cur[:, None], lengths + emitted - 1
                        )
                    nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
                    nxt = jnp.where(done, PAD, nxt)
                    chunk = chunk.at[:, t].set(nxt)
                    emitted = emitted + (~done)
                    done = done | (nxt == EOS) | (emitted >= budget)
                    return (t + 1, cache, nxt, emitted, done, chunk)

                st = (jnp.int32(0), cache, cur, emitted, done, chunk)
                _, cache, cur, emitted, done, chunk = jax.lax.while_loop(cond, body, st)
                # ragged merge: row i's fresh tokens are chunk[i, :emitted-emitted0]
                # landing at out[i, emitted0:emitted]; invalid lanes are clipped
                # into the spare (t_cap) column, which holds no answer tokens
                j = jnp.arange(scfg.sched_chunk)
                idx = jnp.minimum(emitted0[:, None] + j[None, :], t_cap)
                valid = j[None, :] < (emitted - emitted0)[:, None]
                keep = out[rows[:, None], idx]
                out = out.at[rows[:, None], idx].set(jnp.where(valid, chunk, keep))
                return cache, cur, emitted, done, out

            return decode_chunk

        self._prefill = jax.jit(prefill_fn)
        self._decode_loop = jax.jit(decode_loop)
        self._admit_rows = jax.jit(admit_rows)
        self._cow_copy = jax.jit(cow_copy)
        self._upload_block = jax.jit(upload_block)
        self._mixed_rows = jax.jit(mixed_rows)
        self._decode_chunk = jax.jit(make_decode_chunk(scfg.paged))
        if scfg.draft_k > 0:
            self._spec_mixed_rows = jax.jit(spec_mixed_rows)
            self._draft_rows = jax.jit(make_draft_rows(with_fill=True))
            self._draft_tokens = jax.jit(make_draft_rows(with_fill=False))
        self.queue: list[np.ndarray] = []

    def submit(self, prompt_tokens: np.ndarray):
        self.queue.append(prompt_tokens.ravel())

    def _pack(self, prompts: list[np.ndarray]) -> np.ndarray:
        """Left-aligned PAD-tail packing; each row's decode slot is its own
        length (per-row positions), so ragged rows stay correct."""
        width = self.scfg.max_prompt_len
        out = np.zeros((len(prompts), width), np.int32)
        for i, p in enumerate(prompts):
            p = p[-width:]
            out[i, : len(p)] = p
        return out

    def _init_serve_cache(self):
        """Device cache for the continuous path in the configured layout."""
        dtype = jnp.dtype(self.cfg.dtype)
        if self.scfg.paged:
            if self._shards is not None:
                # per-shard slice holds its n_local blocks + its own trash
                return LM.init_paged_cache(
                    self.cfg, self._n_local + 1, self.scfg.block_size,
                    self.scfg.max_batch, dtype=dtype, n_shards=self._shards,
                )
            return LM.init_paged_cache(
                self.cfg, self._n_pool_blocks + 1, self.scfg.block_size,
                self.scfg.max_batch, dtype=dtype,
            )
        return LM.init_cache(self.cfg, self.scfg.max_batch, self._cache_len, dtype=dtype)

    def _place_sharded(self, cache):
        """Lay a sharded paged cache out over the mesh: pool leaves split
        on the shard axis ``P(None, "data", ...)``, per-slot leaves
        replicated — each device then holds exactly its shard's blocks."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        pool_s = NamedSharding(self._mesh, P(None, "data"))
        repl_s = NamedSharding(self._mesh, P())
        return jax.tree.map(
            lambda leaf: jax.device_put(
                leaf, pool_s if self._is_pool_leaf(leaf) else repl_s
            ),
            cache,
        )

    def cache_nbytes(self) -> int:
        """HBM held by the continuous-path decode cache (both layouts),
        computed from abstract shapes — the denominator of every
        paged-vs-contiguous capacity comparison."""
        shapes = jax.eval_shape(self._init_serve_cache)
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(shapes))

    # ------------------------------------------------------------------ #
    # resident paged state
    # ------------------------------------------------------------------ #
    def _fetch_block(self, b: int):
        """Demotion callback for the tiered prefix cache: pull pool block
        ``b``'s K/V to host (one array per pool leaf, ``jax.tree.leaves``
        order) and return ``(payload, nbytes)``."""
        if self._shards is not None:
            s, l = b // self._n_local, b % self._n_local
            payload = [
                np.asarray(leaf[:, s, l])
                for leaf in jax.tree.leaves(self._cache)
                if self._is_pool_leaf(leaf)
            ]
        else:
            payload = [
                np.asarray(leaf[:, b])
                for leaf in jax.tree.leaves(self._cache)
                if self._is_pool_leaf(leaf)
            ]
        return payload, int(sum(p.nbytes for p in payload))

    def _ensure_paged_state(self):
        """Create the resident pool / tables / cache / index on first
        paged use; later serve calls reuse them (warm prefix cache)."""
        if self._pool is not None:
            return
        scfg = self.scfg
        n_shards = self._shards if self._shards is not None else 1
        self._pool = BlockPool(self._n_pool_blocks, scfg.block_size, n_shards=n_shards)
        self._row_tables = [BlockTable(self._pool) for _ in range(scfg.max_batch)]
        # every unallocated (or free-slot) table entry points at the
        # trash block, so masked writes can never land in live blocks
        # (on a sharded pool the global trash id resolves to every
        # shard's local trash — its "shard" n_pool // n_local matches none)
        self._tables_h = np.full(
            (scfg.max_batch, self._blocks_per_slot), self._trash_block, np.int32
        )
        self._cache = self._init_serve_cache()
        if self._shards is not None:
            self._cache = self._place_sharded(self._cache)
        if scfg.draft_k > 0:
            self._draft_pool = BlockPool(
                self._n_pool_blocks, scfg.block_size, n_shards=n_shards
            )
            self._draft_row_tables = [
                BlockTable(self._draft_pool) for _ in range(scfg.max_batch)
            ]
            self._draft_tables_h = np.full(
                (scfg.max_batch, self._blocks_per_slot), self._trash_block, np.int32
            )
            if self._shards is not None:
                self._draft_cache = self._place_sharded(LM.init_paged_cache(
                    self._draft_cfg, self._n_local + 1, scfg.block_size,
                    scfg.max_batch, dtype=jnp.dtype(self._draft_cfg.dtype),
                    n_shards=self._shards,
                ))
            else:
                self._draft_cache = LM.init_paged_cache(
                    self._draft_cfg, self._n_pool_blocks + 1, scfg.block_size,
                    scfg.max_batch, dtype=jnp.dtype(self._draft_cfg.dtype),
                )
        if scfg.prefix_cache:
            store = (
                HostBlockStore(scfg.spill_bytes)
                if scfg.spill_bytes is not None
                else None
            )
            self._spill_store = store
            self._index = PrefixIndex(
                self._pool, spill_store=store, fetch_block=self._fetch_block
            )

    def reset_cache(self):
        """Drop ALL resident paged state — device cache, block pool, prefix
        index, host spill tier.  The next serve call starts cold (used by
        benchmarks to compare cold vs warm arms on one engine)."""
        if self._serving:
            raise RuntimeError("reset_cache() during an active serve loop")
        self._pool = None
        self._row_tables = None
        self._tables_h = None
        self._cache = None
        self._index = None
        self._spill_store = None
        self._draft_pool = None
        self._draft_row_tables = None
        self._draft_tables_h = None
        self._draft_cache = None

    # ------------------------------------------------------------------ #
    # lock-step path (deterministic baseline)
    # ------------------------------------------------------------------ #
    def step_batch(self) -> list[np.ndarray]:
        """Serve up to max_batch queued requests; returns answer token rows."""
        if not self.queue:
            return []
        batch, self.queue = self.queue[: self.scfg.max_batch], self.queue[self.scfg.max_batch :]
        lengths = np.array(
            [min(len(p), self.scfg.max_prompt_len) for p in batch], np.int32
        )
        tokens = self._pack(batch)
        first, cache = self._prefill(self.params, jnp.asarray(tokens), jnp.asarray(lengths))
        out, n_steps = self._decode_loop(self.params, cache, first, jnp.asarray(lengths))
        ans = np.asarray(out)[:, : int(n_steps)]
        return [row for row in ans]

    # ------------------------------------------------------------------ #
    # continuous-batching path (slot pool + scheduler)
    # ------------------------------------------------------------------ #
    def serve(self, scheduler: Scheduler) -> dict[int, np.ndarray]:
        """Drive the slot pool until the scheduler's queue drains and every
        slot has retired (one-shot batch semantics: does NOT wait for more
        submissions).  Returns {rid: answer tokens}; per-request timestamps
        land in ``scheduler.results`` for latency stats.  On a resident
        paged engine, repeated calls reuse the prefix cache — the
        scheduler's top-level stats window covers this call."""
        return dict(self.serve_stream(scheduler, drain=True))

    def serve_stream(self, scheduler: Scheduler, *, drain: bool = False):
        """Generator form of ``serve``: yields ``(rid, answer_tokens)`` the
        moment a slot retires instead of returning one dict at drain, so a
        caller can stream results out (and overlap downstream work) while
        other slots keep decoding.

        With ``drain=False`` (default) the stream is *live*: when the
        queue is momentarily empty but the scheduler is still open, the
        engine keeps decoding active slots and then blocks in
        ``scheduler.wait_for_work`` — a producer thread may keep
        submitting until it calls ``scheduler.close()``, at which point
        the stream drains the remaining work and ends.  ``drain=True``
        restores the one-shot ``serve`` behavior: exit as soon as the
        queue is empty and every slot has retired, closed or not."""
        if self._unified:
            yield from self._serve_unified(scheduler, drain)
            return
        yield from self._serve_contiguous(scheduler, drain)

    def _serve_contiguous(self, scheduler: Scheduler, drain: bool):
        """Continuous batching over contiguous cache stripes: the parity
        oracle for the unified paged path (same admission order, same
        decode semantics, pow-2 bucketed admit prefills)."""
        scfg = self.scfg
        B, t_cap, width = scfg.max_batch, scfg.max_new_tokens, scfg.max_prompt_len
        scheduler.begin_window()
        cache = self._init_serve_cache()
        cur = jnp.zeros((B,), jnp.int32)
        lengths = jnp.ones((B,), jnp.int32)
        emitted = jnp.ones((B,), jnp.int32)
        done = jnp.ones((B,), bool)  # free slots read as done
        budget = jnp.ones((B,), jnp.int32)
        out = jnp.zeros((B, t_cap + 1), jnp.int32)
        slots: list[Request | None] = [None] * B
        # host mirrors of emitted/done/budget keep the loop at ONE device
        # sync per chunk; a just-admitted row's done flag is only known
        # on-device (first token may be EOS), so mirror it as live — the
        # worst case is one no-op chunk dispatch before the readback
        em_h = np.ones((B,), np.int64)
        dn_h = np.ones((B,), bool)
        bu_h = np.ones((B,), np.int64)
        steps = 0  # engine scheduler steps (dispatch-rate denominator)
        a0, d0 = self.admit_dispatches, self.decode_dispatches
        m0 = self.mixed_dispatches

        while True:
            # ---- admit queued requests into free slots (bucketed) ----
            admits: list[tuple[int, np.ndarray, int, int]] = []
            for slot in range(B):
                if slots[slot] is not None:
                    continue
                req = scheduler.pop_ready()
                if req is None:
                    break
                p = req.tokens[-width:]
                length = len(p)
                # prefill always emits one token, so the effective budget
                # floor is 1; None means "engine cap" (0 does not)
                b_new = t_cap if req.max_new_tokens is None else req.max_new_tokens
                b_new = max(1, min(int(b_new), t_cap))
                admits.append((slot, p, length, b_new))
                scheduler.record_tenant_admit(req.tenant, prefill_tokens=length)
                slots[slot] = req
                em_h[slot], dn_h[slot] = 1, b_new <= 1
                bu_h[slot] = b_new
            while admits:
                # power-of-2 buckets: k waiting requests prefill in
                # O(log k) fused dispatches, each a jit trace shared by
                # every future group of that size
                g = 1 << (len(admits).bit_length() - 1)
                group, admits = admits[:g], admits[g:]
                rows = np.zeros((g, width), np.int32)
                for i, (_, p, length, _) in enumerate(group):
                    rows[i, :length] = p
                slot_ids = np.array([s for s, _, _, _ in group], np.int32)
                row_lens = np.array([ln for _, _, ln, _ in group], np.int32)
                b_news = np.array([bn for _, _, _, bn in group], np.int32)
                cache, cur, lengths, emitted, done, budget, out = self._admit_rows(
                    self.params, cache, cur, lengths, emitted, done, budget, out,
                    jnp.asarray(rows), jnp.asarray(slot_ids), jnp.asarray(row_lens),
                    jnp.asarray(b_news),
                )
                self.admit_dispatches += 1
                self.admit_rows_total += g
            active = [i for i in range(B) if slots[i] is not None]
            scheduler.record_occupancy(free_slots=B - len(active))
            scheduler.record_dispatch_stats(
                admit_dispatches=self.admit_dispatches - a0,
                decode_dispatches=self.decode_dispatches - d0,
                mixed_dispatches=self.mixed_dispatches - m0,
                steps=steps,
                lifetime=self._dispatch_lifetime(),
            )
            if not active:
                if drain or scheduler.closed:
                    if scheduler.has_pending:
                        continue  # submit raced the close/empty check
                    return  # queue drained and every slot retired
                # live stream: idle until the producer submits or closes
                scheduler.wait_for_work()
                continue

            remaining = [int(bu_h[i] - em_h[i]) for i in active if not dn_h[i]]
            if remaining:
                # per-request budgets and EOS are enforced on-device, so the
                # chunk length is purely a scheduling granularity: run up to
                # the largest live budget but at most sched_chunk steps, so
                # freed slots wait at most sched_chunk for the next admit
                n = max(1, min(max(remaining), scfg.sched_chunk))
                cache, cur, emitted, done, out = self._decode_chunk(
                    self.params, cache, cur, lengths, emitted, done, budget, out,
                    jnp.int32(n),
                )
                self.decode_dispatches += 1
                steps += 1
            # np.array (not asarray): device views are read-only and the
            # mirrors are written at the next admit
            em_h, dn_h = np.array(emitted), np.array(done)

            retired = [i for i in active if dn_h[i]]
            if retired:
                out_h = np.asarray(out)
                for i in retired:
                    req = slots[i]
                    ans = out_h[i, : int(em_h[i])].copy()
                    scheduler.finish(req, ans)
                    slots[i] = None  # retire: slot free for the next admit
                    yield req.rid, ans

    def _dispatch_lifetime(self) -> dict:
        return {
            "admit_dispatches": self.admit_dispatches,
            "decode_dispatches": self.decode_dispatches,
            "mixed_dispatches": self.mixed_dispatches,
            "draft_dispatches": self.draft_dispatches,
            "draft_fill_dispatches": self.draft_fill_dispatches,
            "spec_rounds": self.spec_rounds,
            "spec_tokens_proposed": self.spec_tokens_proposed,
            "spec_tokens_accepted": self.spec_tokens_accepted,
            "spec_tokens_emitted": self.spec_tokens_emitted,
        }

    def _serve_unified(self, scheduler: Scheduler, drain: bool):
        """Unified chunked-prefill serve loop — THE paged serving path.

        One ``_mixed_rows`` dispatch per engine step: each admitted
        request becomes a host-side *fill* record whose prompt is
        streamed into the pool ``token_budget`` query lanes at a time,
        sharing the step with the 1-lane decode rows.  Decode lanes are
        assigned first (a long prompt arrival chunks across steps instead
        of stalling in-flight decodes), fills consume the remaining lanes
        FIFO.  When no fill is in flight the loop falls back to the fused
        multi-step ``_decode_chunk`` — still one dispatch per step.  The
        jit trace count is O(1): every mixed step has the same static
        ``(max_batch, token_budget)`` shape.

        The pool, device cache, block tables, and prefix index are
        RESIDENT engine state (``_ensure_paged_state``): this loop picks
        them up warm and leaves them warm — retired prompt chains stay
        parked (or demoted to the host tier) for the next call.  A
        re-admitted (spilled) chunk is materialized synchronously via
        ``_upload_block`` before the row's first dispatch, so it never
        enters ``pending_blocks``.

        Prefix-cache cross-request ordering is host-side: chunks an
        in-flight fill has registered but not yet materialized sit in
        ``pending_blocks``; a later admission matching them waits (its
        fill stays unscheduled, see ``resolve_fill_deps``) until the
        owner's fill passes their last token.  Deps always point at
        earlier-admitted rows, so the wait graph is acyclic; if it ever
        stalled anyway, every blocked fill is force-retired with an empty
        ``deadlocked``-flagged answer rather than wedging the loop.
        """
        if self._serving:
            raise RuntimeError(
                "engine is already inside a serve loop; a resident engine "
                "serves one stream at a time"
            )
        scfg = self.scfg
        B, t_cap, width = scfg.max_batch, scfg.max_new_tokens, scfg.max_prompt_len
        bs, W = scfg.block_size, self._token_budget
        scheduler.begin_window()
        self._ensure_paged_state()
        pool, index = self._pool, self._index
        row_tables, tables_h = self._row_tables, self._tables_h
        store = self._spill_store
        if index is not None:
            lk0, ht0 = self.prefix_lookups, self.prefix_hits
            pt0, ps0 = self.prefill_tokens_total, self.prefill_tokens_saved
            sh0 = self.prefix_shared_total
            dm0, rm0 = index.n_demotions, index.n_readmits
        cur = jnp.zeros((B,), jnp.int32)
        lengths = jnp.ones((B,), jnp.int32)
        emitted = jnp.ones((B,), jnp.int32)
        done = jnp.ones((B,), bool)  # free slots read as done
        budget = jnp.ones((B,), jnp.int32)
        out = jnp.zeros((B, t_cap + 1), jnp.int32)
        slots: list[Request | None] = [None] * B
        em_h = np.ones((B,), np.int64)
        dn_h = np.ones((B,), bool)
        bu_h = np.ones((B,), np.int64)
        ln_h = np.ones((B,), np.int64)
        oom_slots: set[int] = set()
        empty = np.zeros((0,), np.int32)
        steps = 0
        a0, d0 = self.admit_dispatches, self.decode_dispatches
        m0 = self.mixed_dispatches
        # fills[slot]: in-flight prompt stream (p/length/b_new/pos/cow/deps);
        # None once the prompt has fully dispatched.  pending_blocks maps a
        # cached-chunk block an in-flight fill will write -> (owner slot,
        # token position at which its content exists on device)
        fills: list[dict | None] = [None] * B
        pending_blocks: dict[int, tuple[int, int]] = {}
        planned: dict[int, object] = {}
        # speculative decoding (draft_k > 0): the drafter mirrors the
        # target's fill machinery against its own pool.  d_fills[slot] is
        # the drafter's prompt stream (ALWAYS the full prompt — the
        # drafter has no prefix cache); a decode row speculates only once
        # its drafter fill completes (it sits out decode meanwhile — pure
        # scheduling, outputs are unaffected).  d_broken marks rows whose
        # drafter ran out of pool blocks mid-flight: they keep verifying
        # (garbage drafts can only be accepted when they MATCH the
        # target, so correctness never depends on the drafter)
        spec = scfg.draft_k > 0
        kd = scfg.draft_k
        d_pool = self._draft_pool
        d_row_tables = self._draft_row_tables
        d_tables_h = self._draft_tables_h
        d_fills: list[dict | None] = [None] * B
        d_broken = np.zeros((B,), bool)
        dr0, sr0 = self.draft_dispatches, self.spec_rounds
        sp0, sa0 = self.spec_tokens_proposed, self.spec_tokens_accepted
        se0, df0 = self.spec_tokens_emitted, self.draft_fill_dispatches
        self._serving = True

        def admit_gate(req: Request) -> bool:
            # dual-pool gate: the drafter re-prefills the full prompt, so
            # admission also requires drafter blocks for prompt + first
            # draft position (checked FIRST — a target-side prefix plan
            # is only memoized for requests that clear both pools)
            if spec and not d_pool.can_alloc(
                blocks_for(min(len(req.tokens), width) + 1, bs)
            ):
                return False
            if index is not None:
                plan = index.plan(req.tokens[-width:])
                if plan is not None:
                    planned[req.rid] = plan
                return plan is not None
            n_tok = min(len(req.tokens), width) + 1
            return pool.can_alloc(blocks_for(n_tok, bs))

        def report_prefix():
            if index is None:
                return
            window = {
                "prefix_lookups": self.prefix_lookups - lk0,
                "prefix_hits": self.prefix_hits - ht0,
                "prefill_tokens": self.prefill_tokens_total - pt0,
                "prefill_tokens_saved": self.prefill_tokens_saved - ps0,
                "prefix_shared_blocks": self.prefix_shared_total - sh0,
                "prefix_cached_blocks": index.n_cached_blocks,
            }
            lifetime = {
                "prefix_lookups": self.prefix_lookups,
                "prefix_hits": self.prefix_hits,
                "prefill_tokens": self.prefill_tokens_total,
                "prefill_tokens_saved": self.prefill_tokens_saved,
                "prefix_shared_blocks": self.prefix_shared_total,
                "prefix_cached_blocks": index.n_cached_blocks,
            }
            if store is not None:
                window.update(
                    spill_demotions=index.n_demotions - dm0,
                    spill_readmits=index.n_readmits - rm0,
                    spilled_blocks=index.n_spilled,
                    spill_bytes_used=store.used_bytes,
                )
                lifetime.update(
                    spill_demotions=index.n_demotions,
                    spill_readmits=index.n_readmits,
                    spilled_blocks=index.n_spilled,
                    spill_bytes_used=store.used_bytes,
                )
            scheduler.record_prefix_stats(window, lifetime)

        try:
            while True:
                # ---- admit queued requests into free slots ----
                # each admit is pure host bookkeeping (pool commit + fill
                # record); NO device dispatch happens here — prompt tokens
                # enter the device through the shared mixed step below
                # (re-admitted spilled chunks are the one exception: their
                # host payload uploads synchronously right here)
                for slot in range(B):
                    if slots[slot] is not None:
                        continue
                    req = scheduler.pop_ready(admit_if=admit_gate)
                    if req is None:
                        break
                    p = req.tokens[-width:]
                    length = len(p)
                    b_new = t_cap if req.max_new_tokens is None else req.max_new_tokens
                    b_new = max(1, min(int(b_new), t_cap))
                    start, cow, deps = 0, None, set()
                    if index is not None:
                        plan = planned.pop(req.rid, None) or index.plan(p)
                        if plan is None:
                            raise RuntimeError("prefix admit raced the block pool")
                        table_ids, cow_dst = index.commit(plan)
                        for payload, b in plan.uploads:
                            # host-tier re-admission: K/V comes back by
                            # upload, not re-prefill; materialized before
                            # any dispatch reads it, so never "pending"
                            if payload:
                                self._cache = self._upload_block(
                                    self._cache, payload, jnp.int32(b)
                                )
                        row_tables[slot].adopt(table_ids)
                        tables_h[slot, :] = self._trash_block
                        tables_h[slot, : len(table_ids)] = table_ids
                        self.prefix_lookups += 1
                        self.prefill_tokens_total += length
                        start = plan.start
                        if start:
                            self.prefix_hits += 1
                            self.prefill_tokens_saved += start
                            self.prefix_shared_total += len(plan.shared) + (cow_dst is not None)
                        if cow_dst is not None and plan.cow_src is not None:
                            # device boundary copy still pending; a host
                            # (spilled) boundary already uploaded above
                            cow = (plan.cow_src, cow_dst)
                        # wait on shared/COW-source chunks another in-flight
                        # fill has registered but not yet computed
                        deps = {
                            b for b in (set(plan.shared) | ({plan.cow_src} if cow else set()))
                            if b in pending_blocks
                        }
                        for c in range(len(plan.nodes), length // bs):
                            pending_blocks[table_ids[c]] = (slot, (c + 1) * bs)
                    else:
                        tb = row_tables[slot]
                        if not tb.extend_to(length + 1):
                            raise RuntimeError("paged admit raced the block pool")
                        tables_h[slot, :] = self._trash_block
                        tables_h[slot, : tb.n_blocks] = tb.ids
                    scheduler.record_tenant_admit(
                        req.tenant, prefill_tokens=length,
                        prefill_tokens_saved=start, hit=start > 0,
                    )
                    slots[slot] = req
                    fills[slot] = dict(
                        p=p, length=length, b_new=b_new, pos=start, cow=cow, deps=deps
                    )
                    if spec:
                        d_tb = d_row_tables[slot]
                        if not d_tb.extend_to(length + 1):
                            raise RuntimeError("draft admit raced the draft pool")
                        d_tables_h[slot, :] = self._trash_block
                        d_tables_h[slot, : d_tb.n_blocks] = d_tb.ids
                        d_fills[slot] = dict(p=p, length=length, pos=0)
                        d_broken[slot] = False
                    # inert on device until the fill's last chunk seeds the
                    # slot (mixed_rows `completes`); done=True keeps any
                    # decode lane from touching it meanwhile
                    em_h[slot], dn_h[slot] = 0, True
                    bu_h[slot], ln_h[slot] = b_new, length

                active = [i for i in range(B) if slots[i] is not None]
                scheduler.record_occupancy(
                    free_slots=B - len(active),
                    free_blocks=pool.free_blocks,
                    reclaimable_blocks=pool.reclaimable_blocks if index is not None else None,
                    # drafter-pool headroom: without it a d_broken (drafter
                    # OOM) degradation is invisible in the memory gauges
                    draft_free_blocks=d_pool.free_blocks if spec else None,
                )
                report_prefix()
                scheduler.record_dispatch_stats(
                    admit_dispatches=self.admit_dispatches - a0,
                    decode_dispatches=self.decode_dispatches - d0,
                    mixed_dispatches=self.mixed_dispatches - m0,
                    steps=steps,
                    lifetime=self._dispatch_lifetime(),
                    draft_dispatches=self.draft_dispatches - dr0,
                    draft_fill_dispatches=self.draft_fill_dispatches - df0,
                    spec_rounds=self.spec_rounds - sr0,
                    spec_tokens_proposed=self.spec_tokens_proposed - sp0,
                    spec_tokens_accepted=self.spec_tokens_accepted - sa0,
                    spec_tokens_emitted=self.spec_tokens_emitted - se0,
                )
                if not active:
                    if drain or scheduler.closed:
                        if scheduler.has_pending:
                            continue
                        return
                    scheduler.wait_for_work()
                    continue

                fill_rows = [i for i in range(B) if fills[i] is not None]
                dec_rows = [i for i in active if fills[i] is None and not dn_h[i]]
                try:
                    runnable = resolve_fill_deps(
                        {i: frozenset(fills[i]["deps"]) for i in fill_rows},
                        pending_blocks.keys(),
                    )
                except AdmissionDeadlock as exc:
                    # every in-flight fill waits on a chunk nobody will
                    # write: unreachable with commit-ordered deps, but
                    # wedging the loop would be worse than degrading —
                    # roll back their cached-chunk registrations (one
                    # leaf-first call), drop COW pins, and retire them
                    # empty + deadlocked
                    doomed = set(exc.stuck)
                    inv = [b for b, (s, _) in pending_blocks.items() if s in doomed]
                    if index is not None and inv:
                        index.invalidate(inv)
                    for b in inv:
                        del pending_blocks[b]
                    for i in sorted(doomed):
                        fl, req = fills[i], slots[i]
                        if fl["cow"] is not None:
                            pool.free([fl["cow"][0]])
                        row_tables[i].release()
                        tables_h[i, :] = self._trash_block
                        if spec:
                            if d_row_tables[i].ids:
                                d_row_tables[i].release()
                            d_tables_h[i, :] = self._trash_block
                            d_fills[i] = None
                        scheduler.finish(req, empty, deadlocked=True)
                        slots[i], fills[i] = None, None
                        em_h[i], dn_h[i] = 1, True
                        yield req.rid, empty
                    continue

                if spec:
                    # ---- speculative round: O(2) dispatches ----
                    # (1) ONE drafter dispatch: drafter prompt chunks for
                    #     rows still streaming + k greedy proposals for
                    #     every drafter-ready decode row
                    # (2) ONE target dispatch: verify descriptors
                    #     (q_len <= k+1) for speculating rows + target
                    #     fill chunks in the remaining token-budget lanes
                    # A decode row whose drafter fill is still streaming
                    # sits out (inert lane) — scheduling only, greedy
                    # outputs are position-independent
                    spec_rows = [i for i in dec_rows if d_fills[i] is None]
                    d_fill_rows = [i for i in range(B) if d_fills[i] is not None]
                    draft_ok: list[int] = []
                    for i in spec_rows:
                        if d_broken[i]:
                            continue
                        if int(bu_h[i] - em_h[i]) < 2 or (
                            int(self._cache_len_padded - (ln_h[i] + em_h[i] - 1)) < 2
                        ):
                            continue  # a 1-token tail can't accept any draft
                        # +1: the k-loop writes K/V for every proposal
                        # including d_k at dec_pos + kd (see draft_body)
                        need = int(ln_h[i] + em_h[i] + kd)
                        if need > self._cache_len_padded:
                            continue  # cache tail: draft to trash this round
                        d_tb = d_row_tables[i]
                        if d_tb.n_tokens_capacity < need:
                            n0 = d_tb.n_blocks
                            if d_tb.extend_to(need):
                                d_tables_h[i, n0 : d_tb.n_blocks] = d_tb.ids[n0:]
                            else:
                                # drafter pool OOM: drop its chain; the row
                                # keeps verifying garbage drafts (an accept
                                # requires a target MATCH, so outputs never
                                # depend on the drafter)
                                d_broken[i] = True
                                d_row_tables[i].release()
                                d_tables_h[i, :] = self._trash_block
                                continue
                        draft_ok.append(i)
                    # rows excluded from drafting write into the trash block
                    d_dec_tab = np.full_like(d_tables_h, self._trash_block)
                    for i in draft_ok:
                        d_dec_tab[i] = d_tables_h[i]
                    dec_pos_h = (ln_h + em_h - 1).astype(np.int32)
                    drafts = None
                    if d_fill_rows:
                        d_tok = np.zeros((B, W), np.int32)
                        d_qs = np.zeros((B,), np.int32)
                        d_ql = np.zeros((B,), np.int32)
                        d_lanes = W
                        for i in d_fill_rows:
                            if d_lanes <= 0:
                                break
                            fl = d_fills[i]
                            take = min(fl["length"] - fl["pos"], d_lanes)
                            d_tok[i, :take] = fl["p"][fl["pos"] : fl["pos"] + take]
                            d_qs[i] = fl["pos"]
                            d_ql[i] = take
                            d_lanes -= take
                            fl["pos"] += take
                            if fl["pos"] >= fl["length"]:
                                d_fills[i] = None
                        drafts, self._draft_cache = self._draft_rows(
                            self._draft_params, self._draft_cache,
                            jnp.asarray(d_tok), jnp.asarray(d_qs), jnp.asarray(d_ql),
                            cur, jnp.asarray(dec_pos_h), jnp.asarray(d_tables_h),
                            jnp.asarray(d_dec_tab),
                        )
                        # a dispatch that only streams drafter prompt
                        # chunks is admission overhead (the drafter's
                        # prefill), not a per-round cost
                        if draft_ok:
                            self.draft_dispatches += 1
                        else:
                            self.draft_fill_dispatches += 1
                    elif draft_ok:
                        drafts, self._draft_cache = self._draft_tokens(
                            self._draft_params, self._draft_cache, cur,
                            jnp.asarray(dec_pos_h), jnp.asarray(d_dec_tab),
                        )
                        self.draft_dispatches += 1
                    tok = np.zeros((B, W), np.int32)
                    q_start_h = np.zeros((B,), np.int32)
                    q_len_h = np.zeros((B,), np.int32)
                    is_spec_h = np.zeros((B,), bool)
                    row_len_h = np.zeros((B,), np.int32)
                    b_new_h = np.ones((B,), np.int32)
                    oom = np.zeros((B,), bool)
                    lanes = W
                    # verify lanes first (fills absorb the wait), drafted
                    # rows before un-drafted ones: a round that paid for a
                    # drafter k-loop always lands >= one q_len >= 2 verify
                    draft_set = set(draft_ok)
                    for i in draft_ok + [r for r in spec_rows if r not in draft_set]:
                        if lanes <= 0:
                            break
                        rem = int(bu_h[i] - em_h[i])
                        space = int(self._cache_len_padded - (ln_h[i] + em_h[i] - 1))
                        v = min(kd + 1, rem, space, lanes)
                        if v < 1:
                            continue
                        need_tok = min(
                            ln_h[i] + em_h[i] - 1 + v, self._cache_len_padded
                        )
                        tb = row_tables[i]
                        if tb.n_tokens_capacity < need_tok:
                            n0 = tb.n_blocks
                            if tb.extend_to(int(need_tok)):
                                tables_h[i, n0 : tb.n_blocks] = tb.ids[n0:]
                            else:
                                oom[i] = True
                                dn_h[i] = True
                                oom_slots.add(i)
                                continue
                        is_spec_h[i] = True
                        q_len_h[i] = v
                        lanes -= v
                    for i in runnable:
                        if lanes <= 0:
                            break
                        fl = fills[i]
                        if fl["cow"] is not None:
                            src, dst = fl["cow"]
                            self._cache = self._cow_copy(
                                self._cache, jnp.int32(src), jnp.int32(dst)
                            )
                            pool.free([src])
                            fl["cow"] = None
                        take = min(fl["length"] - fl["pos"], lanes)
                        tok[i, :take] = fl["p"][fl["pos"] : fl["pos"] + take]
                        q_start_h[i] = fl["pos"]
                        q_len_h[i] = take
                        row_len_h[i] = fl["length"]
                        b_new_h[i] = fl["b_new"]
                        lanes -= take
                        fl["pos"] += take
                        mine = [
                            b for b, (s, e) in pending_blocks.items()
                            if s == i and e <= fl["pos"]
                        ]
                        for b in mine:
                            del pending_blocks[b]
                        if fl["pos"] >= fl["length"]:
                            fills[i] = None
                    if oom.any():
                        done = jnp.logical_or(done, jnp.asarray(oom))
                    if is_spec_h.any() or q_len_h.any():
                        em_before = em_h.copy()
                        (self._cache, cur, lengths, emitted, done, budget, out) = (
                            self._spec_mixed_rows(
                                self.params, self._cache, cur, lengths, emitted,
                                done, budget, out,
                                jnp.asarray(tok), jnp.asarray(q_start_h),
                                jnp.asarray(q_len_h), jnp.asarray(is_spec_h),
                                drafts if drafts is not None
                                else jnp.zeros((B, kd), jnp.int32),
                                jnp.asarray(row_len_h), jnp.asarray(b_new_h),
                                jnp.asarray(tables_h),
                            )
                        )
                        self.mixed_dispatches += 1
                        steps += 1
                        em_h, dn_h = np.array(emitted), np.array(done)
                        if is_spec_h.any():
                            committed = em_h[is_spec_h] - em_before[is_spec_h]
                            self.spec_tokens_emitted += int(committed.sum())
                            self.spec_tokens_proposed += int(
                                (q_len_h[is_spec_h] - 1).sum()
                            )
                            self.spec_tokens_accepted += int(
                                np.maximum(committed - 1, 0).sum()
                            )
                            if (q_len_h[is_spec_h] > 1).any():
                                self.spec_rounds += 1
                elif runnable:
                    # ---- ONE mixed dispatch: decode lanes + fill chunks ----
                    tok = np.zeros((B, W), np.int32)
                    q_start_h = np.zeros((B,), np.int32)
                    q_len_h = np.zeros((B,), np.int32)
                    is_dec = np.zeros((B,), bool)
                    row_len_h = np.zeros((B,), np.int32)
                    b_new_h = np.ones((B,), np.int32)
                    oom = np.zeros((B,), bool)
                    lanes = W
                    for i in dec_rows:  # decode first: fills absorb the wait
                        if lanes <= 0:
                            break
                        need_tok = min(
                            ln_h[i] + min(em_h[i] + 1, bu_h[i]) - 1,
                            self._cache_len_padded,
                        )
                        tb = row_tables[i]
                        if tb.n_tokens_capacity < need_tok:
                            n0 = tb.n_blocks
                            if tb.extend_to(int(need_tok)):
                                tables_h[i, n0 : tb.n_blocks] = tb.ids[n0:]
                            else:
                                oom[i] = True
                                dn_h[i] = True
                                oom_slots.add(i)
                                continue
                        is_dec[i] = True
                        q_len_h[i] = 1
                        lanes -= 1
                    for i in runnable:
                        if lanes <= 0:
                            break
                        fl = fills[i]
                        if fl["cow"] is not None:
                            # boundary copy must precede this fill's writes;
                            # the copy consumes the source's cache VALUE, so
                            # commit's pin drops immediately after dispatch
                            src, dst = fl["cow"]
                            self._cache = self._cow_copy(
                                self._cache, jnp.int32(src), jnp.int32(dst)
                            )
                            pool.free([src])
                            fl["cow"] = None
                        take = min(fl["length"] - fl["pos"], lanes)
                        tok[i, :take] = fl["p"][fl["pos"] : fl["pos"] + take]
                        q_start_h[i] = fl["pos"]
                        q_len_h[i] = take
                        row_len_h[i] = fl["length"]
                        b_new_h[i] = fl["b_new"]
                        lanes -= take
                        fl["pos"] += take
                        # chunks this dispatch materializes become matchable
                        mine = [
                            b for b, (s, e) in pending_blocks.items()
                            if s == i and e <= fl["pos"]
                        ]
                        for b in mine:
                            del pending_blocks[b]
                        if fl["pos"] >= fl["length"]:
                            fills[i] = None  # completes in this dispatch
                    if oom.any():
                        done = jnp.logical_or(done, jnp.asarray(oom))
                    (self._cache, cur, lengths, emitted, done, budget, out) = self._mixed_rows(
                        self.params, self._cache, cur, lengths, emitted, done, budget, out,
                        jnp.asarray(tok), jnp.asarray(q_start_h), jnp.asarray(q_len_h),
                        jnp.asarray(is_dec), jnp.asarray(row_len_h),
                        jnp.asarray(b_new_h), jnp.asarray(tables_h),
                    )
                    self.mixed_dispatches += 1
                    steps += 1
                    em_h, dn_h = np.array(emitted), np.array(done)
                elif dec_rows:
                    # no fill in flight: fused multi-step decode, one dispatch
                    remaining = [int(bu_h[i] - em_h[i]) for i in dec_rows]
                    n = max(1, min(max(remaining), scfg.sched_chunk))
                    oom = np.zeros((B,), bool)
                    for i in dec_rows:
                        need_tok = min(
                            ln_h[i] + min(em_h[i] + n, bu_h[i]) - 1,
                            self._cache_len_padded,
                        )
                        tb = row_tables[i]
                        if tb.n_tokens_capacity >= need_tok:
                            continue
                        n0 = tb.n_blocks
                        if tb.extend_to(int(need_tok)):
                            tables_h[i, n0 : tb.n_blocks] = tb.ids[n0:]
                        else:
                            oom[i] = True
                            dn_h[i] = True
                            oom_slots.add(i)
                    if oom.any():
                        done = jnp.logical_or(done, jnp.asarray(oom))
                    self._cache, cur, emitted, done, out = self._decode_chunk(
                        self.params, self._cache, cur, lengths, emitted, done, budget, out,
                        jnp.int32(n), jnp.asarray(tables_h),
                    )
                    self.decode_dispatches += 1
                    steps += 1
                    em_h, dn_h = np.array(emitted), np.array(done)

                retired = [i for i in active if dn_h[i] and fills[i] is None and slots[i] is not None]
                if retired:
                    out_h = np.asarray(out)
                    for i in retired:
                        req = slots[i]
                        ans = out_h[i, : int(em_h[i])].copy()
                        scheduler.finish(req, ans, truncated=i in oom_slots)
                        oom_slots.discard(i)
                        slots[i] = None
                        row_tables[i].release()
                        tables_h[i, :] = self._trash_block
                        if spec:
                            if d_row_tables[i].ids:
                                d_row_tables[i].release()
                            d_tables_h[i, :] = self._trash_block
                            d_fills[i] = None
                            d_broken[i] = False
                        yield req.rid, ans
        finally:
            # the pool/index outlive this call, so an abandoned stream must
            # not leak owned blocks or half-materialized chunk registrations
            # into the next serve.  Normal exit has already released
            # everything and this is a no-op
            if index is not None and pending_blocks:
                index.invalidate(list(pending_blocks))
            pending_blocks.clear()
            for i in range(B):
                if fills[i] is not None and fills[i].get("cow") is not None:
                    pool.free([fills[i]["cow"][0]])
                fills[i] = None
                if slots[i] is not None and slots[i].status == "active":
                    scheduler.finish(slots[i], empty, deadlocked=True)
                slots[i] = None
                if row_tables[i].ids:
                    row_tables[i].release()
                tables_h[i, :] = self._trash_block
                if spec:
                    d_fills[i] = None
                    if d_row_tables[i].ids:
                        d_row_tables[i].release()
                    d_tables_h[i, :] = self._trash_block
            report_prefix()
            self._serving = False

    def serve_prompts(
        self,
        prompts: Sequence[np.ndarray],
        max_new_tokens: int | Sequence[int] | None = None,
        deadlines: Sequence[float | None] | None = None,
    ) -> list[np.ndarray]:
        """Convenience wrapper: schedule ``prompts`` and serve to completion,
        returning answers in prompt order (expired requests -> empty row)."""
        sched = Scheduler()
        rids = sched.submit_many(prompts, max_new_tokens, deadlines)
        res = self.serve(sched)
        empty = np.zeros((0,), np.int32)
        return [res.get(rid, empty) for rid in rids]


def engine_generator(engine: ServeEngine, mode: str = "continuous") -> Callable:
    """Adapt a ServeEngine to the orchestrator's generator contract:
    callable (1, S) -> (1, T) for single prompts, plus ``generate_batch``
    (list of prompts -> list of answer rows).  ``mode="continuous"``
    (default) routes batches through the slot scheduler so ragged
    generations retire early; ``mode="lockstep"`` keeps the fixed-chunk
    baseline for determinism comparisons."""
    assert mode in ("continuous", "lockstep")

    def generate(prompt_tokens: np.ndarray) -> np.ndarray:
        if engine.queue:
            raise RuntimeError("engine_generator requires exclusive use of the engine queue")
        if mode == "continuous":
            return generate_batch([np.asarray(prompt_tokens)])[0][None, :]
        engine.submit(np.asarray(prompt_tokens))
        return engine.step_batch()[0][None, :]

    def generate_batch(prompts: list[np.ndarray]) -> list[np.ndarray]:
        if engine.queue:
            raise RuntimeError("engine_generator requires exclusive use of the engine queue")
        if mode == "continuous":
            return engine.serve_prompts([np.asarray(p) for p in prompts])
        for p in prompts:
            engine.submit(np.asarray(p))
        outs: list[np.ndarray] = []
        while engine.queue:
            outs.extend(engine.step_batch())
        return outs

    generate.generate_batch = generate_batch
    generate.engine = engine
    generate.mode = mode
    # advertise the engine's prompt window so prompt builders truncate
    # grammar-aware at the right width instead of leaving it to the
    # engine's blind tail-slice
    generate.max_prompt_len = engine.scfg.max_prompt_len
    return generate
