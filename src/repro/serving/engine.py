"""RAG serving engine: batched prefill + decode with the C-FedRAG pipeline.

Request flow (paper Fig. 2/3 in serving form):
  query -> federated retrieval (core.retrieval / orchestrator)
        -> enclave re-rank -> prompt build -> batched prefill -> decode loop

Batching: requests are grouped to `max_batch`, prompts right-aligned into a
common cache; decode proceeds until EOS or `max_new_tokens`.  The engine is
deliberately synchronous (single-host simulation); the scheduler hook
points (queue, deadline, quorum) mirror a production continuous-batching
server."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS, PAD, HashTokenizer
from repro.models import lm as LM
from repro.runtime.sharding import ShardingPolicy


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_prompt_len: int = 512
    max_new_tokens: int = 16
    temperature: float = 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, pol: ShardingPolicy, params, scfg: ServeConfig):
        self.cfg, self.pol, self.params, self.scfg = cfg, pol, params, scfg
        self._prefill = jax.jit(
            lambda p, b: LM.prefill(cfg, pol, p, b, cache_len=scfg.max_prompt_len + scfg.max_new_tokens)
        )
        self._decode = jax.jit(
            lambda p, c, t, pos: LM.decode_step(cfg, pol, p, c, t, pos)
        )
        self.queue: list[np.ndarray] = []

    def submit(self, prompt_tokens: np.ndarray):
        self.queue.append(prompt_tokens.ravel())

    def _pack(self, prompts: list[np.ndarray]) -> np.ndarray:
        width = self.scfg.max_prompt_len
        out = np.zeros((len(prompts), width), np.int32)
        for i, p in enumerate(prompts):
            p = p[-width:]
            out[i, : len(p)] = p  # left-aligned; PAD tail
        return out

    def step_batch(self) -> list[np.ndarray]:
        """Serve up to max_batch queued requests; returns answer token rows."""
        if not self.queue:
            return []
        batch, self.queue = self.queue[: self.scfg.max_batch], self.queue[self.scfg.max_batch :]
        lengths = np.array([min(len(p), self.scfg.max_prompt_len) for p in batch])
        tokens = self._pack(batch)
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(tokens)})
        # logits at each row's true last position
        last = np.asarray(logits)[np.arange(len(batch)), :, :][:, -1, :] if logits.shape[1] == 1 else (
            np.asarray(logits)[np.arange(len(batch)), lengths - 1, :]
        )
        tok = last.argmax(-1).astype(np.int32)
        outs = [tok.copy()]
        pos = int(lengths.max())  # uniform write position (packed batch)
        cur = jnp.asarray(tok)[:, None]
        for t in range(1, self.scfg.max_new_tokens):
            logits, cache = self._decode(self.params, cache, cur, pos)
            cur = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
            outs.append(np.asarray(cur)[:, 0])
            pos += 1
            if all((np.stack(outs, 1) == EOS).any(1)):
                break
        ans = np.stack(outs, 1)
        return [row for row in ans]
