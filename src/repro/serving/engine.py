"""RAG serving engine: continuous batching over a fixed pool of cache slots.

Request flow (paper Fig. 2/3 in serving form):
  query -> federated retrieval (core.retrieval / orchestrator)
        -> enclave re-rank -> prompt build -> slot prefill -> decode chunks

Two serving modes share one cache layout:

  * **Lock-step** (``step_batch``): drain the queue in fixed ``max_batch``
    chunks, one packed prefill + one fused decode ``while_loop`` per
    chunk.  Kept as the deterministic baseline the continuous path is
    parity-tested (and benchmarked) against.
  * **Continuous** (``serve_stream`` / ``serve`` / ``serve_prompts``): a
    fixed pool of ``max_batch`` cache slots.  Finished rows (EOS or
    per-request budget)
    retire and free their slot; the ``Scheduler`` admits queued requests
    into free slots by prefilling just that row and scattering its cache
    in, while the other slots keep decoding.  Decode runs in fused
    chunks of at most ``sched_chunk`` steps (never past the smallest
    remaining per-slot budget) between scheduler interventions, so one
    long generation no longer stalls the batch and host sync stays off
    the per-token path.  ``serve_stream`` yields each ``(rid, answer)``
    at retire time and — fed by a thread-safe ``Scheduler`` — keeps
    consuming submissions from a producer thread until the scheduler is
    closed, so an upstream stage (federated collect for the next
    micro-batch) can overlap decode.

Both paths pack prompts left-aligned (PAD tail) and decode each row from
its OWN cache position (per-row ``lengths``), so ragged batches never
attend to PAD key/values; rows that hit EOS are masked to PAD for the
rest of their stay in the batch (post-EOS logits are never emitted).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS, PAD, HashTokenizer
from repro.models import lm as LM
from repro.runtime.sharding import ShardingPolicy
from repro.serving.scheduler import Request, Scheduler


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8  # cache slots (continuous) / chunk size (lock-step)
    max_prompt_len: int = 512
    max_new_tokens: int = 16  # hard cap; per-request budgets clamp to this
    temperature: float = 0.0
    sched_chunk: int = 8  # max fused decode steps between scheduler runs


class ServeEngine:
    def __init__(self, cfg: ModelConfig, pol: ShardingPolicy, params, scfg: ServeConfig):
        self.cfg, self.pol, self.params, self.scfg = cfg, pol, params, scfg
        cache_len = scfg.max_prompt_len + scfg.max_new_tokens
        self._cache_len = cache_len
        t_cap = scfg.max_new_tokens

        def prefill_fn(params, tokens, lengths):
            logits, cache = LM.prefill(cfg, pol, params, {"tokens": tokens}, cache_len=cache_len)
            # logits at each row's true last prompt position -> first token
            last = jnp.take_along_axis(logits, (lengths - 1)[:, None, None], axis=1)[:, 0, :]
            return jnp.argmax(last, -1).astype(jnp.int32), cache

        def decode_loop(params, cache, first_tok, lengths):
            """Device-resident greedy decode: runs until every row has
            emitted EOS or max_new_tokens, with no host round-trips.
            Rows that are already done emit PAD (never fresh argmax)."""
            b = first_tok.shape[0]
            t_max = scfg.max_new_tokens
            out = jnp.zeros((b, t_max), jnp.int32).at[:, 0].set(first_tok)
            state = (jnp.int32(1), cache, first_tok, first_tok == EOS, out)

            def cond(st):
                t, _, _, done, _ = st
                return (t < t_max) & ~jnp.all(done)

            def body(st):
                t, cache, cur, done, out = st
                logits, cache = LM.decode_step(
                    cfg, pol, params, cache, cur[:, None], lengths + t - 1
                )
                nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
                nxt = jnp.where(done, PAD, nxt)  # finished rows stay PAD
                out = out.at[:, t].set(nxt)
                return (t + 1, cache, nxt, done | (nxt == EOS), out)

            t, _, _, _, out = jax.lax.while_loop(cond, body, state)
            return out, t

        def admit_row(params, cache, cur, lengths, emitted, done, budget, out,
                      row_tokens, slot, length, b_new):
            """Prefill ONE request and scatter it into cache slot ``slot``
            in a single fused call (every cache leaf is (n_blocks, B, ...)
            so the slot axis is 1).  Fusing prefill + scatter keeps
            admission at one dispatch per request."""
            first, row_cache = prefill_fn(params, row_tokens, length[None])
            first = first[0]
            cache = jax.tree.map(lambda c, rc: c.at[:, slot].set(rc[:, 0]), cache, row_cache)
            cur = cur.at[slot].set(first)
            lengths = lengths.at[slot].set(length)
            emitted = emitted.at[slot].set(1)
            budget = budget.at[slot].set(b_new)
            out = out.at[slot].set(jnp.zeros((t_cap + 1,), jnp.int32).at[0].set(first))
            done = done.at[slot].set((first == EOS) | (b_new <= 1))
            return cache, cur, lengths, emitted, done, budget, out

        def decode_chunk(params, cache, cur, lengths, emitted, done, budget, out, n_steps):
            """Fused decode of up to ``n_steps`` tokens across all slots.
            Per-slot write offsets (``emitted``) make retire/admit cheap: a
            slot's output row is always its own [0, emitted) prefix.  The
            inner loop writes a dense (B, chunk) buffer by step index —
            exactly the lock-step hot loop — and the ragged merge into the
            per-slot offsets happens ONCE per chunk, so continuous
            batching adds no per-token bookkeeping to the decode path."""
            b = scfg.max_batch
            rows = jnp.arange(b)
            chunk = jnp.zeros((b, scfg.sched_chunk), jnp.int32)
            emitted0 = emitted

            def cond(st):
                t = st[0]
                return (t < n_steps) & ~jnp.all(st[4])

            def body(st):
                t, cache, cur, emitted, done, chunk = st
                logits, cache = LM.decode_step(
                    cfg, pol, params, cache, cur[:, None], lengths + emitted - 1
                )
                nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
                nxt = jnp.where(done, PAD, nxt)
                chunk = chunk.at[:, t].set(nxt)
                emitted = emitted + (~done)
                done = done | (nxt == EOS) | (emitted >= budget)
                return (t + 1, cache, nxt, emitted, done, chunk)

            st = (jnp.int32(0), cache, cur, emitted, done, chunk)
            _, cache, cur, emitted, done, chunk = jax.lax.while_loop(cond, body, st)
            # ragged merge: row i's fresh tokens are chunk[i, :emitted-emitted0]
            # landing at out[i, emitted0:emitted]; invalid lanes are clipped
            # into the spare (t_cap) column, which holds no answer tokens
            j = jnp.arange(scfg.sched_chunk)
            idx = jnp.minimum(emitted0[:, None] + j[None, :], t_cap)
            valid = j[None, :] < (emitted - emitted0)[:, None]
            keep = out[rows[:, None], idx]
            out = out.at[rows[:, None], idx].set(jnp.where(valid, chunk, keep))
            return cache, cur, emitted, done, out

        self._prefill = jax.jit(prefill_fn)
        self._decode_loop = jax.jit(decode_loop)
        self._admit_row = jax.jit(admit_row)
        self._decode_chunk = jax.jit(decode_chunk)
        self.queue: list[np.ndarray] = []

    def submit(self, prompt_tokens: np.ndarray):
        self.queue.append(prompt_tokens.ravel())

    def _pack(self, prompts: list[np.ndarray]) -> np.ndarray:
        """Left-aligned PAD-tail packing; each row's decode slot is its own
        length (per-row positions), so ragged rows stay correct."""
        width = self.scfg.max_prompt_len
        out = np.zeros((len(prompts), width), np.int32)
        for i, p in enumerate(prompts):
            p = p[-width:]
            out[i, : len(p)] = p
        return out

    # ------------------------------------------------------------------ #
    # lock-step path (deterministic baseline)
    # ------------------------------------------------------------------ #
    def step_batch(self) -> list[np.ndarray]:
        """Serve up to max_batch queued requests; returns answer token rows."""
        if not self.queue:
            return []
        batch, self.queue = self.queue[: self.scfg.max_batch], self.queue[self.scfg.max_batch :]
        lengths = np.array(
            [min(len(p), self.scfg.max_prompt_len) for p in batch], np.int32
        )
        tokens = self._pack(batch)
        first, cache = self._prefill(self.params, jnp.asarray(tokens), jnp.asarray(lengths))
        out, n_steps = self._decode_loop(self.params, cache, first, jnp.asarray(lengths))
        ans = np.asarray(out)[:, : int(n_steps)]
        return [row for row in ans]

    # ------------------------------------------------------------------ #
    # continuous-batching path (slot pool + scheduler)
    # ------------------------------------------------------------------ #
    def serve(self, scheduler: Scheduler) -> dict[int, np.ndarray]:
        """Drive the slot pool until the scheduler's queue drains and every
        slot has retired (one-shot batch semantics: does NOT wait for more
        submissions).  Returns {rid: answer tokens}; per-request timestamps
        land in ``scheduler.results`` for latency stats."""
        return dict(self.serve_stream(scheduler, drain=True))

    def serve_stream(self, scheduler: Scheduler, *, drain: bool = False):
        """Generator form of ``serve``: yields ``(rid, answer_tokens)`` the
        moment a slot retires instead of returning one dict at drain, so a
        caller can stream results out (and overlap downstream work) while
        other slots keep decoding.

        With ``drain=False`` (default) the stream is *live*: when the
        queue is momentarily empty but the scheduler is still open, the
        engine keeps decoding active slots and then blocks in
        ``scheduler.wait_for_work`` — a producer thread may keep
        submitting until it calls ``scheduler.close()``, at which point
        the stream drains the remaining work and ends.  ``drain=True``
        restores the one-shot ``serve`` behavior: exit as soon as the
        queue is empty and every slot has retired, closed or not."""
        scfg = self.scfg
        B, t_cap, width = scfg.max_batch, scfg.max_new_tokens, scfg.max_prompt_len
        cache = LM.init_cache(self.cfg, B, self._cache_len, dtype=jnp.dtype(self.cfg.dtype))
        cur = jnp.zeros((B,), jnp.int32)
        lengths = jnp.ones((B,), jnp.int32)
        emitted = jnp.ones((B,), jnp.int32)
        done = jnp.ones((B,), bool)  # free slots read as done
        budget = jnp.ones((B,), jnp.int32)
        out = jnp.zeros((B, t_cap + 1), jnp.int32)
        slots: list[Request | None] = [None] * B
        # host mirrors of emitted/done/budget keep the loop at ONE device
        # sync per chunk; a just-admitted row's done flag is only known
        # on-device (first token may be EOS), so mirror it as live — the
        # worst case is one no-op chunk dispatch before the readback
        em_h = np.ones((B,), np.int64)
        dn_h = np.ones((B,), bool)
        bu_h = np.ones((B,), np.int64)

        while True:
            # admit queued requests into free slots (one fused prefill each)
            for slot in range(B):
                if slots[slot] is not None:
                    continue
                req = scheduler.pop_ready()
                if req is None:
                    break
                p = req.tokens[-width:]
                row = np.zeros((1, width), np.int32)
                row[0, : len(p)] = p
                length = np.int32(len(p))
                # prefill always emits one token, so the effective budget
                # floor is 1; None means "engine cap" (0 does not)
                b_new = t_cap if req.max_new_tokens is None else req.max_new_tokens
                b_new = max(1, min(int(b_new), t_cap))
                cache, cur, lengths, emitted, done, budget, out = self._admit_row(
                    self.params, cache, cur, lengths, emitted, done, budget, out,
                    jnp.asarray(row), jnp.int32(slot), jnp.asarray(length), jnp.int32(b_new),
                )
                slots[slot] = req
                em_h[slot], dn_h[slot], bu_h[slot] = 1, b_new <= 1, b_new
            active = [i for i in range(B) if slots[i] is not None]
            if not active:
                if drain or scheduler.closed:
                    if scheduler.has_pending:
                        continue  # submit raced the close/empty check
                    return  # queue drained and every slot retired
                # live stream: idle until the producer submits or closes
                scheduler.wait_for_work()
                continue

            remaining = [int(bu_h[i] - em_h[i]) for i in active if not dn_h[i]]
            if remaining:
                # per-request budgets and EOS are enforced on-device, so the
                # chunk length is purely a scheduling granularity: run up to
                # the largest live budget but at most sched_chunk steps, so
                # freed slots wait at most sched_chunk for the next admit
                n = max(1, min(max(remaining), scfg.sched_chunk))
                cache, cur, emitted, done, out = self._decode_chunk(
                    self.params, cache, cur, lengths, emitted, done, budget, out,
                    jnp.int32(n),
                )
            # np.array (not asarray): device views are read-only and the
            # mirrors are written at the next admit
            em_h, dn_h = np.array(emitted), np.array(done)

            retired = [i for i in active if dn_h[i]]
            if retired:
                out_h = np.asarray(out)
                for i in retired:
                    req = slots[i]
                    ans = out_h[i, : int(em_h[i])].copy()
                    scheduler.finish(req, ans)
                    slots[i] = None  # retire: slot free for the next admit
                    yield req.rid, ans

    def serve_prompts(
        self,
        prompts: Sequence[np.ndarray],
        max_new_tokens: int | Sequence[int] | None = None,
        deadlines: Sequence[float | None] | None = None,
    ) -> list[np.ndarray]:
        """Convenience wrapper: schedule ``prompts`` and serve to completion,
        returning answers in prompt order (expired requests -> empty row)."""
        sched = Scheduler()
        rids = sched.submit_many(prompts, max_new_tokens, deadlines)
        res = self.serve(sched)
        empty = np.zeros((0,), np.int32)
        return [res.get(rid, empty) for rid in rids]


def engine_generator(engine: ServeEngine, mode: str = "continuous") -> Callable:
    """Adapt a ServeEngine to the orchestrator's generator contract:
    callable (1, S) -> (1, T) for single prompts, plus ``generate_batch``
    (list of prompts -> list of answer rows).  ``mode="continuous"``
    (default) routes batches through the slot scheduler so ragged
    generations retire early; ``mode="lockstep"`` keeps the fixed-chunk
    baseline for determinism comparisons."""
    assert mode in ("continuous", "lockstep")

    def generate(prompt_tokens: np.ndarray) -> np.ndarray:
        if engine.queue:
            raise RuntimeError("engine_generator requires exclusive use of the engine queue")
        if mode == "continuous":
            return generate_batch([np.asarray(prompt_tokens)])[0][None, :]
        engine.submit(np.asarray(prompt_tokens))
        return engine.step_batch()[0][None, :]

    def generate_batch(prompts: list[np.ndarray]) -> list[np.ndarray]:
        if engine.queue:
            raise RuntimeError("engine_generator requires exclusive use of the engine queue")
        if mode == "continuous":
            return engine.serve_prompts([np.asarray(p) for p in prompts])
        for p in prompts:
            engine.submit(np.asarray(p))
        outs: list[np.ndarray] = []
        while engine.queue:
            outs.extend(engine.step_batch())
        return outs

    generate.generate_batch = generate_batch
    generate.engine = engine
    generate.mode = mode
    # advertise the engine's prompt window so prompt builders truncate
    # grammar-aware at the right width instead of leaving it to the
    # engine's blind tail-slice
    generate.max_prompt_len = engine.scfg.max_prompt_len
    return generate
