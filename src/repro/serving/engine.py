"""RAG serving engine: batched prefill + decode with the C-FedRAG pipeline.

Request flow (paper Fig. 2/3 in serving form):
  query -> federated retrieval (core.retrieval / orchestrator)
        -> enclave re-rank -> prompt build -> batched prefill -> decode loop

Batching: requests are grouped to `max_batch`; prompts are packed
left-aligned (PAD tail) into a common cache and each row decodes from its
OWN write position (per-row `lengths`), so ragged batches never attend to
PAD key/values.  The decode loop is a single jitted ``lax.while_loop``
with on-device EOS tracking — no per-token host sync.  The engine is
deliberately synchronous (single-host simulation); the scheduler hook
points (queue, deadline, quorum) mirror a production continuous-batching
server."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS, PAD, HashTokenizer
from repro.models import lm as LM
from repro.runtime.sharding import ShardingPolicy


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_prompt_len: int = 512
    max_new_tokens: int = 16
    temperature: float = 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, pol: ShardingPolicy, params, scfg: ServeConfig):
        self.cfg, self.pol, self.params, self.scfg = cfg, pol, params, scfg
        cache_len = scfg.max_prompt_len + scfg.max_new_tokens

        def prefill_fn(params, tokens, lengths):
            logits, cache = LM.prefill(cfg, pol, params, {"tokens": tokens}, cache_len=cache_len)
            # logits at each row's true last prompt position -> first token
            last = jnp.take_along_axis(logits, (lengths - 1)[:, None, None], axis=1)[:, 0, :]
            return jnp.argmax(last, -1).astype(jnp.int32), cache

        def decode_loop(params, cache, first_tok, lengths):
            """Device-resident greedy decode: runs until every row has
            emitted EOS or max_new_tokens, with no host round-trips."""
            b = first_tok.shape[0]
            t_max = scfg.max_new_tokens
            out = jnp.zeros((b, t_max), jnp.int32).at[:, 0].set(first_tok)
            state = (jnp.int32(1), cache, first_tok, first_tok == EOS, out)

            def cond(st):
                t, _, _, done, _ = st
                return (t < t_max) & ~jnp.all(done)

            def body(st):
                t, cache, cur, done, out = st
                logits, cache = LM.decode_step(
                    cfg, pol, params, cache, cur[:, None], lengths + t - 1
                )
                nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
                out = out.at[:, t].set(nxt)
                return (t + 1, cache, nxt, done | (nxt == EOS), out)

            t, _, _, _, out = jax.lax.while_loop(cond, body, state)
            return out, t

        self._prefill = jax.jit(prefill_fn)
        self._decode_loop = jax.jit(decode_loop)
        self.queue: list[np.ndarray] = []

    def submit(self, prompt_tokens: np.ndarray):
        self.queue.append(prompt_tokens.ravel())

    def _pack(self, prompts: list[np.ndarray]) -> np.ndarray:
        """Left-aligned PAD-tail packing; each row's decode slot is its own
        length (per-row positions), so ragged rows stay correct."""
        width = self.scfg.max_prompt_len
        out = np.zeros((len(prompts), width), np.int32)
        for i, p in enumerate(prompts):
            p = p[-width:]
            out[i, : len(p)] = p
        return out

    def step_batch(self) -> list[np.ndarray]:
        """Serve up to max_batch queued requests; returns answer token rows."""
        if not self.queue:
            return []
        batch, self.queue = self.queue[: self.scfg.max_batch], self.queue[self.scfg.max_batch :]
        lengths = np.array(
            [min(len(p), self.scfg.max_prompt_len) for p in batch], np.int32
        )
        tokens = self._pack(batch)
        first, cache = self._prefill(self.params, jnp.asarray(tokens), jnp.asarray(lengths))
        out, n_steps = self._decode_loop(self.params, cache, first, jnp.asarray(lengths))
        ans = np.asarray(out)[:, : int(n_steps)]
        return [row for row in ans]


def engine_generator(engine: ServeEngine) -> Callable:
    """Adapt a ServeEngine to the orchestrator's generator contract:
    callable (1, S) -> (1, T) for single prompts, plus ``generate_batch``
    (list of prompts -> list of answer rows) so ``answer_batch`` decodes
    the whole query batch through one packed prefill + decode loop."""

    def generate(prompt_tokens: np.ndarray) -> np.ndarray:
        if engine.queue:
            raise RuntimeError("engine_generator requires exclusive use of the engine queue")
        engine.submit(np.asarray(prompt_tokens))
        return engine.step_batch()[0][None, :]

    def generate_batch(prompts: list[np.ndarray]) -> list[np.ndarray]:
        if engine.queue:
            raise RuntimeError("engine_generator requires exclusive use of the engine queue")
        for p in prompts:
            engine.submit(np.asarray(p))
        outs: list[np.ndarray] = []
        while engine.queue:
            outs.extend(engine.step_batch())
        return outs

    generate.generate_batch = generate_batch
    return generate
