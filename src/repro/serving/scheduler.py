"""Request scheduler for continuous-batching serving.

The scheduler owns the *admission* side of the serving stack: requests
enter per-tenant queues with an optional per-request generation budget
and an optional admission deadline; ``ServeEngine.serve``/``serve_stream``
pull from it whenever a cache slot frees up, so short generations retire
and hand their slot to queued work while long generations keep decoding.

**Tenant SLO classes.**  Every request carries a ``tenant`` label and an
integer ``priority``.  Admission picks the next request in three steps:

  1. strict priority — among the tenant queues' *heads*, only the highest
     priority class is eligible (an interactive class preempts the
     *queue*; it never preempts a running slot — decode always finishes
     or retires on its own terms);
  2. weighted-fair within a class — stride scheduling over per-tenant
     virtual ``pass`` values (each admission advances the winner's pass
     by ``1 / weight``), so a tenant with weight 3 gets ~3x the admission
     slots of a weight-1 tenant under contention;
  3. FIFO within a tenant — a tenant's own requests never reorder.

With a single tenant and uniform priority this degenerates to exactly
the old global FIFO, so engine-vs-engine parity oracles are unaffected.
``fifo=True`` forces global submission-order admission across tenants
(the benchmark baseline that lets an interactive class collapse behind a
batch flood) while still tracking per-tenant stats.

The scheduler is **thread-safe**: a producer thread may ``submit`` while
an engine thread is consuming via ``pop_ready``/``finish`` (the pipelined
front door runs collect for micro-batch N+1 on a collector thread while
the engine decodes micro-batch N).  The producer signals end-of-stream
with ``close()``; the engine blocks in ``wait_for_work`` when the queue
is momentarily empty and exits once the scheduler is closed and drained.

**Windows vs lifetime.**  A resident engine serves many calls against
long-lived state, so every ``latency_stats()`` quantity comes in two
flavors: the *window* (since the engine last called ``begin_window()``,
i.e. the current/most recent serve call) at the top level — keeping the
one-shot reading identical to before — and cumulative *lifetime* totals
nested under ``"lifetime"``.  Without ``begin_window`` the window spans
the scheduler's whole life and the two coincide.

Contracts:
  * ``submit`` is cheap and returns a request id immediately; submitting
    to a closed scheduler raises.
  * ``pop_ready`` admits per the class/weight/FIFO order above; a request
    whose admission deadline has already passed is marked ``expired``
    (recorded in ``results``) and never admitted — the continuous-
    batching analogue of the orchestrator dropping stragglers at the
    collect deadline.  A selected request the engine's gate rejects
    stays at its queue head and ``None`` is returned: big requests wait
    for KV blocks rather than being overtaken, so admission order never
    depends on pool pressure.
  * ``close()`` ends admission; ``drain()`` blocks until every submitted
    request reached a terminal state (done or expired).
  * Completion timestamps are recorded on ``finish`` so per-request
    latency distributions (p50/p95) fall out for free.  ``submit`` takes
    an optional ``t0`` anchor so ``latency_s`` can cover an upstream
    stage (e.g. collect start), not just generation — the anchor moves
    ONLY the latency origin; ``deadline_s`` expiry always counts from
    the actual submit time, so upstream stage cost is never charged
    against the generation SLO.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request tracked through the admission queue."""

    rid: int
    tokens: np.ndarray  # (S,) prompt token ids
    max_new_tokens: int | None = None  # None -> engine's configured cap
    deadline_s: float | None = None  # admission budget from submit time
    submitted_at: float = 0.0  # actual submit time: the expiry clock
    anchor_t0: float | None = None  # optional upstream anchor for latency_s only
    started_at: float | None = None  # slot admission time
    finished_at: float | None = None
    answer: np.ndarray | None = None
    status: str = "queued"  # queued | active | done | expired
    truncated: bool = False  # done, but cut short by KV-pool OOM
    deadlocked: bool = False  # done empty: admission dependency deadlock
    tag: Any = None  # caller-side routing key (e.g. query index)
    tenant: str = "default"  # SLO class label
    priority: int = 0  # higher admits first (queue preemption only)

    @property
    def latency_s(self) -> float | None:
        if self.finished_at is None:
            return None
        start = self.submitted_at if self.anchor_t0 is None else self.anchor_t0
        return self.finished_at - start


def _broadcast(values, n: int, what: str) -> list:
    """Scalar-or-per-request broadcast shared by every serve entry point.

    A 0-d numpy array is a *scalar* (``isinstance(x, np.ndarray)`` alone
    would send it down the ``list(x)`` path, which raises); a list-typed
    value must match ``len(prompts)`` exactly — silent ``zip`` truncation
    would drop requests."""
    if isinstance(values, np.ndarray) and values.ndim == 0:
        values = values.item()
    if isinstance(values, (list, tuple, np.ndarray)):
        out = [None if v is None else v for v in list(values)]
        if len(out) != n:
            raise ValueError(
                f"{what} has {len(out)} entries for {n} prompts; "
                "per-request values must match the prompt count"
            )
        return out
    return [values] * n


def _percentiles(reqs) -> dict:
    """n_done/expiry/flag counts + p50/p95/mean over a request set."""
    done = [r for r in reqs if r.status == "done"]
    out = {
        "n_done": len(done),
        "n_expired": sum(1 for r in reqs if r.status == "expired"),
        "n_truncated": sum(1 for r in done if r.truncated),
        "n_deadlocked": sum(1 for r in done if r.deadlocked),
    }
    lats = sorted(r.latency_s for r in done)
    if lats:
        arr = np.asarray(lats)
        out["p50_s"] = float(np.percentile(arr, 50))
        out["p95_s"] = float(np.percentile(arr, 95))
        out["mean_s"] = float(arr.mean())
    return out


class Scheduler:
    """Thread-safe multi-tenant admission queue feeding a ``ServeEngine``
    slot pool.  See the module docstring for the admission order."""

    def __init__(self, tenant_weights: dict[str, float] | None = None,
                 fifo: bool = False, deadline_slack_s: float | None = None):
        self._queues: dict[str, collections.deque[Request]] = {}
        self._weights = {k: float(v) for k, v in (tenant_weights or {}).items()}
        bad = [k for k, v in self._weights.items() if v <= 0]
        if bad:
            raise ValueError(f"tenant weight(s) must be positive: {bad}")
        if deadline_slack_s is not None and deadline_slack_s < 0:
            raise ValueError(f"deadline_slack_s={deadline_slack_s} must be >= 0")
        self._fifo = bool(fifo)
        # deadline-aware admission boost: a queue head within this many
        # seconds of its admission-deadline expiry is promoted to top
        # priority (fair-share heads can otherwise starve into expiry
        # behind heavier tenants).  None disables the boost; expiry
        # accounting itself is untouched — an already-overdue head still
        # expires before selection ever sees it
        self._deadline_slack = deadline_slack_s
        self._pass: dict[str, float] = {}  # stride-scheduling virtual time
        self._next_rid = 0
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._closed = False
        self.results: dict[int, Request] = {}
        # occupancy gauges (engine-reported): last + extremes, so memory
        # headroom falls out of latency_stats() alongside the percentiles
        self._peak_backlog = 0
        self._occupancy: dict[str, int] = {}
        self._prefix: dict[str, int | float] | None = None
        self._prefix_lifetime: dict[str, int | float] | None = None
        self._dispatch: dict[str, int] | None = None
        self._dispatch_lifetime: dict[str, int] | None = None
        # per-tenant admission gauges (engine-reported, window + lifetime)
        self._tenant_admit: dict[str, dict[str, int]] = {}
        self._tenant_admit_life: dict[str, dict[str, int]] = {}
        self._window_t0 = 0.0  # window == lifetime until begin_window()

    def submit(
        self,
        prompt_tokens: np.ndarray,
        *,
        max_new_tokens: int | None = None,
        deadline_s: float | None = None,
        tag: Any = None,
        t0: float | None = None,
        tenant: str = "default",
        priority: int = 0,
    ) -> int:
        tokens = np.asarray(prompt_tokens).ravel()
        if tokens.size == 0:
            # an empty prompt has no last position to read first-token
            # logits from, yet would still allocate a KV block
            # (blocks_for(0) == 1) — reject at the door, loudly
            raise ValueError(
                "empty prompt: a request must carry at least one token "
                "(zero-length prompts have no position to decode from)"
            )
        req = Request(
            rid=-1,
            tokens=tokens,
            max_new_tokens=None if max_new_tokens is None else int(max_new_tokens),
            deadline_s=deadline_s,
            submitted_at=time.monotonic(),
            anchor_t0=t0,
            tag=tag,
            tenant=str(tenant),
            priority=int(priority),
        )
        with self._cond:
            if self._closed:
                raise RuntimeError("scheduler is closed; no further submissions")
            req.rid = self._next_rid
            self._next_rid += 1
            q = self._queues.get(req.tenant)
            if q is None:
                q = self._queues[req.tenant] = collections.deque()
                # a tenant joining late starts at the current virtual time,
                # not at zero — otherwise it would monopolize admission
                # until its pass catches up with the incumbents
                self._pass.setdefault(
                    req.tenant, min(self._pass.values(), default=0.0)
                )
            q.append(req)
            self._peak_backlog = max(
                self._peak_backlog, sum(len(x) for x in self._queues.values())
            )
            self._cond.notify_all()
        return req.rid

    def submit_many(
        self,
        prompts,
        max_new_tokens=None,
        deadlines=None,
        *,
        tags=None,
        t0: float | None = None,
        tenants=None,
        priorities=None,
    ) -> list[int]:
        """Submit a batch of prompts; ``max_new_tokens``/``deadlines``/
        ``tenants``/``priorities`` may each be a scalar (broadcast) or a
        per-request sequence whose length must equal ``len(prompts)``."""
        n = len(prompts)
        budgets = _broadcast(max_new_tokens, n, "max_new_tokens")
        deads = _broadcast(deadlines, n, "deadlines")
        tens = _broadcast("default" if tenants is None else tenants, n, "tenants")
        prios = _broadcast(0 if priorities is None else priorities, n, "priorities")
        tags = list(tags) if tags is not None else [None] * n
        if len(tags) != n:
            raise ValueError(f"tags has {len(tags)} entries for {n} prompts")
        return [
            self.submit(
                np.asarray(p).ravel(), max_new_tokens=b, deadline_s=d, tag=g,
                t0=t0, tenant=te, priority=pr,
            )
            for p, b, d, g, te, pr in zip(prompts, budgets, deads, tags, tens, prios)
        ]

    @property
    def n_queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    @property
    def has_pending(self) -> bool:
        return any(self._queues.values())

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self):
        """End of admission: no further ``submit`` calls are accepted and
        consumers blocked in ``wait_for_work`` wake up to drain and exit."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def wait_for_work(self, timeout: float | None = None) -> bool:
        """Block until a queue is non-empty or the scheduler is closed.
        Returns True if there is work (or close) to act on, False on
        timeout — the consumer side of the submit/close handshake."""
        with self._cond:
            return self._cond.wait_for(
                lambda: any(self._queues.values()) or self._closed, timeout=timeout
            )

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every submitted request reached a terminal state
        (done or expired) — the producer side of the handshake."""
        with self._cond:
            return self._cond.wait_for(
                lambda: len(self.results) >= self._next_rid, timeout=timeout
            )

    @property
    def n_in_flight(self) -> int:
        """Submitted requests not yet terminal (queued or active)."""
        with self._lock:
            return self._next_rid - len(self.results)

    def wait_backlog_below(self, n: int, timeout: float | None = None) -> bool:
        """Block until fewer than ``n`` submitted requests are non-terminal
        — producer-side backpressure, so a fast collector stays a bounded
        number of micro-batches ahead of a slow engine instead of
        materializing the whole workload in the queue.  Expired requests
        count as terminal the moment ``pop_ready`` drops them, so a
        deadline-heavy workload can never wedge a waiting producer."""
        with self._cond:
            return self._cond.wait_for(
                lambda: self._next_rid - len(self.results) < n, timeout=timeout
            )

    def _expire_heads(self, now: float) -> None:
        """Drop overdue requests from every queue head (holding the lock)."""
        for q in self._queues.values():
            while q:
                req = q[0]
                if req.deadline_s is not None and now - req.submitted_at > req.deadline_s:
                    q.popleft()
                    req.status = "expired"
                    req.finished_at = now
                    self.results[req.rid] = req
                    self._cond.notify_all()  # wake drain() waiters
                else:
                    break

    def pop_ready(self, admit_if=None) -> Request | None:
        """Next admissible request per class priority -> tenant weighted-
        fair -> per-tenant FIFO (see module docstring); expires overdue
        queue heads in passing.

        ``admit_if(req) -> bool`` is the engine's memory-aware admission
        gate (paged KV: does the pool have blocks for this prompt?).  A
        selected request the gate rejects stays AT ITS QUEUE HEAD and
        ``None`` is returned: big requests wait for blocks rather than
        being overtaken (no cross-tenant overtake under memory pressure
        either — admission order stays deterministic, so paged-vs-
        contiguous bit-parity never depends on pool pressure)."""
        with self._cond:
            now = time.monotonic()
            self._expire_heads(now)
            heads = [q[0] for q in self._queues.values() if q]
            if not heads:
                return None
            if self._fifo:
                req = min(heads, key=lambda r: r.rid)
            else:
                # deadline boost: heads whose expiry is within the slack
                # outrank every priority class (they would expire waiting
                # their fair-share turn); ties among urgent heads fall
                # back to the same weighted-fair order
                urgent = [
                    r for r in heads
                    if self._deadline_slack is not None
                    and r.deadline_s is not None
                    and r.deadline_s - (now - r.submitted_at) <= self._deadline_slack
                ]
                eligible = urgent
                if not eligible:
                    top = max(r.priority for r in heads)
                    eligible = [r for r in heads if r.priority == top]
                req = min(
                    eligible,
                    key=lambda r: (self._pass.get(r.tenant, 0.0), r.rid),
                )
            if admit_if is not None and not admit_if(req):
                return None  # head stays queued until resources free up
            self._queues[req.tenant].popleft()
            if not self._fifo:
                w = self._weights.get(req.tenant, 1.0)
                self._pass[req.tenant] = self._pass.get(req.tenant, 0.0) + 1.0 / w
            req.status = "active"
            req.started_at = now
            return req

    def finish(self, req: Request, answer: np.ndarray, truncated: bool = False,
               deadlocked: bool = False):
        """``truncated=True`` marks a request the engine force-retired on
        KV-pool OOM: terminal and answered, but the answer is a prefix of
        what the budget allowed — callers watching degradation under
        memory pressure read it off the request / ``n_truncated``.
        ``deadlocked=True`` marks a request force-done (empty answer) when
        its admission hit a prefix-dependency deadlock — the graceful
        degradation of ``AdmissionDeadlock``, same contract as truncation:
        terminal, flagged, neighbors unharmed."""
        req.status = "done"
        req.truncated = truncated
        req.deadlocked = deadlocked
        req.finished_at = time.monotonic()
        req.answer = np.asarray(answer)
        with self._cond:
            self.results[req.rid] = req
            self._cond.notify_all()  # wake drain() waiters

    # ---- observability ----
    def begin_window(self):
        """Start a stats window: subsequent ``latency_stats()`` top-level
        numbers cover completions (and engine-reported window gauges)
        from this point on, with cumulative totals under ``"lifetime"``.
        The engine calls this on every ``serve``/``serve_stream`` entry,
        so on a resident engine each call reads as its own window."""
        with self._lock:
            self._window_t0 = time.monotonic()
            self._tenant_admit = {}
            self._prefix = None
            self._dispatch = None

    def record_occupancy(self, *, free_slots: int | None = None, free_blocks: int | None = None,
                         reclaimable_blocks: int | None = None,
                         draft_free_blocks: int | None = None):
        """Engine-side memory gauges, sampled once per scheduler pass.

        ``free_slots``: open decode slots right now; ``free_blocks``: free
        KV blocks in the TARGET pool (paged engines only — contiguous
        engines pass None); ``reclaimable_blocks``: parked zero-ref
        prefix-cache blocks the pool can evict under pressure
        (prefix-cache engines only); ``draft_free_blocks``: free blocks
        in the DRAFTER's pool (speculative engines only — a drafter-side
        OOM breaks speculation for the row, so its headroom needs its own
        gauge).  Keeps the last sample plus the running minimum of each,
        so "how close did serving get to the memory wall" (peak
        concurrency = ``max_batch - min_free_slots``, block headroom =
        ``min_free_blocks`` + reclaimable) is answerable after the fact."""
        with self._lock:
            for key, val in (
                ("free_slots", free_slots),
                ("free_blocks", free_blocks),
                ("reclaimable_blocks", reclaimable_blocks),
                ("draft_free_blocks", draft_free_blocks),
            ):
                if val is None:
                    continue
                self._occupancy[key] = int(val)
                low = f"min_{key}"
                self._occupancy[low] = min(self._occupancy.get(low, int(val)), int(val))

    def record_prefix_stats(self, window: dict, lifetime: dict | None = None):
        """Prefix-cache counters, engine-reported each pass.  ``window``
        covers the current serve call (deltas since ``begin_window``) and
        lands at the TOP level of ``latency_stats()``; ``lifetime`` holds
        the engine's cumulative totals (a resident engine outlives many
        windows) and nests under ``"lifetime"``.  Expected keys:
        ``prefix_lookups``/``prefix_hits``/``prefill_tokens``/
        ``prefill_tokens_saved``/``prefix_shared_blocks``/
        ``prefix_cached_blocks`` plus, on a tiered cache, the spill
        gauges (``spilled_blocks``, ``spill_bytes_used``,
        ``spill_demotions``, ``spill_readmits``).  ``latency_stats``
        derives ``prefix_hit_rate`` and ``prefill_saved_frac``."""
        with self._lock:
            self._prefix = {k: v for k, v in window.items()}
            if lifetime is not None:
                self._prefix_lifetime = {k: v for k, v in lifetime.items()}

    def record_dispatch_stats(self, *, admit_dispatches: int, decode_dispatches: int,
                              mixed_dispatches: int, steps: int,
                              lifetime: dict | None = None,
                              draft_dispatches: int = 0,
                              draft_fill_dispatches: int = 0,
                              spec_rounds: int = 0,
                              spec_tokens_proposed: int = 0,
                              spec_tokens_accepted: int = 0,
                              spec_tokens_emitted: int = 0):
        """Dispatch counters for THIS serve window (engine deltas,
        overwritten each pass): fused admit prefills, fused decode
        chunks, and unified mixed prefill+decode dispatches, plus the
        number of engine scheduler steps — ``latency_stats`` derives
        ``dispatches_per_step`` from them (the O(1)-per-step regression
        gauge of the unified path).  ``lifetime`` optionally carries the
        engine's cumulative totals for the nested lifetime view.

        Speculative engines (``draft_k > 0``) additionally report:
        drafter k-loop dispatches, drafter prefill-only dispatches
        (``draft_fill_dispatches`` — admission cost, like target
        prefill, excluded from the per-round bound), spec rounds
        (verify dispatches that carried at least one ``q_len > 1``
        descriptor), and per-round token
        tallies (proposed drafts / accepted drafts / committed tokens,
        where committed includes the correction token).  These stay OUT
        of ``dispatches_per_step`` — ``latency_stats`` derives the
        speculative gauges ``spec_accept_rate``,
        ``spec_tokens_per_round`` (the tokens/step > 1 headline), and
        ``dispatches_per_spec_round`` (the O(2) bound) from them."""
        with self._lock:
            self._dispatch = {
                "admit_dispatches": int(admit_dispatches),
                "decode_dispatches": int(decode_dispatches),
                "mixed_dispatches": int(mixed_dispatches),
                "engine_steps": int(steps),
            }
            if draft_dispatches or draft_fill_dispatches or spec_rounds:
                self._dispatch.update(
                    draft_dispatches=int(draft_dispatches),
                    draft_fill_dispatches=int(draft_fill_dispatches),
                    spec_rounds=int(spec_rounds),
                    spec_tokens_proposed=int(spec_tokens_proposed),
                    spec_tokens_accepted=int(spec_tokens_accepted),
                    spec_tokens_emitted=int(spec_tokens_emitted),
                )
            if lifetime is not None:
                self._dispatch_lifetime = {k: int(v) for k, v in lifetime.items()}

    def record_tenant_admit(self, tenant: str, *, prefill_tokens: int,
                            prefill_tokens_saved: int = 0, hit: bool = False):
        """One admission's prefix accounting, attributed to a tenant (the
        engine calls this at every slot admit).  Accumulated per window
        AND per scheduler lifetime; surfaced under
        ``latency_stats()["tenants"][tenant]``."""
        with self._lock:
            for book in (self._tenant_admit, self._tenant_admit_life):
                acc = book.setdefault(
                    tenant,
                    {"n_admitted": 0, "prefix_lookups": 0, "prefix_hits": 0,
                     "prefill_tokens": 0, "prefill_tokens_saved": 0},
                )
                acc["n_admitted"] += 1
                acc["prefix_lookups"] += 1
                acc["prefix_hits"] += int(bool(hit))
                acc["prefill_tokens"] += int(prefill_tokens)
                acc["prefill_tokens_saved"] += int(prefill_tokens_saved)

    @staticmethod
    def _derive_prefix(g: dict) -> dict:
        out = dict(g)
        if out.get("prefix_lookups"):
            out["prefix_hit_rate"] = out["prefix_hits"] / out["prefix_lookups"]
        if out.get("prefill_tokens"):
            out["prefill_saved_frac"] = (
                out["prefill_tokens_saved"] / out["prefill_tokens"]
            )
        return out

    def _tenant_stats(self, reqs, admit_book) -> dict:
        """Per-tenant view over ``reqs`` (window or lifetime): completion
        counts, percentiles, output tokens, and admission/prefix gauges
        from the matching accounting book."""
        by_tenant: dict[str, list[Request]] = {}
        for r in reqs:
            by_tenant.setdefault(r.tenant, []).append(r)
        tenants = {}
        for name in sorted(set(by_tenant) | set(admit_book)):
            treqs = by_tenant.get(name, [])
            st = _percentiles(treqs)
            st["tokens_out"] = int(
                sum(len(r.answer) for r in treqs if r.status == "done" and r.answer is not None)
            )
            admit = admit_book.get(name)
            if admit is not None:
                st.update(self._derive_prefix(admit))
            tenants[name] = st
        return tenants

    def latency_stats(self) -> dict:
        """p50/p95/mean submit->finish latency plus occupancy, prefix-
        cache, dispatch, and per-tenant gauges.

        Top-level numbers cover the current WINDOW (since the last
        ``begin_window()``; the scheduler's whole life if never called).
        ``"lifetime"`` nests the cumulative view — completion counts and
        percentiles over every request this scheduler ever finished, plus
        the engine's lifetime prefix/dispatch totals when reported.
        ``"tenants"`` (present when tenants completed work or admitted in
        the window) maps tenant -> per-tenant window stats."""
        with self._lock:
            all_reqs = list(self.results.values())
            window = [
                r for r in all_reqs
                if r.finished_at is not None and r.finished_at >= self._window_t0
            ]
            gauges: dict[str, Any] = {"peak_backlog": self._peak_backlog, **self._occupancy}
            if self._dispatch is not None:
                gauges.update(self._dispatch)
                if self._dispatch["engine_steps"]:
                    gauges["dispatches_per_step"] = (
                        self._dispatch["admit_dispatches"]
                        + self._dispatch["decode_dispatches"]
                        + self._dispatch["mixed_dispatches"]
                    ) / self._dispatch["engine_steps"]
                if self._dispatch.get("spec_tokens_proposed"):
                    gauges["spec_accept_rate"] = (
                        self._dispatch["spec_tokens_accepted"]
                        / self._dispatch["spec_tokens_proposed"]
                    )
                if self._dispatch.get("spec_rounds"):
                    gauges["spec_tokens_per_round"] = (
                        self._dispatch["spec_tokens_emitted"]
                        / self._dispatch["spec_rounds"]
                    )
                    # every drafter dispatch + its paired verify dispatch;
                    # the unified-path O(2)-per-spec-round regression gauge
                    gauges["dispatches_per_spec_round"] = (
                        self._dispatch.get("draft_dispatches", 0)
                        + self._dispatch["spec_rounds"]
                    ) / self._dispatch["spec_rounds"]
            if self._prefix is not None:
                gauges.update(self._derive_prefix(self._prefix))
            lifetime = _percentiles(all_reqs)
            if self._prefix_lifetime is not None:
                lifetime.update(self._derive_prefix(self._prefix_lifetime))
            if self._dispatch_lifetime is not None:
                lifetime.update(self._dispatch_lifetime)
            lt_tenants = self._tenant_stats(all_reqs, self._tenant_admit_life)
            if lt_tenants:
                lifetime["tenants"] = lt_tenants
            tenants = self._tenant_stats(window, self._tenant_admit)
            win = _percentiles(window)
        out = {**win, **gauges, "lifetime": lifetime}
        if win["n_done"] == 0:
            # preserve the historical empty-window shape: n_done plus
            # gauges only (tests and callers probe keys conditionally)
            out = {"n_done": 0, **gauges, "lifetime": lifetime}
        if tenants:
            out["tenants"] = tenants
        return out
