"""Request scheduler for continuous-batching serving.

The scheduler owns the *admission* side of the serving stack: requests
enter a FIFO queue with an optional per-request generation budget and an
optional admission deadline; ``ServeEngine.serve`` pulls from it whenever
a cache slot frees up, so short generations retire and hand their slot to
queued work while long generations keep decoding.

Contracts:
  * ``submit`` is cheap and returns a request id immediately.
  * ``pop_ready`` is FIFO over live requests; a request whose admission
    deadline has already passed is marked ``expired`` (recorded in
    ``results``) and never admitted — the continuous-batching analogue of
    the orchestrator dropping stragglers at the collect deadline.
  * Completion timestamps are recorded on ``finish`` so per-request
    latency distributions (p50/p95) fall out for free.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request tracked through the admission queue."""

    rid: int
    tokens: np.ndarray  # (S,) prompt token ids
    max_new_tokens: int | None = None  # None -> engine's configured cap
    deadline_s: float | None = None  # admission budget from submit time
    submitted_at: float = 0.0
    started_at: float | None = None  # slot admission time
    finished_at: float | None = None
    answer: np.ndarray | None = None
    status: str = "queued"  # queued | active | done | expired

    @property
    def latency_s(self) -> float | None:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at


class Scheduler:
    """FIFO admission queue feeding the slot pool of a ``ServeEngine``."""

    def __init__(self):
        self._queue: collections.deque[Request] = collections.deque()
        self._next_rid = 0
        self.results: dict[int, Request] = {}

    def submit(
        self,
        prompt_tokens: np.ndarray,
        *,
        max_new_tokens: int | None = None,
        deadline_s: float | None = None,
    ) -> int:
        req = Request(
            rid=self._next_rid,
            tokens=np.asarray(prompt_tokens).ravel(),
            max_new_tokens=max_new_tokens,
            deadline_s=deadline_s,
            submitted_at=time.monotonic(),
        )
        self._next_rid += 1
        self._queue.append(req)
        return req.rid

    def submit_many(
        self,
        prompts,
        max_new_tokens=None,
        deadlines=None,
    ) -> list[int]:
        """Submit a batch of prompts; scalar-or-per-request budget and
        deadline broadcast shared by every serve entry point."""
        n = len(prompts)
        budgets = (
            list(max_new_tokens)
            if isinstance(max_new_tokens, (list, tuple, np.ndarray))
            else [max_new_tokens] * n
        )
        deadlines = list(deadlines) if deadlines is not None else [None] * n
        return [
            self.submit(np.asarray(p).ravel(), max_new_tokens=b, deadline_s=d)
            for p, b, d in zip(prompts, budgets, deadlines)
        ]

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def has_pending(self) -> bool:
        return bool(self._queue)

    def pop_ready(self) -> Request | None:
        """Next admissible request (FIFO); expires overdue ones in passing."""
        while self._queue:
            req = self._queue.popleft()
            now = time.monotonic()
            if req.deadline_s is not None and now - req.submitted_at > req.deadline_s:
                req.status = "expired"
                req.finished_at = now
                self.results[req.rid] = req
                continue
            req.status = "active"
            req.started_at = now
            return req
        return None

    def finish(self, req: Request, answer: np.ndarray):
        req.status = "done"
        req.finished_at = time.monotonic()
        req.answer = np.asarray(answer)
        self.results[req.rid] = req

    # ---- observability ----
    def latency_stats(self) -> dict:
        """p50/p95/mean submit->finish latency over completed requests."""
        lats = sorted(
            r.latency_s for r in self.results.values() if r.status == "done"
        )
        if not lats:
            return {"n_done": 0}
        arr = np.asarray(lats)
        return {
            "n_done": len(lats),
            "n_expired": sum(1 for r in self.results.values() if r.status == "expired"),
            "p50_s": float(np.percentile(arr, 50)),
            "p95_s": float(np.percentile(arr, 95)),
            "mean_s": float(arr.mean()),
        }
