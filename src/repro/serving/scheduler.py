"""Request scheduler for continuous-batching serving.

The scheduler owns the *admission* side of the serving stack: requests
enter a FIFO queue with an optional per-request generation budget and an
optional admission deadline; ``ServeEngine.serve``/``serve_stream`` pull
from it whenever a cache slot frees up, so short generations retire and
hand their slot to queued work while long generations keep decoding.

The scheduler is **thread-safe**: a producer thread may ``submit`` while
an engine thread is consuming via ``pop_ready``/``finish`` (the pipelined
front door runs collect for micro-batch N+1 on a collector thread while
the engine decodes micro-batch N).  The producer signals end-of-stream
with ``close()``; the engine blocks in ``wait_for_work`` when the queue
is momentarily empty and exits once the scheduler is closed and drained.

Contracts:
  * ``submit`` is cheap and returns a request id immediately; submitting
    to a closed scheduler raises.
  * ``pop_ready`` is FIFO over live requests; a request whose admission
    deadline has already passed is marked ``expired`` (recorded in
    ``results``) and never admitted — the continuous-batching analogue of
    the orchestrator dropping stragglers at the collect deadline.
  * ``close()`` ends admission; ``drain()`` blocks until every submitted
    request reached a terminal state (done or expired).
  * Completion timestamps are recorded on ``finish`` so per-request
    latency distributions (p50/p95) fall out for free.  ``submit`` takes
    an optional ``t0`` anchor so ``latency_s`` can cover an upstream
    stage (e.g. collect start), not just generation — the anchor moves
    ONLY the latency origin; ``deadline_s`` expiry always counts from
    the actual submit time, so upstream stage cost is never charged
    against the generation SLO.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request tracked through the admission queue."""

    rid: int
    tokens: np.ndarray  # (S,) prompt token ids
    max_new_tokens: int | None = None  # None -> engine's configured cap
    deadline_s: float | None = None  # admission budget from submit time
    submitted_at: float = 0.0  # actual submit time: the expiry clock
    anchor_t0: float | None = None  # optional upstream anchor for latency_s only
    started_at: float | None = None  # slot admission time
    finished_at: float | None = None
    answer: np.ndarray | None = None
    status: str = "queued"  # queued | active | done | expired
    truncated: bool = False  # done, but cut short by KV-pool OOM
    deadlocked: bool = False  # done empty: admission dependency deadlock
    tag: Any = None  # caller-side routing key (e.g. query index)

    @property
    def latency_s(self) -> float | None:
        if self.finished_at is None:
            return None
        start = self.submitted_at if self.anchor_t0 is None else self.anchor_t0
        return self.finished_at - start


def _broadcast(values, n: int, what: str) -> list:
    """Scalar-or-per-request broadcast shared by every serve entry point.

    A 0-d numpy array is a *scalar* (``isinstance(x, np.ndarray)`` alone
    would send it down the ``list(x)`` path, which raises); a list-typed
    value must match ``len(prompts)`` exactly — silent ``zip`` truncation
    would drop requests."""
    if isinstance(values, np.ndarray) and values.ndim == 0:
        values = values.item()
    if isinstance(values, (list, tuple, np.ndarray)):
        out = [None if v is None else v for v in list(values)]
        if len(out) != n:
            raise ValueError(
                f"{what} has {len(out)} entries for {n} prompts; "
                "per-request values must match the prompt count"
            )
        return out
    return [values] * n


class Scheduler:
    """Thread-safe FIFO admission queue feeding a ``ServeEngine`` slot pool."""

    def __init__(self):
        self._queue: collections.deque[Request] = collections.deque()
        self._next_rid = 0
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._closed = False
        self.results: dict[int, Request] = {}
        # occupancy gauges (engine-reported): last + extremes, so memory
        # headroom falls out of latency_stats() alongside the percentiles
        self._peak_backlog = 0
        self._occupancy: dict[str, int] = {}
        self._prefix: dict[str, int] | None = None
        self._dispatch: dict[str, int] | None = None

    def submit(
        self,
        prompt_tokens: np.ndarray,
        *,
        max_new_tokens: int | None = None,
        deadline_s: float | None = None,
        tag: Any = None,
        t0: float | None = None,
    ) -> int:
        tokens = np.asarray(prompt_tokens).ravel()
        if tokens.size == 0:
            # an empty prompt has no last position to read first-token
            # logits from, yet would still allocate a KV block
            # (blocks_for(0) == 1) — reject at the door, loudly
            raise ValueError(
                "empty prompt: a request must carry at least one token "
                "(zero-length prompts have no position to decode from)"
            )
        req = Request(
            rid=-1,
            tokens=tokens,
            max_new_tokens=None if max_new_tokens is None else int(max_new_tokens),
            deadline_s=deadline_s,
            submitted_at=time.monotonic(),
            anchor_t0=t0,
            tag=tag,
        )
        with self._cond:
            if self._closed:
                raise RuntimeError("scheduler is closed; no further submissions")
            req.rid = self._next_rid
            self._next_rid += 1
            self._queue.append(req)
            self._peak_backlog = max(self._peak_backlog, len(self._queue))
            self._cond.notify_all()
        return req.rid

    def submit_many(
        self,
        prompts,
        max_new_tokens=None,
        deadlines=None,
        *,
        tags=None,
        t0: float | None = None,
    ) -> list[int]:
        """Submit a batch of prompts; ``max_new_tokens``/``deadlines`` may
        each be a scalar (broadcast) or a per-request sequence whose length
        must equal ``len(prompts)``."""
        n = len(prompts)
        budgets = _broadcast(max_new_tokens, n, "max_new_tokens")
        deads = _broadcast(deadlines, n, "deadlines")
        tags = list(tags) if tags is not None else [None] * n
        if len(tags) != n:
            raise ValueError(f"tags has {len(tags)} entries for {n} prompts")
        return [
            self.submit(
                np.asarray(p).ravel(), max_new_tokens=b, deadline_s=d, tag=g, t0=t0
            )
            for p, b, d, g in zip(prompts, budgets, deads, tags)
        ]

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def has_pending(self) -> bool:
        return bool(self._queue)

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self):
        """End of admission: no further ``submit`` calls are accepted and
        consumers blocked in ``wait_for_work`` wake up to drain and exit."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def wait_for_work(self, timeout: float | None = None) -> bool:
        """Block until the queue is non-empty or the scheduler is closed.
        Returns True if there is work (or close) to act on, False on
        timeout — the consumer side of the submit/close handshake."""
        with self._cond:
            return self._cond.wait_for(
                lambda: bool(self._queue) or self._closed, timeout=timeout
            )

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every submitted request reached a terminal state
        (done or expired) — the producer side of the handshake."""
        with self._cond:
            return self._cond.wait_for(
                lambda: len(self.results) >= self._next_rid, timeout=timeout
            )

    @property
    def n_in_flight(self) -> int:
        """Submitted requests not yet terminal (queued or active)."""
        with self._lock:
            return self._next_rid - len(self.results)

    def wait_backlog_below(self, n: int, timeout: float | None = None) -> bool:
        """Block until fewer than ``n`` submitted requests are non-terminal
        — producer-side backpressure, so a fast collector stays a bounded
        number of micro-batches ahead of a slow engine instead of
        materializing the whole workload in the queue.  Expired requests
        count as terminal the moment ``pop_ready`` drops them, so a
        deadline-heavy workload can never wedge a waiting producer."""
        with self._cond:
            return self._cond.wait_for(
                lambda: self._next_rid - len(self.results) < n, timeout=timeout
            )

    def pop_ready(self, admit_if=None) -> Request | None:
        """Next admissible request (FIFO); expires overdue ones in passing.

        ``admit_if(req) -> bool`` is the engine's memory-aware admission
        gate (paged KV: does the pool have blocks for this prompt?).  A
        head request the gate rejects stays AT THE HEAD and ``None`` is
        returned: strict FIFO is preserved — big requests wait for blocks
        rather than being overtaken, so admission order (and therefore
        paged-vs-contiguous bit-parity) never depends on pool pressure."""
        with self._cond:
            while self._queue:
                req = self._queue[0]
                now = time.monotonic()
                if req.deadline_s is not None and now - req.submitted_at > req.deadline_s:
                    self._queue.popleft()
                    req.status = "expired"
                    req.finished_at = now
                    self.results[req.rid] = req
                    self._cond.notify_all()  # wake drain() waiters
                    continue
                if admit_if is not None and not admit_if(req):
                    return None  # head stays queued until resources free up
                self._queue.popleft()
                req.status = "active"
                req.started_at = now
                return req
            return None

    def finish(self, req: Request, answer: np.ndarray, truncated: bool = False,
               deadlocked: bool = False):
        """``truncated=True`` marks a request the engine force-retired on
        KV-pool OOM: terminal and answered, but the answer is a prefix of
        what the budget allowed — callers watching degradation under
        memory pressure read it off the request / ``n_truncated``.
        ``deadlocked=True`` marks a request force-done (empty answer) when
        its admission hit a prefix-dependency deadlock — the graceful
        degradation of ``AdmissionDeadlock``, same contract as truncation:
        terminal, flagged, neighbors unharmed."""
        req.status = "done"
        req.truncated = truncated
        req.deadlocked = deadlocked
        req.finished_at = time.monotonic()
        req.answer = np.asarray(answer)
        with self._cond:
            self.results[req.rid] = req
            self._cond.notify_all()  # wake drain() waiters

    # ---- observability ----
    def record_occupancy(self, *, free_slots: int | None = None, free_blocks: int | None = None,
                         reclaimable_blocks: int | None = None):
        """Engine-side memory gauges, sampled once per scheduler pass.

        ``free_slots``: open decode slots right now; ``free_blocks``: free
        KV blocks (paged engines only — contiguous engines pass None);
        ``reclaimable_blocks``: parked zero-ref prefix-cache blocks the
        pool can evict under pressure (prefix-cache engines only).
        Keeps the last sample plus the running minimum of each, so "how
        close did serving get to the memory wall" (peak concurrency =
        ``max_batch - min_free_slots``, block headroom =
        ``min_free_blocks`` + reclaimable) is answerable after the fact."""
        with self._lock:
            for key, val in (
                ("free_slots", free_slots),
                ("free_blocks", free_blocks),
                ("reclaimable_blocks", reclaimable_blocks),
            ):
                if val is None:
                    continue
                self._occupancy[key] = int(val)
                low = f"min_{key}"
                self._occupancy[low] = min(self._occupancy.get(low, int(val)), int(val))

    def record_prefix_stats(self, *, lookups: int, hits: int, prefill_tokens: int,
                            prefill_tokens_saved: int, shared_blocks: int,
                            cached_blocks: int):
        """Prefix-cache counters (engine-cumulative, overwritten each
        pass): admission lookups / hits, prompt tokens seen vs skipped by
        prefix sharing, blocks adopted by reference, and chunks currently
        cached.  ``latency_stats`` derives ``prefix_hit_rate`` and
        ``prefill_saved_frac`` from them."""
        with self._lock:
            self._prefix = {
                "prefix_lookups": int(lookups),
                "prefix_hits": int(hits),
                "prefill_tokens": int(prefill_tokens),
                "prefill_tokens_saved": int(prefill_tokens_saved),
                "prefix_shared_blocks": int(shared_blocks),
                "prefix_cached_blocks": int(cached_blocks),
            }

    def record_dispatch_stats(self, *, admit_dispatches: int, decode_dispatches: int,
                              mixed_dispatches: int, steps: int):
        """Dispatch counters for THIS serve pass (engine deltas,
        overwritten each pass): fused admit prefills, fused decode
        chunks, and unified mixed prefill+decode dispatches, plus the
        number of engine scheduler steps — ``latency_stats`` derives
        ``dispatches_per_step`` from them (the O(1)-per-step regression
        gauge of the unified path)."""
        with self._lock:
            self._dispatch = {
                "admit_dispatches": int(admit_dispatches),
                "decode_dispatches": int(decode_dispatches),
                "mixed_dispatches": int(mixed_dispatches),
                "engine_steps": int(steps),
            }

    def latency_stats(self) -> dict:
        """p50/p95/mean submit->finish latency over completed requests,
        plus occupancy gauges (peak backlog; free/min-free slots and KV
        blocks when an engine reported them via ``record_occupancy``),
        prefix-cache hit-rate gauges (``record_prefix_stats``), and
        dispatch-count gauges (``record_dispatch_stats``)."""
        with self._lock:
            done = [r for r in self.results.values() if r.status == "done"]
            n_expired = sum(1 for r in self.results.values() if r.status == "expired")
            n_truncated = sum(1 for r in done if r.truncated)
            n_deadlocked = sum(1 for r in done if r.deadlocked)
            gauges = {"peak_backlog": self._peak_backlog, **self._occupancy}
            if self._dispatch is not None:
                gauges.update(self._dispatch)
                if self._dispatch["engine_steps"]:
                    gauges["dispatches_per_step"] = (
                        self._dispatch["admit_dispatches"]
                        + self._dispatch["decode_dispatches"]
                        + self._dispatch["mixed_dispatches"]
                    ) / self._dispatch["engine_steps"]
            if self._prefix is not None:
                gauges.update(self._prefix)
                if self._prefix["prefix_lookups"]:
                    gauges["prefix_hit_rate"] = (
                        self._prefix["prefix_hits"] / self._prefix["prefix_lookups"]
                    )
                if self._prefix["prefill_tokens"]:
                    gauges["prefill_saved_frac"] = (
                        self._prefix["prefill_tokens_saved"] / self._prefix["prefill_tokens"]
                    )
        lats = sorted(r.latency_s for r in done)
        if not lats:
            return {"n_done": 0, **gauges}
        arr = np.asarray(lats)
        return {
            "n_done": len(lats),
            "n_expired": n_expired,
            "n_truncated": n_truncated,
            "n_deadlocked": n_deadlocked,
            "p50_s": float(np.percentile(arr, 50)),
            "p95_s": float(np.percentile(arr, 95)),
            "mean_s": float(arr.mean()),
            **gauges,
        }
