"""Distributed flash-decode: single-token attention over a KV cache whose
SEQUENCE dim is sharded across a mesh axis (§Perf cell A3 as runnable code).

Each shard computes (o, m, l) softmax partials over its cache slice, then
a 3-tensor combine (pmax + 2 psums of per-head scalars/rows) produces the
exact global attention — the same math as
kernels/decode_attention.combine_partials, validated in
tests/test_kernels.py and tests/test_serving.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.runtime.compat import shard_map


def _local_partials(q, k_loc, v_loc, lengths, *, axis_name):
    """Per-shard partials + cross-shard flash-decode merge."""
    axis = jax.lax.axis_index(axis_name)
    shard_len = k_loc.shape[1]
    local_valid = jnp.clip(lengths - axis * shard_len, 0, shard_len)
    b, h, dh = q.shape
    kv = k_loc.shape[2]
    qr = q.astype(jnp.float32).reshape(b, kv, h // kv, dh)
    logits = jnp.einsum("bkgd,bskd->bkgs", qr, k_loc.astype(jnp.float32)) / np.sqrt(dh)
    valid = jnp.arange(shard_len)[None, None, None, :] < local_valid[:, None, None, None]
    logits = jnp.where(valid, logits, -1e30)
    m = logits.max(-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = p.sum(-1, keepdims=True)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_loc.astype(jnp.float32))
    m_g = jax.lax.pmax(m, axis_name)
    scale = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * scale, axis_name)
    o_g = jax.lax.psum(o * scale, axis_name)
    out = (o_g / jnp.maximum(l_g, 1e-30)).reshape(b, h, dh)
    return out.astype(q.dtype)


def dist_decode_attention(
    q,  # (B, H, dh) replicated over the shard axis
    k_cache,  # (B, S, KV, dh), dim 1 sharded over `axis_name`
    v_cache,
    lengths,  # (B,) global valid lengths
    mesh,
    axis_name: str = "data",
):
    fn = shard_map(
        partial(_local_partials, axis_name=axis_name),
        mesh=mesh,
        in_specs=(P(), P(None, axis_name, None, None), P(None, axis_name, None, None), P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(q, k_cache, v_cache, lengths)
