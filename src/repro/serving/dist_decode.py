"""Distributed flash-decode: single-token attention over a KV cache whose
SEQUENCE dim is sharded across a mesh axis (§Perf cell A3 as runnable code).

Each shard computes (o, m, l) softmax partials over its cache slice, then
``combine_partials`` — a 3-tensor combine (pmax + 2 psums of per-head
scalars/rows) — produces the exact global attention: the same math as the
list-based ``kernels/decode_attention.combine_partials``, validated in
tests/test_kernels.py and tests/test_sharded_serving.py.

``combine_partials`` here is THE shared cross-shard merge: the sharded
paged engine's distributed mixed dispatch (``layers.attn_mixed_paged`` /
``attn_decode_paged`` with a 5-D sharded pool) imports it rather than
re-deriving the merge.  Its bit-parity contract: when a query row's KV
blocks are all resident on ONE shard (the allocator's row-affinity
invariant) and every other shard contributes exact-zero partials
(``m = -1e30``, ``l = 0``, ``o = 0`` — the trash-block masking contract),
the combine returns the owner's ``o / l`` bitwise: ``pmax`` over
``{m, -1e30, ...}`` is ``m``, the owner's scale is ``exp(0) = 1.0``
exactly, non-owner scales underflow to ``+0.0`` exactly, and adding
``±0.0`` in the psums preserves the owner's bits.  So an N-shard run is
bit-identical to the 1-shard run of the same partials-form attention.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.runtime.compat import shard_map


def combine_partials(o, m, l, *, axis_name: str):
    """Merge per-shard flash-softmax partials across ``axis_name``.

    ``o``: un-normalized weighted values (``sum_j e_ij v_j`` over the
    shard's keys), ``m``: the shard's row max (masked rows carry
    ``-1e30``), ``l``: the shard's partition sum — all with the reduced
    key dim kept at size 1 on ``m``/``l``.  Returns the exact global
    ``softmax @ V`` output (same shape as ``o``)."""
    m_g = jax.lax.pmax(m, axis_name)
    scale = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * scale, axis_name)
    o_g = jax.lax.psum(o * scale, axis_name)
    return o_g / jnp.maximum(l_g, 1e-30)


def _local_partials(q, k_loc, v_loc, lengths, *, axis_name):
    """Per-shard partials + cross-shard flash-decode merge."""
    axis = jax.lax.axis_index(axis_name)
    shard_len = k_loc.shape[1]
    local_valid = jnp.clip(lengths - axis * shard_len, 0, shard_len)
    b, h, dh = q.shape
    kv = k_loc.shape[2]
    qr = q.astype(jnp.float32).reshape(b, kv, h // kv, dh)
    logits = jnp.einsum("bkgd,bskd->bkgs", qr, k_loc.astype(jnp.float32)) / np.sqrt(dh)
    valid = jnp.arange(shard_len)[None, None, None, :] < local_valid[:, None, None, None]
    logits = jnp.where(valid, logits, -1e30)
    m = logits.max(-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(valid, p, 0.0)  # all-masked shards contribute exact zeros
    l = p.sum(-1, keepdims=True)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_loc.astype(jnp.float32))
    out = combine_partials(o, m, l, axis_name=axis_name).reshape(b, h, dh)
    return out.astype(q.dtype)


def dist_decode_attention(
    q,  # (B, H, dh) replicated over the shard axis
    k_cache,  # (B, S, KV, dh), dim 1 sharded over `axis_name`
    v_cache,
    lengths,  # (B,) global valid lengths
    mesh,
    axis_name: str = "data",
):
    fn = shard_map(
        partial(_local_partials, axis_name=axis_name),
        mesh=mesh,
        in_specs=(P(), P(None, axis_name, None, None), P(None, axis_name, None, None), P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(q, k_cache, v_cache, lengths)
