"""Mesh-agnostic sharded checkpointing with integrity + async save.

Layout:  <dir>/step_<N>/
            manifest.json   tree structure, shapes, dtypes, sha256 per file
            <leaf-path>.npy one file per tensor (full logical array)

Design points for 1000+ node runs (single-host simulation here, layout
chosen so the multi-host generalization is mechanical):
  * tensors stored in LOGICAL layout -> restore re-shards onto any live
    mesh (elastic scaling / failover to a different pod count);
  * integrity hash per tensor + atomic directory rename (a crashed save
    never corrupts the latest checkpoint);
  * async save thread (training continues; `wait()` joins before exit);
  * data-iterator state saved alongside so restarts are exactly resumed.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path).replace("/", "_").strip("[']").replace("']['", "__").replace("'][", "__").replace("][", "__").replace("'", "")
        items.append((name, leaf))
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---------------- save ----------------
    def save(self, step: int, tree, extra: dict | None = None, sync: bool = False):
        """Snapshot to host memory immediately; write asynchronously."""
        host_tree = jax.tree.map(np.asarray, tree)
        self.wait()
        if sync:
            self._write(step, host_tree, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra or {})
            )
            self._thread.start()

    def _write(self, step: int, host_tree, extra: dict):
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        items, treedef = _leaf_paths(host_tree)
        manifest = {"step": step, "extra": extra, "tensors": {}, "treedef": None}
        names = []
        for name, arr in items:
            arr = np.asarray(arr)
            fn = f"{name}.npy"
            np.save(os.path.join(tmp, fn), arr)
            with open(os.path.join(tmp, fn), "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            manifest["tensors"][fn] = {
                "sha256": digest,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
            names.append(fn)
        manifest["order"] = names
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ---------------- restore ----------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None, shardings=None, verify: bool = True):
        """Restore into the structure of `tree_like`; re-shard to `shardings`
        (a matching tree of NamedSharding) if given -> elastic restore."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        items, treedef = _leaf_paths(tree_like)
        arrays = []
        for (name, like), fn in zip(items, manifest["order"]):
            path = os.path.join(d, fn)
            if verify:
                with open(path, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                if digest != manifest["tensors"][fn]["sha256"]:
                    raise IOError(f"checkpoint corruption detected in {fn}")
            arrays.append(np.load(path))
        restored = jax.tree.unflatten(treedef, arrays)
        if shardings is not None:
            restored = jax.tree.map(
                lambda a, s: jax.device_put(a, s), restored, shardings
            )
        return restored, manifest["extra"], step
