"""Continuous-batching scheduler + slot pool semantics.

Two layers of coverage:

  * **FakeLM tests** — a deterministic stand-in model whose next token is
    always ``(cur + 1) % vocab``, so the exact answer of every request
    (including where EOS lands) is computable in closed form.  These
    exercise slot retire/admit, per-request budgets, post-EOS PAD
    masking, and continuous-vs-lockstep parity with exact expectations.
  * **Real-LM tests** — the qwen3 smoke model, checking that the slot
    scatter path (cache tree insert + per-slot positions) reproduces the
    lock-step decode bit-for-bit on ragged batches.
"""
import time

import numpy as np
import pytest

from _fake_lm import POL, expected_answer as _expected, make_fake_engine, prompt_ending as _prompt
from repro.data.tokenizer import PAD
from repro.serving.engine import ServeConfig, ServeEngine, engine_generator
from repro.serving.scheduler import Scheduler


@pytest.fixture()
def fake_engine(monkeypatch):
    def make(max_batch=2, max_new_tokens=6, sched_chunk=3):
        return make_fake_engine(
            monkeypatch, max_batch=max_batch,
            max_new_tokens=max_new_tokens, sched_chunk=sched_chunk,
        )

    return make


# ------------------------------------------------------------------ #
# scheduler unit behavior
# ------------------------------------------------------------------ #
def test_scheduler_fifo_and_expiry():
    s = Scheduler()
    r1 = s.submit(np.arange(3))
    r2 = s.submit(np.arange(3), deadline_s=0.0)  # expired by pop time
    r3 = s.submit(np.arange(3), max_new_tokens=4)
    time.sleep(0.01)
    assert s.pop_ready().rid == r1
    nxt = s.pop_ready()  # r2 expires in passing
    assert nxt.rid == r3 and nxt.max_new_tokens == 4
    assert s.pop_ready() is None and not s.has_pending
    assert s.results[r2].status == "expired"


def test_zero_length_prompt_rejected_at_submit():
    """Regression: an empty prompt used to flow through admission into
    ``blocks_for(0, bs) == 1`` — a KV block allocated for a request with
    no position to decode from.  Both submit entry points must reject it
    at the door with a clear message, taking nothing into the queue."""
    s = Scheduler()
    with pytest.raises(ValueError, match="empty prompt"):
        s.submit(np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="empty prompt"):
        s.submit(np.zeros((0, 5), np.int32))  # ravel()s to zero length too
    with pytest.raises(ValueError, match="empty prompt"):
        s.submit_many([np.arange(3), np.zeros((0,), np.int32)], 4)
    # the good prompt of the failed batch was submitted before the bad
    # one raised; nothing after it entered, and the queue stays usable
    assert s.n_queued == 1
    assert s.pop_ready() is not None and s.pop_ready() is None


def test_pop_ready_admit_gate_keeps_fifo():
    """A head request the memory gate rejects stays AT THE HEAD: smaller
    requests behind it must not overtake (admission order is part of the
    paged/contiguous parity contract), and the same pop succeeds once the
    gate opens."""
    s = Scheduler()
    r1 = s.submit(np.arange(10))  # "big": gate rejects
    r2 = s.submit(np.arange(2))  # small, but FIFO says it waits
    gate_open = []
    gate = lambda req: bool(gate_open) or len(req.tokens) < 5
    assert s.pop_ready(admit_if=gate) is None
    assert s.n_queued == 2 and s.results == {}  # nothing popped or expired
    gate_open.append(True)
    assert s.pop_ready(admit_if=gate).rid == r1
    assert s.pop_ready(admit_if=gate).rid == r2


def test_pop_ready_gate_still_expires_overdue():
    s = Scheduler()
    r1 = s.submit(np.arange(3), deadline_s=0.0)
    s.submit(np.arange(3))
    time.sleep(0.01)
    # the gate rejects everything, but the overdue head still expires
    assert s.pop_ready(admit_if=lambda req: False) is None
    assert s.results[r1].status == "expired" and s.n_queued == 1


def test_occupancy_gauges_in_latency_stats():
    s = Scheduler()
    assert s.latency_stats()["peak_backlog"] == 0
    s.submit(np.arange(3)), s.submit(np.arange(3)), s.submit(np.arange(3))
    s.record_occupancy(free_slots=4, free_blocks=16)
    s.record_occupancy(free_slots=0, free_blocks=3)
    s.record_occupancy(free_slots=2, free_blocks=9)  # last != min
    req = s.pop_ready()
    s.finish(req, np.arange(1))
    st = s.latency_stats()
    assert st["peak_backlog"] == 3
    assert st["free_slots"] == 2 and st["min_free_slots"] == 0
    assert st["free_blocks"] == 9 and st["min_free_blocks"] == 3
    # contiguous engines report no blocks; gauge stays absent, not zero
    s2 = Scheduler()
    s2.record_occupancy(free_slots=1, free_blocks=None)
    assert "free_blocks" not in s2.latency_stats()


def test_submit_many_scalar_ndarray_broadcasts():
    """Regression: a 0-d numpy array passes the np.ndarray isinstance
    check but is not iterable (``list(np.array(5))`` raises) — it must
    broadcast like a python scalar."""
    s = Scheduler()
    rids = s.submit_many([np.arange(3)] * 3, np.array(5), np.array(1.5))
    assert len(rids) == 3
    for rid in rids:
        req = s.pop_ready()
        assert req.rid == rid and req.max_new_tokens == 5 and req.deadline_s == 1.5


def test_submit_many_rejects_mismatched_lengths():
    """Regression: a per-request list shorter than the prompt batch used
    to zip-truncate silently, dropping requests."""
    s = Scheduler()
    prompts = [np.arange(3)] * 3
    with pytest.raises(ValueError, match="max_new_tokens"):
        s.submit_many(prompts, [4, 4])
    with pytest.raises(ValueError, match="deadlines"):
        s.submit_many(prompts, None, [1.0, 1.0, 1.0, 1.0])
    with pytest.raises(ValueError, match="tags"):
        s.submit_many(prompts, tags=[0])
    assert s.n_queued == 0  # no partial submission from a rejected batch
    rids = s.submit_many(prompts, [4, 5, 6], [None, 0.5, None], tags=["a", "b", "c"])
    got = [s.pop_ready() for _ in rids]
    assert [r.max_new_tokens for r in got] == [4, 5, 6]
    assert [r.deadline_s for r in got] == [None, 0.5, None]
    assert [r.tag for r in got] == ["a", "b", "c"]


def test_latency_anchor_does_not_move_expiry_clock():
    """The t0 anchor widens latency_s to cover an upstream stage; the
    deadline_s expiry clock must still start at the actual submit."""
    s = Scheduler()
    rid = s.submit(np.arange(3), deadline_s=0.05, t0=time.monotonic() - 10.0)
    req = s.pop_ready()
    assert req is not None and req.rid == rid, (
        "anchored request expired: upstream time was charged to the deadline"
    )
    s.finish(req, np.arange(1))
    assert s.results[rid].latency_s > 9.0  # latency spans the anchor


def test_wait_backlog_below_backpressure():
    s = Scheduler()
    assert s.wait_backlog_below(1, timeout=0.0)  # nothing in flight
    s.submit(np.arange(3))
    s.submit(np.arange(3))
    assert not s.wait_backlog_below(2, timeout=0.0)
    req = s.pop_ready()
    s.finish(req, np.arange(1))
    assert s.n_in_flight == 1 and s.wait_backlog_below(2, timeout=0.0)
    # expired requests count as terminal too (no producer wedge)
    s.submit(np.arange(3), deadline_s=0.0)
    time.sleep(0.01)
    assert s.pop_ready() is not None  # the first live request
    assert s.pop_ready() is None  # expires the overdue one in passing
    assert s.wait_backlog_below(2, timeout=0.0)


def test_scheduler_close_and_drain_handshake():
    s = Scheduler()
    rid = s.submit(np.arange(3))
    assert s.wait_for_work(timeout=0.0)  # queued work is visible
    s.close()
    with pytest.raises(RuntimeError, match="closed"):
        s.submit(np.arange(3))
    assert s.closed and s.wait_for_work(timeout=0.0)
    assert not s.drain(timeout=0.0)  # rid has no terminal result yet
    req = s.pop_ready()
    s.finish(req, np.arange(2))
    assert s.drain(timeout=0.0)
    assert s.results[rid].status == "done"


# ------------------------------------------------------------------ #
# FakeLM: exact end-to-end semantics
# ------------------------------------------------------------------ #
def test_post_eos_rows_emit_pad_lockstep(fake_engine):
    """Satellite fix: rows already done must emit PAD, not fresh argmax.
    Row 1 hits EOS after 2 tokens while row 2 never does; the lock-step
    batch keeps decoding to 6 steps and row 1's tail must be PAD."""
    eng = fake_engine(max_batch=3, max_new_tokens=6)
    ends = [253, 0, 10]  # EOS after 5 / 2 / never (within 6)
    for e in ends:
        eng.submit(_prompt(e))
    rows = eng.step_batch()
    assert len(rows) == 3
    for e, row in zip(ends, rows):
        want = _expected(e, 6)
        assert list(row[: len(want)]) == want
        assert all(t == PAD for t in row[len(want):]), (
            f"post-EOS tokens of row ending {e} must be PAD, got {list(row)}"
        )


def test_continuous_matches_lockstep_exactly(fake_engine):
    eng = fake_engine(max_batch=2, max_new_tokens=6, sched_chunk=3)
    ends = [253, 0, 10, 254, 5]
    for e in ends:
        eng.submit(_prompt(e))
    lock = []
    while eng.queue:
        lock.extend(eng.step_batch())
    cont = eng.serve_prompts([_prompt(e) for e in ends])
    for e, l, c in zip(ends, lock, cont):
        want = _expected(e, 6)
        assert list(c) == want, "continuous answer diverged from closed form"
        assert list(l[: len(want)]) == want and all(t == PAD for t in l[len(want):])


def test_slot_retire_admit_exact(fake_engine):
    """7 requests through 2 slots with mixed budgets/EOS distances: every
    retire must free its slot for the next queued request and every
    answer must match the closed form (no cross-slot contamination)."""
    eng = fake_engine(max_batch=2, max_new_tokens=8, sched_chunk=3)
    ends = [250, 0, 10, 253, 99, 1, 200]
    budgets = [8, 3, 2, 8, 5, 8, 1]
    outs = eng.serve_prompts([_prompt(e) for e in ends], max_new_tokens=budgets)
    for e, b, got in zip(ends, budgets, outs):
        assert list(got) == _expected(e, b), f"end={e} budget={b}: {list(got)}"


def test_request_deadline_expires_unserved(fake_engine):
    eng = fake_engine(max_batch=1, max_new_tokens=4)
    sched = Scheduler()
    r1 = sched.submit(_prompt(10), max_new_tokens=4)
    r2 = sched.submit(_prompt(20), deadline_s=0.0)  # expires before admit
    time.sleep(0.01)
    results = eng.serve(sched)
    assert list(results[r1]) == _expected(10, 4)
    assert r2 not in results
    assert sched.results[r2].status == "expired"
    stats = sched.latency_stats()
    assert stats["n_done"] == 1 and stats["n_expired"] == 1
    assert stats["p50_s"] <= stats["p95_s"]


def test_engine_generator_continuous_mode(fake_engine):
    eng = fake_engine(max_batch=2, max_new_tokens=6)
    gen = engine_generator(eng)
    assert gen.engine is eng and gen.mode == "continuous"
    single = gen(_prompt(0)[None, :])
    assert single.shape[0] == 1 and list(single[0]) == _expected(0, 6)
    batch = gen.generate_batch([_prompt(e) for e in (253, 10, 0)])
    for e, row in zip((253, 10, 0), batch):
        assert list(row) == _expected(e, 6)


# ------------------------------------------------------------------ #
# real LM: slot scatter parity with lock-step decode
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def small_lm():
    import jax

    from repro.configs import get_config, smoke_config
    from repro.models import lm as LM
    from repro.models.params import init_params

    cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(dtype="float32")
    params = init_params(LM.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_continuous_matches_lockstep_real_lm(small_lm):
    """Acceptance parity: the slot pool (cache scatter + per-slot decode
    positions) must produce the same tokens as lock-step step_batch for
    the same ragged inputs."""
    cfg, params = small_lm
    eng = ServeEngine(
        cfg, POL, params,
        ServeConfig(max_batch=2, max_prompt_len=16, max_new_tokens=5, sched_chunk=2),
    )
    rng = np.random.default_rng(7)
    prompts = [rng.integers(8, cfg.vocab_size, size=n).astype(np.int32) for n in (9, 16, 12, 5, 14)]
    for p in prompts:
        eng.submit(p)
    lock = []
    while eng.queue:
        lock.extend(eng.step_batch())
    cont = eng.serve_prompts(prompts)
    for l, c in zip(lock, cont):
        n = len(c)
        assert n >= 1
        assert (l[:n] == np.asarray(c)).all(), "continuous diverged from lock-step"
        assert all(t == PAD for t in l[n:])


def test_per_request_budgets_real_lm(small_lm):
    cfg, params = small_lm
    eng = ServeEngine(
        cfg, POL, params,
        ServeConfig(max_batch=2, max_prompt_len=16, max_new_tokens=6, sched_chunk=4),
    )
    rng = np.random.default_rng(3)
    prompts = [rng.integers(8, cfg.vocab_size, size=12).astype(np.int32) for _ in range(4)]
    budgets = [1, 3, 6, 2]
    outs = eng.serve_prompts(prompts, max_new_tokens=budgets)
    full = eng.serve_prompts(prompts)  # budget = cap
    for got, ref, b in zip(outs, full, budgets):
        assert len(got) <= b
        n = len(got)
        assert (np.asarray(got) == np.asarray(ref)[:n]).all(), (
            "budgeted prefix diverged from uncapped generation"
        )


# ------------------------------------------------------------------ #
# tenant SLO classes: priority / weighted-fair / fifo admission order
# ------------------------------------------------------------------ #
def test_weighted_fair_stride_ratio_is_deterministic():
    """Stride scheduling: under contention a weight-2 tenant gets exactly
    2x the admissions of a weight-1 tenant, in a deterministic order
    (pass advances by 1/weight, ties break by rid)."""
    s = Scheduler(tenant_weights={"a": 2.0, "b": 1.0})
    for _ in range(8):
        s.submit(np.arange(3), tenant="a")
    for _ in range(8):
        s.submit(np.arange(3), tenant="b")
    order = [s.pop_ready().tenant for _ in range(9)]
    assert order == ["a", "b", "a", "a", "b", "a", "a", "b", "a"]
    assert order.count("a") == 2 * order.count("b")
    with pytest.raises(ValueError, match="positive"):
        Scheduler(tenant_weights={"a": 0.0})


def test_priority_preempts_queue_but_not_within_tenant_fifo():
    """A higher class admits first across tenants regardless of arrival;
    WITHIN a tenant, FIFO is absolute — a late high-priority request
    never overtakes its own tenant's queue head."""
    s = Scheduler()
    lo = [s.submit(np.arange(3), tenant="t", priority=0) for _ in range(2)]
    hi = s.submit(np.arange(3), tenant="u", priority=5)
    assert s.pop_ready().rid == hi  # class preempts the queue
    assert [s.pop_ready().rid, s.pop_ready().rid] == lo
    s2 = Scheduler()
    first = s2.submit(np.arange(3), priority=0)
    s2.submit(np.arange(3), priority=9)  # same tenant, behind the head
    assert s2.pop_ready().rid == first


def test_fifo_flag_restores_global_arrival_order():
    s = Scheduler(tenant_weights={"a": 5.0}, fifo=True)
    rids = [
        s.submit(np.arange(3), tenant=t, priority=p)
        for t, p in [("a", 0), ("b", 9), ("a", 0), ("b", 0)]
    ]
    assert [s.pop_ready().rid for _ in range(4)] == rids


def test_late_tenant_joins_at_current_virtual_time():
    """A tenant submitting its first request mid-run starts at the
    incumbents' pass, not zero — otherwise it would monopolize admission
    until its virtual time caught up."""
    s = Scheduler(tenant_weights={"a": 1.0, "late": 1.0})
    for _ in range(4):
        s.submit(np.arange(3), tenant="a")
    for _ in range(3):
        s.pop_ready()
    for _ in range(4):
        s.submit(np.arange(3), tenant="late")
    assert s._pass["late"] == pytest.approx(s._pass["a"])
    assert s.pop_ready().tenant == "a", "late joiner must not jump the queue"


def test_gate_rejection_preserves_tenant_order():
    """A gate-rejected selection keeps the request at its queue head and
    must not advance the tenant's pass (no charge without admission)."""
    s = Scheduler(tenant_weights={"a": 1.0, "b": 1.0})
    ra = s.submit(np.arange(9), tenant="a")
    s.submit(np.arange(2), tenant="b")
    assert s.pop_ready(admit_if=lambda r: len(r.tokens) < 5) is None
    assert s._pass["a"] == 0.0
    assert s.pop_ready(admit_if=lambda r: True).rid == ra


def test_deadline_boost_promotes_near_expiry_head():
    """Satellite: a queue head whose deadline expires within the
    configured slack outranks EVERY priority class — it admits before an
    equal-priority (and even higher-priority) rival submitted earlier —
    while expiry accounting stays untouched: an already-overdue head
    still expires instead of being boost-admitted."""
    s = Scheduler(deadline_slack_s=1.0)
    rival = s.submit(np.arange(3), tenant="a", priority=0)  # equal prio, lower rid
    hi = s.submit(np.arange(3), tenant="b", priority=5)  # higher class
    urgent = s.submit(np.arange(3), tenant="c", priority=0, deadline_s=0.5)
    relaxed = s.submit(np.arange(3), tenant="d", priority=0, deadline_s=60.0)
    # within-slack head first, then normal class/fair order resumes
    assert s.pop_ready().rid == urgent
    assert s.pop_ready().rid == hi
    assert s.pop_ready().rid == rival
    assert s.pop_ready().rid == relaxed

    # without the slack, the same workload admits by class then rid:
    # the boost is opt-in, not a default behavior change
    s2 = Scheduler()
    s2.submit(np.arange(3), tenant="a", priority=0)
    hi2 = s2.submit(np.arange(3), tenant="b", priority=5)
    s2.submit(np.arange(3), tenant="c", priority=0, deadline_s=0.5)
    assert s2.pop_ready().rid == hi2

    # expiry accounting unchanged: an overdue head expires in passing,
    # it is never boost-admitted past its deadline
    s3 = Scheduler(deadline_slack_s=1.0)
    dead = s3.submit(np.arange(3), deadline_s=0.0, tenant="a")
    live = s3.submit(np.arange(3), tenant="b")
    time.sleep(0.01)
    assert s3.pop_ready().rid == live
    assert s3.results[dead].status == "expired"
    assert s3.latency_stats()["lifetime"]["n_expired"] == 1

    # a gate-rejected boosted head stays at its head without being
    # charged fair-share pass (same no-charge rule as normal selection)
    s4 = Scheduler(deadline_slack_s=1.0)
    ru = s4.submit(np.arange(9), tenant="a", deadline_s=0.5)
    assert s4.pop_ready(admit_if=lambda r: False) is None
    assert s4._pass.get("a", 0.0) == 0.0
    assert s4.pop_ready(admit_if=lambda r: True).rid == ru

    with pytest.raises(ValueError, match="deadline_slack_s"):
        Scheduler(deadline_slack_s=-0.1)


# ------------------------------------------------------------------ #
# stats windows: per-serve deltas vs scheduler lifetime
# ------------------------------------------------------------------ #
def test_latency_stats_window_vs_lifetime():
    """begin_window() resets the TOP-LEVEL stats to the new window while
    ``"lifetime"`` keeps accumulating — the per-serve-call view of a
    resident engine (satellite: per-window deltas in latency_stats)."""
    s = Scheduler()
    s.submit(np.arange(3))
    s.finish(s.pop_ready(), np.arange(2))
    s.record_prefix_stats(
        {"prefix_lookups": 1, "prefix_hits": 1},
        lifetime={"prefix_lookups": 1, "prefix_hits": 1},
    )
    s.record_tenant_admit("default", prefill_tokens=3, prefill_tokens_saved=0)
    st = s.latency_stats()
    assert st["n_done"] == 1 and st["lifetime"]["n_done"] == 1
    assert st["prefix_hit_rate"] == 1.0
    assert st["tenants"]["default"]["n_admitted"] == 1
    time.sleep(0.002)
    s.begin_window()
    st = s.latency_stats()
    # fresh window: completions, prefix gauges, and tenant admits reset...
    assert st["n_done"] == 0 and "p50_s" not in st
    assert "prefix_hit_rate" not in st and "tenants" not in st
    # ...while the lifetime view keeps everything
    assert st["lifetime"]["n_done"] == 1
    assert st["lifetime"]["prefix_hit_rate"] == 1.0
    assert st["lifetime"]["tenants"]["default"]["n_admitted"] == 1
    s.submit(np.arange(3)), s.submit(np.arange(3))
    s.finish(s.pop_ready(), np.arange(4))
    s.finish(s.pop_ready(), np.arange(4))
    s.record_prefix_stats(
        {"prefix_lookups": 2, "prefix_hits": 1},
        lifetime={"prefix_lookups": 3, "prefix_hits": 2},
    )
    s.record_tenant_admit("default", prefill_tokens=3)
    st = s.latency_stats()
    assert st["n_done"] == 2 and st["lifetime"]["n_done"] == 3
    assert st["prefix_hit_rate"] == 0.5
    assert st["lifetime"]["prefix_hit_rate"] == pytest.approx(2 / 3)
    assert st["tenants"]["default"]["n_admitted"] == 1
    assert st["lifetime"]["tenants"]["default"]["n_admitted"] == 2
