"""Continuous-batching scheduler + slot pool semantics.

Two layers of coverage:

  * **FakeLM tests** — a deterministic stand-in model whose next token is
    always ``(cur + 1) % vocab``, so the exact answer of every request
    (including where EOS lands) is computable in closed form.  These
    exercise slot retire/admit, per-request budgets, post-EOS PAD
    masking, and continuous-vs-lockstep parity with exact expectations.
  * **Real-LM tests** — the qwen3 smoke model, checking that the slot
    scatter path (cache tree insert + per-slot positions) reproduces the
    lock-step decode bit-for-bit on ragged batches.
"""
import time

import jax.numpy as jnp
import numpy as np
import pytest

import repro.serving.engine as engine_mod
from repro.data.tokenizer import EOS, PAD
from repro.runtime.sharding import ShardingPolicy, base_rules
from repro.serving.engine import ServeConfig, ServeEngine, engine_generator
from repro.serving.scheduler import Scheduler

POL = ShardingPolicy(rules=base_rules(False), mesh=None)
VOCAB = 256


class _FakeLM:
    """Deterministic LM: next token is (cur + 1) % vocab.  A prompt whose
    last token is e generates e+1, e+2, ... so EOS (=2) arrives exactly
    (2 - e - 1) % vocab + 1 tokens after prefill."""

    @staticmethod
    def _logits(tokens):
        nxt = (tokens + 1) % VOCAB
        return jnp.eye(VOCAB, dtype=jnp.float32)[nxt]

    @staticmethod
    def prefill(cfg, pol, params, batch, cache_len=None):
        tokens = batch["tokens"]
        return _FakeLM._logits(tokens), _FakeLM.init_cache(cfg, tokens.shape[0], cache_len)

    @staticmethod
    def decode_step(cfg, pol, params, cache, tokens, pos):
        return _FakeLM._logits(tokens), cache

    @staticmethod
    def init_cache(cfg, batch, cache_len, dtype=jnp.float32, abstract=False):
        # same (n_blocks, B, ...) leaf layout contract as the real cache
        return {"dummy": jnp.zeros((1, batch, 1), jnp.float32)}


def _expected(end_token: int, budget: int) -> list[int]:
    """Closed-form answer of the FakeLM for a prompt ending in end_token."""
    toks, x = [], end_token
    while len(toks) < budget:
        x = (x + 1) % VOCAB
        toks.append(x)
        if x == EOS:
            break
    return toks


def _prompt(end_token: int, length: int = 5) -> np.ndarray:
    p = np.full((length,), 7, np.int32)
    p[-1] = end_token
    return p


@pytest.fixture()
def fake_engine(monkeypatch):
    def make(max_batch=2, max_new_tokens=6, sched_chunk=3):
        monkeypatch.setattr(engine_mod, "LM", _FakeLM)
        from repro.configs import get_config, smoke_config

        cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(dtype="float32")
        assert cfg.vocab_size == VOCAB
        return ServeEngine(
            cfg, POL, {},
            ServeConfig(
                max_batch=max_batch, max_prompt_len=8,
                max_new_tokens=max_new_tokens, sched_chunk=sched_chunk,
            ),
        )

    return make


# ------------------------------------------------------------------ #
# scheduler unit behavior
# ------------------------------------------------------------------ #
def test_scheduler_fifo_and_expiry():
    s = Scheduler()
    r1 = s.submit(np.arange(3))
    r2 = s.submit(np.arange(3), deadline_s=0.0)  # expired by pop time
    r3 = s.submit(np.arange(3), max_new_tokens=4)
    time.sleep(0.01)
    assert s.pop_ready().rid == r1
    nxt = s.pop_ready()  # r2 expires in passing
    assert nxt.rid == r3 and nxt.max_new_tokens == 4
    assert s.pop_ready() is None and not s.has_pending
    assert s.results[r2].status == "expired"


# ------------------------------------------------------------------ #
# FakeLM: exact end-to-end semantics
# ------------------------------------------------------------------ #
def test_post_eos_rows_emit_pad_lockstep(fake_engine):
    """Satellite fix: rows already done must emit PAD, not fresh argmax.
    Row 1 hits EOS after 2 tokens while row 2 never does; the lock-step
    batch keeps decoding to 6 steps and row 1's tail must be PAD."""
    eng = fake_engine(max_batch=3, max_new_tokens=6)
    ends = [253, 0, 10]  # EOS after 5 / 2 / never (within 6)
    for e in ends:
        eng.submit(_prompt(e))
    rows = eng.step_batch()
    assert len(rows) == 3
    for e, row in zip(ends, rows):
        want = _expected(e, 6)
        assert list(row[: len(want)]) == want
        assert all(t == PAD for t in row[len(want):]), (
            f"post-EOS tokens of row ending {e} must be PAD, got {list(row)}"
        )


def test_continuous_matches_lockstep_exactly(fake_engine):
    eng = fake_engine(max_batch=2, max_new_tokens=6, sched_chunk=3)
    ends = [253, 0, 10, 254, 5]
    for e in ends:
        eng.submit(_prompt(e))
    lock = []
    while eng.queue:
        lock.extend(eng.step_batch())
    cont = eng.serve_prompts([_prompt(e) for e in ends])
    for e, l, c in zip(ends, lock, cont):
        want = _expected(e, 6)
        assert list(c) == want, "continuous answer diverged from closed form"
        assert list(l[: len(want)]) == want and all(t == PAD for t in l[len(want):])


def test_slot_retire_admit_exact(fake_engine):
    """7 requests through 2 slots with mixed budgets/EOS distances: every
    retire must free its slot for the next queued request and every
    answer must match the closed form (no cross-slot contamination)."""
    eng = fake_engine(max_batch=2, max_new_tokens=8, sched_chunk=3)
    ends = [250, 0, 10, 253, 99, 1, 200]
    budgets = [8, 3, 2, 8, 5, 8, 1]
    outs = eng.serve_prompts([_prompt(e) for e in ends], max_new_tokens=budgets)
    for e, b, got in zip(ends, budgets, outs):
        assert list(got) == _expected(e, b), f"end={e} budget={b}: {list(got)}"


def test_request_deadline_expires_unserved(fake_engine):
    eng = fake_engine(max_batch=1, max_new_tokens=4)
    sched = Scheduler()
    r1 = sched.submit(_prompt(10), max_new_tokens=4)
    r2 = sched.submit(_prompt(20), deadline_s=0.0)  # expires before admit
    time.sleep(0.01)
    results = eng.serve(sched)
    assert list(results[r1]) == _expected(10, 4)
    assert r2 not in results
    assert sched.results[r2].status == "expired"
    stats = sched.latency_stats()
    assert stats["n_done"] == 1 and stats["n_expired"] == 1
    assert stats["p50_s"] <= stats["p95_s"]


def test_engine_generator_continuous_mode(fake_engine):
    eng = fake_engine(max_batch=2, max_new_tokens=6)
    gen = engine_generator(eng)
    assert gen.engine is eng and gen.mode == "continuous"
    single = gen(_prompt(0)[None, :])
    assert single.shape[0] == 1 and list(single[0]) == _expected(0, 6)
    batch = gen.generate_batch([_prompt(e) for e in (253, 10, 0)])
    for e, row in zip((253, 10, 0), batch):
        assert list(row) == _expected(e, 6)


# ------------------------------------------------------------------ #
# real LM: slot scatter parity with lock-step decode
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def small_lm():
    import jax

    from repro.configs import get_config, smoke_config
    from repro.models import lm as LM
    from repro.models.params import init_params

    cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(dtype="float32")
    params = init_params(LM.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_continuous_matches_lockstep_real_lm(small_lm):
    """Acceptance parity: the slot pool (cache scatter + per-slot decode
    positions) must produce the same tokens as lock-step step_batch for
    the same ragged inputs."""
    cfg, params = small_lm
    eng = ServeEngine(
        cfg, POL, params,
        ServeConfig(max_batch=2, max_prompt_len=16, max_new_tokens=5, sched_chunk=2),
    )
    rng = np.random.default_rng(7)
    prompts = [rng.integers(8, cfg.vocab_size, size=n).astype(np.int32) for n in (9, 16, 12, 5, 14)]
    for p in prompts:
        eng.submit(p)
    lock = []
    while eng.queue:
        lock.extend(eng.step_batch())
    cont = eng.serve_prompts(prompts)
    for l, c in zip(lock, cont):
        n = len(c)
        assert n >= 1
        assert (l[:n] == np.asarray(c)).all(), "continuous diverged from lock-step"
        assert all(t == PAD for t in l[n:])


def test_per_request_budgets_real_lm(small_lm):
    cfg, params = small_lm
    eng = ServeEngine(
        cfg, POL, params,
        ServeConfig(max_batch=2, max_prompt_len=16, max_new_tokens=6, sched_chunk=4),
    )
    rng = np.random.default_rng(3)
    prompts = [rng.integers(8, cfg.vocab_size, size=12).astype(np.int32) for _ in range(4)]
    budgets = [1, 3, 6, 2]
    outs = eng.serve_prompts(prompts, max_new_tokens=budgets)
    full = eng.serve_prompts(prompts)  # budget = cap
    for got, ref, b in zip(outs, full, budgets):
        assert len(got) <= b
        n = len(got)
        assert (np.asarray(got) == np.asarray(ref)[:n]).all(), (
            "budgeted prefix diverged from uncapped generation"
        )
