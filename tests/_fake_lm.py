"""Deterministic stand-in LM shared by the scheduler/streaming tests.

Next token is always ``(cur + 1) % VOCAB``, so the exact answer of every
request — including where EOS lands — is computable in closed form.
"""
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS
from repro.runtime.sharding import ShardingPolicy, base_rules

POL = ShardingPolicy(rules=base_rules(False), mesh=None)
VOCAB = 256


class FakeLM:
    """Deterministic LM: next token is (cur + 1) % vocab.  A prompt whose
    last token is e generates e+1, e+2, ... so EOS (=2) arrives exactly
    (2 - e - 1) % vocab + 1 tokens after prefill."""

    @staticmethod
    def _logits(tokens, offset=1):
        nxt = (tokens + offset) % VOCAB
        return jnp.eye(VOCAB, dtype=jnp.float32)[nxt]

    @staticmethod
    def _offset(params):
        # params rides the offset so a speculative DRAFTER can follow a
        # deliberately different rule than the target (offset=2 drafts
        # always diverge -> every draft rejected, outputs must not move)
        return params.get("offset", 1) if isinstance(params, dict) else 1

    @staticmethod
    def prefill(cfg, pol, params, batch, cache_len=None):
        tokens = batch["tokens"]
        logits = FakeLM._logits(tokens, FakeLM._offset(params))
        return logits, FakeLM.init_cache(cfg, tokens.shape[0], cache_len)

    @staticmethod
    def decode_step(cfg, pol, params, cache, tokens, pos, block_tables=None, block_size=0,
                    mesh=None):
        return FakeLM._logits(tokens, FakeLM._offset(params)), cache

    @staticmethod
    def init_cache(cfg, batch, cache_len, dtype=jnp.float32, abstract=False):
        # same (n_blocks, B, ...) leaf layout contract as the real cache
        return {"dummy": jnp.zeros((1, batch, 1), jnp.float32)}

    @staticmethod
    def init_paged_cache(cfg, n_pool_blocks, block_size, n_slots, dtype=jnp.float32,
                         n_shards=None):
        # stateless model: the paged cache carries no information either,
        # but keeps the per-slot leaf contract so slot scatters typecheck
        return {"dummy": jnp.zeros((1, n_slots, 1), jnp.float32)}

    @staticmethod
    def paged_copy_block(cfg, cache, src, dst):
        return cache  # no pooled K/V to copy

    @staticmethod
    def mixed_step(cfg, pol, params, tokens, cache, block_tables, q_start, q_len,
                   block_size, mesh=None):
        # stateless next-token rule: per-lane logits are all the unified
        # engine reads (it takes lane q_len - 1), so no pool K/V needed
        return FakeLM._logits(tokens, FakeLM._offset(params)), cache

    @staticmethod
    def verify_step(cfg, pol, params, tokens, cache, block_tables, q_start, q_len,
                    block_size, mesh=None):
        # the stateless rule is position-free, so per-lane verify logits
        # ARE the plain-decode logits — same contract as LM.verify_step
        return FakeLM.mixed_step(
            cfg, pol, params, tokens, cache, block_tables, q_start, q_len, block_size
        )


def expected_answer(end_token: int, budget: int) -> list[int]:
    """Closed-form answer of the FakeLM for a prompt ending in end_token."""
    toks, x = [], end_token
    while len(toks) < budget:
        x = (x + 1) % VOCAB
        toks.append(x)
        if x == EOS:
            break
    return toks


def prompt_ending(end_token: int, length: int = 5) -> np.ndarray:
    p = np.full((length,), 7, np.int32)
    p[-1] = end_token
    return p


def make_fake_engine(monkeypatch, max_batch=2, max_new_tokens=6, sched_chunk=3, **scfg_kw):
    """ServeEngine over the FakeLM (monkeypatched in place of the real
    model module) with the qwen3 smoke config's 256-token vocab.
    ``scfg_kw`` passes through to ServeConfig (paged/block_size/...)."""
    import repro.serving.engine as engine_mod
    from repro.configs import get_config, smoke_config
    from repro.serving.engine import ServeConfig, ServeEngine

    monkeypatch.setattr(engine_mod, "LM", FakeLM)
    cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(dtype="float32")
    assert cfg.vocab_size == VOCAB
    return ServeEngine(
        cfg, POL, {},
        ServeConfig(
            max_batch=max_batch, max_prompt_len=8,
            max_new_tokens=max_new_tokens, sched_chunk=sched_chunk, **scfg_kw,
        ),
    )
