"""Serving engine + dry-run cell smoke (small mesh).

Includes the paged-KV acceptance suite: the block-pool engine must be
bit-identical to the contiguous baseline for the same admission order,
degrade a request to early-retire (never corrupt a neighbor) on pool
OOM, and admit more concurrent slots than the contiguous stripe count
at equal HBM on short-prompt traffic."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _fake_lm import expected_answer, make_fake_engine, prompt_ending
from repro.configs import get_config, smoke_config
from repro.data.tokenizer import HashTokenizer
from repro.models import lm as LM
from repro.models.params import init_params
from repro.runtime.sharding import ShardingPolicy, base_rules
from repro.serving.engine import ServeConfig, ServeEngine
from repro.serving.scheduler import Scheduler

POL = ShardingPolicy(rules=base_rules(False), mesh=None)


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(dtype="float32")
    params = init_params(LM.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_engine_matches_direct_generate(small_lm):
    cfg, params = small_lm
    scfg = ServeConfig(max_batch=2, max_prompt_len=16, max_new_tokens=4)
    eng = ServeEngine(cfg, POL, params, scfg)
    rng = np.random.default_rng(0)
    p1 = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
    p2 = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
    eng.submit(p1)
    eng.submit(p2)
    outs = eng.step_batch()
    assert len(outs) == 2
    direct = LM.generate(cfg, POL, params, {"tokens": jnp.stack([jnp.asarray(p1), jnp.asarray(p2)])}, n_tokens=4)
    for got, want in zip(outs, np.asarray(direct)):
        assert (got[: len(want)] == want).all(), "batched serving diverged from generate()"


def test_engine_ragged_batch_matches_single(small_lm):
    """Per-row decode positions: a ragged batch must produce the same
    tokens as serving each prompt alone (same packing width), i.e. short
    rows decode from their own cache slot and never attend to PAD kv."""
    cfg, params = small_lm
    scfg = ServeConfig(max_batch=3, max_prompt_len=16, max_new_tokens=4)
    rng = np.random.default_rng(1)
    prompts = [
        rng.integers(8, cfg.vocab_size, size=n).astype(np.int32) for n in (10, 16, 13)
    ]
    eng = ServeEngine(cfg, POL, params, scfg)
    for p in prompts:
        eng.submit(p)
    batched = eng.step_batch()
    assert len(batched) == 3
    solo_eng = ServeEngine(
        cfg, POL, params, ServeConfig(max_batch=1, max_prompt_len=16, max_new_tokens=4)
    )
    for p, got in zip(prompts, batched):
        solo_eng.submit(p)
        want = solo_eng.step_batch()[0]
        n = min(len(got), len(want))
        assert (got[:n] == want[:n]).all(), "ragged row diverged from solo decode"


def test_engine_queue_drains(small_lm):
    cfg, params = small_lm
    eng = ServeEngine(cfg, POL, params, ServeConfig(max_batch=2, max_prompt_len=8, max_new_tokens=2))
    for _ in range(5):
        eng.submit(np.arange(1, 9, dtype=np.int32))
    served = 0
    while eng.queue:
        served += len(eng.step_batch())
    assert served == 5  # 2 + 2 + 1
    assert eng.step_batch() == []  # drained


# ------------------------------------------------------------------ #
# paged KV cache: bit-parity with the contiguous baseline (real LM)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("block_size", [4, 8, 16])
def test_paged_matches_contiguous_bitwise(small_lm, block_size):
    """Acceptance: for the same admission order, the paged engine must
    produce the contiguous engine's tokens BIT-IDENTICALLY on a ragged
    prompt/budget workload — same prefill, same bucketed admission
    groups, same masked-softmax lane count (cache_len here is a multiple
    of every tested block size), only the K/V storage layout differs."""
    cfg, params = small_lm
    base_kw = dict(max_batch=2, max_prompt_len=11, max_new_tokens=5, sched_chunk=2)
    rng = np.random.default_rng(42)
    prompts = [
        rng.integers(8, cfg.vocab_size, size=n).astype(np.int32)
        for n in (9, 11, 6, 3, 11, 7)
    ]
    budgets = [5, 1, 4, 5, 2, 5]
    base = ServeEngine(cfg, POL, params, ServeConfig(**base_kw))
    want = base.serve_prompts(prompts, max_new_tokens=budgets)
    paged = ServeEngine(
        cfg, POL, params, ServeConfig(paged=True, block_size=block_size, **base_kw)
    )
    got = paged.serve_prompts(prompts, max_new_tokens=budgets)
    for i, (w, g) in enumerate(zip(want, got)):
        assert np.array_equal(w, g), f"prompt {i}: paged {list(g)} != contiguous {list(w)}"


def test_paged_more_slots_than_stripes_same_hbm(small_lm):
    """The point of paging: with the HBM of 2 contiguous stripes, a paged
    engine with 4 slots serves short prompts 4-at-a-time — concurrency is
    bounded by resident tokens, not worst-case stripes — and the answers
    still match the contiguous engine bit-for-bit."""
    cfg, params = small_lm
    bs = 4
    kw = dict(max_prompt_len=12, max_new_tokens=4, sched_chunk=2)
    stripes = -(-(12 + 4) // bs)  # blocks per contiguous stripe
    base = ServeEngine(cfg, POL, params, ServeConfig(max_batch=2, **kw))
    paged = ServeEngine(
        cfg, POL, params,
        ServeConfig(max_batch=4, paged=True, block_size=bs, n_pool_blocks=2 * stripes, **kw),
    )
    assert paged.cache_nbytes() <= base.cache_nbytes() * (1 + 1 / (2 * stripes)) + 1
    rng = np.random.default_rng(5)
    prompts = [rng.integers(8, cfg.vocab_size, size=6).astype(np.int32) for _ in range(8)]
    sched = Scheduler()
    sched.submit_many(prompts, 3)
    res = paged.serve(sched)
    want = base.serve_prompts(prompts, max_new_tokens=3)
    for rid, w in enumerate(want):
        assert np.array_equal(res[rid], w)
    st = sched.latency_stats()
    # short prompts (6+3 tokens = 3 blocks) pack 4 concurrent requests
    # into 2 stripes' worth of blocks: strictly more than the stripe count
    assert paged.scfg.max_batch - st["min_free_slots"] > base.scfg.max_batch
    assert st["min_free_blocks"] >= 0


# ------------------------------------------------------------------ #
# paged KV cache: OOM + allocator lifecycle semantics (FakeLM, exact)
# ------------------------------------------------------------------ #
def test_paged_oom_retires_early_without_corruption(monkeypatch):
    """Two requests whose full budgets need 6 blocks contend for a
    4-block pool: both must retire early at the chunk boundary where the
    pool runs dry, each with an exact closed-form PREFIX — a failed
    allocation truncates its own request and can never corrupt the
    neighbor's tokens."""
    # cache_len = 8+6 = 14 -> 4 blocks of 4 per worst-case request
    eng = make_fake_engine(
        monkeypatch, max_batch=2, max_new_tokens=6, sched_chunk=3,
        paged=True, block_size=4, n_pool_blocks=4,
    )
    ends = (10, 20)
    sched = Scheduler()
    rids = sched.submit_many([prompt_ending(e, 5) for e in ends], [6, 6])
    res = eng.serve(sched)
    for e, rid in zip(ends, rids):
        got, full = res[rid], expected_answer(e, 6)
        assert 1 <= len(got) < len(full), "pool pressure must truncate, not kill"
        assert list(got) == full[: len(got)], f"end={e}: corrupted prefix {list(got)}"
        # OOM truncation is flagged, not silent: status stays terminal
        # "done" but the request carries the degradation marker
        assert sched.results[rid].status == "done" and sched.results[rid].truncated
    assert sched.latency_stats()["n_truncated"] == 2


def test_paged_blocks_recycle_across_requests(monkeypatch):
    """Retired requests return their blocks; a long FIFO stream through a
    small pool must serve every request exactly (blocks recycle) while
    strict FIFO admission holds the line when the pool is full."""
    eng = make_fake_engine(
        monkeypatch, max_batch=3, max_new_tokens=4, sched_chunk=2,
        paged=True, block_size=4, n_pool_blocks=4,  # one worst-case request
    )
    ends = [250, 0, 10, 253, 99, 1, 200, 30]
    budgets = [4, 3, 2, 4, 1, 4, 2, 3]
    sched = Scheduler()
    rids = sched.submit_many([prompt_ending(e) for e in ends], budgets)
    res = eng.serve(sched)
    for e, b, rid in zip(ends, budgets, rids):
        assert list(res[rid]) == expected_answer(e, b), f"end={e} budget={b}"
    # requests WAITED for blocks (FIFO gate) rather than truncating:
    # a normal completion never reads as truncated
    assert sched.latency_stats()["n_truncated"] == 0


def test_paged_admit_reserves_first_decode_block(monkeypatch):
    """Regression: the admission gate checks free blocks for prompt+1
    tokens, so admit must RESERVE that much.  Three block-aligned prompts
    into a pool with room for two must admit exactly two (the third
    waits, strict FIFO) — not admit all three under-reserved and then
    force-truncate at the first chunk boundary."""
    # cache_len = 8+2 = 10 -> blocks_per_slot ceil(10/4) = 3 <= pool 4
    eng = make_fake_engine(
        monkeypatch, max_batch=3, max_new_tokens=2, sched_chunk=2,
        paged=True, block_size=4, n_pool_blocks=4,
    )
    ends = (10, 20, 30)
    sched = Scheduler()
    rids = sched.submit_many([prompt_ending(e, 4) for e in ends], 2)
    res = eng.serve(sched)
    for e, rid in zip(ends, rids):
        assert list(res[rid]) == expected_answer(e, 2), f"end={e}: {list(res[rid])}"
    st = sched.latency_stats()
    assert st["n_truncated"] == 0, "under-reserved admits truncated instead of waiting"
    # pool holds 2 x blocks_for(4+1)=2: the third request waited its turn
    assert st["min_free_slots"] == 1


def test_paged_pool_must_fit_one_request(monkeypatch):
    with pytest.raises(ValueError, match="cannot hold one max-size request"):
        make_fake_engine(monkeypatch, paged=True, block_size=4, n_pool_blocks=2)


# ------------------------------------------------------------------ #
# refcounted prefix cache: bit-parity + sharing semantics
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("block_size", [4, 8, 16])
def test_prefix_shared_matches_unshared_bitwise(small_lm, block_size):
    """Acceptance: prefix-shared serving must be BIT-identical to the
    non-shared paged path for the same admission order on a ragged
    prompt/budget workload that mixes cold prompts, partial-prefix hits,
    same-pass identical siblings, and a full-prefix hit whose length is a
    multiple of every tested block size (the COW boundary-block case:
    the last prompt token's K/V write lands in a shared block and must
    go through a private copy, never mutate it)."""
    cfg, params = small_lm
    # width fits every prompt whole: a window tail-slice would shift the
    # preamble off block alignment and (correctly) turn hits into misses
    base_kw = dict(max_batch=2, max_prompt_len=20, max_new_tokens=5, sched_chunk=2)
    rng = np.random.default_rng(42)
    pre = rng.integers(8, cfg.vocab_size, size=16).astype(np.int32)  # 16 % {4,8,16} == 0
    tails = [rng.integers(8, cfg.vocab_size, size=n).astype(np.int32) for n in (1, 3, 2)]
    prompts = [
        np.concatenate([pre, tails[0]]),  # cold: inserts the preamble chunks
        np.concatenate([pre, tails[1]]),  # same-pass sibling: shares them
        pre.copy(),                        # full-prefix hit -> COW boundary block
        rng.integers(8, cfg.vocab_size, size=9).astype(np.int32),  # unrelated cold
        pre.copy(),                        # COW again, now against a parked chain
        np.concatenate([pre, tails[2]]),
    ]
    budgets = [5, 1, 4, 5, 2, 3]
    base = ServeEngine(
        cfg, POL, params, ServeConfig(paged=True, block_size=block_size, **base_kw)
    )
    want = base.serve_prompts(prompts, max_new_tokens=budgets)
    shared = ServeEngine(
        cfg, POL, params,
        ServeConfig(paged=True, prefix_cache=True, block_size=block_size, **base_kw),
    )
    got = shared.serve_prompts(prompts, max_new_tokens=budgets)
    for i, (w, g) in enumerate(zip(want, got)):
        assert np.array_equal(w, g), f"prompt {i}: shared {list(g)} != unshared {list(w)}"
    assert shared.prefix_lookups == len(prompts)
    assert shared.prefix_hits >= 3  # sibling + both full-prefix hits
    assert shared.prefill_tokens_saved > 0


def test_prefix_cache_gauges_and_savings(small_lm):
    """The hit-rate / tokens-saved gauges must surface through
    ``Scheduler.latency_stats`` and count real sharing: 4 prompts with a
    common 8-token preamble (block size 4) skip the preamble prefill on
    every hit."""
    cfg, params = small_lm
    eng = ServeEngine(
        cfg, POL, params,
        ServeConfig(max_batch=2, max_prompt_len=12, max_new_tokens=3,
                    sched_chunk=2, paged=True, prefix_cache=True, block_size=4),
    )
    rng = np.random.default_rng(3)
    pre = rng.integers(8, cfg.vocab_size, size=8).astype(np.int32)
    prompts = [
        np.concatenate([pre, rng.integers(8, cfg.vocab_size, size=n).astype(np.int32)])
        for n in (2, 3, 1, 4)
    ]
    sched = Scheduler()
    sched.submit_many(prompts, 3)
    eng.serve(sched)
    st = sched.latency_stats()
    assert st["prefix_lookups"] == 4 and st["prefix_hits"] == 3
    assert st["prefix_hit_rate"] == pytest.approx(0.75)
    assert st["prefill_tokens_saved"] == 3 * len(pre)
    assert st["prefill_tokens"] == sum(len(p) for p in prompts)
    assert st["prefill_saved_frac"] == pytest.approx(
        3 * len(pre) / sum(len(p) for p in prompts)
    )
    assert st["prefix_cached_blocks"] >= 2 and st["prefix_shared_blocks"] >= 6
    assert "reclaimable_blocks" in st


def test_prefix_cache_recycles_and_evicts_exactly(monkeypatch):
    """FIFO stream of repeated + distinct prompts through a pool too
    small to cache everything: every answer must stay exact (eviction
    only ever recycles zero-ref parked blocks; live chains are pinned by
    their refcounts) and nothing may truncate — pressure is absorbed by
    the LRU sweep, not by degrading requests."""
    eng = make_fake_engine(
        monkeypatch, max_batch=3, max_new_tokens=4, sched_chunk=2,
        paged=True, block_size=4, n_pool_blocks=6, prefix_cache=True,
    )
    ends = [250, 250, 10, 250, 99, 10, 250, 30, 99]
    budgets = [4, 3, 2, 4, 1, 4, 2, 3, 2]
    sched = Scheduler()
    rids = sched.submit_many([prompt_ending(e, 8) for e in ends], budgets)
    res = eng.serve(sched)
    for e, b, rid in zip(ends, budgets, rids):
        assert list(res[rid]) == expected_answer(e, b), f"end={e} budget={b}"
    st = sched.latency_stats()
    assert st["n_truncated"] == 0
    assert st["prefix_hits"] > 0  # repeats actually shared


def test_prefix_cache_config_validation(small_lm):
    cfg, params = small_lm
    with pytest.raises(ValueError, match="requires paged"):
        ServeEngine(cfg, POL, params, ServeConfig(prefix_cache=True, paged=False))
    with pytest.raises(ValueError, match="attn_chunk"):
        ServeEngine(
            cfg.with_overrides(attn_chunk=8), POL, params,
            ServeConfig(prefix_cache=True, paged=True, max_prompt_len=16),
        )
    ssm = smoke_config(get_config("mamba2-1.3b")).with_overrides(dtype="float32")
    with pytest.raises(ValueError, match="all-attention"):
        ServeEngine(ssm, POL, {}, ServeConfig(prefix_cache=True, paged=True))
    # pallas prefill would make cold (flash-kernel) and warm (XLA) rows
    # numerically diverge — hit-vs-miss parity must reject it
    with pytest.raises(ValueError, match="pallas"):
        ServeEngine(
            cfg.with_overrides(attn_impl="pallas"), POL, params,
            ServeConfig(prefix_cache=True, paged=True),
        )
    # a bf16 pool rounds the shared prefix K/V that a cold prefill would
    # attend to in f32 — same hit-vs-miss divergence, same rejection
    with pytest.raises(ValueError, match="float32"):
        ServeEngine(
            cfg.with_overrides(dtype="bfloat16"), POL, params,
            ServeConfig(prefix_cache=True, paged=True, max_prompt_len=16),
        )


# ------------------------------------------------------------------ #
# bucketed admission (applies to both cache layouts)
# ------------------------------------------------------------------ #
def test_bucketed_admission_dispatch_count(monkeypatch):
    """k requests waiting for k free slots must prefill in O(log k)
    power-of-2 fused dispatches, not k: 8 requests into 8 free slots is
    ONE dispatch of 8 rows; answers stay exact."""
    eng = make_fake_engine(monkeypatch, max_batch=8, max_new_tokens=4, sched_chunk=2)
    ends = [250, 0, 10, 253, 99, 1, 200, 30]
    outs = eng.serve_prompts([prompt_ending(e) for e in ends])
    assert eng.admit_rows_total == 8
    assert eng.admit_dispatches == 1, "8 simultaneous admits must fuse into one prefill"
    for e, got in zip(ends, outs):
        assert list(got) == expected_answer(e, 4)

    eng2 = make_fake_engine(monkeypatch, max_batch=4, max_new_tokens=4, sched_chunk=2)
    eng2.serve_prompts([prompt_ending(e) for e in (250, 0, 10)])
    # 3 waiting -> pow2 buckets 2 + 1
    assert eng2.admit_rows_total == 3 and eng2.admit_dispatches == 2
