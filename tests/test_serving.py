"""Serving engine + dry-run cell smoke (small mesh).

Includes the paged-KV acceptance suite: the block-pool engine must be
bit-identical to the contiguous baseline for the same admission order,
degrade a request to early-retire (never corrupt a neighbor) on pool
OOM, and admit more concurrent slots than the contiguous stripe count
at equal HBM on short-prompt traffic."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _fake_lm import expected_answer, make_fake_engine, prompt_ending
from repro.configs import get_config, smoke_config
from repro.data.tokenizer import HashTokenizer
from repro.models import lm as LM
from repro.models.params import init_params
from repro.runtime.sharding import ShardingPolicy, base_rules
from repro.serving.engine import ServeConfig, ServeEngine
from repro.serving.scheduler import Scheduler

POL = ShardingPolicy(rules=base_rules(False), mesh=None)


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(dtype="float32")
    params = init_params(LM.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_engine_matches_direct_generate(small_lm):
    cfg, params = small_lm
    scfg = ServeConfig(max_batch=2, max_prompt_len=16, max_new_tokens=4)
    eng = ServeEngine(cfg, POL, params, scfg)
    rng = np.random.default_rng(0)
    p1 = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
    p2 = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
    eng.submit(p1)
    eng.submit(p2)
    outs = eng.step_batch()
    assert len(outs) == 2
    direct = LM.generate(cfg, POL, params, {"tokens": jnp.stack([jnp.asarray(p1), jnp.asarray(p2)])}, n_tokens=4)
    for got, want in zip(outs, np.asarray(direct)):
        assert (got[: len(want)] == want).all(), "batched serving diverged from generate()"


def test_engine_ragged_batch_matches_single(small_lm):
    """Per-row decode positions: a ragged batch must produce the same
    tokens as serving each prompt alone (same packing width), i.e. short
    rows decode from their own cache slot and never attend to PAD kv."""
    cfg, params = small_lm
    scfg = ServeConfig(max_batch=3, max_prompt_len=16, max_new_tokens=4)
    rng = np.random.default_rng(1)
    prompts = [
        rng.integers(8, cfg.vocab_size, size=n).astype(np.int32) for n in (10, 16, 13)
    ]
    eng = ServeEngine(cfg, POL, params, scfg)
    for p in prompts:
        eng.submit(p)
    batched = eng.step_batch()
    assert len(batched) == 3
    solo_eng = ServeEngine(
        cfg, POL, params, ServeConfig(max_batch=1, max_prompt_len=16, max_new_tokens=4)
    )
    for p, got in zip(prompts, batched):
        solo_eng.submit(p)
        want = solo_eng.step_batch()[0]
        n = min(len(got), len(want))
        assert (got[:n] == want[:n]).all(), "ragged row diverged from solo decode"


def test_engine_queue_drains(small_lm):
    cfg, params = small_lm
    eng = ServeEngine(cfg, POL, params, ServeConfig(max_batch=2, max_prompt_len=8, max_new_tokens=2))
    for _ in range(5):
        eng.submit(np.arange(1, 9, dtype=np.int32))
    served = 0
    while eng.queue:
        served += len(eng.step_batch())
    assert served == 5  # 2 + 2 + 1
    assert eng.step_batch() == []  # drained


# ------------------------------------------------------------------ #
# paged KV cache: bit-parity with the contiguous baseline (real LM)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("block_size", [4, 8, 16])
def test_paged_matches_contiguous_bitwise(small_lm, block_size):
    """Acceptance: for the same admission order, the paged engine must
    produce the contiguous engine's tokens BIT-IDENTICALLY on a ragged
    prompt/budget workload — same admission order, same masked-softmax
    lane count (cache_len here is a multiple of every tested block
    size), only the K/V storage layout and dispatch shape differ."""
    cfg, params = small_lm
    base_kw = dict(max_batch=2, max_prompt_len=11, max_new_tokens=5, sched_chunk=2)
    rng = np.random.default_rng(42)
    prompts = [
        rng.integers(8, cfg.vocab_size, size=n).astype(np.int32)
        for n in (9, 11, 6, 3, 11, 7)
    ]
    budgets = [5, 1, 4, 5, 2, 5]
    base = ServeEngine(cfg, POL, params, ServeConfig(**base_kw))
    want = base.serve_prompts(prompts, max_new_tokens=budgets)
    paged = ServeEngine(
        cfg, POL, params, ServeConfig(paged=True, block_size=block_size, **base_kw)
    )
    got = paged.serve_prompts(prompts, max_new_tokens=budgets)
    for i, (w, g) in enumerate(zip(want, got)):
        assert np.array_equal(w, g), f"prompt {i}: paged {list(g)} != contiguous {list(w)}"


def test_paged_more_slots_than_stripes_same_hbm(small_lm):
    """The point of paging: with the HBM of 2 contiguous stripes, a paged
    engine with 4 slots serves short prompts 4-at-a-time — concurrency is
    bounded by resident tokens, not worst-case stripes — and the answers
    still match the contiguous engine bit-for-bit."""
    cfg, params = small_lm
    bs = 4
    kw = dict(max_prompt_len=12, max_new_tokens=4, sched_chunk=2)
    stripes = -(-(12 + 4) // bs)  # blocks per contiguous stripe
    base = ServeEngine(cfg, POL, params, ServeConfig(max_batch=2, **kw))
    paged = ServeEngine(
        cfg, POL, params,
        ServeConfig(max_batch=4, paged=True, block_size=bs, n_pool_blocks=2 * stripes, **kw),
    )
    assert paged.cache_nbytes() <= base.cache_nbytes() * (1 + 1 / (2 * stripes)) + 1
    rng = np.random.default_rng(5)
    prompts = [rng.integers(8, cfg.vocab_size, size=6).astype(np.int32) for _ in range(8)]
    sched = Scheduler()
    sched.submit_many(prompts, 3)
    res = paged.serve(sched)
    want = base.serve_prompts(prompts, max_new_tokens=3)
    for rid, w in enumerate(want):
        assert np.array_equal(res[rid], w)
    st = sched.latency_stats()
    # short prompts (6+3 tokens = 3 blocks) pack 4 concurrent requests
    # into 2 stripes' worth of blocks: strictly more than the stripe count
    assert paged.scfg.max_batch - st["min_free_slots"] > base.scfg.max_batch
    assert st["min_free_blocks"] >= 0


# ------------------------------------------------------------------ #
# paged KV cache: OOM + allocator lifecycle semantics (FakeLM, exact)
# ------------------------------------------------------------------ #
def test_paged_oom_retires_early_without_corruption(monkeypatch):
    """Two requests whose full budgets need 6 blocks contend for a
    4-block pool: whichever row hits the dry pool first retires early
    with an exact closed-form PREFIX and the ``truncated`` marker — a
    failed allocation truncates its own request and can never corrupt
    the neighbor's tokens.  The neighbor inherits the freed blocks and
    is allowed to finish its full budget (pool recycling, not fate
    sharing)."""
    # cache_len = 8+6 = 14 -> 4 blocks of 4 per worst-case request
    eng = make_fake_engine(
        monkeypatch, max_batch=2, max_new_tokens=6, sched_chunk=3,
        paged=True, block_size=4, n_pool_blocks=4,
    )
    ends = (10, 20)
    sched = Scheduler()
    rids = sched.submit_many([prompt_ending(e, 5) for e in ends], [6, 6])
    res = eng.serve(sched)
    for e, rid in zip(ends, rids):
        got, full = res[rid], expected_answer(e, 6)
        assert 1 <= len(got) <= len(full), "pool pressure must truncate, not kill"
        assert list(got) == full[: len(got)], f"end={e}: corrupted prefix {list(got)}"
        # OOM truncation is flagged, not silent: status stays terminal
        # "done" and short answers carry the degradation marker exactly
        assert sched.results[rid].status == "done"
        assert sched.results[rid].truncated == (len(got) < len(full))
    assert 1 <= sched.latency_stats()["n_truncated"] <= 2
    assert any(len(res[rid]) < 6 for rid in rids), "pool never ran dry?"


def test_paged_blocks_recycle_across_requests(monkeypatch):
    """Retired requests return their blocks; a long FIFO stream through a
    small pool must serve every request exactly (blocks recycle) while
    strict FIFO admission holds the line when the pool is full."""
    eng = make_fake_engine(
        monkeypatch, max_batch=3, max_new_tokens=4, sched_chunk=2,
        paged=True, block_size=4, n_pool_blocks=4,  # one worst-case request
    )
    ends = [250, 0, 10, 253, 99, 1, 200, 30]
    budgets = [4, 3, 2, 4, 1, 4, 2, 3]
    sched = Scheduler()
    rids = sched.submit_many([prompt_ending(e) for e in ends], budgets)
    res = eng.serve(sched)
    for e, b, rid in zip(ends, budgets, rids):
        assert list(res[rid]) == expected_answer(e, b), f"end={e} budget={b}"
    # requests WAITED for blocks (FIFO gate) rather than truncating:
    # a normal completion never reads as truncated
    assert sched.latency_stats()["n_truncated"] == 0


def test_paged_admit_reserves_first_decode_block(monkeypatch):
    """Regression: the admission gate checks free blocks for prompt+1
    tokens, so admit must RESERVE that much.  Three block-aligned prompts
    into a pool with room for two must admit exactly two (the third
    waits, strict FIFO) — not admit all three under-reserved and then
    force-truncate at the first chunk boundary."""
    # cache_len = 8+2 = 10 -> blocks_per_slot ceil(10/4) = 3 <= pool 4
    eng = make_fake_engine(
        monkeypatch, max_batch=3, max_new_tokens=2, sched_chunk=2,
        paged=True, block_size=4, n_pool_blocks=4,
    )
    ends = (10, 20, 30)
    sched = Scheduler()
    rids = sched.submit_many([prompt_ending(e, 4) for e in ends], 2)
    res = eng.serve(sched)
    for e, rid in zip(ends, rids):
        assert list(res[rid]) == expected_answer(e, 2), f"end={e}: {list(res[rid])}"
    st = sched.latency_stats()
    assert st["n_truncated"] == 0, "under-reserved admits truncated instead of waiting"
    # pool holds 2 x blocks_for(4+1)=2: the third request waited its turn
    assert st["min_free_slots"] == 1


def test_paged_pool_must_fit_one_request(monkeypatch):
    with pytest.raises(ValueError, match="cannot hold one max-size request"):
        make_fake_engine(monkeypatch, paged=True, block_size=4, n_pool_blocks=2)


# ------------------------------------------------------------------ #
# refcounted prefix cache: bit-parity + sharing semantics
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("block_size", [4, 8, 16])
def test_prefix_shared_matches_unshared_bitwise(small_lm, block_size):
    """Acceptance: prefix-shared serving must be BIT-identical to the
    non-shared paged path for the same admission order on a ragged
    prompt/budget workload that mixes cold prompts, partial-prefix hits,
    same-pass identical siblings, and a full-prefix hit whose length is a
    multiple of every tested block size (the COW boundary-block case:
    the last prompt token's K/V write lands in a shared block and must
    go through a private copy, never mutate it)."""
    cfg, params = small_lm
    # width fits every prompt whole: a window tail-slice would shift the
    # preamble off block alignment and (correctly) turn hits into misses
    base_kw = dict(max_batch=2, max_prompt_len=20, max_new_tokens=5, sched_chunk=2)
    rng = np.random.default_rng(42)
    pre = rng.integers(8, cfg.vocab_size, size=16).astype(np.int32)  # 16 % {4,8,16} == 0
    tails = [rng.integers(8, cfg.vocab_size, size=n).astype(np.int32) for n in (1, 3, 2)]
    prompts = [
        np.concatenate([pre, tails[0]]),  # cold: inserts the preamble chunks
        np.concatenate([pre, tails[1]]),  # same-pass sibling: shares them
        pre.copy(),                        # full-prefix hit -> COW boundary block
        rng.integers(8, cfg.vocab_size, size=9).astype(np.int32),  # unrelated cold
        pre.copy(),                        # COW again, now against a parked chain
        np.concatenate([pre, tails[2]]),
    ]
    budgets = [5, 1, 4, 5, 2, 3]
    base = ServeEngine(
        cfg, POL, params, ServeConfig(paged=True, block_size=block_size, **base_kw)
    )
    want = base.serve_prompts(prompts, max_new_tokens=budgets)
    shared = ServeEngine(
        cfg, POL, params,
        ServeConfig(paged=True, prefix_cache=True, block_size=block_size, **base_kw),
    )
    got = shared.serve_prompts(prompts, max_new_tokens=budgets)
    for i, (w, g) in enumerate(zip(want, got)):
        assert np.array_equal(w, g), f"prompt {i}: shared {list(g)} != unshared {list(w)}"
    assert shared.prefix_lookups == len(prompts)
    assert shared.prefix_hits >= 3  # sibling + both full-prefix hits
    assert shared.prefill_tokens_saved > 0


def test_prefix_cache_gauges_and_savings(small_lm):
    """The hit-rate / tokens-saved gauges must surface through
    ``Scheduler.latency_stats`` and count real sharing: 4 prompts with a
    common 8-token preamble (block size 4) skip the preamble prefill on
    every hit."""
    cfg, params = small_lm
    eng = ServeEngine(
        cfg, POL, params,
        ServeConfig(max_batch=2, max_prompt_len=12, max_new_tokens=3,
                    sched_chunk=2, paged=True, prefix_cache=True, block_size=4),
    )
    rng = np.random.default_rng(3)
    pre = rng.integers(8, cfg.vocab_size, size=8).astype(np.int32)
    prompts = [
        np.concatenate([pre, rng.integers(8, cfg.vocab_size, size=n).astype(np.int32)])
        for n in (2, 3, 1, 4)
    ]
    sched = Scheduler()
    sched.submit_many(prompts, 3)
    eng.serve(sched)
    st = sched.latency_stats()
    assert st["prefix_lookups"] == 4 and st["prefix_hits"] == 3
    assert st["prefix_hit_rate"] == pytest.approx(0.75)
    assert st["prefill_tokens_saved"] == 3 * len(pre)
    assert st["prefill_tokens"] == sum(len(p) for p in prompts)
    assert st["prefill_saved_frac"] == pytest.approx(
        3 * len(pre) / sum(len(p) for p in prompts)
    )
    assert st["prefix_cached_blocks"] >= 2 and st["prefix_shared_blocks"] >= 6
    assert "reclaimable_blocks" in st


def test_prefix_cache_recycles_and_evicts_exactly(monkeypatch):
    """FIFO stream of repeated + distinct prompts through a pool too
    small to cache everything: every answer must stay exact (eviction
    only ever recycles zero-ref parked blocks; live chains are pinned by
    their refcounts) and nothing may truncate — pressure is absorbed by
    the LRU sweep, not by degrading requests."""
    eng = make_fake_engine(
        monkeypatch, max_batch=3, max_new_tokens=4, sched_chunk=2,
        paged=True, block_size=4, n_pool_blocks=6, prefix_cache=True,
    )
    ends = [250, 250, 10, 250, 99, 10, 250, 30, 99]
    budgets = [4, 3, 2, 4, 1, 4, 2, 3, 2]
    sched = Scheduler()
    rids = sched.submit_many([prompt_ending(e, 8) for e in ends], budgets)
    res = eng.serve(sched)
    for e, b, rid in zip(ends, budgets, rids):
        assert list(res[rid]) == expected_answer(e, b), f"end={e} budget={b}"
    st = sched.latency_stats()
    assert st["n_truncated"] == 0
    assert st["prefix_hits"] > 0  # repeats actually shared


def test_prefix_cache_config_validation(small_lm):
    cfg, params = small_lm
    with pytest.raises(ValueError, match="requires paged"):
        ServeEngine(cfg, POL, params, ServeConfig(prefix_cache=True, paged=False))
    ssm = smoke_config(get_config("mamba2-1.3b")).with_overrides(dtype="float32")
    with pytest.raises(ValueError, match="all-attention"):
        ServeEngine(ssm, POL, {}, ServeConfig(prefix_cache=True, paged=True))
    with pytest.raises(ValueError, match="all-attention"):
        ServeEngine(ssm, POL, {}, ServeConfig(paged=True))
    # EVERY paged engine runs the unified chunked-prefill path now —
    # including the configs the retired dense+suffix pipeline could not
    # serve (pallas attention, prompts beyond attn_chunk, non-f32 caches)
    for c, kw in [
        (cfg, dict(max_prompt_len=16)),
        (cfg.with_overrides(attn_chunk=8), dict(max_prompt_len=16)),
        (cfg.with_overrides(attn_impl="pallas"), {}),
        (cfg.with_overrides(dtype="bfloat16"), dict(max_prompt_len=16)),
    ]:
        eng = ServeEngine(c, POL, params, ServeConfig(prefix_cache=True, paged=True, **kw))
        assert eng._unified, "paged engines must always run the unified path"
    # token_budget defaults on for paged engines (whole-prompt lanes)
    eng = ServeEngine(cfg, POL, params, ServeConfig(paged=True, max_prompt_len=16))
    assert eng._unified and eng._token_budget == 16
    # explicit token_budget has its own preconditions
    with pytest.raises(ValueError, match="requires.*paged"):
        ServeEngine(cfg, POL, params, ServeConfig(token_budget=8, paged=False))
    with pytest.raises(ValueError, match="token_budget"):
        ServeEngine(cfg, POL, params, ServeConfig(token_budget=0, paged=True))
    # host spill tier requires the prefix cache under it
    with pytest.raises(ValueError, match="spill_bytes"):
        ServeEngine(cfg, POL, params, ServeConfig(paged=True, spill_bytes=1 << 20))
    with pytest.raises(ValueError, match="spill_bytes"):
        ServeEngine(
            cfg, POL, params,
            ServeConfig(paged=True, prefix_cache=True, spill_bytes=0),
        )


# ------------------------------------------------------------------ #
# bucketed admission (applies to both cache layouts)
# ------------------------------------------------------------------ #
def test_bucketed_admission_dispatch_count(monkeypatch):
    """k requests waiting for k free slots must prefill in O(log k)
    power-of-2 fused dispatches, not k: 8 requests into 8 free slots is
    ONE dispatch of 8 rows; answers stay exact."""
    eng = make_fake_engine(monkeypatch, max_batch=8, max_new_tokens=4, sched_chunk=2)
    ends = [250, 0, 10, 253, 99, 1, 200, 30]
    outs = eng.serve_prompts([prompt_ending(e) for e in ends])
    assert eng.admit_rows_total == 8
    assert eng.admit_dispatches == 1, "8 simultaneous admits must fuse into one prefill"
    for e, got in zip(ends, outs):
        assert list(got) == expected_answer(e, 4)

    eng2 = make_fake_engine(monkeypatch, max_batch=4, max_new_tokens=4, sched_chunk=2)
    eng2.serve_prompts([prompt_ending(e) for e in (250, 0, 10)])
    # 3 waiting -> pow2 buckets 2 + 1
    assert eng2.admit_rows_total == 3 and eng2.admit_dispatches == 2


# ------------------------------------------------------------------ #
# unified chunked prefill: one mixed dispatch per engine step
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("block_size", [4, 8, 16])
def test_unified_matches_contiguous_oracle_bitwise(small_lm, block_size):
    """Acceptance: for the same admission order, the unified token-budget
    engine must produce the CONTIGUOUS oracle's tokens BIT-IDENTICALLY on
    a ragged prompt/budget workload — prompts chunk across steps (budget
    3 splits every prompt) and decode rides the same dispatches, yet
    every emitted token matches the dedicated-stripe baseline."""
    cfg, params = small_lm
    base_kw = dict(max_batch=2, max_prompt_len=11, max_new_tokens=5, sched_chunk=2)
    rng = np.random.default_rng(42)
    prompts = [
        rng.integers(8, cfg.vocab_size, size=n).astype(np.int32)
        for n in (9, 11, 6, 3, 11, 7)
    ]
    budgets = [5, 1, 4, 5, 2, 5]
    oracle = ServeEngine(cfg, POL, params, ServeConfig(**base_kw))
    want = oracle.serve_prompts(prompts, max_new_tokens=budgets)
    for tb in (3, 11):
        uni = ServeEngine(
            cfg, POL, params,
            ServeConfig(paged=True, block_size=block_size, token_budget=tb, **base_kw),
        )
        got = uni.serve_prompts(prompts, max_new_tokens=budgets)
        for i, (w, g) in enumerate(zip(want, got)):
            assert np.array_equal(w, g), (
                f"tb={tb} prompt {i}: unified {list(g)} != contiguous {list(w)}"
            )
        assert uni.admit_dispatches == 0 and uni.mixed_dispatches > 0


@pytest.mark.parametrize("block_size", [4, 8, 16])
def test_unified_prefix_shared_matches_contiguous_oracle_bitwise(small_lm, block_size):
    """Prefix sharing through the unified path (host-ordered pending
    chunks) must reproduce the CONTIGUOUS oracle bit-for-bit on a COW +
    sibling workload — cold prompts, a same-pass sibling that waits on
    pending chunks, full-prefix hits crossing the COW boundary block —
    and still actually share (hits, tokens saved)."""
    cfg, params = small_lm
    base_kw = dict(max_batch=2, max_prompt_len=20, max_new_tokens=5, sched_chunk=2)
    rng = np.random.default_rng(42)
    pre = rng.integers(8, cfg.vocab_size, size=16).astype(np.int32)
    tails = [rng.integers(8, cfg.vocab_size, size=n).astype(np.int32) for n in (1, 3, 2)]
    prompts = [
        np.concatenate([pre, tails[0]]),
        np.concatenate([pre, tails[1]]),  # same-pass sibling: waits on pending chunks
        pre.copy(),                        # full-prefix hit -> COW boundary block
        rng.integers(8, cfg.vocab_size, size=9).astype(np.int32),
        pre.copy(),
        np.concatenate([pre, tails[2]]),
    ]
    budgets = [5, 1, 4, 5, 2, 3]
    oracle = ServeEngine(cfg, POL, params, ServeConfig(**base_kw))
    want = oracle.serve_prompts(prompts, max_new_tokens=budgets)
    uni = ServeEngine(
        cfg, POL, params,
        ServeConfig(paged=True, prefix_cache=True, block_size=block_size,
                    token_budget=7, **base_kw),
    )
    got = uni.serve_prompts(prompts, max_new_tokens=budgets)
    for i, (w, g) in enumerate(zip(want, got)):
        assert np.array_equal(w, g), f"prompt {i}: shared {list(g)} != contiguous {list(w)}"
    assert uni.prefix_hits >= 3 and uni.prefill_tokens_saved > 0


def test_unified_lifts_dense_pipeline_restrictions(small_lm):
    """The configs the retired dense+suffix pipeline could not serve —
    pallas attention and prompts longer than attn_chunk — must serve
    through the unified path with hit-vs-miss bit-parity (cold and warm
    rows both attend through the pool, so sharing cannot change tokens)."""
    cfg, params = small_lm
    base_kw = dict(max_batch=2, max_prompt_len=20, max_new_tokens=4, sched_chunk=2,
                   paged=True, block_size=8)
    rng = np.random.default_rng(11)
    pre = rng.integers(8, cfg.vocab_size, size=16).astype(np.int32)
    prompts = [np.concatenate([pre, rng.integers(8, cfg.vocab_size, size=n).astype(np.int32)])
               for n in (2, 3, 1)]
    for c in (cfg.with_overrides(attn_impl="pallas"), cfg.with_overrides(attn_chunk=8)):
        hit_eng = ServeEngine(c, POL, params, ServeConfig(prefix_cache=True, **base_kw))
        assert hit_eng._unified
        hit = hit_eng.serve_prompts(prompts, max_new_tokens=4)
        miss = ServeEngine(
            c, POL, params,
            ServeConfig(token_budget=hit_eng._token_budget, **base_kw),
        ).serve_prompts(prompts, max_new_tokens=4)
        for i, (h, m) in enumerate(zip(hit, miss)):
            assert np.array_equal(h, m), f"prompt {i}: hit {list(h)} != miss {list(m)}"
        assert hit_eng.prefix_hits >= 2


def test_unified_dispatch_count_o1_per_step(monkeypatch):
    """Regression: the unified engine must issue exactly ONE device
    dispatch per engine step — no per-admit prefill calls (k admits in a
    pass cost 0 admit dispatches, vs O(log k) legacy) — and the mixed
    step must compile to a single jit trace (static shapes)."""
    eng = make_fake_engine(
        monkeypatch, max_batch=8, max_new_tokens=4, sched_chunk=2,
        paged=True, block_size=4, token_budget=4,
    )
    ends = [250, 0, 10, 253, 99, 1, 200, 30]
    sched = Scheduler()
    rids = sched.submit_many([prompt_ending(e) for e in ends], 4)
    res = eng.serve(sched)
    for e, rid in zip(ends, rids):
        assert list(res[rid]) == expected_answer(e, 4)
    assert eng.admit_dispatches == 0, "unified path must not dispatch admit prefills"
    assert eng.mixed_dispatches > 0
    st = sched.latency_stats()
    assert st["engine_steps"] == st["mixed_dispatches"] + st["decode_dispatches"]
    assert st["dispatches_per_step"] == 1.0
    cache_size = getattr(eng._mixed_rows, "_cache_size", None)
    if cache_size is not None:  # jax-version-dependent introspection
        assert cache_size() == 1, "mixed step must retrace O(1), not per shape"


# ------------------------------------------------------------------ #
# admission deadlock: typed error + graceful force-done
# ------------------------------------------------------------------ #
def test_resolve_fill_deps_orders_and_raises():
    from repro.serving.engine import AdmissionDeadlock, resolve_fill_deps

    # fills with satisfied deps run (slot order); blocked ones wait
    deps = {0: frozenset(), 1: frozenset({7}), 2: frozenset({5})}
    assert resolve_fill_deps(deps, {7}) == [0, 2]
    assert resolve_fill_deps({}, {7}) == []
    # every fill blocked -> typed error carrying the stuck slots
    with pytest.raises(AdmissionDeadlock) as ei:
        resolve_fill_deps({3: frozenset({20}), 4: frozenset({21})}, {20, 21})
    assert sorted(ei.value.stuck) == [3, 4]
    assert "stalled" in str(ei.value)


def test_admission_deadlock_force_dones_stuck_row(monkeypatch):
    """A stuck warm admission must retire with an EMPTY, deadlocked-
    flagged result (like OOM truncation: degrade, never wedge or
    corrupt), its pool blocks and cached-chunk registrations rolled back
    so later requests — including an identical resubmission — still
    serve exactly."""
    import repro.serving.engine as engine_mod
    from repro.serving.engine import AdmissionDeadlock

    eng = make_fake_engine(
        monkeypatch, max_batch=2, max_new_tokens=4, sched_chunk=2,
        paged=True, block_size=4, n_pool_blocks=8, prefix_cache=True,
    )
    real = engine_mod.resolve_fill_deps
    tripped = []

    def sabotage(fill_deps, pending):
        warm = [i for i, d in fill_deps.items() if d]
        if warm and not tripped:  # wedge only the first warm admission
            tripped.append(True)
            raise AdmissionDeadlock([], warm)
        return real(fill_deps, pending)

    monkeypatch.setattr(engine_mod, "resolve_fill_deps", sabotage)
    pre = np.full((4,), 7, np.int32)  # one full block -> shareable chunk
    prompts = [
        np.concatenate([pre, np.array([10], np.int32)]),  # cold
        np.concatenate([pre, np.array([20], np.int32)]),  # warm sibling: sabotaged
        np.concatenate([pre, np.array([20], np.int32)]),  # resubmission: must work
    ]
    sched = Scheduler()
    rids = sched.submit_many(prompts, 4)
    res = eng.serve(sched)
    assert list(res[rids[0]]) == expected_answer(10, 4)
    assert len(res[rids[1]]) == 0, "stuck admission must retire empty, not hang"
    assert sched.results[rids[1]].status == "done" and sched.results[rids[1]].deadlocked
    assert list(res[rids[2]]) == expected_answer(20, 4), "pool state corrupted by rollback"
    st = sched.latency_stats()
    assert st["n_deadlocked"] == 1 and st["n_truncated"] == 0


# ------------------------------------------------------------------ #
# resident engine: warm restart + tiered (spill) prefix cache
# ------------------------------------------------------------------ #
def test_warm_restart_reuses_resident_prefix_index(small_lm):
    """Acceptance: the prefix index + block pool survive across serve()
    calls on one engine — a second call over the same prompts is all
    hits (prefill tokens saved reported per window), stays bit-identical
    to a cold engine on the same admission order, and reset_cache()
    drops the residency for an explicit cold start."""
    cfg, params = small_lm
    mk = lambda: ServeConfig(max_batch=2, max_prompt_len=20, max_new_tokens=4,
                             sched_chunk=2, paged=True, prefix_cache=True,
                             block_size=4)
    rng = np.random.default_rng(7)
    pre = rng.integers(8, cfg.vocab_size, size=12).astype(np.int32)
    prompts = [
        np.concatenate([pre, rng.integers(8, cfg.vocab_size, size=n).astype(np.int32)])
        for n in (2, 3)
    ]
    eng = ServeEngine(cfg, POL, params, mk())
    s1 = Scheduler()
    rids1 = s1.submit_many(prompts, 4)
    r1 = eng.serve(s1)
    st1 = s1.latency_stats()
    s2 = Scheduler()
    rids2 = s2.submit_many(prompts, 4)
    r2 = eng.serve(s2)
    st2 = s2.latency_stats()
    # call 2 rides the resident index: every prompt hits, prefill saved
    assert st1["prefix_hits"] == 1  # only the same-pass sibling hit cold
    assert st2["prefix_hits"] == len(prompts) and st2["prefix_hit_rate"] == 1.0
    assert st2["prefill_tokens_saved"] >= len(prompts) * len(pre)
    # warm results == cold engine on the same admission order, bit-exact
    cold = ServeEngine(cfg, POL, params, mk()).serve_prompts(prompts, max_new_tokens=4)
    for rid1, rid2, w in zip(rids1, rids2, cold):
        assert np.array_equal(r1[rid1], w)
        assert np.array_equal(r2[rid2], w), "warm restart changed tokens"
    # scheduler window covers ONE call; engine lifetime covers both
    assert st2["prefix_lookups"] == len(prompts)
    assert st2["lifetime"]["prefix_lookups"] == 2 * len(prompts)
    assert eng.prefix_lookups == 2 * len(prompts)
    # explicit cold start: residency dropped, hits gone
    eng.reset_cache()
    s3 = Scheduler()
    s3.submit_many(prompts, 4)
    eng.serve(s3)
    assert s3.latency_stats()["prefix_hits"] == 1


def test_spilled_chain_readmits_bit_identical(small_lm):
    """Acceptance: a cached chain demoted to the host tier under pool
    pressure re-admits by upload (not re-prefill) and decodes
    BIT-IDENTICALLY to its never-evicted first serve."""
    cfg, params = small_lm
    scfg = ServeConfig(max_batch=1, max_prompt_len=8, max_new_tokens=4,
                       sched_chunk=2, paged=True, prefix_cache=True,
                       block_size=4, n_pool_blocks=3, spill_bytes=4 << 20)
    rng = np.random.default_rng(9)
    a = rng.integers(8, cfg.vocab_size, size=8).astype(np.int32)
    b = rng.integers(8, cfg.vocab_size, size=8).astype(np.int32)
    eng = ServeEngine(cfg, POL, params, scfg)
    cold_a = eng.serve_prompts([a], max_new_tokens=4)[0]  # cold reference
    eng.serve_prompts([b], max_new_tokens=4)  # pool pressure demotes a's chain
    assert eng._index.n_demotions >= 1 and eng._index.n_spilled >= 1
    assert 0 <= eng._spill_store.used_bytes <= scfg.spill_bytes
    sched = Scheduler()
    rids = sched.submit_many([a], 4)
    warm_a = eng.serve(sched)[rids[0]]
    assert eng._index.n_readmits >= 1, "spilled chain must come back by upload"
    assert np.array_equal(warm_a, cold_a), "re-admitted chain changed tokens"
    st = sched.latency_stats()
    assert st["spill_readmits"] >= 1 and st["prefix_hits"] == 1
    assert st["lifetime"]["spill_demotions"] == eng._index.n_demotions


# ------------------------------------------------------------------ #
# per-tenant SLO classes through the engine
# ------------------------------------------------------------------ #
def test_tenant_priority_and_fifo_admission_order(monkeypatch):
    """Priority preempts the QUEUE (interactive requests submitted after
    a batch flood still admit first) while running slots always finish
    on their own terms; ``fifo=True`` restores global arrival order.
    Answers stay exact for every tenant and per-tenant stats surface."""
    from _fake_lm import VOCAB

    def run(fifo):
        eng = make_fake_engine(monkeypatch, max_batch=1, max_new_tokens=4, sched_chunk=2)
        sched = Scheduler(tenant_weights={"interactive": 4.0, "batch": 1.0}, fifo=fifo)
        b_rids = sched.submit_many(
            [prompt_ending(e) for e in (10, 20, 30)], 4, tenants="batch"
        )
        i_rids = sched.submit_many(
            [prompt_ending(e) for e in (40, 50)], 4, tenants="interactive", priorities=1
        )
        res = eng.serve(sched)
        for e, rid in zip((10, 20, 30), b_rids):
            assert list(res[rid]) == expected_answer(e, 4)
        for e, rid in zip((40, 50), i_rids):
            assert list(res[rid]) == expected_answer(e, 4)
        return sched, b_rids, i_rids

    sched, b_rids, i_rids = run(fifo=False)
    starts = {rid: sched.results[rid].started_at for rid in b_rids + i_rids}
    # the interactive class preempted the queue: both its requests
    # admitted before any batch request despite submitting last
    assert max(starts[r] for r in i_rids) < min(starts[r] for r in b_rids)
    # FIFO within each tenant never reorders
    assert starts[b_rids[0]] < starts[b_rids[1]] < starts[b_rids[2]]
    st = sched.latency_stats()
    assert st["tenants"]["interactive"]["n_done"] == 2
    assert st["tenants"]["batch"]["n_done"] == 3
    assert st["tenants"]["batch"]["n_admitted"] == 3
    assert st["tenants"]["interactive"]["tokens_out"] == 8
    assert "p95_s" in st["tenants"]["batch"]

    sched, b_rids, i_rids = run(fifo=True)
    starts = {rid: sched.results[rid].started_at for rid in b_rids + i_rids}
    # arrival-order baseline: the batch flood admits first
    assert max(starts[r] for r in b_rids) < min(starts[r] for r in i_rids)


# ------------------------------------------------------------------ #
# speculative decoding: draft-k/verify-1 through the unified dispatch
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("block_size", [4, 8, 16])
def test_spec_decode_matches_plain_bitwise(small_lm, block_size):
    """Acceptance: draft-k/verify-1 speculation must be BIT-identical to
    plain greedy decode for the same admission order — self-speculation
    (drafter == target) on the ragged prompt/budget workload the paged
    parity suite uses, across block sizes, with verify rounds riding the
    same token-budget dispatch as chunked prefill lanes."""
    cfg, params = small_lm
    base_kw = dict(max_batch=2, max_prompt_len=11, max_new_tokens=5, sched_chunk=2)
    rng = np.random.default_rng(42)
    prompts = [
        rng.integers(8, cfg.vocab_size, size=n).astype(np.int32)
        for n in (9, 11, 6, 3, 11, 7)
    ]
    budgets = [5, 1, 4, 5, 2, 5]
    plain = ServeEngine(
        cfg, POL, params,
        ServeConfig(paged=True, block_size=block_size, token_budget=5, **base_kw),
    )
    want = plain.serve_prompts(prompts, max_new_tokens=budgets)
    spec = ServeEngine(
        cfg, POL, params,
        ServeConfig(paged=True, block_size=block_size, token_budget=5,
                    draft_k=3, **base_kw),
    )
    got = spec.serve_prompts(prompts, max_new_tokens=budgets)
    for i, (w, g) in enumerate(zip(want, got)):
        assert np.array_equal(w, g), f"prompt {i}: spec {list(g)} != plain {list(w)}"
    # self-speculation drafts the target's own tokens: accepts happen
    assert spec.spec_rounds > 0 and spec.spec_tokens_accepted > 0
    assert spec.decode_dispatches == 0, "spec rounds must ride the mixed dispatch"


def test_spec_decode_prefix_cache_matches_plain_bitwise(small_lm):
    """Speculation composes with the prefix cache: a COW + sibling
    workload (cold prompts, same-pass sibling, full-prefix hits) under
    draft-k must still match the plain contiguous oracle bit-for-bit
    while the cache actually shares."""
    cfg, params = small_lm
    base_kw = dict(max_batch=2, max_prompt_len=20, max_new_tokens=5, sched_chunk=2)
    rng = np.random.default_rng(42)
    pre = rng.integers(8, cfg.vocab_size, size=16).astype(np.int32)
    tails = [rng.integers(8, cfg.vocab_size, size=n).astype(np.int32) for n in (1, 3, 2)]
    prompts = [
        np.concatenate([pre, tails[0]]),
        np.concatenate([pre, tails[1]]),  # same-pass sibling
        pre.copy(),                        # full-prefix hit -> COW boundary
        rng.integers(8, cfg.vocab_size, size=9).astype(np.int32),
        pre.copy(),
        np.concatenate([pre, tails[2]]),
    ]
    budgets = [5, 1, 4, 5, 2, 3]
    oracle = ServeEngine(cfg, POL, params, ServeConfig(**base_kw))
    want = oracle.serve_prompts(prompts, max_new_tokens=budgets)
    spec = ServeEngine(
        cfg, POL, params,
        ServeConfig(paged=True, prefix_cache=True, block_size=8, token_budget=7,
                    draft_k=3, **base_kw),
    )
    got = spec.serve_prompts(prompts, max_new_tokens=budgets)
    for i, (w, g) in enumerate(zip(want, got)):
        assert np.array_equal(w, g), f"prompt {i}: spec {list(g)} != oracle {list(w)}"
    assert spec.prefix_hits >= 3 and spec.spec_tokens_accepted > 0


def test_spec_decode_divergent_drafter_never_changes_tokens(monkeypatch):
    """A drafter that ALWAYS disagrees (offset-2 rule vs the target's
    offset-1) must reject every draft — zero accepts — and the outputs
    still match plain decode exactly: correctness never depends on the
    drafter, only throughput does."""
    kw = dict(max_batch=3, max_new_tokens=6, sched_chunk=2,
              paged=True, block_size=4, token_budget=6)
    ends = [250, 0, 10, 253, 99, 30]
    budgets = [6, 3, 2, 6, 1, 4]
    eng = make_fake_engine(monkeypatch, draft_k=3, draft_params={"offset": 2}, **kw)
    sched = Scheduler()
    rids = sched.submit_many([prompt_ending(e) for e in ends], budgets)
    res = eng.serve(sched)
    for e, b, rid in zip(ends, budgets, rids):
        assert list(res[rid]) == expected_answer(e, b), f"end={e} budget={b}"
    assert eng.spec_tokens_proposed > 0 and eng.spec_tokens_accepted == 0
    st = sched.latency_stats()
    assert st["spec_accept_rate"] == 0.0
    # every lane still emits the target's lane-0 correction token
    assert st["spec_tokens_per_round"] >= 1.0


def test_spec_decode_dispatch_count_o2_per_round(monkeypatch):
    """CI guard: a speculative round costs at most TWO device dispatches
    — one drafter call (fill or k-token loop) + one target verify — with
    zero legacy decode dispatches, and self-speculation (perfect drafter)
    emits > 1 token per verify round."""
    eng = make_fake_engine(
        monkeypatch, max_batch=4, max_new_tokens=6, sched_chunk=2,
        paged=True, block_size=4, token_budget=8, draft_k=3,
    )
    ends = [250, 10, 99, 30, 200, 1]
    sched = Scheduler()
    rids = sched.submit_many([prompt_ending(e) for e in ends], 6)
    res = eng.serve(sched)
    for e, rid in zip(ends, rids):
        assert list(res[rid]) == expected_answer(e, 6)
    assert eng.decode_dispatches == 0 and eng.admit_dispatches == 0
    assert eng.spec_rounds > 0
    assert eng.draft_dispatches <= eng.spec_rounds, "O(2): <= 1 drafter call per round"
    st = sched.latency_stats()
    assert st["dispatches_per_spec_round"] <= 2.0
    assert st["spec_tokens_per_round"] > 1.0, "perfect drafter must beat 1 token/round"
    assert st["spec_accept_rate"] > 0.5
    assert st["engine_steps"] == st["mixed_dispatches"] + st["decode_dispatches"]
    # draft dispatches are overhead, not engine steps: the per-step gauge
    # still reads one TARGET dispatch per step
    assert st["dispatches_per_step"] == 1.0


def test_spec_decode_config_validation(small_lm, monkeypatch):
    cfg, params = small_lm
    with pytest.raises(ValueError, match="requires paged"):
        ServeEngine(cfg, POL, params, ServeConfig(draft_k=3))
    with pytest.raises(ValueError, match="must be >= 0"):
        ServeEngine(cfg, POL, params, ServeConfig(draft_k=-1, paged=True))
    with pytest.raises(ValueError, match="cannot fit one verify"):
        ServeEngine(
            cfg, POL, params,
            ServeConfig(draft_k=4, paged=True, token_budget=4, max_prompt_len=8),
        )
    with pytest.raises(ValueError, match="draft_config without draft_params"):
        ServeEngine(
            cfg, POL, params,
            ServeConfig(draft_k=3, paged=True, draft_config=cfg, max_prompt_len=8),
        )
    small = cfg.with_overrides(vocab_size=cfg.vocab_size // 2)
    with pytest.raises(ValueError, match="vocab_size"):
        ServeEngine(
            cfg, POL, params,
            ServeConfig(draft_k=3, paged=True, draft_config=small, draft_params={},
                        max_prompt_len=8),
        )
