"""Serving engine + dry-run cell smoke (small mesh)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_config
from repro.data.tokenizer import HashTokenizer
from repro.models import lm as LM
from repro.models.params import init_params
from repro.runtime.sharding import ShardingPolicy, base_rules
from repro.serving.engine import ServeConfig, ServeEngine

POL = ShardingPolicy(rules=base_rules(False), mesh=None)


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(dtype="float32")
    params = init_params(LM.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_engine_matches_direct_generate(small_lm):
    cfg, params = small_lm
    scfg = ServeConfig(max_batch=2, max_prompt_len=16, max_new_tokens=4)
    eng = ServeEngine(cfg, POL, params, scfg)
    rng = np.random.default_rng(0)
    p1 = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
    p2 = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
    eng.submit(p1)
    eng.submit(p2)
    outs = eng.step_batch()
    assert len(outs) == 2
    direct = LM.generate(cfg, POL, params, {"tokens": jnp.stack([jnp.asarray(p1), jnp.asarray(p2)])}, n_tokens=4)
    for got, want in zip(outs, np.asarray(direct)):
        assert (got[: len(want)] == want).all(), "batched serving diverged from generate()"


def test_engine_ragged_batch_matches_single(small_lm):
    """Per-row decode positions: a ragged batch must produce the same
    tokens as serving each prompt alone (same packing width), i.e. short
    rows decode from their own cache slot and never attend to PAD kv."""
    cfg, params = small_lm
    scfg = ServeConfig(max_batch=3, max_prompt_len=16, max_new_tokens=4)
    rng = np.random.default_rng(1)
    prompts = [
        rng.integers(8, cfg.vocab_size, size=n).astype(np.int32) for n in (10, 16, 13)
    ]
    eng = ServeEngine(cfg, POL, params, scfg)
    for p in prompts:
        eng.submit(p)
    batched = eng.step_batch()
    assert len(batched) == 3
    solo_eng = ServeEngine(
        cfg, POL, params, ServeConfig(max_batch=1, max_prompt_len=16, max_new_tokens=4)
    )
    for p, got in zip(prompts, batched):
        solo_eng.submit(p)
        want = solo_eng.step_batch()[0]
        n = min(len(got), len(want))
        assert (got[:n] == want[:n]).all(), "ragged row diverged from solo decode"


def test_engine_queue_drains(small_lm):
    cfg, params = small_lm
    eng = ServeEngine(cfg, POL, params, ServeConfig(max_batch=2, max_prompt_len=8, max_new_tokens=2))
    for _ in range(5):
        eng.submit(np.arange(1, 9, dtype=np.int32))
    served = 0
    while eng.queue:
        served += len(eng.step_batch())
    assert served == 5  # 2 + 2 + 1
    assert eng.step_batch() == []  # drained
