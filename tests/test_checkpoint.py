"""Checkpoint/restart fault tolerance: roundtrip, corruption detection,
bit-exact resume, async save, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_config, smoke_config
from repro.data.pipeline import LMBatchStream
from repro.optim.optimizers import get_optimizer
from repro.runtime.sharding import ShardingPolicy, base_rules
from repro.runtime.train_loop import SimulatedFailure, Trainer, TrainerConfig

POL = ShardingPolicy(rules=base_rules(False), mesh=None)


def _tree(key):
    return {
        "a": jax.random.normal(key, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path, key):
    m = CheckpointManager(str(tmp_path))
    t = _tree(key)
    m.save(7, t, extra={"stream": {"seed": 1, "step": 9}}, sync=True)
    restored, extra, step = m.restore(t)
    assert step == 7 and extra["stream"]["step"] == 9
    jax.tree.map(lambda a, b: assert_allclose(np.asarray(a), np.asarray(b)), t, restored)


def test_async_save_then_restore(tmp_path, key):
    m = CheckpointManager(str(tmp_path))
    t = _tree(key)
    m.save(1, t, sync=False)
    m.wait()
    restored, _, _ = m.restore(t)
    assert_allclose(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_corruption_detected(tmp_path, key):
    m = CheckpointManager(str(tmp_path))
    t = _tree(key)
    m.save(0, t, sync=True)
    d = os.path.join(str(tmp_path), "step_0")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad")
    with pytest.raises(IOError, match="corruption"):
        m.restore(t)


def test_keep_n_gc(tmp_path, key):
    m = CheckpointManager(str(tmp_path), keep_n=2)
    t = {"x": jnp.zeros(4)}
    for s in range(5):
        m.save(s, t, sync=True)
    assert m.all_steps() == [3, 4]


def _mk_trainer(tmp_path, steps, fail_at=None):
    cfg = smoke_config(get_config("qwen3-0.6b"))
    stream = LMBatchStream(2, 32, cfg.vocab_size, seed=5)
    tcfg = TrainerConfig(
        total_steps=steps, ckpt_every=4, ckpt_dir=str(tmp_path), fail_at_step=fail_at
    )
    return Trainer(cfg, POL, get_optimizer("adamw"), stream, tcfg, lr_fn=lambda s: 1e-3)


def test_failure_restart_resumes_exact_trajectory(tmp_path):
    """Train 12 steps straight vs crash-at-8 + resume: identical losses."""
    t_ref = _mk_trainer(tmp_path / "ref", 12)
    t_ref.run(resume="never")
    ref_losses = [m["loss"] for m in t_ref.metrics_log]

    t_crash = _mk_trainer(tmp_path / "crash", 12, fail_at=8)
    with pytest.raises(SimulatedFailure):
        t_crash.run(resume="never")
    t_resume = _mk_trainer(tmp_path / "crash", 12)
    t_resume.run(resume="auto")
    resumed = {m["step"]: m["loss"] for m in t_crash.metrics_log + t_resume.metrics_log}
    for i, ref in enumerate(ref_losses):
        assert resumed[i] == pytest.approx(ref, rel=1e-5), f"step {i} diverged after restart"


def test_elastic_restore_to_different_sharding(tmp_path, key):
    """Checkpoints are mesh-agnostic: restore with explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec, Mesh

    m = CheckpointManager(str(tmp_path))
    t = {"w": jax.random.normal(key, (8, 4))}
    m.save(0, t, sync=True)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    sh = {"w": NamedSharding(mesh, PartitionSpec("data", None))}
    restored, _, _ = m.restore(t, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    assert_allclose(np.asarray(restored["w"]), np.asarray(t["w"]))
