"""Per-arch smoke tests (reduced same-family configs) + substrate checks:
one forward/train step on CPU, asserting output shapes + no NaNs, plus
prefill/decode consistency and the SSD-vs-sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config, smoke_config
from repro.models import encoder as ENC
from repro.models import layers as L
from repro.models import lm as LM
from repro.models import mamba2 as M
from repro.models.params import init_params, param_count
from repro.runtime.sharding import ShardingPolicy, base_rules

POL = ShardingPolicy(rules=base_rules(False), mesh=None)
B, S = 2, 32


def _lm_batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    if cfg.frontend == "patches":
        batch["patch_embeds"] = jax.random.normal(key, (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_arch_smoke(arch, key):
    cfg = smoke_config(get_config(arch))
    if cfg.family == "encoder":
        params = init_params(ENC.param_specs(cfg), key)
        frames = jax.random.normal(key, (B, S, cfg.d_model))
        mask = jax.random.bernoulli(key, 0.3, (B, S))
        targets = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        loss, metrics = ENC.loss_fn(cfg, POL, params, {"frames": frames, "mask": mask, "targets": targets})
        emb = ENC.encode(cfg, POL, params, frames)
        assert emb.shape == (B, S, cfg.d_model)
    else:
        params = init_params(LM.param_specs(cfg), key)
        batch = _lm_batch(cfg, key)
        logits, aux = LM.forward(cfg, POL, params, batch)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any()), "NaN in logits"
        loss, metrics = LM.loss_fn(cfg, POL, params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "qwen2-moe-a2.7b", "mamba2-1.3b", "jamba-1.5-large-398b", "pixtral-12b"])
def test_arch_train_step_decreases_loss(arch, key):
    from repro.optim.optimizers import get_optimizer
    from repro.runtime.steps import make_train_step

    cfg = smoke_config(get_config(arch))
    params = init_params(LM.param_specs(cfg), key)
    opt = get_optimizer("adamw")
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, POL, opt, lambda s: 1e-2))
    batch = _lm_batch(cfg, key)
    losses = []
    for i in range(4):
        params, state, metrics = step(params, state, batch, jnp.asarray(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], f"loss not decreasing: {losses}"
    assert all(np.isfinite(l) for l in losses)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "smollm-360m", "mamba2-1.3b", "jamba-1.5-large-398b", "dbrx-132b"])
def test_prefill_decode_matches_forward(arch, key):
    cfg = smoke_config(get_config(arch)).with_overrides(dtype="float32")
    params = init_params(LM.param_specs(cfg), key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = LM.forward(cfg, POL, params, {"tokens": toks})
    p = S // 2
    logits_pf, cache = LM.prefill(cfg, POL, params, {"tokens": toks[:, :p]}, cache_len=S)
    assert_allclose(np.asarray(logits_pf), np.asarray(full[:, :p]), rtol=2e-3, atol=2e-3)
    lg = logits_pf[:, -1:]
    for t in range(p, min(p + 3, S)):
        lg, cache = LM.decode_step(cfg, POL, params, cache, toks[:, t : t + 1], t)
        # chunked-SSD prefill vs recurrent decode differ by summation order
        assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} decode step {t} diverged from teacher-forced forward",
        )


def test_ssd_chunked_equals_sequential(key):
    cfg = smoke_config(get_config("mamba2-1.3b")).with_overrides(dtype="float32", ssd_chunk=8)
    p = init_params(M.mamba_specs(cfg), key)
    x = jax.random.normal(key, (2, 24, cfg.d_model)) * 0.5
    y_chunk, _ = M.mamba_apply(cfg, POL, p, x)
    y_seq = M.mamba_reference(cfg, p, x)
    assert_allclose(np.asarray(y_chunk), np.asarray(y_seq), rtol=1e-4, atol=1e-4)


def test_generate_shapes(key):
    cfg = smoke_config(get_config("qwen3-0.6b"))
    params = init_params(LM.param_specs(cfg), key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    out = LM.generate(cfg, POL, params, {"tokens": toks}, n_tokens=5)
    assert out.shape == (2, 5)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab_size).all()


def test_param_counts_match_published():
    """Configs must land on the published parameter counts (±5%)."""
    expect = {
        "dbrx-132b": 132e9,
        "command-r-plus-104b": 104e9,
        "jamba-1.5-large-398b": 398e9,
        "qwen3-4b": 4.0e9,
        "llama3-8b": 8.0e9,
        "pixtral-12b": 12.4e9,
    }
    for arch, n in expect.items():
        cfg = get_config(arch)
        got = cfg.param_count(False) + cfg.embedding_params()
        assert abs(got - n) / n < 0.12, f"{arch}: {got/1e9:.1f}B vs {n/1e9:.1f}B"


def test_vlm_patch_merge_changes_output(key):
    cfg = smoke_config(get_config("pixtral-12b"))
    params = init_params(LM.param_specs(cfg), key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pe1 = jnp.zeros((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    pe2 = jnp.ones((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    l1, _ = LM.forward(cfg, POL, params, {"tokens": toks, "patch_embeds": pe1})
    l2, _ = LM.forward(cfg, POL, params, {"tokens": toks, "patch_embeds": pe2})
    assert float(jnp.abs(l1 - l2).max()) > 1e-3, "patch embeddings ignored"


def test_attn_decode_paged_pallas_matches_xla(key):
    """ROADMAP item: ``attn_impl="pallas"`` routes paged decode attention
    through the scalar-prefetch flash-decode kernel instead of the XLA
    gather view.  Both impls must scatter the new K/V identically
    (bitwise — same .at[].set) and agree on the attention output within
    flash-softmax reassociation tolerance, on ragged per-row positions
    with shuffled disjoint tables and a trash block in play."""
    cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(dtype="float32")
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b, bs, n_t = 3, 8, 4
    n_pool = b * n_t + 1  # last index = trash block
    ks = jax.random.split(key, 8)
    p = {
        "wq": jax.random.normal(ks[0], (d, h, hd)) * 0.05,
        "wk": jax.random.normal(ks[1], (d, kv, hd)) * 0.05,
        "wv": jax.random.normal(ks[2], (d, kv, hd)) * 0.05,
        "wo": jax.random.normal(ks[3], (h, hd, d)) * 0.05,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    x = jax.random.normal(ks[4], (b, 1, d))
    kp = jax.random.normal(ks[5], (n_pool, bs, kv, hd))
    vp = jax.random.normal(ks[6], (n_pool, bs, kv, hd))
    rng = np.random.default_rng(0)
    tables = jnp.asarray(
        rng.permutation(n_pool - 1)[: b * n_t].reshape(b, n_t), jnp.int32
    )
    pos = jnp.asarray(rng.integers(0, n_t * bs, size=b), jnp.int32)
    o_x, k_x, v_x = L.attn_decode_paged(cfg, POL, p, x, kp, vp, pos, tables, bs)
    cfg_p = cfg.with_overrides(attn_impl="pallas")
    o_p, k_p, v_p = L.attn_decode_paged(cfg_p, POL, p, x, kp, vp, pos, tables, bs)
    # the K/V scatter is shared code: pools must match bit-for-bit
    assert jnp.array_equal(k_x, k_p) and jnp.array_equal(v_x, v_p)
    assert_allclose(np.asarray(o_p), np.asarray(o_x), rtol=2e-5, atol=2e-5)
