"""Property tests for the greedy accept-prefix rule of speculative
decoding (draft-k/verify-1), plus the draft_k=0 identity guarantee.

The accepted run over random draft/target streams must equal the longest
common prefix of the two streams plus EXACTLY ONE target-sourced
correction token — that is what makes spec decode bit-identical to plain
greedy decode — and ``draft_k=0`` must be byte-identical to the
non-speculative engine (the spec branch never runs).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from _fake_lm import expected_answer, make_fake_engine, prompt_ending
from repro.data.tokenizer import EOS
from repro.serving.engine import accept_prefix
from repro.serving.scheduler import Scheduler

VOCAB = 5  # tiny alphabet: collisions and EOS (=2) occur naturally


def _streams(seed: int, k: int, rows: int = 4):
    """Random draft/target streams with a planted match prefix per row so
    every LCP length 0..k gets exercised."""
    rng = np.random.default_rng(seed)
    t = rng.integers(0, VOCAB, size=(rows, k + 1)).astype(np.int32)
    d = rng.integers(0, VOCAB, size=(rows, k)).astype(np.int32)
    for r in range(rows):
        m = int(rng.integers(0, k + 1))
        d[r, :m] = t[r, :m]
    return d, t


def _expected_n_emit(d, t, *, q_len, rem, done):
    """Closed-form oracle: lane j emits iff drafts 0..j-1 all matched,
    no earlier lane emitted EOS, and j clears the q_len/budget caps."""
    k = d.shape[0]
    n = 0
    if not done:
        for j in range(k + 1):
            if j >= q_len or j >= rem:
                break
            if any(d[i] != t[i] for i in range(j)):
                break
            if any(t[i] == EOS for i in range(j)):
                break
            n = j + 1
    return n


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1 << 31),
       k=st.sampled_from([1, 2, 3, 4]))
def test_accept_prefix_is_lcp_plus_one_correction(seed, k):
    """Uncapped rounds: the accepted run is the draft/target LCP plus
    exactly one target correction token (EOS in the target stream ends
    the run at the EOS lane)."""
    d, t = _streams(seed, k)
    rows = d.shape[0]
    q_len = np.full((rows,), k + 1, np.int32)
    rem = np.full((rows,), k + 1, np.int32)
    done = np.zeros((rows,), bool)
    n_emit, can = accept_prefix(d, t, q_len=q_len, rem=rem, done=done)
    n_emit, can = np.asarray(n_emit), np.asarray(can)
    for r in range(rows):
        n = int(n_emit[r])
        lcp = 0
        while lcp < k and d[r, lcp] == t[r, lcp] and t[r, lcp] != EOS:
            lcp += 1
        eos_cut = any(t[r, i] == EOS for i in range(lcp))
        if not eos_cut:
            # LCP drafts accepted + exactly one correction token, always
            assert n == lcp + 1, f"row {r}: n_emit {n} != lcp {lcp} + 1"
            assert (d[r, :lcp] == t[r, :lcp]).all()
        # emitted tokens are target-sourced: accepted drafts ARE the
        # matching target lanes, the last token is the correction
        assert n >= 1, "a live row always emits at least the correction"
        assert (can[r, :n]).all() and not can[r, n:].any(), "prefix mask"
        if t[r, : n - 1].size:
            assert EOS not in t[r, : n - 1], "nothing emits past EOS"


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1 << 31),
       k=st.sampled_from([1, 2, 3, 4]),
       q_len_raw=st.integers(min_value=0, max_value=5),
       rem_raw=st.integers(min_value=0, max_value=6),
       is_done=st.sampled_from([False, True]))
def test_accept_prefix_respects_caps(seed, k, q_len_raw, rem_raw, is_done):
    """Capped rounds: n_emit never exceeds the verify descriptor length,
    the remaining token budget, or a finished row (which emits zero)."""
    d, t = _streams(seed, k)
    rows = d.shape[0]
    q_len = np.full((rows,), min(q_len_raw, k + 1), np.int32)
    rem = np.full((rows,), rem_raw, np.int32)
    done = np.full((rows,), is_done, bool)
    n_emit, can = accept_prefix(d, t, q_len=q_len, rem=rem, done=done)
    n_emit, can = np.asarray(n_emit), np.asarray(can)
    for r in range(rows):
        want = _expected_n_emit(
            d[r], t[r], q_len=int(q_len[r]), rem=int(rem[r]), done=is_done
        )
        assert int(n_emit[r]) == want, f"row {r}"
        assert int(can[r].sum()) == want
        # committed lanes are contiguous from lane 0 (positional rollback
        # depends on this: everything past n_emit is stale, nothing gaps)
        assert (can[r, :want]).all() and not can[r, want:].any()


def test_draft_k_zero_is_byte_identical_to_plain_decode(monkeypatch):
    """draft_k=0 IS the plain engine: same bytes out, zero speculative
    state or dispatches — the spec branch never runs."""
    kw = dict(max_batch=3, max_new_tokens=6, sched_chunk=2,
              paged=True, block_size=4, token_budget=6)
    ends = [250, 0, 10, 253, 99, 30]
    budgets = [6, 3, 2, 6, 1, 4]

    def run(draft_k):
        eng = make_fake_engine(monkeypatch, draft_k=draft_k, **kw)
        sched = Scheduler()
        rids = sched.submit_many([prompt_ending(e) for e in ends], budgets)
        res = eng.serve(sched)
        return eng, sched, [np.asarray(res[r]) for r in rids]

    eng0, sched0, outs0 = run(draft_k=0)
    for e, b, got in zip(ends, budgets, outs0):
        assert list(got) == expected_answer(e, b)
    assert eng0.draft_dispatches == 0 and eng0.spec_rounds == 0
    assert eng0._draft_pool is None, "draft_k=0 must not allocate a drafter pool"
    st0 = sched0.latency_stats()
    assert "spec_accept_rate" not in st0, "no speculative gauges when spec is off"
    # and a speculating engine emits the same BYTES on the same workload
    _, _, outs3 = run(draft_k=3)
    for a, b in zip(outs0, outs3):
        assert a.tobytes() == b.tobytes()
