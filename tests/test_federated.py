"""Federated learning + secure aggregation properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core.confidential import Enclave
from repro.core.federated import (
    SecureAggregator,
    fedavg,
    federated_train_embedder,
    secure_fedavg,
)


def _tree(rng, scale=1.0):
    return {
        "w": rng.normal(0, scale, (8, 16)).astype(np.float32),
        "b": rng.normal(0, scale, (16,)).astype(np.float32),
    }


def test_secure_agg_equals_plain_mean_exactly(rng):
    """Masks cancel in exact modular arithmetic: bit-identical mean."""
    n = 4
    updates = [_tree(rng) for _ in range(n)]
    agg = SecureAggregator([Enclave(f"c{i}") for i in range(n)])
    sec = secure_fedavg(updates, agg, round_id=3)
    plain = jax.tree.map(lambda *xs: sum(x.astype(np.float64) for x in xs) / n, *updates)
    for k in ("w", "b"):
        assert_allclose(sec[k], plain[k].astype(np.float32), rtol=0, atol=2 ** -20)


def test_masked_update_leaks_nothing_obvious(rng):
    """A single masked update must not correlate with the raw update."""
    n = 3
    updates = [_tree(rng) for _ in range(n)]
    agg = SecureAggregator([Enclave(f"c{i}") for i in range(n)])
    masked = agg.mask_update(0, updates[0]["w"].ravel().astype(np.float64), 0)
    # masked values are ~uniform mod 2^62; correlation with input ~ 0
    corr = np.corrcoef(masked.astype(np.float64), updates[0]["w"].ravel())[0, 1]
    assert abs(corr) < 0.3


@given(seed=st.integers(0, 1000), n=st.integers(2, 5))
@settings(max_examples=10, deadline=None)
def test_secure_agg_property(seed, n):
    rng = np.random.default_rng(seed)
    updates = [{"x": rng.normal(0, 2, (5, 7)).astype(np.float32)} for _ in range(n)]
    agg = SecureAggregator([Enclave(f"c{i}") for i in range(n)])
    sec = secure_fedavg(updates, agg, round_id=seed)
    plain = sum(u["x"].astype(np.float64) for u in updates) / n
    assert_allclose(sec["x"], plain.astype(np.float32), atol=2 ** -18)


def test_fedavg_weighted():
    a = {"w": np.ones((2, 2), np.float32)}
    b = {"w": np.zeros((2, 2), np.float32)}
    out = fedavg([a, b], weights=[3, 1])
    assert_allclose(out["w"], 0.75 * np.ones((2, 2)))


def test_fedavg_one_local_step_equals_dp_gradient_mean(rng):
    """FedAvg(1 local SGD step) == data-parallel gradient mean — the identity
    that lets the pod axis implement the paper's federation (DESIGN §3)."""
    w0 = np.asarray(rng.normal(size=(4,)), np.float32)
    data = [np.asarray(rng.normal(size=(4,)), np.float32) for _ in range(3)]
    lr = 0.1

    def grad(w, x):  # grad of 0.5||w - x||^2
        return w - x

    # FedAvg: each client does one step, average models
    clients = [w0 - lr * grad(w0, x) for x in data]
    fed = np.mean(clients, axis=0)
    # DP: average gradients, one step
    dp = w0 - lr * np.mean([grad(w0, x) for x in data], axis=0)
    assert_allclose(fed, dp, rtol=1e-6)


def test_federated_embedder_training_improves(rng):
    """FedAvg rounds on a toy contrastive objective reduce loss; secure and
    plain aggregation produce the same trajectory."""
    dim = 8

    def grad_fn(params, batch):
        w = jnp.asarray(params["w"])
        q, d = jnp.asarray(batch["q"]), jnp.asarray(batch["d"])
        def loss(w):
            qe, de = q @ w, d @ w
            sim = qe @ de.T
            return -jnp.mean(jax.nn.log_softmax(sim, -1)[jnp.arange(q.shape[0]), jnp.arange(q.shape[0])])
        l, g = jax.value_and_grad(loss)(w)
        return float(l), {"w": np.asarray(g)}

    def apply_update(params, grads):
        return {"w": params["w"] - 0.5 * grads["w"]}

    def batch_fn_for(c):
        def fn(r):
            rng_ = np.random.default_rng((c, r))
            d = rng_.normal(size=(16, dim)).astype(np.float32)
            return {"q": d + 0.1 * rng_.normal(size=d.shape).astype(np.float32), "d": d}
        return fn

    init = {"w": np.eye(dim, dtype=np.float32) * 0.1}
    hist = {}
    for secure in (False, True):
        _, h = federated_train_embedder(
            {"w": init["w"].copy()},
            [batch_fn_for(c) for c in range(3)],
            grad_fn, apply_update, n_rounds=6, secure=secure,
        )
        hist[secure] = [r["mean_loss"] for r in h]
        assert hist[secure][-1] < hist[secure][0], "FL training must reduce loss"
    assert_allclose(hist[True], hist[False], rtol=1e-4), "secure agg changed the trajectory"
