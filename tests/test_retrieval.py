"""In-mesh federated retrieval: federated == centralized top-k (the
correctness invariant of the paper's Alg. 1 merge), quorum masking."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core.retrieval import federated_topk
from repro.kernels.retrieval_topk.ref import retrieval_topk_ref


def test_federated_equals_centralized_single_device(key):
    q = jax.random.normal(key, (4, 32))
    c = jax.random.normal(jax.random.fold_in(key, 1), (128, 32))
    s_f, i_f, _ = federated_topk(q, c, m_local=8, n_global=8, mesh=None)
    s_c, i_c = retrieval_topk_ref(q, c, 8)
    assert_allclose(np.asarray(s_f), np.asarray(s_c), rtol=1e-5)
    assert (np.asarray(i_f) == np.asarray(i_c)).all()


@given(seed=st.integers(0, 500), m=st.integers(4, 16))
@settings(max_examples=10, deadline=None)
def test_federated_merge_property(seed, m):
    """With m_local >= n_global, merging per-shard top-m must equal global
    top-n (scores), for any shard split."""
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(3, 16)).astype(np.float32)
    c = rng.normal(size=(64, 16)).astype(np.float32)
    n_global = min(m, 8)
    full = q @ c.T
    expect = np.sort(full, axis=1)[:, -n_global:][:, ::-1]
    # simulate the shard merge on host (mesh-free path + manual shards)
    shards = np.split(c, 4)
    cand_s = []
    for sh in shards:
        s = q @ sh.T
        cand_s.append(np.sort(s, 1)[:, -m:])
    merged = np.sort(np.concatenate(cand_s, 1), 1)[:, -n_global:][:, ::-1]
    assert_allclose(merged, expect, rtol=1e-5)


def _spawn_multidevice_check():
    """Runs the sharded federated_topk on 8 fake devices in a subprocess
    (this process is pinned to 1 device for the smoke tests)."""
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.retrieval import federated_topk
        from repro.kernels.retrieval_topk.ref import retrieval_topk_ref
        from repro.runtime.compat import make_mesh
        mesh = make_mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        k = jax.random.PRNGKey(0)
        q = jax.random.normal(k, (4, 32))
        c = jax.random.normal(jax.random.fold_in(k, 1), (128, 32))
        s_f, i_f, p_f = federated_topk(q, c, m_local=8, n_global=8, mesh=mesh)
        s_c, i_c = retrieval_topk_ref(q, c, 8)
        np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_c), rtol=1e-5)
        assert (np.asarray(i_f) == np.asarray(i_c)).all(), "indices differ"
        assert (np.asarray(p_f) == np.asarray(i_f) // 32).all(), "provider attribution"
        # quorum: kill provider 0 -> its chunks must vanish
        alive = jnp.array([False, True, True, True])
        s_q, i_q, p_q = federated_topk(q, c, m_local=8, n_global=8, mesh=mesh, alive=alive)
        assert (np.asarray(p_q) != 0).all(), "dead provider leaked chunks"
        print("MULTIDEVICE_OK")
        """
    )
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )


def test_federated_topk_sharded_8dev():
    r = _spawn_multidevice_check()
    assert "MULTIDEVICE_OK" in r.stdout, r.stderr[-2000:]
