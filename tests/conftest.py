import os

# smoke tests and benches must see 1 device (the dry-run sets 512 itself)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:  # property tests prefer the real library when available
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util as _ilu

    _spec = _ilu.spec_from_file_location(
        "_hypothesis_stub", os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    )
    _stub = _ilu.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    _stub.install()

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    import jax.random

    return jax.random.PRNGKey(0)
