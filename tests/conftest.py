import os

# smoke tests and benches must see 1 device (the dry-run sets 512 itself)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    import jax.random

    return jax.random.PRNGKey(0)
