"""MoE layer: masked-local EP vs dense reference, capacity semantics,
multi-device shard_map equivalence (subprocess: 8 fake devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.configs.base import ModelConfig
from repro.models.moe import moe_apply, moe_reference, moe_specs, _capacity
from repro.models.params import init_params
from repro.runtime.sharding import ShardingPolicy, base_rules

POL = ShardingPolicy(rules=base_rules(False), mesh=None)


def _cfg(e=8, k=2, shared=0, slack=4.0):
    return ModelConfig(
        name="t", family="moe", d_model=32, n_experts=e, moe_top_k=k,
        moe_d_ff=64, d_ff=64, n_shared_experts=shared, capacity_slack=slack,
    )


@pytest.mark.parametrize("e,k,shared", [(4, 1, 0), (8, 2, 0), (8, 2, 1), (16, 4, 0)])
def test_moe_matches_dense_reference(e, k, shared, key):
    cfg = _cfg(e, k, shared)
    p = init_params(moe_specs(cfg, tp_hint=1), key)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    out, aux = moe_apply(cfg, POL, p, x)
    ref, aux_r = moe_reference(cfg, p, x)
    if shared:
        from repro.models.layers import mlp_apply

        gate = jax.nn.sigmoid((x @ p["shared_gate"]).astype(jnp.float32))
        ref = ref + mlp_apply(cfg, POL, p["shared"], x) * gate.astype(x.dtype)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    assert_allclose(float(aux), float(aux_r), rtol=1e-5)


def test_capacity_drops_tokens_when_tight(key):
    """With slack<1 some (token, expert) pairs must drop — output changes but
    stays finite (capacity-based load shedding)."""
    cfg = _cfg(slack=0.25)
    p = init_params(moe_specs(cfg, tp_hint=1), key)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    out, _ = moe_apply(cfg, POL, p, x)
    ref, _ = moe_reference(cfg, p, x)
    assert np.isfinite(np.asarray(out)).all()
    assert float(jnp.abs(out - ref).max()) > 1e-6, "expected drops under tight capacity"


@given(t=st.integers(1, 64), k=st.integers(1, 4), tp=st.sampled_from([1, 2, 4, 16]))
@settings(max_examples=20, deadline=None)
def test_capacity_formula_properties(t, k, tp):
    cfg = ModelConfig(name="t", n_experts=16, moe_top_k=k, capacity_slack=1.5)
    cap = _capacity(cfg, t, tp)
    assert cap >= k  # a single token's k choices on one shard always fit
    assert cap % 8 == 0  # TPU-aligned
    assert cap >= int(np.ceil(t * k / tp))  # >= expected load


@pytest.mark.parametrize("impl", ["psum", "a2a"])
def test_moe_sharded_equals_single_device(impl):
    code = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs.base import ModelConfig
        from repro.models.moe import moe_apply, moe_reference, moe_specs
        from repro.models.params import init_params
        from repro.runtime.sharding import ShardingPolicy, base_rules

        cfg = ModelConfig(name="t", family="moe", d_model=32, n_experts=8,
                          moe_top_k=2, moe_d_ff=64, d_ff=64, capacity_slack=8.0,
                          moe_impl="{impl}")
        key = jax.random.PRNGKey(0)
        p = init_params(moe_specs(cfg, tp_hint=4), key)
        x = jax.random.normal(key, (4, 16, cfg.d_model))
        from repro.runtime.compat import make_mesh
        mesh = make_mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        pol = ShardingPolicy(rules=base_rules(False), mesh=mesh)
        out_sharded, aux_s = jax.jit(lambda p, x: moe_apply(cfg, pol, p, x))(p, x)
        ref, aux_r = moe_reference(cfg, p, x)
        np.testing.assert_allclose(np.asarray(out_sharded), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)
        print("MOE_SHARDED_OK")
        """
    )
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "MOE_SHARDED_OK" in r.stdout, r.stderr[-2000:]


def test_router_gates_renormalized(key):
    from repro.models.moe import _route

    cfg = _cfg(e=8, k=2)
    p = init_params(moe_specs(cfg, tp_hint=1), key)
    x = jax.random.normal(key, (32, cfg.d_model))
    gates, ids, probs = _route(cfg, p["router"], x)
    assert_allclose(np.asarray(gates.sum(-1)), np.ones(32), rtol=1e-5)
    assert (np.asarray(ids) < cfg.n_experts).all(), "padded experts must never be routed"
