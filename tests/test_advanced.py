"""Paper §2.2/§4.4 advanced variations: provider selection, query rewriting,
multi-LLM answer fusion."""
import numpy as np
import pytest

from repro.core.advanced import (
    AnswerFusion,
    GeneratorEndpoint,
    ProviderSelector,
    QueryRewriter,
    build_expansion_maps,
)
from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus
from repro.data.tokenizer import HashTokenizer


@pytest.fixture(scope="module")
def system():
    corpus = make_federated_corpus(n_facts=96, n_distractors=96, n_queries=24, seed=5)
    return CFedRAGSystem(
        corpus, CFedRAGConfig(aggregation="embedding_rank", split_by="corpus")
    )


def test_selector_routes_to_gold_provider(system):
    sel = ProviderSelector(system.providers, system.embed_fn)
    hits = 0
    queries = system.corpus.queries[:16]
    for q in queries:
        gold_site = system.corpus.chunks[q.gold_chunk_id]
        chosen = sel.select(system.tok.encode(q.text, max_len=24), system.providers, top_p=2)
        names = set()
        for p in chosen:
            names.update(c.corpus for c in p.chunks[:1])
        hits += any(gold_site.corpus == c.corpus for p in chosen for c in p.chunks[:1])
    # corpus centroids should route most queries toward the right silo
    assert hits >= len(queries) * 0.4, f"selector routed only {hits}/{len(queries)}"


def test_selector_reduces_dispatch_fanout(system):
    sel = ProviderSelector(system.providers, system.embed_fn)
    q = system.corpus.queries[0]
    chosen = sel.select(system.tok.encode(q.text, max_len=24), system.providers, top_p=2)
    assert len(chosen) == 2 < len(system.providers)


def test_query_rewriter_expands_with_provider_vocab(system):
    maps = build_expansion_maps(system.providers, system.tok)
    rw = QueryRewriter(maps)
    q = system.tok.encode(system.corpus.queries[0].text, max_len=12)
    pid = system.providers[0].provider_id
    out = rw.rewrite(q, pid)
    assert len(out) >= len(q)
    assert (out[: len(q)] == q).all(), "original query preserved"


def test_answer_fusion_votes_and_routes():
    def mk_gen(tok):
        return lambda prompt: np.asarray([[tok, 2]])

    eps = [
        GeneratorEndpoint("pubmed-expert", mk_gen(101), domains=(0,)),
        GeneratorEndpoint("generalist", mk_gen(202), domains=()),
        GeneratorEndpoint("texbook-expert", mk_gen(303), domains=(3,)),
    ]
    fusion = AnswerFusion(eps, top_m=2)
    ctx = {"providers": np.asarray([0, 0, 0, 3])}
    chosen = fusion.route(ctx)
    assert chosen[0].name == "pubmed-expert"  # most context affinity
    out = fusion.answer(np.zeros((1, 4), np.int32), ctx)
    assert out["answer_token"] == 101  # top-ranked expert wins the vote
    assert set(out["models"]) <= {"pubmed-expert", "generalist", "texbook-expert"}


def test_quorum_sweep_graceful():
    from benchmarks.quorum_sweep import run

    rows = run(n_queries=16)
    recalls = [r["recall_at_8"] for r in rows]
    assert recalls[0] >= recalls[-1]
    assert all(r >= 0 for r in recalls)  # every config answered (no crash)
