"""Roofline extraction: HLO parsers + term math on synthetic inputs, and
the dist_decode serving path vs the monolithic oracle."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch.roofline import (
    Roofline,
    parse_collective_bytes,
    parse_convert_bytes,
    parse_dus_bytes,
)

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ag = bf16[2048,256]{1,0} all-gather(%p0), replica_groups={}
  %cv = f32[2048,256]{1,0} convert(%ag)
  %ar = f32[2048,256]{1,0} all-reduce(%cv), to_apply=%add
  %rs = f32[128,256]{1,0} reduce-scatter(%ar), to_apply=%add
  %a2a = f32[128,256]{1,0} all-to-all(%rs)
  %dus = f32[2048,256]{1,0} dynamic-update-slice(%ar, %rs, %c0, %c0)
  ROOT %cp = f32[128,256]{1,0} collective-permute(%a2a)
}
"""


def test_parse_collective_bytes_per_kind():
    out = parse_collective_bytes(HLO)
    assert out["all-gather"] == 128 * 256 * 2  # operand bytes (bf16 p0)
    assert out["all-reduce"] == 2048 * 256 * 4
    assert out["reduce-scatter"] == 2048 * 256 * 4
    assert out["all-to-all"] == 128 * 256 * 4
    assert out["collective-permute"] == 128 * 256 * 4
    assert out["collective_count"] == 5


def test_parse_convert_bytes():
    # bf16 -> f32 convert of 2048x256: 4B out + 2B in per elem
    assert parse_convert_bytes(HLO) == 2048 * 256 * (4 + 2)


def test_parse_dus_bytes():
    assert parse_dus_bytes(HLO) == 2048 * 256 * 4


def test_roofline_terms_math():
    r = Roofline(
        arch="x", shape="train_4k", mesh="single", n_chips=256,
        hlo_flops=256 * 197e12,  # exactly 1s of compute
        hlo_bytes=256 * 819e9 * 0.5,  # 0.5s memory
        collective_bytes=256 * 49.5e9 * 2.0,  # 2s collective
        collective_detail={}, model_flops=256 * 197e12 * 0.8,
        memory_per_device=1,
    )
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(2.0)
    assert r.dominant == "collective"
    assert r.step_bound_s == pytest.approx(2.0)
    assert r.mfu_bound == pytest.approx(0.8 / 2.0)
    assert r.useful_flops_frac == pytest.approx(0.8)


def test_dist_decode_matches_oracle_8dev():
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.serving.dist_decode import dist_decode_attention
        from repro.kernels.decode_attention.ref import decode_attention_ref

        from repro.runtime.compat import make_mesh
        mesh = make_mesh(np.array(jax.devices()).reshape(8,), ("data",))
        k = jax.random.PRNGKey(0)
        b, s, h, kv, dh = 2, 128, 8, 4, 32
        q = jax.random.normal(k, (b, h, dh))
        kc = jax.random.normal(jax.random.fold_in(k, 1), (b, s, kv, dh))
        vc = jax.random.normal(jax.random.fold_in(k, 2), (b, s, kv, dh))
        lens = jnp.array([100, 77])
        out = jax.jit(lambda *a: dist_decode_attention(*a, mesh=mesh))(q, kc, vc, lens)
        ref = decode_attention_ref(q, kc, vc, lens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref, np.float32),
                                   rtol=2e-5, atol=2e-5)
        print("DIST_DECODE_OK")
        """
    )
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "DIST_DECODE_OK" in r.stdout, r.stderr[-2000:]
