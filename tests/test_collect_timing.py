"""Deadline/quorum timing semantics of the concurrent provider fan-out.

Algorithm 1 tolerates k_n <= k providers; the concurrent ``_collect``
must make that real under wall-clock pressure: a provider slower than
``deadline_s`` is cut off (not awaited), quorum is satisfied by whoever
arrived by the deadline, quorum failure raises promptly, and — when every
provider answers in time — results are bit-identical to the sequential
dispatch loop.
"""
import time

import numpy as np
import pytest

from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus
from repro.data.tokenizer import HashTokenizer
from repro.launch.serve import overlap_reranker

SLOW = 5.0  # straggler delay; every test must finish far below this


@pytest.fixture(scope="module")
def corpus():
    return make_federated_corpus(n_facts=48, n_distractors=48, n_queries=8, seed=5)


def _system(corpus, *, concurrent=True, deadline=None, quorum=1, delays=None, warm=0):
    """Build a 4-provider system; ``warm`` collects that many queries per
    shape BEFORE delays are applied, so jit compilation of the embed path
    never eats into a wall-clock deadline assertion."""
    tok = HashTokenizer()
    sys_ = CFedRAGSystem(
        corpus,
        CFedRAGConfig(
            aggregation="rerank",
            split_by="corpus",  # 4 providers
            quorum=quorum,
            deadline_s=deadline,
            concurrent_collect=concurrent,
        ),
        tokenizer=tok,
        reranker=overlap_reranker(tok),
    )
    if warm:
        saved = sys_.orchestrator.deadline_s
        sys_.orchestrator.deadline_s = None
        sys_.orchestrator.collect_contexts_batch([q.text for q in corpus.queries[:warm]])
        sys_.orchestrator.collect_contexts(corpus.queries[0].text)
        sys_.orchestrator.deadline_s = saved
    for p, d in zip(sys_.providers, delays or ()):
        p.delay_s = d
    return sys_


def _assert_context_equal(a: dict, b: dict):
    for k in ("chunk_tokens", "chunk_ids", "scores", "providers"):
        assert np.array_equal(a[k], b[k]), f"context[{k}] diverged"


def test_concurrent_matches_sequential_bitwise(corpus):
    """When every provider responds in time, concurrent fan-out must be
    bit-identical to the sequential loop (responses re-ordered by
    provider id before aggregation)."""
    con = _system(corpus, concurrent=True)
    seq = _system(corpus, concurrent=False)
    assert con.orchestrator.concurrent_collect and not seq.orchestrator.concurrent_collect
    texts = [q.text for q in corpus.queries[:4]]
    for a, b in zip(con.orchestrator.answer_batch(texts), seq.orchestrator.answer_batch(texts)):
        _assert_context_equal(a["context"], b["context"])
        assert a["n_providers"] == b["n_providers"]
    for t in texts:
        _assert_context_equal(
            con.orchestrator.answer(t)["context"], seq.orchestrator.answer(t)["context"]
        )


def test_collect_wallclock_is_max_not_sum(corpus):
    """Acceptance: 4 providers, one with delay 0.2s — batched collect
    wall-clock must track the slowest provider (max), not the sum."""
    delays = (0.1, 0.2, 0.1, 0.1)
    sys_ = _system(corpus, delays=delays, warm=4)
    texts = [q.text for q in corpus.queries[:4]]
    sys_.orchestrator.collect_contexts_batch(texts)  # warm jit caches
    t0 = time.monotonic()
    responses = sys_.orchestrator.collect_contexts_batch(texts)
    dt = time.monotonic() - t0
    assert len(responses) == 4  # no deadline: everyone included
    assert dt < 2 * max(delays), f"collect took {dt:.3f}s (sum={sum(delays)}s)"


def test_straggler_cut_off_at_deadline(corpus):
    """A provider slower than deadline_s must be abandoned mid-flight,
    not awaited: collect returns around the deadline with the fast
    providers' responses."""
    sys_ = _system(corpus, deadline=0.5, delays=(0.0, SLOW, 0.0, 0.0), warm=2)
    t0 = time.monotonic()
    responses = sys_.orchestrator.collect_contexts_batch(
        [q.text for q in corpus.queries[:2]]
    )
    dt = time.monotonic() - t0
    assert dt < 2.0, f"deadline did not cut the straggler off ({dt:.3f}s)"
    assert sorted(int(r["provider"]) for r in responses) == [0, 2, 3]


def test_quorum_early_return_does_not_wait_for_stragglers(corpus):
    """With quorum met at the deadline, collect must return immediately —
    the slow provider's response is simply dropped (k_n < k)."""
    sys_ = _system(corpus, quorum=3, deadline=0.5, delays=(0.0, SLOW, 0.0, 0.0), warm=1)
    t0 = time.monotonic()
    res = sys_.orchestrator.answer(corpus.queries[0].text)
    dt = time.monotonic() - t0
    assert dt < 2.0, f"quorum return waited for the straggler ({dt:.3f}s)"
    assert res["n_providers"] == 3


def test_quorum_failure_raises_promptly(corpus):
    """Too few providers inside the deadline -> RuntimeError at the
    deadline, without waiting the stragglers out."""
    sys_ = _system(corpus, quorum=3, deadline=0.3, delays=(SLOW, SLOW, SLOW, 0.0), warm=1)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="quorum"):
        sys_.orchestrator.collect_contexts_batch([corpus.queries[0].text])
    assert time.monotonic() - t0 < 2.0


def test_failed_provider_tolerated_concurrently(corpus):
    """ConnectionError from one provider is straggler-tolerated by the
    concurrent path exactly as by the sequential one."""
    con = _system(corpus, concurrent=True)
    seq = _system(corpus, concurrent=False)
    con.providers[1].fail = True
    seq.providers[1].fail = True
    t = corpus.queries[0].text
    a, b = con.orchestrator.answer(t), seq.orchestrator.answer(t)
    assert a["n_providers"] == b["n_providers"] == 3
    _assert_context_equal(a["context"], b["context"])
