"""Deadline/quorum timing semantics of the concurrent provider fan-out.

Algorithm 1 tolerates k_n <= k providers; the concurrent ``_collect``
must make that real under wall-clock pressure: a provider slower than
``deadline_s`` is cut off (not awaited), quorum is satisfied by whoever
arrived by the deadline, quorum failure raises promptly, and — when every
provider answers in time — results are bit-identical to the sequential
dispatch loop.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus
from repro.data.tokenizer import ANS, BOS, CTX, EOS, PAD, QRY, SEP, HashTokenizer
from repro.launch.serve import overlap_reranker

SLOW = 5.0  # straggler delay; every test must finish far below this


@pytest.fixture(scope="module")
def corpus():
    return make_federated_corpus(n_facts=48, n_distractors=48, n_queries=8, seed=5)


def _system(corpus, *, concurrent=True, deadline=None, quorum=1, delays=None, warm=0):
    """Build a 4-provider system; ``warm`` collects that many queries per
    shape BEFORE delays are applied, so jit compilation of the embed path
    never eats into a wall-clock deadline assertion."""
    tok = HashTokenizer()
    sys_ = CFedRAGSystem(
        corpus,
        CFedRAGConfig(
            aggregation="rerank",
            split_by="corpus",  # 4 providers
            quorum=quorum,
            deadline_s=deadline,
            concurrent_collect=concurrent,
        ),
        tokenizer=tok,
        reranker=overlap_reranker(tok),
    )
    if warm:
        saved = sys_.orchestrator.deadline_s
        sys_.orchestrator.deadline_s = None
        sys_.orchestrator.collect_contexts_batch([q.text for q in corpus.queries[:warm]])
        sys_.orchestrator.collect_contexts(corpus.queries[0].text)
        sys_.orchestrator.deadline_s = saved
    for p, d in zip(sys_.providers, delays or ()):
        p.delay_s = d
    return sys_


def _assert_context_equal(a: dict, b: dict):
    for k in ("chunk_tokens", "chunk_ids", "scores", "providers"):
        assert np.array_equal(a[k], b[k]), f"context[{k}] diverged"


def test_concurrent_matches_sequential_bitwise(corpus):
    """When every provider responds in time, concurrent fan-out must be
    bit-identical to the sequential loop (responses re-ordered by
    provider id before aggregation)."""
    con = _system(corpus, concurrent=True)
    seq = _system(corpus, concurrent=False)
    assert con.orchestrator.concurrent_collect and not seq.orchestrator.concurrent_collect
    texts = [q.text for q in corpus.queries[:4]]
    for a, b in zip(con.orchestrator.answer_batch(texts), seq.orchestrator.answer_batch(texts)):
        _assert_context_equal(a["context"], b["context"])
        assert a["n_providers"] == b["n_providers"]
    for t in texts:
        _assert_context_equal(
            con.orchestrator.answer(t)["context"], seq.orchestrator.answer(t)["context"]
        )


@pytest.mark.timing
def test_collect_wallclock_is_max_not_sum(corpus):
    """Acceptance: 4 providers, one with delay 0.2s — batched collect
    wall-clock must track the slowest provider (max), not the sum."""
    delays = (0.1, 0.2, 0.1, 0.1)
    sys_ = _system(corpus, delays=delays, warm=4)
    texts = [q.text for q in corpus.queries[:4]]
    sys_.orchestrator.collect_contexts_batch(texts)  # warm jit caches
    t0 = time.monotonic()
    responses = sys_.orchestrator.collect_contexts_batch(texts)
    dt = time.monotonic() - t0
    assert len(responses) == 4  # no deadline: everyone included
    assert dt < 2 * max(delays), f"collect took {dt:.3f}s (sum={sum(delays)}s)"


@pytest.mark.timing
def test_straggler_cut_off_at_deadline(corpus):
    """A provider slower than deadline_s must be abandoned mid-flight,
    not awaited: collect returns around the deadline with the fast
    providers' responses."""
    sys_ = _system(corpus, deadline=0.5, delays=(0.0, SLOW, 0.0, 0.0), warm=2)
    t0 = time.monotonic()
    responses = sys_.orchestrator.collect_contexts_batch(
        [q.text for q in corpus.queries[:2]]
    )
    dt = time.monotonic() - t0
    assert dt < 2.0, f"deadline did not cut the straggler off ({dt:.3f}s)"
    assert sorted(int(r["provider"]) for r in responses) == [0, 2, 3]


@pytest.mark.timing
def test_quorum_early_return_does_not_wait_for_stragglers(corpus):
    """With quorum met at the deadline, collect must return immediately —
    the slow provider's response is simply dropped (k_n < k)."""
    sys_ = _system(corpus, quorum=3, deadline=0.5, delays=(0.0, SLOW, 0.0, 0.0), warm=1)
    t0 = time.monotonic()
    res = sys_.orchestrator.answer(corpus.queries[0].text)
    dt = time.monotonic() - t0
    assert dt < 2.0, f"quorum return waited for the straggler ({dt:.3f}s)"
    assert res["n_providers"] == 3


@pytest.mark.timing
def test_quorum_failure_raises_promptly(corpus):
    """Too few providers inside the deadline -> RuntimeError at the
    deadline, without waiting the stragglers out."""
    sys_ = _system(corpus, quorum=3, deadline=0.3, delays=(SLOW, SLOW, SLOW, 0.0), warm=1)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="quorum"):
        sys_.orchestrator.collect_contexts_batch([corpus.queries[0].text])
    assert time.monotonic() - t0 < 2.0


@pytest.mark.timing
def test_deadline_budget_anchored_before_spawn(corpus):
    """Regression: the deadline clock must start at ``_collect`` entry,
    not at the post-spawn ``wait_for`` — time already burned before the
    wait (payload build, thread spawn) comes OUT of the wait budget.
    Simulated by handing ``_collect_concurrent`` an anchor aged by most
    of the deadline: only the remainder may be spent waiting."""
    sys_ = _system(corpus, deadline=0.5, delays=(SLOW, SLOW, SLOW, SLOW), warm=1)
    orch = sys_.orchestrator
    tokens = sys_.tok.encode(corpus.queries[0].text, max_len=24)
    t0 = time.monotonic() - 0.45  # 0.45s of the 0.5s SLO already spent
    t_start = time.monotonic()
    with pytest.raises(RuntimeError, match="quorum"):
        orch._collect_concurrent(orch.providers, lambda p: tokens, t0)
    dt = time.monotonic() - t_start
    assert dt < 0.4, (
        f"wait consumed {dt:.3f}s, but only ~0.05s of the SLO remained — "
        "the deadline was re-anchored after spawn"
    )


@pytest.mark.timing
def test_worker_exception_wakes_collect_without_deadline(corpus):
    """Regression: with ``deadline_s=None``, an unexpected worker
    exception plus one hung provider used to park ``wait_for`` forever —
    the predicate only counted finished workers, so the re-raise was
    unreachable.  The wait must wake on the exception and surface it."""
    sys_ = _system(corpus, concurrent=True, warm=1)
    sys_.providers[1].delay_s = SLOW  # hung straggler, never finishes

    def boom(nonce, sealed):
        raise ValueError("unexpected provider bug")

    sys_.providers[0].handle_request = boom
    done: list[BaseException] = []

    def run():
        try:
            sys_.orchestrator.collect_contexts(corpus.queries[0].text)
        except BaseException as e:
            done.append(e)

    t = threading.Thread(target=run, daemon=True)
    t.start()
    t.join(timeout=2.0)
    assert not t.is_alive(), "collect hung: worker exception did not wake wait_for"
    assert done and isinstance(done[0], ValueError)


def test_build_prompt_overflow_keeps_grammar(corpus):
    """Regression: overflowing prompts used to be tail-sliced
    (``ids[-max_len:]``), cutting off BOS/CTX and bisecting a chunk.
    Whole lowest-ranked chunks must be dropped instead, and the
    [BOS] CTX ... QRY query ANS skeleton preserved."""
    sys_ = _system(corpus)
    orch = sys_.orchestrator
    text = corpus.queries[0].text
    context = orch.aggregate(text, orch.collect_contexts(text))
    full = orch.build_prompt(text, context, max_len=512)[0]
    q_toks = [int(t) for t in sys_.tok.encode(text, bos=False) if t not in (PAD, EOS)]
    chunks = [
        [int(t) for t in row if t not in (PAD, BOS, EOS)]
        for row in context["chunk_tokens"]
    ]
    # non-overflow: exact grammar, all chunks, unchanged by the fix
    want = [BOS, CTX]
    for c in chunks:
        want += c + [SEP]
    want += [QRY] + q_toks + [ANS]
    assert list(full) == want
    # overflow: room for only some chunks
    max_len = 2 + sum(len(c) + 1 for c in chunks[:3]) + 1 + len(q_toks) + 1 + 2
    small = list(orch.build_prompt(text, context, max_len=max_len)[0])
    assert len(small) <= max_len
    assert small[:2] == [BOS, CTX], "BOS/CTX sliced off on overflow"
    assert small[-1] == ANS and small[-len(q_toks) - 2] == QRY
    assert small[-len(q_toks) - 1 : -1] == q_toks, "query must survive intact"
    body = small[2 : -len(q_toks) - 2]
    # kept chunks are an exact prefix of the ranked list, SEP-terminated
    kept, i = 0, 0
    while i < len(body):
        c = chunks[kept]
        assert body[i : i + len(c)] == c, f"chunk {kept} bisected on overflow"
        assert body[i + len(c)] == SEP
        i += len(c) + 1
        kept += 1
    assert 0 < kept < len(chunks), "overflow case must drop some tail chunks"


def test_failed_provider_tolerated_concurrently(corpus):
    """ConnectionError from one provider is straggler-tolerated by the
    concurrent path exactly as by the sequential one."""
    con = _system(corpus, concurrent=True)
    seq = _system(corpus, concurrent=False)
    con.providers[1].fail = True
    seq.providers[1].fail = True
    t = corpus.queries[0].text
    a, b = con.orchestrator.answer(t), seq.orchestrator.answer(t)
    assert a["n_providers"] == b["n_providers"] == 3
    _assert_context_equal(a["context"], b["context"])
