"""Data substrate: tokenizer determinism, corpus provenance, stream resume."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.corpus import CORPORA, SITE_OF, make_federated_corpus
from repro.data.embeddings import bag_embed
from repro.data.pipeline import LMBatchStream
from repro.data.tokenizer import N_SPECIAL, HashTokenizer


@given(st.text(alphabet=st.characters(min_codepoint=33, max_codepoint=126), min_size=1, max_size=30))
@settings(max_examples=30, deadline=None)
def test_tokenizer_deterministic_and_in_range(word):
    tok = HashTokenizer(4096)
    t1, t2 = tok.token(word), tok.token(word)
    assert t1 == t2
    assert N_SPECIAL <= t1 < 4096


def test_tokenizer_case_insensitive():
    tok = HashTokenizer()
    assert tok.token("Aspirin") == tok.token("aspirin")


def test_encode_fixed_len():
    tok = HashTokenizer()
    out = tok.encode("a b c", max_len=10)
    assert out.shape == (10,) and out.dtype == np.int32


def test_corpus_provenance_consistent():
    c = make_federated_corpus(n_facts=32, n_distractors=16, n_queries=20)
    for q in c.queries:
        gold = c.chunks[q.gold_chunk_id]
        assert gold.chunk_id == q.gold_chunk_id
        assert q.answer in gold.text, "gold chunk must contain the answer"
        assert gold.corpus == q.corpus
    for ch in c.chunks:
        assert ch.site == SITE_OF[ch.corpus]
    assert {ch.corpus for ch in c.chunks} == set(CORPORA)


def test_corpus_query_mix_is_skewed():
    c = make_federated_corpus(n_facts=300, n_queries=200, seed=3)
    frac_pubmed = sum(q.corpus == "pubmed" for q in c.queries) / len(c.queries)
    assert frac_pubmed > 0.35, "pubmed must dominate (Table 1 topology)"


def test_stream_resume_exact():
    s1 = LMBatchStream(2, 16, 1024, seed=7)
    b1 = [s1.next() for _ in range(5)]
    state = s1.state_dict()
    b_next = s1.next()
    s2 = LMBatchStream(2, 16, 1024, seed=0)
    s2.load_state_dict(state)
    b2 = s2.next()
    assert (b_next["tokens"] == b2["tokens"]).all(), "resumed stream must continue exactly"


def test_copy_task_structure():
    from repro.data.tokenizer import ANS, QRY, SEP

    s = LMBatchStream(4, 64, 512, seed=1, copy_task_frac=1.0)
    b = s.next()
    tokens, targets = b["tokens"][0], b["targets"][0]
    assert (tokens == QRY).any() and (tokens == ANS).any() and (tokens == SEP).any()
    pos_ans = int(np.argmax(tokens == ANS))
    pos_sep = int(np.argmax(tokens == SEP))
    # the supervised answer (target at ANS) is the token after the SEP marker
    assert targets[pos_ans] == tokens[pos_sep + 1], "answer must be the marked value"
    # only the answer position is supervised on copy rows
    assert (targets[:pos_ans] == -1).all() and (targets[pos_ans + 1 :] == -1).all()


def test_bag_embed_similarity_orders():
    tok = HashTokenizer()
    a = tok.encode("heart attack symptoms treatment", max_len=16)[None]
    b = tok.encode("heart attack symptoms diagnosis", max_len=16)[None]
    c = tok.encode("jupiter orbital mechanics telescope", max_len=16)[None]
    ea, eb, ec = (np.asarray(bag_embed(x)) for x in (a, b, c))
    assert (ea @ eb.T) > (ea @ ec.T), "lexical overlap must dominate similarity"
