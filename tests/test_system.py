"""End-to-end C-FedRAG behaviour (the paper's Table-1 mechanism + Alg. 1
robustness semantics)."""
import numpy as np
import pytest

from repro.core.pipeline import (
    CFedRAGConfig,
    CFedRAGSystem,
    centralized_system,
    single_silo_system,
)
from repro.data.corpus import CORPORA, make_federated_corpus
from repro.data.tokenizer import HashTokenizer
from repro.launch.serve import overlap_reranker


@pytest.fixture(scope="module")
def corpus():
    return make_federated_corpus(n_facts=96, n_distractors=96, n_queries=48, seed=1)


@pytest.fixture(scope="module")
def fed(corpus):
    return CFedRAGSystem(corpus, CFedRAGConfig(aggregation="embedding_rank"))


def test_federated_matches_centralized_recall(corpus, fed):
    """Key claim: federated retrieval recovers the centralized context."""
    r_fed = fed.eval_retrieval(32)
    r_cent = centralized_system(corpus).eval_retrieval(32)
    assert r_fed["recall_at_n"] >= r_cent["recall_at_n"] - 0.05


def test_single_silo_much_worse(corpus, fed):
    r_fed = fed.eval_retrieval(32)
    worst = min(
        single_silo_system(corpus, c).eval_retrieval(32)["recall_at_n"] for c in CORPORA
    )
    assert r_fed["recall_at_n"] > worst + 0.2, "federation must beat the weakest silo clearly"


def test_rerank_not_worse_than_embedding_rank(corpus):
    tok = HashTokenizer()
    emb = CFedRAGSystem(corpus, CFedRAGConfig(aggregation="embedding_rank"), tokenizer=tok)
    rr = CFedRAGSystem(
        corpus, CFedRAGConfig(aggregation="rerank"), tokenizer=tok, reranker=overlap_reranker(tok)
    )
    assert rr.eval_retrieval(32)["recall_at_n"] >= emb.eval_retrieval(32)["recall_at_n"] - 0.05


def test_quorum_tolerates_provider_failure(corpus):
    sys_ = CFedRAGSystem(corpus, CFedRAGConfig(aggregation="embedding_rank", quorum=1))
    sys_.providers[0].fail = True
    res = sys_.orchestrator.answer(corpus.queries[0].text)
    assert res["n_providers"] == len(sys_.providers) - 1  # k_n < k, still answers


def test_quorum_violation_raises(corpus):
    sys_ = CFedRAGSystem(corpus, CFedRAGConfig(quorum=2))
    for p in sys_.providers:
        p.fail = True
    with pytest.raises(RuntimeError, match="quorum"):
        sys_.orchestrator.answer(corpus.queries[0].text)


def test_context_never_exceeds_window(corpus, fed):
    res = fed.orchestrator.answer(corpus.queries[0].text)
    assert len(res["context"]["chunk_ids"]) <= fed.cfg.n_global
    assert res["context"]["n_candidates"] <= fed.cfg.m_local * len(fed.providers)


def test_provider_payload_is_filtered(corpus, fed):
    """ProvenanceStripFilter: only whitelisted keys leave the provider."""
    p = fed.providers[0]
    out = p.retrieve(fed.tok.encode(corpus.queries[0].text, max_len=24), 4)
    assert set(out) <= {"chunk_tokens", "scores", "chunk_ids", "provider"}


def test_transport_is_sealed(corpus, fed):
    """The orchestrator<->provider payload is AEAD-sealed: flipping one byte
    must break integrity."""
    from repro.core.confidential import IntegrityError
    from repro.core.provider import pack

    p = fed.providers[0]
    ch = getattr(p, "_orch_channel")
    nonce, sealed = ch.seal(pack({"query_tokens": np.zeros(4, np.int32), "m": np.int64(2)}))
    corrupted = bytearray(sealed)
    corrupted[len(corrupted) // 2] ^= 0xFF
    with pytest.raises(IntegrityError):
        p.channel.open(nonce, bytes(corrupted))


def test_prompt_contains_retrieved_context(corpus, fed):
    q = corpus.queries[0]
    res = fed.orchestrator.answer(q.text)
    prompt = fed.orchestrator.build_prompt(q.text, res["context"])
    # the gold chunk's distinctive value token should appear in the prompt
    gold_tokens = set(fed.tok.encode(corpus.chunks[q.gold_chunk_id].text).tolist())
    if q.gold_chunk_id in list(res["context"]["chunk_ids"]):
        overlap = gold_tokens & set(prompt[0].tolist())
        assert len(overlap) > 5
