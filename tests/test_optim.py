"""Optimizer + gradient-compression correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.optim.compression import (
    compress_with_ef,
    decompress,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)
from repro.optim.optimizers import cosine_schedule, get_optimizer, global_norm


@pytest.mark.parametrize("name,lr", [("adamw", 0.05), ("adafactor", 0.05), ("sgdm", 1.0)])
def test_optimizer_minimizes_quadratic(name, lr):
    # mean-loss grads scale as 1/N: keep N small so plain SGD sees O(1) steps
    opt = get_optimizer(name)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(16, 16)), jnp.float32)}
    target = jnp.ones((16, 16))
    state = opt.init(params)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for i in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params, lr=lr)
    assert float(loss(params)) < 0.2 * l0, name


def test_adafactor_memory_is_factored():
    opt = get_optimizer("adafactor")
    params = {"w": jnp.zeros((256, 512))}
    state = opt.init(params)
    v = state["v"]["w"]
    assert set(v) == {"vr", "vc"} and v["vr"].shape == (256,) and v["vc"].shape == (512,)


def test_adafactor_factored_converges():
    opt = get_optimizer("adafactor")
    params = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(256, 256)), jnp.float32)}
    target = jnp.ones((256, 256))
    state = opt.init(params)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss(params))
    p, s = params, state
    for i in range(60):
        g = jax.grad(loss)(p)
        p, s, _ = opt.update(g, s, p, lr=0.05)
    assert float(loss(p)) < 0.2 * l0  # factored second moment still converges


@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
@settings(max_examples=20, deadline=None)
def test_quantize_int8_error_bound(seed, scale):
    x = jnp.asarray(np.random.default_rng(seed).normal(0, scale, (64,)), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6  # half-ulp of the int8 grid


def test_error_feedback_removes_bias():
    """With EF, the LONG-RUN average of compressed grads equals the true
    gradient (bias cancels); without EF the bias persists."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(0, 1, (128,)), jnp.float32)}
    ef = init_error_feedback(g_true)
    acc = jnp.zeros((128,))
    n = 50
    for _ in range(n):
        comp, ef = compress_with_ef(g_true, ef)
        acc = acc + decompress(comp)["w"]
    assert_allclose(np.asarray(acc / n), np.asarray(g_true["w"]), atol=2e-3)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-3)
    assert float(lr(55)) < float(lr(20))


def test_global_norm_clipping():
    from repro.optim.optimizers import clip_by_global_norm

    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
