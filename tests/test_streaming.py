"""Streaming serve + pipelined front door.

Engine layer: ``serve_stream`` must yield each ``(rid, answer)`` at
retire time (retire order, not submission order), stay bit-identical to
the one-shot ``serve`` dict, and keep consuming submissions from a
producer thread until the scheduler is closed — the submit-while-serving
race the thread-safe scheduler exists to make safe.

System layer: ``CFedRAGSystem.serve_stream`` double-buffers collect and
decode (collector thread runs collect/aggregate for micro-batch N+1
while the engine decodes N) and must stay bit-identical to the
phase-barrier ``serve`` on the same inputs, with ``latency_s`` covering
collect -> finish.
"""
import threading
import time

import numpy as np
import pytest

from _fake_lm import expected_answer, make_fake_engine, prompt_ending
from repro.serving.scheduler import Scheduler


@pytest.fixture()
def fake_engine(monkeypatch):
    def make(**kw):
        return make_fake_engine(monkeypatch, **kw)

    return make


# ------------------------------------------------------------------ #
# engine layer
# ------------------------------------------------------------------ #
def test_serve_stream_yields_in_retire_order(fake_engine):
    """A short-budget request admitted alongside a long one must be
    yielded first, while the long row is still decoding."""
    eng = fake_engine(max_batch=2, max_new_tokens=8, sched_chunk=1)
    sched = Scheduler()
    r_long = sched.submit(prompt_ending(10), max_new_tokens=8)  # no EOS in 8
    r_short = sched.submit(prompt_ending(10), max_new_tokens=2)
    order = []
    for rid, ans in eng.serve_stream(sched, drain=True):
        order.append(rid)
        want = expected_answer(10, 8 if rid == r_long else 2)
        assert list(ans) == want
    assert order == [r_short, r_long], "short budget must retire (and yield) first"


def test_serve_stream_matches_serve_bitwise(fake_engine):
    eng = fake_engine(max_batch=2, max_new_tokens=6, sched_chunk=3)
    ends = [253, 0, 10, 254, 5, 1, 77]
    s1, s2 = Scheduler(), Scheduler()
    rids1 = s1.submit_many([prompt_ending(e) for e in ends])
    rids2 = s2.submit_many([prompt_ending(e) for e in ends])
    streamed = dict(eng.serve_stream(s1, drain=True))
    oneshot = eng.serve(s2)
    assert set(streamed) == set(rids1)
    for e, ra, rb in zip(ends, rids1, rids2):
        assert list(streamed[ra]) == list(oneshot[rb]) == expected_answer(e, 6)


def test_submit_while_serving_threaded_producer(fake_engine):
    """A producer thread submits into the live scheduler while the engine
    consumes; every answer must match the closed form and the stream must
    end exactly at close+drain (no lost or duplicated requests)."""
    eng = fake_engine(max_batch=2, max_new_tokens=6, sched_chunk=2)
    sched = Scheduler()
    ends = [(37 * i + 11) % 256 for i in range(24)]
    submitted: dict[int, int] = {}  # rid -> end token

    def producer():
        for i, e in enumerate(ends):
            submitted[sched.submit(prompt_ending(e))] = e
            if i % 3 == 0:
                time.sleep(0.002)  # interleave with decode chunks
        sched.close()

    t = threading.Thread(target=producer)
    t.start()
    got = dict(eng.serve_stream(sched))  # live mode: waits for close
    t.join()
    assert len(got) == len(ends)
    for rid, e in submitted.items():
        assert list(got[rid]) == expected_answer(e, 6), f"rid={rid} end={e}"
    assert sched.drain(timeout=0.0)  # everything reached a terminal state


def test_serve_stream_live_exits_on_close_with_empty_queue(fake_engine):
    eng = fake_engine(max_batch=2)
    sched = Scheduler()
    sched.close()
    assert list(eng.serve_stream(sched)) == []


# ------------------------------------------------------------------ #
# system layer (real small LM): pipelined front door parity
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def streamed_system():
    import jax

    from repro.configs import get_config, smoke_config
    from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
    from repro.data.corpus import make_federated_corpus
    from repro.data.tokenizer import HashTokenizer
    from repro.launch.serve import overlap_reranker
    from repro.models import lm as LM
    from repro.models.params import init_params
    from repro.runtime.sharding import ShardingPolicy, base_rules
    from repro.serving.engine import ServeConfig, ServeEngine, engine_generator

    cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(dtype="float32")
    params = init_params(LM.param_specs(cfg), jax.random.PRNGKey(0))
    pol = ShardingPolicy(rules=base_rules(False), mesh=None)
    engine = ServeEngine(
        cfg, pol, params,
        ServeConfig(max_batch=2, max_prompt_len=128, max_new_tokens=4, sched_chunk=2),
    )
    corpus = make_federated_corpus(n_facts=24, n_distractors=24, n_queries=8, seed=11)
    tok = HashTokenizer()
    sys_ = CFedRAGSystem(
        corpus,
        CFedRAGConfig(
            aggregation="rerank", m_local=4, n_global=4, chunk_max_len=16
        ),
        tokenizer=tok,
        reranker=overlap_reranker(tok),
        generator=engine_generator(engine),
    )
    return corpus, sys_


def test_pipeline_serve_stream_matches_serve(streamed_system):
    """Acceptance parity: pipelined serve_stream results bit-identical to
    the phase-barrier serve on the same queries (modulo latency, whose
    span now covers collect -> finish)."""
    corpus, sys_ = streamed_system
    texts = [q.text for q in corpus.queries[:7]]  # uneven micro-batching
    barrier = sys_.serve(texts, max_new_tokens=4)
    streamed = [None] * len(texts)
    seen = []
    for qidx, out in sys_.serve_stream(texts, max_new_tokens=4, collect_batch=3):
        seen.append(qidx)
        streamed[qidx] = out
    assert sorted(seen) == list(range(len(texts))), "each query yields exactly once"
    for a, b in zip(barrier, streamed):
        assert b["status"] == a["status"] == "done"
        assert np.array_equal(a["prompt"], b["prompt"])
        assert np.array_equal(a["answer_tokens"], b["answer_tokens"])
        for k in ("chunk_tokens", "chunk_ids", "scores", "providers"):
            assert np.array_equal(a["context"][k], b["context"][k])
        assert b["latency_s"] is not None and b["latency_s"] > 0


@pytest.mark.timing
def test_pipeline_serve_stream_latency_covers_collect(streamed_system):
    """latency_s is anchored at the micro-batch's collect start: with a
    slow provider, streamed latency must include the provider round-trip,
    not just generation."""
    corpus, sys_ = streamed_system
    texts = [q.text for q in corpus.queries[:2]]
    delay = 0.15
    try:
        for p in sys_.providers:
            p.delay_s = delay
        outs = dict(sys_.serve_stream(texts, max_new_tokens=2, collect_batch=2))
    finally:
        for p in sys_.providers:
            p.delay_s = 0.0
    assert len(outs) == 2
    for out in outs.values():
        assert out["latency_s"] >= delay, (
            f"latency_s={out['latency_s']:.3f}s must cover the {delay}s collect"
        )
