"""Per-kernel allclose sweeps (interpret=True) against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.kernels.decode_attention.kernel import combine_partials, decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.retrieval_topk.kernel import retrieval_topk_pallas
from repro.kernels.retrieval_topk.ref import retrieval_topk_ref
from repro.kernels.ssd_scan.kernel import ssd_chunk_pallas
from repro.kernels.ssd_scan.ref import ssd_chunk_ref


# ---------------- retrieval_topk ----------------
@pytest.mark.parametrize("q,n,d,k", [(5, 100, 32, 4), (16, 257, 64, 8), (33, 1024, 128, 16), (1, 50, 16, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_retrieval_topk_sweep(q, n, d, k, dtype):
    kk = jax.random.PRNGKey(q * n)
    qs = jax.random.normal(kk, (q, d), dtype)
    cs = jax.random.normal(jax.random.fold_in(kk, 1), (n, d), dtype)
    s_p, i_p = retrieval_topk_pallas(qs, cs, k, bq=8, bn=64)
    s_r, i_r = retrieval_topk_ref(qs, cs, k)
    assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=2e-2, atol=2e-2)
    # indices may swap under score ties in bf16; check score-equivalence
    gathered = np.take_along_axis(
        np.asarray(qs, np.float32) @ np.asarray(cs, np.float32).T, np.asarray(i_p), axis=1
    )
    assert_allclose(gathered, np.asarray(s_r), rtol=2e-2, atol=2e-2)


@given(
    q=st.integers(1, 12),
    n=st.integers(10, 300),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=15, deadline=None)
def test_retrieval_topk_property(q, n, k, seed):
    kk = jax.random.PRNGKey(seed)
    qs = jax.random.normal(kk, (q, 16))
    cs = jax.random.normal(jax.random.fold_in(kk, 1), (n, 16))
    s, i = retrieval_topk_pallas(qs, cs, k, bq=8, bn=32)
    s, i = np.asarray(s), np.asarray(i)
    assert (np.diff(s, axis=1) <= 1e-6).all(), "scores sorted desc"
    assert ((i >= 0) & (i < n)).all(), "indices valid (padding never leaks)"
    full = np.asarray(qs) @ np.asarray(cs).T
    assert_allclose(np.sort(s, 1), np.sort(np.sort(full, 1)[:, -k:], 1), rtol=1e-5, atol=1e-5)


# ---------------- flash attention ----------------
@pytest.mark.parametrize("sq,sk,h,kv,dh", [(32, 32, 4, 4, 16), (64, 64, 8, 2, 32), (128, 128, 4, 1, 64)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(sq, sk, h, kv, dh, causal, dtype):
    kk = jax.random.PRNGKey(sq + h)
    q = jax.random.normal(kk, (2, sq, h, dh), dtype)
    k = jax.random.normal(jax.random.fold_in(kk, 1), (2, sk, kv, dh), dtype)
    v = jax.random.normal(jax.random.fold_in(kk, 2), (2, sk, kv, dh), dtype)
    o_p = flash_attention_pallas(q, k, v, causal=causal, bq=16, bk=16)
    o_r = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert_allclose(np.asarray(o_p, np.float32), np.asarray(o_r, np.float32), rtol=tol, atol=tol)


# ---------------- decode attention ----------------
@pytest.mark.parametrize("b,s,h,kv,dh,bs", [(2, 64, 8, 4, 32, 16), (4, 128, 4, 4, 16, 32), (1, 256, 16, 2, 64, 64)])
def test_decode_attention_sweep(b, s, h, kv, dh, bs):
    kk = jax.random.PRNGKey(b * s)
    q = jax.random.normal(kk, (b, h, dh))
    kc = jax.random.normal(jax.random.fold_in(kk, 1), (b, s, kv, dh))
    vc = jax.random.normal(jax.random.fold_in(kk, 2), (b, s, kv, dh))
    lens = jnp.asarray(np.random.default_rng(0).integers(1, s + 1, size=b))
    o_p = decode_attention_pallas(q, kc, vc, lens, bs=bs)
    o_r = decode_attention_ref(q, kc, vc, lens)
    assert_allclose(np.asarray(o_p), np.asarray(o_r), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize(
    "b,h,kv,dh,bs,n_t", [(2, 8, 4, 32, 16, 4), (3, 4, 4, 16, 32, 2), (1, 16, 2, 64, 8, 8)]
)
def test_paged_decode_attention_sweep(b, h, kv, dh, bs, n_t):
    """Paged flash-decode: block-table gather through scalar-prefetch
    index maps must match (a) the gather reference and (b) the dense
    kernel run on each row's materialized contiguous view."""
    from repro.kernels.decode_attention.kernel import paged_decode_attention_pallas
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref

    n_pool = b * n_t + 1  # +1 pool block left dangling (never referenced)
    kk = jax.random.PRNGKey(b * h + n_t)
    q = jax.random.normal(kk, (b, h, dh))
    kp = jax.random.normal(jax.random.fold_in(kk, 1), (n_pool, bs, kv, dh))
    vp = jax.random.normal(jax.random.fold_in(kk, 2), (n_pool, bs, kv, dh))
    rng = np.random.default_rng(0)
    # disjoint, shuffled tables: physical order != logical order
    tables = jnp.asarray(rng.permutation(n_pool - 1)[: b * n_t].reshape(b, n_t), jnp.int32)
    lens = jnp.asarray(rng.integers(1, n_t * bs + 1, size=b), jnp.int32)
    o_p = paged_decode_attention_pallas(q, kp, vp, tables, lens)
    o_r = paged_decode_attention_ref(q, kp, vp, tables, lens)
    assert_allclose(np.asarray(o_p), np.asarray(o_r, np.float32), rtol=2e-5, atol=2e-5)
    # dense equivalence: gather each row's blocks into a contiguous cache
    kc = np.asarray(kp)[np.asarray(tables)].reshape(b, n_t * bs, kv, dh)
    vc = np.asarray(vp)[np.asarray(tables)].reshape(b, n_t * bs, kv, dh)
    o_d = decode_attention_ref(q, jnp.asarray(kc), jnp.asarray(vc), lens)
    assert_allclose(np.asarray(o_r), np.asarray(o_d, np.float32), rtol=0, atol=0)


def test_paged_decode_trash_blocks_never_leak():
    """Lanes past ``lengths`` (including whole table entries that point at
    a trash block full of garbage) must contribute exactly nothing."""
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref

    b, h, kv, dh, bs, n_t = 2, 4, 2, 16, 8, 3
    kk = jax.random.PRNGKey(3)
    q = jax.random.normal(kk, (b, h, dh))
    kp = jax.random.normal(jax.random.fold_in(kk, 1), (7, bs, kv, dh))
    vp = jax.random.normal(jax.random.fold_in(kk, 2), (7, bs, kv, dh))
    trash = 6
    tables = jnp.asarray([[0, 1, trash], [2, 3, trash]], jnp.int32)
    lens = jnp.asarray([2 * bs, bs + 3], jnp.int32)
    base = paged_decode_attention_ref(q, kp, vp, tables, lens)
    # poison the trash block and every masked lane of a live block
    kp2 = kp.at[trash].set(1e4).at[3, 4:].set(-1e4)
    vp2 = vp.at[trash].set(1e4).at[3, 4:].set(-1e4)
    poisoned = paged_decode_attention_ref(q, kp2, vp2, tables, lens)
    assert_allclose(np.asarray(base), np.asarray(poisoned), rtol=0, atol=0)


def test_decode_partials_combine_equals_monolithic():
    """flash-decode: combining per-shard partials == attention over full cache."""
    kk = jax.random.PRNGKey(7)
    b, s, h, kv, dh, shards = 2, 128, 8, 4, 32, 4
    q = jax.random.normal(kk, (b, h, dh))
    kc = jax.random.normal(jax.random.fold_in(kk, 1), (b, s, kv, dh))
    vc = jax.random.normal(jax.random.fold_in(kk, 2), (b, s, kv, dh))
    lens = jnp.full((b,), s)
    full = decode_attention_ref(q, kc, vc, lens)
    os_, ms_, ls_ = [], [], []
    for i in range(shards):
        sl = slice(i * s // shards, (i + 1) * s // shards)
        o, m, l = decode_attention_pallas(
            q, kc[:, sl], vc[:, sl], jnp.full((b,), s // shards), bs=16, return_partials=True
        )
        os_.append(o), ms_.append(m), ls_.append(l)
    combined = combine_partials(os_, ms_, ls_).reshape(b, h, dh)
    assert_allclose(np.asarray(combined), np.asarray(full, np.float32), rtol=2e-5, atol=2e-5)


# ---------------- chunked prefill (mixed prefill+decode) ----------------
def _mixed_oracle_np(q, kp, vp, tables, desc):
    """Independent float64 numpy oracle for the descriptor contract: lane
    ``j`` of row ``r`` attends positions ``<= q_start + j`` and ``<
    kv_len`` of its slot's gathered pool view; dead lanes are exactly 0."""
    q, kp, vp = (np.asarray(a, np.float64) for a in (q, kp, vp))
    tables = np.asarray(tables)
    r, w, h, dh = q.shape
    bs, kv = kp.shape[1], kp.shape[2]
    g = h // kv
    out = np.zeros_like(q)
    for i in range(r):
        slot, q0, ql, kl = (int(x) for x in np.asarray(desc)[i])
        kview = kp[tables[slot]].reshape(-1, kv, dh)
        vview = vp[tables[slot]].reshape(-1, kv, dh)
        for j in range(ql):
            n = min(q0 + j + 1, kl)
            for hh in range(h):
                s = kview[:n, hh // g] @ q[i, j, hh] / np.sqrt(dh)
                p = np.exp(s - s.max())
                out[i, j, hh] = (p / p.sum()) @ vview[:n, hh // g]
    return out


def _rand_mixed_case(rng, b, w, h, kv, dh, bs, n_t):
    """Random pool + disjoint shuffled tables + a descriptor mix covering
    decode rows, cold/warm fill chunks, a COW-style boundary row, and a
    zero-length row when b allows."""
    n_pool = b * n_t + 1
    kk = jax.random.PRNGKey(rng.integers(2**31))
    q = jax.random.normal(kk, (b, w, h, dh))
    kp = jax.random.normal(jax.random.fold_in(kk, 1), (n_pool, bs, kv, dh))
    vp = jax.random.normal(jax.random.fold_in(kk, 2), (n_pool, bs, kv, dh))
    tables = jnp.asarray(
        rng.permutation(n_pool - 1)[: b * n_t].reshape(b, n_t), jnp.int32
    )
    cap = n_t * bs
    desc = np.zeros((b, 4), np.int32)
    for i in range(b):
        kind = ["decode", "cold", "warm", "boundary", "dead"][i % 5]
        if kind == "decode":  # 1 fresh token at the tip of a live cache
            q0 = int(rng.integers(0, cap))
            desc[i] = (i, q0, 1, q0 + 1)
        elif kind == "cold":  # prompt chunk from position 0
            ql = int(rng.integers(1, w + 1))
            desc[i] = (i, 0, ql, ql)
        elif kind == "warm":  # suffix chunk riding resident prefix K/V
            q0 = int(rng.integers(1, cap - 1))
            ql = int(rng.integers(1, min(w, cap - q0) + 1))
            desc[i] = (i, q0, ql, q0 + ql)
        elif kind == "boundary":  # full-prefix COW hit: single suffix lane
            kl = int(rng.integers(1, cap + 1))
            desc[i] = (i, kl - 1, 1, kl)
        else:  # zero-length suffix: inert row, must output exact 0
            desc[i] = (i, int(rng.integers(0, cap)), 0, int(rng.integers(1, cap)))
    return q, kp, vp, tables, jnp.asarray(desc)


@pytest.mark.parametrize(
    "b,w,h,kv,dh,bs,n_t", [(5, 6, 8, 4, 32, 16, 4), (6, 4, 4, 4, 16, 4, 3), (3, 8, 16, 2, 64, 8, 2)]
)
def test_mixed_prefill_attention_sweep(b, w, h, kv, dh, bs, n_t):
    """Unified kernel vs the jnp ref vs an independent float64 numpy
    oracle on a batch mixing every descriptor kind the engine emits."""
    from repro.kernels.chunked_prefill.kernel import mixed_prefill_attention_pallas
    from repro.kernels.chunked_prefill.ref import mixed_prefill_attention_ref

    rng = np.random.default_rng(b * w + n_t)
    q, kp, vp, tables, desc = _rand_mixed_case(rng, b, w, h, kv, dh, bs, n_t)
    o_p = mixed_prefill_attention_pallas(q, kp, vp, tables, desc)
    o_r = mixed_prefill_attention_ref(q, kp, vp, tables, desc)
    assert_allclose(np.asarray(o_p), np.asarray(o_r), rtol=2e-5, atol=2e-5)
    o_n = _mixed_oracle_np(q, kp, vp, tables, desc)
    assert_allclose(np.asarray(o_r), o_n, rtol=1e-5, atol=1e-5)
    # dead lanes (j >= q_len) must be exactly zero in both implementations
    lanes = np.arange(w)[None, :] >= np.asarray(desc)[:, 2][:, None]
    assert (np.asarray(o_p)[lanes] == 0).all() and (np.asarray(o_r)[lanes] == 0).all()


@given(
    b=st.integers(1, 6),
    w=st.integers(1, 7),
    bs=st.sampled_from([4, 8]),
    n_t=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=15, deadline=None)
def test_mixed_prefill_attention_property(b, w, bs, n_t, seed):
    """Ragged descriptor mixes under hypothesis: pallas == ref for any
    (decode / cold / warm / boundary / zero-length) row combination."""
    from repro.kernels.chunked_prefill.kernel import mixed_prefill_attention_pallas
    from repro.kernels.chunked_prefill.ref import mixed_prefill_attention_ref

    rng = np.random.default_rng(seed)
    q, kp, vp, tables, desc = _rand_mixed_case(rng, b, w, 4, 2, 16, bs, n_t)
    o_p = mixed_prefill_attention_pallas(q, kp, vp, tables, desc)
    o_r = mixed_prefill_attention_ref(q, kp, vp, tables, desc)
    assert_allclose(np.asarray(o_p), np.asarray(o_r), rtol=2e-5, atol=2e-5)
    lanes = np.arange(w)[None, :] >= np.asarray(desc)[:, 2][:, None]
    assert (np.asarray(o_p)[lanes] == 0).all()


def test_mixed_prefill_trash_blocks_never_leak():
    """Positions past ``kv_len`` — including whole table entries pointing
    at a garbage trash block (how the engine pads dead lanes' K/V
    scatter) — must contribute exactly nothing to any live lane."""
    from repro.kernels.chunked_prefill.ref import mixed_prefill_attention_ref

    b, w, h, kv, dh, bs = 2, 4, 4, 2, 16, 8
    kk = jax.random.PRNGKey(3)
    q = jax.random.normal(kk, (b, w, h, dh))
    kp = jax.random.normal(jax.random.fold_in(kk, 1), (7, bs, kv, dh))
    vp = jax.random.normal(jax.random.fold_in(kk, 2), (7, bs, kv, dh))
    trash = 6
    tables = jnp.asarray([[0, 1, trash], [2, 3, trash]], jnp.int32)
    # row 0: warm fill ending mid-block-1; row 1: decode at the tip
    desc = jnp.asarray([[0, 8, 4, 12], [1, 10, 1, 11]], jnp.int32)
    base = mixed_prefill_attention_ref(q, kp, vp, tables, desc)
    kp2 = kp.at[trash].set(1e4).at[1, 4:].set(-1e4).at[3, 3:].set(-1e4)
    vp2 = vp.at[trash].set(1e4).at[1, 4:].set(-1e4).at[3, 3:].set(-1e4)
    poisoned = mixed_prefill_attention_ref(q, kp2, vp2, tables, desc)
    assert_allclose(np.asarray(base), np.asarray(poisoned), rtol=0, atol=0)


def test_mixed_prefill_verify_rows_match_per_lane_decode():
    """Speculative VERIFY descriptors — ``q_len = k + 1`` starting at the
    row's committed position — must be lane-for-lane identical to k+1
    independent decode descriptors over the same resident pool K/V: the
    kernel-level fact that makes draft-k/verify-1 greedy accept-prefix
    bit-identical to plain 1-token decode."""
    from repro.kernels.chunked_prefill.kernel import mixed_prefill_attention_pallas
    from repro.kernels.chunked_prefill.ref import mixed_prefill_attention_ref

    b, w, h, kv, dh, bs, n_t = 3, 5, 4, 2, 16, 8, 3
    rng = np.random.default_rng(17)
    kk = jax.random.PRNGKey(11)
    n_pool = b * n_t + 1
    q = jax.random.normal(kk, (b, w, h, dh))
    kp = jax.random.normal(jax.random.fold_in(kk, 1), (n_pool, bs, kv, dh))
    vp = jax.random.normal(jax.random.fold_in(kk, 2), (n_pool, bs, kv, dh))
    tables = jnp.asarray(
        rng.permutation(n_pool - 1)[: b * n_t].reshape(b, n_t), jnp.int32
    )
    k = w - 1  # draft_k: verify q_len = k + 1 = w lanes
    q0 = [3, 7, 0]  # per-row committed position (q_start)
    desc_v = jnp.asarray(
        [[i, q0[i], k + 1, q0[i] + k + 1] for i in range(b)], jnp.int32
    )
    o_v = mixed_prefill_attention_ref(q, kp, vp, tables, desc_v)
    o_vp = mixed_prefill_attention_pallas(q, kp, vp, tables, desc_v)
    assert_allclose(np.asarray(o_vp), np.asarray(o_v), rtol=2e-5, atol=2e-5)
    assert_allclose(
        np.asarray(o_v), _mixed_oracle_np(q, kp, vp, tables, desc_v),
        rtol=1e-5, atol=1e-5,
    )
    # verify lane j == a plain q_len=1 decode descriptor at q_start + j
    for j in range(k + 1):
        desc_d = jnp.asarray(
            [[i, q0[i] + j, 1, q0[i] + j + 1] for i in range(b)], jnp.int32
        )
        o_d = mixed_prefill_attention_ref(q[:, j : j + 1], kp, vp, tables, desc_d)
        assert_allclose(
            np.asarray(o_v)[:, j], np.asarray(o_d)[:, 0], rtol=1e-6, atol=1e-6
        )


# ---------------- ssd scan ----------------
@pytest.mark.parametrize("b,l,h,hd,ds", [(1, 16, 2, 8, 8), (2, 32, 4, 16, 8), (2, 64, 2, 32, 16)])
def test_ssd_chunk_sweep(b, l, h, hd, ds):
    kk = jax.random.PRNGKey(l)
    x = jax.random.normal(kk, (b, l, h, hd))
    bb = jax.random.normal(jax.random.fold_in(kk, 1), (b, l, h, ds))
    cc = jax.random.normal(jax.random.fold_in(kk, 2), (b, l, h, ds))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(kk, 3), (b, l, h)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(kk, 4), (h,)))
    outs_p = ssd_chunk_pallas(x, bb, cc, dt, a)
    outs_r = ssd_chunk_ref(x, bb, cc, dt, a)
    for o_p, o_r in zip(outs_p, outs_r):
        assert_allclose(np.asarray(o_p), np.asarray(o_r), rtol=1e-4, atol=1e-4)
