"""Minimal stand-in for `hypothesis` used when the real package is absent.

conftest.py registers this module as ``hypothesis`` (and its ``strategies``
submodule) only on ImportError, so environments with the real library are
unaffected.  Each strategy is a deterministic sampler; ``@given`` runs the
test body ``max_examples`` times with seeded pseudo-random draws.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: random.Random):
        return self._sample(rng)


def integers(min_value=0, max_value=1 << 30):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def binary(min_size=0, max_size=64):
    return _Strategy(
        lambda rng: bytes(rng.randrange(256) for _ in range(rng.randint(min_size, max_size)))
    )


def characters(min_codepoint=32, max_codepoint=126, **_kw):
    return _Strategy(lambda rng: chr(rng.randint(min_codepoint, max_codepoint)))


def text(alphabet=None, min_size=0, max_size=20):
    alpha = alphabet or characters()
    return _Strategy(
        lambda rng: "".join(alpha.example(rng) for _ in range(rng.randint(min_size, max_size)))
    )


def settings(max_examples=20, deadline=None, **_kw):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn

    return deco


def given(*gargs, **gkwargs):
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        # real hypothesis binds positional strategies to the RIGHTMOST
        # params (leftmost stay free for pytest fixtures)
        pos_names = [p.name for p in params[len(params) - len(gargs) :]]
        strat_by_name = dict(zip(pos_names, gargs), **gkwargs)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hyp_max_examples", None) or getattr(
                fn, "_hyp_max_examples", 20
            )
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                drawn = {name: s.example(rng) for name, s in strat_by_name.items()}
                fn(*args, **{**drawn, **kwargs})

        # hide strategy-bound params so pytest doesn't treat them as fixtures
        wrapper.__signature__ = sig.replace(
            parameters=[p for p in params if p.name not in strat_by_name]
        )
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper

    return deco


def install():
    """Register this module as `hypothesis` + `hypothesis.strategies`."""
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "binary", "characters", "text"):
        setattr(st, name, globals()[name])
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
