"""Batched federated query pipeline: answer_batch must be bit-identical to
B sequential answer() calls while issuing exactly ONE sealed request per
provider per batch; retrieval_topk handles (B*Q, D) query blocks natively."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus
from repro.data.tokenizer import HashTokenizer
from repro.kernels.retrieval_topk.kernel import retrieval_topk_pallas
from repro.kernels.retrieval_topk.ref import retrieval_topk_ref
from repro.launch.serve import overlap_reranker


@pytest.fixture(scope="module")
def corpus():
    return make_federated_corpus(n_facts=64, n_distractors=64, n_queries=16, seed=3)


def _make_system(corpus, aggregation="rerank", quorum=1):
    tok = HashTokenizer()
    return CFedRAGSystem(
        corpus,
        CFedRAGConfig(aggregation=aggregation, quorum=quorum),
        tokenizer=tok,
        reranker=overlap_reranker(tok) if aggregation == "rerank" else None,
    )


def _assert_context_equal(a: dict, b: dict):
    for k in ("chunk_tokens", "chunk_ids", "scores", "providers"):
        assert np.array_equal(a[k], b[k]), f"context[{k}] diverged"
    assert a["n_candidates"] == b["n_candidates"]


@pytest.mark.parametrize("aggregation", ["embedding_rank", "rerank"])
def test_answer_batch_matches_sequential(corpus, aggregation):
    sys_ = _make_system(corpus, aggregation)
    texts = [q.text for q in corpus.queries[:8]]
    seq = [sys_.orchestrator.answer(t) for t in texts]
    bat = sys_.orchestrator.answer_batch(texts)
    assert len(bat) == len(seq)
    for s, b in zip(seq, bat):
        _assert_context_equal(s["context"], b["context"])
        assert s["n_providers"] == b["n_providers"]


def test_answer_batch_single_request_per_provider(corpus):
    sys_ = _make_system(corpus)
    texts = [q.text for q in corpus.queries[:8]]
    for p in sys_.providers:
        p.n_requests = 0
    sys_.orchestrator.answer_batch(texts)
    assert all(p.n_requests == 1 for p in sys_.providers), (
        "batched path must issue exactly one sealed request per provider"
    )
    for p in sys_.providers:
        p.n_requests = 0
    for t in texts:
        sys_.orchestrator.answer(t)
    assert all(p.n_requests == len(texts) for p in sys_.providers)


def test_answer_batch_with_failed_provider(corpus):
    sys_ = _make_system(corpus)
    sys_.providers[0].fail = True
    texts = [q.text for q in corpus.queries[:4]]
    seq = [sys_.orchestrator.answer(t) for t in texts]
    bat = sys_.orchestrator.answer_batch(texts)
    for s, b in zip(seq, bat):
        _assert_context_equal(s["context"], b["context"])
        assert b["n_providers"] == len(sys_.providers) - 1  # k_n < k, still answers


def test_answer_batch_quorum_violation_raises(corpus):
    sys_ = _make_system(corpus, quorum=2)
    for p in sys_.providers:
        p.fail = True
    with pytest.raises(RuntimeError, match="quorum"):
        sys_.orchestrator.answer_batch([corpus.queries[0].text])


def test_answer_batch_selector_routing_matches_sequential(corpus):
    """Satellite: selector_top_p setups used to fall back to B sequential
    ``answer()`` calls; the routed batch path (ragged fan-out, non-
    selected query rows PAD-masked) must stay bit-identical to the
    sequential selector path while sending at most ONE sealed request per
    SELECTED provider and none to providers no query routed to."""
    from repro.core.advanced import ProviderSelector

    sys_ = _make_system(corpus)
    orch = sys_.orchestrator
    orch.selector = ProviderSelector(sys_.providers, sys_.embed_fn)
    orch.selector_top_p = 2
    texts = [q.text for q in corpus.queries[:8]]
    seq = [orch.answer(t) for t in texts]
    for p in sys_.providers:
        p.n_requests = 0
    bat = orch.answer_batch(texts)
    assert len(bat) == len(seq)
    for s, b in zip(seq, bat):
        _assert_context_equal(s["context"], b["context"])
        assert s["n_providers"] == b["n_providers"] == 2
    routes = orch.query_routes(texts)
    sel_ids = {int(p.provider_id) for sub in routes for p in sub}
    for p in sys_.providers:
        want = 1 if int(p.provider_id) in sel_ids else 0
        assert p.n_requests == want, (
            f"provider {p.provider_id}: {p.n_requests} requests, want {want}"
        )


def test_batched_retrieve_matches_per_query(corpus):
    sys_ = _make_system(corpus)
    p = sys_.providers[0]
    tok = sys_.tok
    q_rows = np.stack([tok.encode(q.text, max_len=24) for q in corpus.queries[:6]])
    batched = p.retrieve(q_rows, 4)
    for b in range(len(q_rows)):
        single = p.retrieve(q_rows[b], 4)
        assert np.array_equal(single["scores"], batched["scores"][b])
        assert np.array_equal(single["chunk_ids"], batched["chunk_ids"][b])
        assert np.array_equal(single["chunk_tokens"], batched["chunk_tokens"][b])


def test_eval_retrieval_batched_matches_sequential(corpus):
    sys_ = _make_system(corpus)
    r_b = sys_.eval_retrieval(12, batch_size=8)
    r_s = sys_.eval_retrieval(12, batch_size=1)
    assert r_b["recall_at_n"] == r_s["recall_at_n"]
    assert r_b["mrr"] == pytest.approx(r_s["mrr"])


def test_cross_encoder_reranker_batched_matches_per_query(corpus):
    """make_reranker: one flattened (B*C, S) forward pass must score the
    same as per-query calls, and drive answer_batch == answer parity."""
    from repro.configs import get_config, smoke_config
    from repro.models.cross_encoder import make_reranker, param_specs
    from repro.models.params import init_params
    from repro.runtime.sharding import ShardingPolicy, base_rules

    cfg = smoke_config(get_config("bge-reranker-base")).with_overrides(dtype="float32")
    pol = ShardingPolicy(rules=base_rules(False), mesh=None)
    params = init_params(param_specs(cfg), jax.random.PRNGKey(0))
    rerank = make_reranker(cfg, pol, params, max_len=48)
    assert rerank.supports_batch

    tok = HashTokenizer()
    sys_ = CFedRAGSystem(
        corpus, CFedRAGConfig(aggregation="rerank"), tokenizer=tok, reranker=rerank
    )
    texts = [q.text for q in corpus.queries[:3]]
    seq = [sys_.orchestrator.answer(t) for t in texts]
    bat = sys_.orchestrator.answer_batch(texts)
    for s, b in zip(seq, bat):
        assert np.array_equal(s["context"]["chunk_ids"], b["context"]["chunk_ids"])
        assert_allclose(s["context"]["scores"], b["context"]["scores"], rtol=1e-5, atol=1e-6)


# ---------------- batched kernel path ----------------
@given(
    q=st.integers(1, 40),
    n=st.integers(10, 300),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=12, deadline=None)
def test_retrieval_topk_batched_property(q, n, k, seed):
    """Default block sizes (the production path) over random (B*Q, D)
    shapes: kernel == oracle."""
    kk = jax.random.PRNGKey(seed)
    qs = jax.random.normal(kk, (q, 16))
    cs = jax.random.normal(jax.random.fold_in(kk, 1), (n, 16))
    s_p, i_p = retrieval_topk_pallas(qs, cs, k)
    s_r, i_r = retrieval_topk_ref(qs, cs, k)
    assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=1e-5, atol=1e-5)
    gathered = np.take_along_axis(
        np.asarray(qs) @ np.asarray(cs).T, np.asarray(i_p), axis=1
    )
    assert_allclose(gathered, np.asarray(s_r), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("q", [1, 3, 5, 7, 9, 12, 17])
def test_retrieval_topk_small_q_block_alignment(q):
    """Regression: bq clamped to tiny/odd Q must round up to a multiple of
    8 (sublane alignment), never producing a ragged block shape."""
    kk = jax.random.PRNGKey(q)
    qs = jax.random.normal(kk, (q, 32))
    cs = jax.random.normal(jax.random.fold_in(kk, 1), (100, 32))
    s_p, i_p = retrieval_topk_pallas(qs, cs, 4, bn=64)
    s_r, i_r = retrieval_topk_ref(qs, cs, 4)
    assert s_p.shape == (q, 4) and i_p.shape == (q, 4)
    assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=1e-5, atol=1e-5)
    assert (np.asarray(i_p) == np.asarray(i_r)).all()


@pytest.mark.parametrize("q,n,k", [(5, 70, 4), (9, 130, 8)])
def test_retrieval_topk_bitonic_merge_matches_ref(q, n, k):
    """The TPU-side compare-exchange network must agree with the XLA sort
    merge and the oracle (indices included — tie-break parity)."""
    kk = jax.random.PRNGKey(q * n)
    qs = jax.random.normal(kk, (q, 16))
    cs = jax.random.normal(jax.random.fold_in(kk, 1), (n, 16))
    s_b, i_b = retrieval_topk_pallas(qs, cs, k, bq=8, bn=32, merge="bitonic")
    s_r, i_r = retrieval_topk_ref(qs, cs, k)
    assert_allclose(np.asarray(s_b), np.asarray(s_r), rtol=1e-5, atol=1e-5)
    assert (np.asarray(i_b) == np.asarray(i_r)).all()
