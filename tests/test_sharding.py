"""Sharding rules: divisibility filtering, axis dedup, policy behaviour."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.models.params import ParamSpec, spec_to_pspec
from repro.runtime.sharding import ShardingPolicy, base_rules, make_policy

SIZES = {"pod": 2, "data": 16, "model": 16}


def test_divisible_dims_get_sharded():
    s = ParamSpec((1024, 4096), ("embed", "mlp"))
    ps = spec_to_pspec(s, base_rules(False), SIZES)
    assert ps == P("data", "model")


def test_non_divisible_dims_stay_replicated():
    # smollm: 15 heads / 5 kv heads vs model=16
    s = ParamSpec((960, 15, 64), ("embed", "heads", "head_dim"))
    ps = spec_to_pspec(s, base_rules(False), SIZES)
    assert ps == P("data", None, None)


def test_mesh_axis_never_reused():
    s = ParamSpec((64, 4096, 4096), ("experts", "expert_in", "mlp"))
    rules = dict(base_rules(False), expert_in="model")  # force a conflict
    ps = spec_to_pspec(s, rules, SIZES)
    flat = [a for e in ps if e for a in ((e,) if isinstance(e, str) else e)]
    assert len(flat) == len(set(flat))


def test_multi_axis_batch_partial_divisibility():
    rules = base_rules(True)  # batch -> ("pod", "data"), 2*16=32
    pol = ShardingPolicy(rules=rules, mesh=None)
    # batch 32 divisible by both; batch 16 only by... 16%2==0 then 16%(2*16)!=0
    spec32 = pol.spec("act_batch", shape=(32,))
    assert spec32 == P(("pod", "data"))


@given(
    dim=st.integers(1, 4096),
    ax=st.sampled_from(["embed", "mlp", "vocab", "heads", "experts"]),
)
@settings(max_examples=40, deadline=None)
def test_filter_property_shard_divides(dim, ax):
    s = ParamSpec((dim,), (ax,))
    ps = spec_to_pspec(s, base_rules(False), SIZES)
    entry = ps[0]
    if entry is not None:
        axes = (entry,) if isinstance(entry, str) else entry
        fac = int(np.prod([SIZES[a] for a in axes]))
        assert dim % fac == 0, f"{dim} sharded by {fac}"


class _StubMesh:
    """Production-mesh stand-in (this CPU process only has 1 real device)."""

    shape = {"data": 16, "model": 16}


def test_policy_small_batch_replicates_and_reshards_cache():
    pol = make_policy(
        _StubMesh(), shape_kind="decode", global_batch=1, seq_len=1 << 19, long_context=True
    )
    assert pol.rules["act_batch"] is None  # batch 1 < dp 16 -> replicate
    assert pol.rules["cache_seq"] == "data"  # KV cache seq-sharded instead


def test_policy_normal_batch_keeps_data_sharding():
    pol = make_policy(_StubMesh(), shape_kind="decode", global_batch=128, seq_len=1 << 15)
    assert pol.rules["act_batch"] == ("data",)
    assert pol.rules["cache_seq"] is None
