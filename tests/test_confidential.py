"""Confidential-computing simulation: attestation policy, AEAD integrity,
replay protection, channel key agreement."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.confidential import (
    AttestationError,
    Enclave,
    IntegrityError,
    SecureChannel,
    aead_open,
    aead_seal,
    hkdf,
    measure,
    verify_report,
)


def test_attestation_accepts_expected_measurement():
    e = Enclave("orchestrator-v1")
    nonce = b"n" * 16
    verify_report(e.attest(nonce), measure("orchestrator-v1"), nonce)


def test_attestation_rejects_wrong_code():
    evil = Enclave("orchestrator-v1-TAMPERED")
    nonce = b"n" * 16
    with pytest.raises(AttestationError, match="measurement"):
        verify_report(evil.attest(nonce), measure("orchestrator-v1"), nonce)


def test_attestation_rejects_stale_nonce():
    e = Enclave("x")
    with pytest.raises(AttestationError, match="nonce"):
        verify_report(e.attest(b"a" * 16), e.measurement, b"b" * 16)


def test_attestation_rejects_forged_quote():
    e = Enclave("x")
    r = e.attest(b"n" * 16)
    forged = type(r)(r.measurement, r.nonce, r.dh_public, b"\x00" * 32)
    with pytest.raises(AttestationError, match="quote"):
        verify_report(forged, e.measurement, b"n" * 16)


@given(st.binary(min_size=0, max_size=500), st.binary(min_size=0, max_size=30))
@settings(max_examples=25, deadline=None)
def test_aead_roundtrip(msg, aad):
    key = hkdf(b"k", b"test")
    nonce = b"\x01" * 12
    assert aead_open(key, nonce, aead_seal(key, nonce, msg, aad), aad) == msg


def test_aead_detects_tamper():
    key = hkdf(b"k", b"test")
    sealed = bytearray(aead_seal(key, b"\x00" * 12, b"secret context chunk"))
    sealed[0] ^= 1
    with pytest.raises(IntegrityError):
        aead_open(key, b"\x00" * 12, bytes(sealed))


def test_aead_binds_aad():
    key = hkdf(b"k", b"test")
    sealed = aead_seal(key, b"\x00" * 12, b"msg", aad=b"query-1")
    with pytest.raises(IntegrityError):
        aead_open(key, b"\x00" * 12, sealed, aad=b"query-2")


def test_channel_duplex_and_replay():
    a, b = Enclave("orch"), Enclave("provider-0")
    ch_a = SecureChannel.establish(a, b, b.measurement)
    ch_b = SecureChannel.establish(b, a, a.measurement)
    n1, s1 = ch_a.seal(b"top-8 request")
    assert ch_b.open(n1, s1) == b"top-8 request"
    n2, s2 = ch_b.seal(b"chunks response")
    assert ch_a.open(n2, s2) == b"chunks response"
    with pytest.raises(IntegrityError, match="replay"):
        ch_b.open(n1, s1)  # replayed provider-bound message


def test_channel_keys_differ_per_direction():
    a, b = Enclave("orch"), Enclave("provider-0")
    ch_a = SecureChannel.establish(a, b, b.measurement)
    assert ch_a._ks != ch_a._kr
