"""Federation resilience layer (core/resilience.py).

Covers the hardened collect path end to end:

  * bit-parity overlay invariant — FaultyProvider wrappers at zero rates
    with retries/breaker/gate off produce collect results identical to
    the plain system (resilience must be pure overlay)
  * deterministic fault injection — same seed, same schedule, and every
    injected fault reconciles against an observed one in the health
    ledger (injected conn/timeout == observed; corrupt+replay ==
    observed integrity)
  * IntegrityError tolerance in BOTH dispatchers (the satellite-1
    regression: a tampering provider must cost only itself, not the
    round) + channel self-heal after transient corruption and after a
    provider-side re-key (sequence desync)
  * retry/backoff recovery and the deadline-budget guard
  * circuit breaker unit transitions (fake clock) and system-level
    skip/recovery of a dead provider
  * typed QuorumNotMet + degraded (never fatal) serve / serve_stream
  * ScoreGate: onset poisoning quarantined with provenance tags,
    honest-majority fallback when every provider looks poisoned
  * confidential-channel failure modes through handle_request ->
    concurrent _collect: replayed nonce, truncated ciphertext, flipped
    tag bytes
"""
import time

import numpy as np
import pytest

from _fake_lm import make_fake_engine
from repro.core.confidential import SecureChannel
from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.core.resilience import (
    BreakerPolicy,
    CircuitBreaker,
    FaultSpec,
    FaultyProvider,
    QuorumNotMet,
    RetryPolicy,
    ScoreGate,
)
from repro.data.corpus import make_federated_corpus


@pytest.fixture(scope="module")
def corpus():
    return make_federated_corpus(n_facts=48, n_distractors=48, n_queries=8, seed=5)


def build_system(corpus, fault_spec=None, **cfg_kw):
    kw = dict(
        split_by="corpus",  # 4 providers
        aggregation="embedding_rank",
        m_local=4,
        n_global=4,
        chunk_max_len=16,
    )
    kw.update(cfg_kw)
    return CFedRAGSystem(corpus, CFedRAGConfig(**kw), fault_spec=fault_spec)


# ------------------------------------------------------------------ #
# FaultSpec / policy units
# ------------------------------------------------------------------ #
def test_fault_spec_validation_and_json():
    spec = FaultSpec.from_json('{"seed": 3, "p_conn": 0.1, "p_corrupt": 0.05}')
    assert spec.seed == 3 and spec.p_conn == 0.1 and spec.p_corrupt == 0.05
    assert spec.total_rate == pytest.approx(0.15)
    with pytest.raises(ValueError, match="unknown"):
        FaultSpec.from_json('{"p_oops": 0.1}')
    with pytest.raises(ValueError, match="> 1"):
        FaultSpec(p_conn=0.7, p_timeout=0.7)


def test_retry_policy_backoff_is_exponential():
    r = RetryPolicy(max_attempts=4, backoff_s=0.01, backoff_mult=3.0)
    assert r.backoff(1) == pytest.approx(0.01)
    assert r.backoff(2) == pytest.approx(0.03)
    assert r.backoff(3) == pytest.approx(0.09)
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)


def test_circuit_breaker_state_machine():
    clk = [0.0]
    br = CircuitBreaker(
        BreakerPolicy(fail_threshold=2, cooldown_s=10.0), clock=lambda: clk[0]
    )
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "closed" and br.allow(), "one failure below threshold"
    br.record_failure()
    assert br.state == "open" and br.trips == 1
    assert not br.allow(), "open: requests skipped during cooldown"
    clk[0] = 10.0
    assert br.state == "half-open"
    assert br.allow(), "cooldown elapsed: one probe admitted"
    assert not br.allow(), "only a single half-open probe may be in flight"
    br.record_failure()
    assert br.state == "open" and br.trips == 2, "failed probe re-opens"
    clk[0] = 20.0
    assert br.allow()
    br.record_success()
    assert br.state == "closed" and br.allow() and br.allow()


def test_score_gate_unit_quarantine_and_history_hygiene():
    gate = ScoreGate(z_max=4.0, min_history=8)
    rng = np.random.default_rng(0)
    base = rng.normal(0.5, 0.1, size=8).astype(np.float32)
    keep, out = gate.admit(0, base)
    assert keep and np.array_equal(out, base), "cold start ranks raw scores"
    n_before = gate.snapshot()[0]["n"]
    keep, _ = gate.admit(0, base + np.float32(50.0))
    assert not keep, "outlier round quarantined once history is warm"
    assert gate.snapshot()[0]["n"] == n_before, "poison never folds into history"
    keep, out = gate.admit(0, base)
    assert keep, "honest scores still admitted after the attack"
    assert not np.array_equal(out, base), "warm history: scores are calibrated"


# ------------------------------------------------------------------ #
# bit-parity overlay invariant
# ------------------------------------------------------------------ #
def test_bit_parity_with_overlay_off(corpus):
    """FaultyProvider wrappers at zero rates + retries off + gate off:
    collect/aggregate results are bit-identical to the plain system."""
    texts = [q.text for q in corpus.queries[:4]]
    plain = build_system(corpus)
    wrapped = build_system(corpus, fault_spec=FaultSpec(seed=0))
    assert all(isinstance(p, FaultyProvider) for p in wrapped.providers)
    for conc in (False, True):
        plain.orchestrator.concurrent_collect = conc
        wrapped.orchestrator.concurrent_collect = conc
        ra = plain.orchestrator.collect_contexts_batch(texts)
        rb = wrapped.orchestrator.collect_contexts_batch(texts)
        assert len(ra) == len(rb) == 4
        for a, b in zip(ra, rb):
            for k in ("provider", "scores", "chunk_ids", "chunk_tokens"):
                assert np.array_equal(a[k], b[k]), (conc, k)
        ca = plain.orchestrator.aggregate_batch(texts, ra)
        cb = wrapped.orchestrator.aggregate_batch(texts, rb)
        for a, b in zip(ca, cb):
            assert "gated" not in a and "gated" not in b
            for k in ("chunk_ids", "scores", "providers"):
                assert np.array_equal(a[k], b[k])
    assert all(f == 0 for p in wrapped.providers for f in p.faults.values())


# ------------------------------------------------------------------ #
# deterministic injection + accounting
# ------------------------------------------------------------------ #
MIXED = FaultSpec(
    seed=7, p_conn=0.2, p_timeout=0.1, p_corrupt=0.1, p_replay=0.1, p_poison=0.05
)


def _run_rounds(sys_, texts, rounds):
    absorbed = 0
    for i in range(rounds):
        try:
            sys_.orchestrator.collect_contexts(texts[i % len(texts)])
        except QuorumNotMet:
            absorbed += 1
    return absorbed


def test_fault_schedule_is_deterministic(corpus):
    texts = [q.text for q in corpus.queries]
    runs = []
    for _ in range(2):
        sys_ = build_system(corpus, fault_spec=MIXED, quorum=1, retries=2,
                            retry_backoff_s=0.0)
        _run_rounds(sys_, texts, 8)
        runs.append([dict(p.faults) for p in sys_.orchestrator.providers])
    assert runs[0] == runs[1], "same seed must reproduce the fault schedule"
    assert sum(sum(f.values()) for f in runs[0]) > 0, "schedule actually fired"


@pytest.mark.parametrize("conc", [False, True])
def test_every_injected_fault_is_accounted(corpus, conc):
    """No deadline, so every worker finishes: the orchestrator's observed
    fault ledger must reconcile exactly against the wrapper's injected
    counters, and attempts == successes + faults."""
    texts = [q.text for q in corpus.queries]
    sys_ = build_system(corpus, fault_spec=MIXED, quorum=1, retries=2,
                        retry_backoff_s=0.0, concurrent_collect=conc)
    _run_rounds(sys_, texts, 10)
    stats = sys_.orchestrator.federation_stats()
    fired = 0
    for p in sys_.orchestrator.providers:
        d = stats["providers"][int(p.provider_id)]
        inj, obs = d["injected"], d["faults"]
        assert inj == dict(p.faults)
        assert obs["conn"] == inj["conn"]
        assert obs["timeout"] == inj["timeout"]
        assert obs["integrity"] == inj["corrupt"] + inj["replay"]
        assert d["attempts"] == d["successes"] + sum(obs.values())
        fired += sum(inj.values())
    assert fired > 0, "mixed spec must actually inject faults over 10 rounds"
    tot = stats["totals"]
    assert tot["attempts"] == sum(
        d["attempts"] for d in stats["providers"].values()
    )


# ------------------------------------------------------------------ #
# IntegrityError tolerance + channel self-heal (satellite 1)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("conc", [False, True])
def test_corrupting_provider_absorbed_by_quorum(corpus, conc):
    """A provider whose sealed payloads always arrive tampered fails only
    itself: both dispatchers must return the other providers' responses
    and count the IntegrityErrors per provider."""
    sys_ = build_system(corpus, quorum=1, concurrent_collect=conc)
    orch = sys_.orchestrator
    orch.providers[1] = FaultyProvider(
        orch.providers[1], FaultSpec(seed=0, p_corrupt=1.0)
    )
    text = corpus.queries[0].text
    responses = orch.collect_contexts(text)
    assert sorted(int(r["provider"]) for r in responses) == [0, 2, 3]
    h = orch.federation_stats()["providers"][1]
    # first exchange corrupts, the one-shot heal retry corrupts again
    assert h["faults"]["integrity"] == 2
    assert h["rechannels"] == 1
    assert h["successes"] == 0


def test_channel_self_heal_recovers_one_shot_corruption(corpus):
    """One tampered response: the orchestrator re-attests, re-establishes
    the channel, and retries within the SAME round — no provider lost."""
    sys_ = build_system(corpus, quorum=1, concurrent_collect=True)
    orch = sys_.orchestrator
    p = orch.providers[2]
    orig = p.handle_request
    state = {"fired": False}

    def corrupt_once(nonce, sealed):
        r_nonce, r_sealed = orig(nonce, sealed)
        if not state["fired"]:
            state["fired"] = True
            tampered = bytearray(r_sealed)
            tampered[len(tampered) // 2] ^= 0xFF
            return r_nonce, bytes(tampered)
        return r_nonce, r_sealed

    p.handle_request = corrupt_once
    responses = orch.collect_contexts(corpus.queries[0].text)
    assert sorted(int(r["provider"]) for r in responses) == [0, 1, 2, 3]
    h = orch.federation_stats()["providers"][2]
    assert h["rechannels"] == 1
    assert h["faults"]["integrity"] == 1
    assert h["successes"] == 1


def test_channel_self_heal_after_provider_rekey(corpus):
    """A provider that restarted (fresh channel, sequence numbers reset)
    answers with an already-seen nonce -> replay detection fires at the
    orchestrator; the self-heal re-establishes BOTH directions and the
    round succeeds."""
    sys_ = build_system(corpus, quorum=1)
    orch = sys_.orchestrator
    assert len(orch.collect_contexts(corpus.queries[0].text)) == 4  # advance seqs
    p = orch.providers[3]
    p.channel = SecureChannel.establish(
        p.enclave, orch.enclave, orch.enclave.measurement
    )
    responses = orch.collect_contexts(corpus.queries[1].text)
    assert sorted(int(r["provider"]) for r in responses) == [0, 1, 2, 3]
    h = orch.federation_stats()["providers"][3]
    assert h["rechannels"] == 1
    assert h["faults"]["integrity"] == 1


# ------------------------------------------------------------------ #
# retry / deadline budget
# ------------------------------------------------------------------ #
def test_retry_recovers_transiently_failing_provider(corpus):
    """A provider whose link drops every other request: with retries the
    round always completes with all 4 providers; without, it cannot."""
    sys_ = build_system(corpus, quorum=1, retries=2, retry_backoff_s=0.001)
    orch = sys_.orchestrator
    p = orch.providers[0]
    orig = p.handle_request
    calls = {"n": 0}

    def flaky(nonce, sealed):
        calls["n"] += 1
        if calls["n"] % 2 == 1:
            raise ConnectionError("transient link drop")
        return orig(nonce, sealed)

    p.handle_request = flaky
    for q in corpus.queries[:3]:
        assert len(orch.collect_contexts(q.text)) == 4
    h = orch.federation_stats()["providers"][0]
    assert h["retries"] == 3 and h["faults"]["conn"] == 3
    assert h["successes"] == 3 and h["attempts"] == 6


def test_retry_backoff_respects_deadline_budget(corpus):
    """Backoff comes OUT of the remaining deadline: a 5s backoff against a
    0.25s SLO must be skipped, not slept."""
    sys_ = build_system(
        corpus, quorum=1, retries=4, retry_backoff_s=5.0, deadline_s=0.25,
        concurrent_collect=False,
    )
    orch = sys_.orchestrator
    orch.providers[0].fail = True  # forwards to the inner provider
    t0 = time.monotonic()
    responses = orch.collect_contexts(corpus.queries[0].text)
    assert time.monotonic() - t0 < 2.0, "must not sleep the 5s backoff"
    assert sorted(int(r["provider"]) for r in responses) == [1, 2, 3]
    h = orch.federation_stats()["providers"][0]
    assert h["attempts"] == 1 and h["retries"] == 0


# ------------------------------------------------------------------ #
# circuit breaker in the collect path
# ------------------------------------------------------------------ #
def test_breaker_skips_dead_provider(corpus):
    sys_ = build_system(
        corpus, quorum=1, breaker=True, breaker_threshold=2,
        breaker_cooldown_s=60.0,
    )
    orch = sys_.orchestrator
    dead = orch.providers[0]
    dead.fail = True
    for q in corpus.queries[:5]:
        assert len(orch.collect_contexts(q.text)) == 3
    stats = orch.federation_stats()
    h = stats["providers"][0]
    assert h["attempts"] == 2, "threshold=2: two failed rounds, then open"
    assert h["skips"] == 3, "remaining rounds skipped without a round-trip"
    assert h["breaker"] == "open" and h["breaker_trips"] == 1
    assert dead.n_requests == 2, "skipped rounds never reach the provider"
    assert stats["totals"]["breakers_open"] == 1


def test_breaker_half_open_probe_recovers(corpus):
    """cooldown 0: every post-trip round is a half-open probe; once the
    provider comes back the probe closes the breaker and the provider
    rejoins the federation."""
    sys_ = build_system(
        corpus, quorum=1, breaker=True, breaker_threshold=2,
        breaker_cooldown_s=0.0,
    )
    orch = sys_.orchestrator
    p = orch.providers[0]
    p.fail = True
    for q in corpus.queries[:3]:  # 2 to trip + 1 failed probe
        orch.collect_contexts(q.text)
    br = orch.federation_stats()["providers"][0]
    assert br["breaker_trips"] == 2, "failed half-open probe re-opens"
    p.fail = False
    responses = orch.collect_contexts(corpus.queries[3].text)
    assert sorted(int(r["provider"]) for r in responses) == [0, 1, 2, 3]
    h = orch.federation_stats()["providers"][0]
    assert h["breaker"] == "closed" and h["successes"] == 1


# ------------------------------------------------------------------ #
# typed quorum failure + degraded serving
# ------------------------------------------------------------------ #
def test_quorum_not_met_is_typed_and_backward_compatible(corpus):
    sys_ = build_system(corpus, quorum=3)
    orch = sys_.orchestrator
    orch.providers[0].fail = True
    orch.providers[1].fail = True
    with pytest.raises(QuorumNotMet) as ei:
        orch.collect_contexts(corpus.queries[0].text)
    assert ei.value.arrived == 2 and ei.value.required == 3
    # legacy call sites catch RuntimeError with match="quorum"
    with pytest.raises(RuntimeError, match="quorum"):
        orch.collect_contexts(corpus.queries[0].text)


def test_serve_returns_degraded_results_on_quorum_failure(corpus):
    """serve never dies on quorum: every query gets a flagged degraded
    result (mirroring the ``truncated`` convention) and the federation
    ledger lands in last_serve_stats."""
    sys_ = build_system(corpus, quorum=4)
    for p in sys_.orchestrator.providers:
        p.fail = True
    texts = [q.text for q in corpus.queries[:3]]
    results = sys_.serve(texts)
    assert len(results) == 3
    for res in results:
        assert res["status"] == "degraded" and res["degraded"] is True
        assert res["n_providers"] == 0 and res["context"] is None
        assert "quorum" in res["error"]
    fed = sys_.last_serve_stats["federation"]
    assert fed["totals"]["faults"]["conn"] == 4
    # the raw batched API keeps raising: degradation is a serving-layer choice
    with pytest.raises(QuorumNotMet):
        sys_.answer_batch(texts)


def test_serve_stream_degrades_per_microbatch(corpus, monkeypatch):
    """Engine-backed stream: a micro-batch that misses quorum yields
    flagged degraded results for ITS queries only — earlier micro-batches
    decode and retire normally, one result per query either way."""
    from repro.serving.engine import engine_generator

    engine = make_fake_engine(monkeypatch, max_batch=2, max_new_tokens=4,
                              sched_chunk=2)
    sys_ = CFedRAGSystem(
        corpus,
        CFedRAGConfig(split_by="corpus", aggregation="embedding_rank",
                      m_local=4, n_global=4, chunk_max_len=16, quorum=1),
        generator=engine_generator(engine),
    )
    # every provider dies after its first (batched) request: micro-batch 1
    # collects cleanly, micro-batch 2 arrives to a dead federation
    for p in sys_.orchestrator.providers:
        orig = p.handle_request
        state = {"n": 0}

        def die_after_first(nonce, sealed, _orig=orig, _s=state):
            _s["n"] += 1
            if _s["n"] > 1:
                raise ConnectionError("provider went away")
            return _orig(nonce, sealed)

        p.handle_request = die_after_first
    texts = [q.text for q in corpus.queries[:6]]
    results = dict(sys_.serve_stream(texts, max_new_tokens=4, collect_batch=3))
    assert sorted(results) == list(range(6)), "one result per query"
    for qidx in (0, 1, 2):
        assert results[qidx]["status"] == "done"
        assert results[qidx]["n_providers"] == 4
    for qidx in (3, 4, 5):
        assert results[qidx]["status"] == "degraded"
        assert results[qidx]["degraded"] is True and results[qidx]["context"] is None
    assert sys_.last_serve_stats["federation"]["totals"]["faults"]["conn"] == 4


# ------------------------------------------------------------------ #
# poisoning gate in the aggregate path
# ------------------------------------------------------------------ #
def test_score_gate_quarantines_onset_poisoning(corpus):
    """A provider honest long enough to build a baseline, then inflating
    its scores: the round is quarantined, its chunks never reach the
    context, and the provenance tags say so."""
    sys_ = build_system(corpus, quorum=1, score_gate=True, m_local=8)
    orch = sys_.orchestrator
    warm = [q.text for q in corpus.queries[:2]]
    for t in warm:  # 2 rounds x m_local=8 -> min_history=16 per provider
        orch.aggregate(t, orch.collect_contexts(t))
    orch.providers[1] = FaultyProvider(
        orch.providers[1], FaultSpec(seed=0, p_poison=1.0, poison_scale=50.0)
    )
    text = corpus.queries[2].text
    ctx = orch.aggregate(text, orch.collect_contexts(text))
    assert ctx["gated"] == {"quarantined": [1], "calibrated": True}
    assert 1 not in ctx["providers"], "poisoned chunks never reach the context"
    stats = orch.federation_stats()
    h = stats["providers"][1]
    assert h["quarantined"] == 1 and h["dropped_chunks"] == 8
    assert h["injected"]["poison"] == 1
    assert stats["totals"]["score_gate"][1]["n"] == 16, "history unpolluted"


def test_score_gate_honest_majority_fallback(corpus):
    """If the gate would quarantine EVERY provider (global distribution
    shift, not a minority attacker), raw rounds are kept: the defense
    must not become its own denial of service."""
    sys_ = build_system(corpus, quorum=1, score_gate=True, m_local=8)
    orch = sys_.orchestrator
    for t in (q.text for q in corpus.queries[:2]):
        orch.aggregate(t, orch.collect_contexts(t))
    orch.providers = [
        FaultyProvider(p, FaultSpec(seed=0, p_poison=1.0)) for p in orch.providers
    ]
    text = corpus.queries[2].text
    ctx = orch.aggregate(text, orch.collect_contexts(text))
    assert ctx["gated"] == {"quarantined": [], "calibrated": False}
    assert len(ctx["chunk_ids"]) > 0
    stats = orch.federation_stats()
    assert stats["totals"]["quarantined"] == 0, "fallback does not count drops"


# ------------------------------------------------------------------ #
# channel failure modes e2e (satellite 3): replayed nonce, truncated
# ciphertext, flipped tag bytes -> handle_request -> concurrent collect
# ------------------------------------------------------------------ #
def test_channel_failure_modes_concurrent_collect(corpus):
    sys_ = build_system(corpus, quorum=1, concurrent_collect=True)
    orch = sys_.orchestrator

    def patch(p, mutate):
        orig = p.handle_request

        def h(nonce, sealed, _orig=orig, _m=mutate):
            return _m(*_orig(nonce, sealed))

        p.handle_request = h

    prev = {}

    def replay(n, s):  # provider 1: always re-send the previous response
        out = prev.get("r", (n, s))
        prev["r"] = (n, s)
        return out

    patch(orch.providers[1], replay)
    patch(orch.providers[2], lambda n, s: (n, s[: len(s) // 2]))  # truncated ct
    patch(
        orch.providers[3],
        lambda n, s: (n, s[:-1] + bytes([s[-1] ^ 0xFF])),  # flipped tag byte
    )
    # round 1: provider 1 replays its own first response only on round 2+
    r1 = orch.collect_contexts(corpus.queries[0].text)
    assert sorted(int(r["provider"]) for r in r1) == [0, 1]
    # round 2: the replayed round-1 nonce is behind the receive sequence
    # -> IntegrityError; the self-heal resets sequence numbers, so the
    # stale-but-authentic message verifies again and the round recovers
    r2 = orch.collect_contexts(corpus.queries[1].text)
    assert sorted(int(r["provider"]) for r in r2) == [0, 1]
    stats = orch.federation_stats()
    h1 = stats["providers"][1]
    assert h1["faults"]["integrity"] == 1, "replayed nonce detected"
    assert h1["rechannels"] == 1 and h1["successes"] == 2
    # truncated/tampered providers fail initial + heal-retry every round
    for pid in (2, 3):
        h = stats["providers"][pid]
        assert h["faults"]["integrity"] == 4 and h["rechannels"] == 2
        assert h["successes"] == 0
    assert stats["providers"][0]["successes"] == 2, "honest provider untouched"
