"""Sharded paged serving: distributed mixed dispatch + combine parity.

The tentpole contract under test: with the KV block pool partitioned
over the mesh ``data`` axis (row-affine allocation — every block of a
request lives on ONE shard), each engine step is a single distributed
mixed dispatch where non-owner shards mask every lane of a foreign row
to exact-zero partials and ``dist_decode.combine_partials`` passes the
owner's output through BITWISE.  So ``shards=4`` must equal ``shards=1``
bit-for-bit, and ``shards=1`` must match the unsharded engine token-for-
token, across block sizes, prefix cache on/off, and spec decode on/off.

Needs a multi-device host: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
"sharded-serving parity" step sets it); skips on fewer than 4 devices.
"""
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, smoke_config
from repro.kernels.chunked_prefill.ref import (
    mixed_prefill_attention_ref,
    mixed_prefill_partials,
)
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.models import lm as LM
from repro.models.params import init_params
from repro.runtime import compat
from repro.runtime.sharding import ShardingPolicy, base_rules
from repro.serving.dist_decode import combine_partials, dist_decode_attention
from repro.serving.engine import ServeConfig, ServeEngine
from repro.serving.scheduler import Scheduler

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >=4 host devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

POL = ShardingPolicy(rules=base_rules(False), mesh=None)


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(dtype="float32")
    params = init_params(LM.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _mesh(n):
    return compat.make_mesh(np.array(jax.devices()[:n]), ("data",))


# ------------------------------------------------------------------ #
# S2: the shared combine vs the decode-attention numpy oracle
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("n_shards", [2, 4])
def test_dist_decode_matches_oracle_ragged(n_shards):
    """Sequence-sharded flash decode through ``combine_partials`` equals
    the dense oracle under ragged lengths — including rows fully
    resident on shard 0 (every other shard's slice is zero-length) and
    rows whose valid keys end exactly on a shard boundary."""
    b, s, kv, g, dh = 6, 16, 2, 2, 8
    h = kv * g
    key = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, dh), jnp.float32)
    k_cache = jax.random.normal(kk, (b, s, kv, dh), jnp.float32)
    v_cache = jax.random.normal(kv_, (b, s, kv, dh), jnp.float32)
    shard_len = s // n_shards
    # row 0: one key; rows fully inside shard 0; a shard-boundary row;
    # a full row; the rest ragged
    lengths = jnp.array([1, shard_len - 1, shard_len, s, 3, s - 1], jnp.int32)
    got = dist_decode_attention(q, k_cache, v_cache, lengths, _mesh(n_shards))
    want = decode_attention_ref(q, k_cache, v_cache, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)


def test_combine_passes_owner_through_bitwise():
    """The bit-parity contract the sharded engine rests on: when exactly
    one shard holds finite partials and every other shard contributes
    the exact-zero triple (o=0, m=-1e30, l=0), the combine returns the
    owner's ``o / max(l, 1e-30)`` with not a single bit changed."""
    n_shards = 4
    mesh = _mesh(n_shards)
    rows, kv, g, dh = 8, 2, 2, 8
    key = jax.random.PRNGKey(7)
    ko, km, kl = jax.random.split(key, 3)
    o_own = jax.random.normal(ko, (rows, kv, g, dh), jnp.float32)
    m_own = jax.random.normal(km, (rows, kv, g, 1), jnp.float32)
    l_own = jax.random.uniform(kl, (rows, kv, g, 1), jnp.float32, 0.5, 4.0)
    owner = jnp.arange(rows, dtype=jnp.int32) % n_shards

    def body(o, m, l, owner):
        me = jax.lax.axis_index("data")
        mine = (owner == me)[:, None, None, None]
        o_s = jnp.where(mine, o, 0.0)
        m_s = jnp.where(mine, m, -1e30)
        l_s = jnp.where(mine, l, 0.0)
        return combine_partials(o_s, m_s, l_s, axis_name="data")

    fn = compat.shard_map(
        body, mesh=mesh, in_specs=(P(), P(), P(), P()), out_specs=P(),
        check_vma=False,
    )
    got = np.asarray(fn(o_own, m_own, l_own, owner))
    want = np.asarray(o_own / jnp.maximum(l_own, 1e-30))
    assert np.array_equal(got, want), "combine must pass the owner through bitwise"


def test_mixed_partials_owned_split_matches_full_ref():
    """``mixed_prefill_partials`` with complementary ``owned`` masks,
    merged by the same flash combine (numpy re-derivation), equals the
    unsplit mixed-prefill reference — the host-side model of what the
    shard_map'd dispatch computes."""
    rng = np.random.default_rng(3)
    b, w, kv, g, dh, bs, n_blk = 3, 4, 2, 2, 8, 4, 6
    h = kv * g
    n_pool = b * n_blk  # one trash block appended below
    q = jnp.asarray(rng.normal(size=(b, w, h, dh)), jnp.float32)
    k_pool = jnp.asarray(rng.normal(size=(n_pool + 1, bs, kv, dh)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(n_pool + 1, bs, kv, dh)), jnp.float32)
    tables = jnp.arange(n_pool, dtype=jnp.int32).reshape(b, n_blk)
    # ragged mixed rows: (slot, q_start, q_len, kv_len)
    desc = jnp.array(
        [[0, 5, 3, 8], [1, 0, 4, 4], [2, 9, 1, 10]], jnp.int32
    )
    want = mixed_prefill_attention_ref(q, k_pool, v_pool, tables, desc)
    # split pool blocks over two "shards" by parity of the block id
    parts = []
    for s in range(2):
        owned = (tables % 2) == s
        parts.append(mixed_prefill_partials(q, k_pool, v_pool, tables, desc, owned=owned))
    o = np.stack([np.asarray(p[0]) for p in parts])
    m = np.stack([np.asarray(p[1]) for p in parts])
    l = np.stack([np.asarray(p[2]) for p in parts])
    m_g = m.max(axis=0)
    scale = np.exp(m - m_g)
    l_g = (l * scale).sum(axis=0)
    o_g = (o * scale).sum(axis=0)
    got = o_g / np.maximum(l_g, 1e-30)
    rows, q_start, q_len = desc[:, 0], desc[:, 1], desc[:, 2]
    live = np.asarray(jnp.arange(w)[None, :] < q_len[:, None])
    np.testing.assert_allclose(
        got.transpose(0, 3, 1, 2, 4).reshape(b, w, h, dh)[live],
        np.asarray(want)[live], atol=1e-5, rtol=1e-5,
    )


# ------------------------------------------------------------------ #
# tentpole: sharded engine bit-parity across serving modes
# ------------------------------------------------------------------ #
_PROMPT_LENS = (9, 11, 6, 3, 11, 7)
_BUDGETS = [5, 1, 4, 5, 2, 5]


def _prompts(cfg, seed=42):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(8, cfg.vocab_size, size=n).astype(np.int32)
        for n in _PROMPT_LENS
    ]


def _serve(cfg, params, shards, **extra):
    # 16 pool blocks in BOTH arms (n_local=4 at shards=4, enough for a
    # max-size request on every shard) so the admission order is identical
    kw = dict(max_batch=2, max_prompt_len=11, max_new_tokens=5, sched_chunk=2,
              paged=True, n_pool_blocks=16, shards=shards, **extra)
    eng = ServeEngine(cfg, POL, params, ServeConfig(**kw))
    return eng.serve_prompts(_prompts(cfg), max_new_tokens=_BUDGETS), eng


@pytest.mark.parametrize("block_size", [4, 8, 16])
def test_sharded_matches_single_shard_bitwise(small_lm, block_size):
    """Acceptance: for the same admission order, shards=4 must produce
    shards=1's tokens BIT-identically — non-owner lanes are masked to
    the trash block and contribute exact zeros, so the combine is a
    bitwise pass-through of the owning shard."""
    cfg, params = small_lm
    want, _ = _serve(cfg, params, 1, block_size=block_size)
    got, eng = _serve(cfg, params, 4, block_size=block_size)
    for i, (w, g) in enumerate(zip(want, got)):
        assert np.array_equal(w, g), f"prompt {i}: shards=4 {list(g)} != shards=1 {list(w)}"
    assert eng._mesh is not None and eng._mesh.devices.size == 4


def test_single_shard_matches_unsharded_tokens(small_lm):
    """shards=1 runs the full distributed machinery on a 1-device mesh;
    its tokens must match the plain unified engine (token-level — the
    partials+combine form is a different reduction order than softmax)."""
    cfg, params = small_lm
    want, _ = _serve(cfg, params, None, block_size=4)
    got, _ = _serve(cfg, params, 1, block_size=4)
    for i, (w, g) in enumerate(zip(want, got)):
        assert np.array_equal(w, g), f"prompt {i}: shards=1 {list(g)} != unsharded {list(w)}"


def test_sharded_prefix_cache_matches_single_shard_bitwise(small_lm):
    """Prefix sharing composes with sharding: shared chains stay on
    their recorded shard, COW copies and re-admissions allocate there,
    and shards=4 still equals shards=1 bit-for-bit."""
    cfg, params = small_lm
    want, _ = _serve(cfg, params, 1, block_size=4, prefix_cache=True)
    got, _ = _serve(cfg, params, 4, block_size=4, prefix_cache=True)
    for i, (w, g) in enumerate(zip(want, got)):
        assert np.array_equal(w, g), f"prompt {i}: {list(g)} != {list(w)}"


def test_sharded_spec_decode_matches_single_shard_bitwise(small_lm):
    """Speculation's drafter pool is sharded the same way as the target
    pool; draft + verify rounds ride the distributed dispatch and stay
    bit-identical, and the drafter-occupancy gauges (S1) are visible."""
    cfg, params = small_lm
    want, _ = _serve(cfg, params, 1, block_size=4, draft_k=2, token_budget=5)
    got, eng = _serve(cfg, params, 4, block_size=4, draft_k=2, token_budget=5)
    for i, (w, g) in enumerate(zip(want, got)):
        assert np.array_equal(w, g), f"prompt {i}: {list(g)} != {list(w)}"
    assert eng.spec_rounds > 0
    # drafter occupancy is no longer invisible: serve through an explicit
    # scheduler and read the draft gauges back
    sched = Scheduler()
    sched.submit_many(_prompts(cfg), 3)
    eng2 = ServeEngine(cfg, POL, params, ServeConfig(
        max_batch=2, max_prompt_len=11, max_new_tokens=5, sched_chunk=2,
        paged=True, n_pool_blocks=16, block_size=4, shards=4, draft_k=2,
        token_budget=5))
    eng2.serve(sched)
    st = sched.latency_stats()
    assert "min_draft_free_blocks" in st and st["min_draft_free_blocks"] >= 0
    assert st["min_draft_free_blocks"] <= st["draft_free_blocks"]


def test_sharded_capacity_scales_with_shards(small_lm):
    """The point of the partition: at MATCHED per-shard HBM (same
    n_local), 4 shards hold 4x the pool and admit ~4x the concurrent
    slots, at bit-parity with the 1-shard engine on the same order."""
    cfg, params = small_lm
    bs = 4
    per_shard = 8  # blocks per shard, identical in both arms
    kw = dict(max_prompt_len=12, max_new_tokens=3, sched_chunk=2, paged=True,
              block_size=bs)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(8, cfg.vocab_size, size=6).astype(np.int32) for _ in range(12)]

    def run(shards, max_batch):
        eng = ServeEngine(cfg, POL, params, ServeConfig(
            max_batch=max_batch, n_pool_blocks=per_shard * shards, shards=shards, **kw))
        sched = Scheduler()
        sched.submit_many(prompts, 3)
        res = eng.serve(sched)
        st = sched.latency_stats()
        return res, eng.scfg.max_batch - st["min_free_slots"]

    res1, peak1 = run(1, 12)
    res4, peak4 = run(4, 12)
    for rid in range(len(prompts)):
        assert np.array_equal(res1[rid], res4[rid]), f"rid {rid} diverged"
    # 6+3 tokens = 3 blocks/request: shard arm 1 caps at 2 resident
    # requests, 4 shards fit 8+
    assert peak4 >= 3 * peak1, f"peak slots {peak4} < 3x single-shard {peak1}"
