"""Block-pool allocator invariants (unit + property tests).

The pool hands out integer block ids that the paged serving engine turns
into device scatter/gather indices, so the invariants here are the ones
cache correctness rests on: a block is never owned twice, alloc is
all-or-nothing, frees are loud on double-free, and allocation order is
deterministic (paged serving replays must be reproducible)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving.kv_cache import BlockPool, BlockPoolOOM, BlockTable, blocks_for


def test_blocks_for():
    assert blocks_for(1, 4) == 1
    assert blocks_for(4, 4) == 1
    assert blocks_for(5, 4) == 2
    assert blocks_for(0, 4) == 1  # a request always holds at least a block


def test_alloc_free_roundtrip():
    pool = BlockPool(4, 16)
    a = pool.alloc(2)
    b = pool.alloc(2)
    assert sorted(a + b) == [0, 1, 2, 3] and pool.free_blocks == 0
    assert not pool.can_alloc(1)
    pool.free(a)
    assert pool.free_blocks == 2
    # deterministic LIFO reuse: the just-freed blocks come back first
    assert pool.alloc(2) == a


def test_alloc_is_all_or_nothing():
    pool = BlockPool(3, 8)
    pool.alloc(2)
    with pytest.raises(BlockPoolOOM):
        pool.alloc(2)
    assert pool.free_blocks == 1  # the failed alloc took nothing
    assert pool.try_alloc(2) is None
    assert pool.try_alloc(1) is not None


def test_double_free_and_foreign_free_raise():
    pool = BlockPool(4, 8)
    ids = pool.alloc(2)
    pool.free(ids)
    with pytest.raises(ValueError, match="unowned"):
        pool.free(ids)  # double-free
    other = pool.alloc(1)
    with pytest.raises(ValueError, match="unowned"):
        pool.free([other[0], 99])  # foreign id
    with pytest.raises(ValueError, match="duplicate"):
        pool.free(other + other)
    assert other[0] in pool._owned  # rejected frees must not half-apply


def test_block_table_grow_and_release():
    pool = BlockPool(4, 8)
    tb = BlockTable(pool)
    assert tb.extend_to(5) and tb.n_blocks == 1  # ceil(5/8)
    assert tb.extend_to(8) and tb.n_blocks == 1  # already covered
    assert tb.extend_to(17) and tb.n_blocks == 3
    other = BlockTable(pool)
    assert other.extend_to(9) is False  # needs 2, pool has 1 -> nothing taken
    assert pool.free_blocks == 1
    tb.release()
    assert pool.free_blocks == 4 and tb.n_blocks == 0
    assert other.extend_to(9) and other.n_blocks == 2


@given(
    n_blocks=st.integers(1, 24),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_pool_random_traffic_invariants(n_blocks, seed):
    """Random alloc/free interleavings: no block is ever owned by two
    tables, counts conserve, and OOM never corrupts state."""
    import random

    rng = random.Random(seed)
    pool = BlockPool(n_blocks, 4)
    live: list[list[int]] = []
    for _ in range(200):
        if live and rng.random() < 0.4:
            ids = live.pop(rng.randrange(len(live)))
            pool.free(ids)
        else:
            want = rng.randint(1, max(1, n_blocks // 2))
            got = pool.try_alloc(want)
            if got is None:
                assert want > pool.free_blocks  # OOM only when truly short
            else:
                live.append(got)
        owned = [b for ids in live for b in ids]
        assert len(set(owned)) == len(owned), "block owned twice"
        assert pool.free_blocks + len(owned) == n_blocks, "blocks leaked"
        assert all(0 <= b < n_blocks for b in owned)
    for ids in live:
        pool.free(ids)
    assert pool.free_blocks == n_blocks
