"""Block-pool allocator + prefix-index invariants (unit + property tests).

The pool hands out integer block ids that the paged serving engine turns
into device scatter/gather indices, so the invariants here are the ones
cache correctness rests on: a block is never owned twice, refcounts never
go negative, alloc is all-or-nothing, frees are loud on double-free,
zero-ref blocks are always reclaimable (free list or parked), eviction
never touches a block with refcount > 0, and allocation order is
deterministic (paged serving replays must be reproducible)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving.kv_cache import (
    BlockPool,
    BlockPoolOOM,
    BlockTable,
    PrefixIndex,
    blocks_for,
)


def test_blocks_for():
    assert blocks_for(1, 4) == 1
    assert blocks_for(4, 4) == 1
    assert blocks_for(5, 4) == 2
    assert blocks_for(0, 4) == 1  # a request always holds at least a block


def test_alloc_free_roundtrip():
    pool = BlockPool(4, 16)
    a = pool.alloc(2)
    b = pool.alloc(2)
    assert sorted(a + b) == [0, 1, 2, 3] and pool.free_blocks == 0
    assert not pool.can_alloc(1)
    pool.free(a)
    assert pool.free_blocks == 2
    # deterministic LIFO reuse: the just-freed blocks come back first
    assert pool.alloc(2) == a


def test_alloc_is_all_or_nothing():
    pool = BlockPool(3, 8)
    pool.alloc(2)
    with pytest.raises(BlockPoolOOM):
        pool.alloc(2)
    assert pool.free_blocks == 1  # the failed alloc took nothing
    assert pool.try_alloc(2) is None
    assert pool.try_alloc(1) is not None


def test_double_free_and_foreign_free_raise():
    pool = BlockPool(4, 8)
    ids = pool.alloc(2)
    pool.free(ids)
    with pytest.raises(ValueError, match="unowned"):
        pool.free(ids)  # double-free
    other = pool.alloc(1)
    with pytest.raises(ValueError, match="unowned"):
        pool.free([other[0], 99])  # foreign id
    with pytest.raises(ValueError, match="below zero"):
        pool.free(other + other)  # one ref, two decrements in one call
    assert pool.refcount(other[0]) == 1  # rejected frees must not half-apply


def test_refcount_share_lifecycle():
    """share increments, free decrements, and the block only recycles at
    zero — two tables pointing at one prompt block both get to release."""
    pool = BlockPool(2, 4)
    (b,) = pool.alloc(1)
    pool.share([b])
    assert pool.refcount(b) == 2
    pool.free([b])  # first owner retires
    assert pool.refcount(b) == 1 and pool.free_blocks == 1
    pool.free([b])  # second owner retires -> recycled
    assert pool.refcount(b) == 0 and pool.free_blocks == 2
    with pytest.raises(ValueError, match="unowned"):
        pool.share([b])  # free blocks are not shareable


def test_cached_blocks_park_instead_of_recycling():
    """A zero-ref block a prefix index holds parks (contents preserved,
    reclaimable) instead of returning to the free list; reactivate brings
    it back at refcount 1."""
    pool = BlockPool(3, 4)
    (b,) = pool.alloc(1)
    pool.mark_cached(b)
    pool.free([b])
    assert pool.is_parked(b) and pool.reclaimable_blocks == 1
    assert pool.free_blocks == 2  # parked != free
    pool.reactivate([b])
    assert pool.refcount(b) == 1 and pool.reclaimable_blocks == 0
    pool.free([b])
    pool.recycle_parked(b)  # eviction endpoint
    assert pool.free_blocks == 3 and not pool.is_parked(b)
    with pytest.raises(ValueError, match="non-parked"):
        pool.recycle_parked(b)


def test_block_table_grow_and_release():
    pool = BlockPool(4, 8)
    tb = BlockTable(pool)
    assert tb.extend_to(5) and tb.n_blocks == 1  # ceil(5/8)
    assert tb.extend_to(8) and tb.n_blocks == 1  # already covered
    assert tb.extend_to(17) and tb.n_blocks == 3
    other = BlockTable(pool)
    assert other.extend_to(9) is False  # needs 2, pool has 1 -> nothing taken
    assert pool.free_blocks == 1
    tb.release()
    assert pool.free_blocks == 4 and tb.n_blocks == 0
    assert other.extend_to(9) and other.n_blocks == 2


# ------------------------------------------------------------------ #
# prefix index: trie lookup, plans, COW, LRU eviction
# ------------------------------------------------------------------ #
def _toks(*chunks):
    out = []
    for c in chunks:
        out.extend(c)
    return out


def test_prefix_lookup_longest_match_and_plan():
    pool = BlockPool(16, 4)
    idx = PrefixIndex(pool)
    A, B, C = (1, 1, 1, 1), (2, 2, 2, 2), (3, 3, 3, 3)
    # cold request: 10 tokens = 2 full chunks + tail
    p1 = idx.plan(_toks(A, B, (9, 9)))
    assert p1.start == 0 and p1.shared == [] and p1.cow_src is None
    assert p1.n_fresh == blocks_for(11, 4)
    t1, cow = idx.commit(p1)
    assert cow is None and len(t1) == p1.n_fresh
    # warm: same two chunks, different tail -> shares 2 blocks, starts at 8
    p2 = idx.plan(_toks(A, B, (7, 7, 7)))
    assert p2.start == 8 and p2.shared == t1[:2]
    # diverging second chunk -> only the first chunk matches
    p3 = idx.plan(_toks(A, C, (7,)))
    assert p3.start == 4 and p3.shared == t1[:1]
    # shorter than one chunk -> cold
    assert idx.plan([5, 5, 5]).start == 0


def test_prefix_full_match_plans_cow():
    """A full-prefix hit ending on a block boundary must recompute the
    last token and copy-on-write the boundary block, never mutate it."""
    pool = BlockPool(16, 4)
    idx = PrefixIndex(pool)
    A, B = (1, 2, 3, 4), (5, 6, 7, 8)
    t1, _ = idx.commit(idx.plan(_toks(A, B)))
    p = idx.plan(_toks(A, B))
    assert p.start == 7  # L - 1: one suffix token for first-decode logits
    assert p.shared == t1[:1] and p.cow_src == t1[1]
    table, cow_dst = idx.commit(p)
    assert cow_dst is not None and cow_dst != t1[1]
    assert table[0] == t1[0] and table[1] == cow_dst
    # the source comes back PINNED (+1) so same-pass pressure can never
    # evict it before the device copy; the engine unpins after the copy
    assert pool.refcount(t1[1]) == 2
    pool.free([p.cow_src])
    assert pool.refcount(t1[1]) == 1  # donor's own reference remains
    assert pool.refcount(t1[0]) == 2  # genuinely shared
    assert pool.refcount(cow_dst) == 1  # private copy


def test_prefix_eviction_is_lru_leaf_first_and_spares_owned():
    pool = BlockPool(4, 4)
    idx = PrefixIndex(pool)
    A, B, C = (1, 1, 1, 1), (2, 2, 2, 2), (3, 3, 3, 3)
    tAB, _ = idx.commit(idx.plan(_toks(A, B)))  # chain A -> B (3 blocks: +1 decode)
    # retire: both chunks park (cached), third block recycles
    pool.free(tAB)
    assert pool.reclaimable_blocks == 2 and pool.free_blocks == 2
    # C needs 3 blocks but only 2 are free -> pressure evicts exactly one
    # parked block, and it must be the LEAF (B): evicting the parent (A)
    # would orphan B's chain
    pC = idx.plan(_toks(C, (9, 9, 9, 9)))
    assert pC.shared == [] and pC.start == 0
    tC, _ = idx.commit(pC)
    assert len(tC) == 3
    assert idx.lookup(_toks(A)) and not idx.lookup(_toks(A, B))[1:], (
        "evicting under pressure must take the leaf (B), not the parent (A)"
    )
    # owned blocks are never evicted: C's chunk is cached AND owned; a
    # plan needing more than free+parked must simply fail
    assert idx.plan([7] * 16) is None  # needs 5 blocks, pool of 4
    pool.free(tC)


def test_prefix_plan_excludes_own_chain_from_reclaimable():
    """Feasibility must not count the plan's own parked chain as
    evictable headroom — sharing it and evicting it are exclusive."""
    pool = BlockPool(3, 4)
    idx = PrefixIndex(pool)
    A = (1, 1, 1, 1)
    tA, _ = idx.commit(idx.plan(_toks(A, (2, 2))))  # 3 blocks: A + tail + decode
    pool.free(tA)  # A parks; 2 recycle
    # warm request over A needs blocks_for(4+3+1)=2 fresh; free=2 -> ok
    p = idx.plan(_toks(A, (3, 3, 3)))
    assert p is not None and p.shared == [tA[0]]
    t2, _ = idx.commit(p)
    assert pool.refcount(tA[0]) == 1  # reactivated, not evicted
    pool.free(t2)


# ------------------------------------------------------------------ #
# property tests: random alloc/share/free/evict traffic
# ------------------------------------------------------------------ #
@given(
    n_shards=st.sampled_from([1, 2, 4]),
    blocks_per_shard=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_pool_random_traffic_invariants(n_shards, blocks_per_shard, seed):
    """Random alloc/share/free interleavings: refcounts never negative,
    no block simultaneously free and owned, counts conserve, OOM never
    corrupts state.  At ``n_shards > 1`` the per-shard partition holds
    throughout: every block id maps to exactly one shard, each shard's
    free list holds only its own ids, the per-shard free gauges sum to
    the global gauge, and every allocation lands wholly on one shard."""
    import random

    rng = random.Random(seed)
    n_blocks = n_shards * blocks_per_shard
    pool = BlockPool(n_blocks, 4, n_shards=n_shards)
    n_local = n_blocks // n_shards
    live: list[list[int]] = []  # tables; a block may appear in several
    for _ in range(200):
        r = rng.random()
        if live and r < 0.35:
            ids = live.pop(rng.randrange(len(live)))
            pool.free(ids)
        elif live and r < 0.5:
            src = rng.choice(live)  # share an existing table's blocks
            pool.share(src)
            live.append(list(src))
        else:
            want = rng.randint(1, max(1, n_blocks // 2))
            got = pool.try_alloc(want)
            if got is None:
                # OOM only when no single shard could host the request
                assert want > max(pool.free_blocks_by_shard)
            else:
                live.append(got)
                assert len({pool.shard_of(b) for b in got}) == 1, (
                    "an allocation must land wholly on one shard"
                )
        owned = {b for ids in live for b in ids}
        for b in owned:
            refs = sum(ids.count(b) for ids in live)
            assert pool.refcount(b) == refs, "refcount drifted from ownership"
        assert pool.free_blocks + len(owned) == n_blocks, "blocks leaked"
        assert not (set(pool._free) & owned), "block both free and owned"
        assert all(0 <= b < n_blocks for b in owned)
        # ---- per-shard partition invariants ----
        assert sum(pool.free_blocks_by_shard) == pool.free_blocks
        for s, fl in enumerate(pool._frees):
            assert all(pool.shard_of(b) == s for b in fl), (
                "free list holds a block owned by another shard"
            )
        assert all(pool.shard_of(b) == b // n_local for b in range(n_blocks))
    for ids in live:
        pool.free(ids)
    assert pool.free_blocks == n_blocks
    assert pool.free_blocks_by_shard == [n_local] * n_shards


@given(
    n_blocks=st.integers(2, 20),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_prefix_index_random_traffic_invariants(n_blocks, seed):
    """Random admit (plan/commit) + retire traffic through the prefix
    index: refcounts match table multiplicity, zero-ref blocks are always
    reclaimable (free or parked), eviction only ever recycled zero-ref
    blocks, and every cached chain stays reachable from the root."""
    import random

    rng = random.Random(seed)
    bs = 4
    pool = BlockPool(n_blocks, bs)
    idx = PrefixIndex(pool)
    vocab = [(i, i, i, i) for i in range(1, 5)]  # few chunks -> real reuse
    tables: list[list[int]] = []
    for _ in range(150):
        if tables and rng.random() < 0.45:
            pool.free(tables.pop(rng.randrange(len(tables))))
        else:
            chunks = [rng.choice(vocab) for _ in range(rng.randint(0, 2))]
            tail = [9] * rng.randint(1, bs - 1) if rng.random() < 0.7 else []
            tokens = _toks(*chunks) + tail
            if not tokens:
                continue
            plan = idx.plan(tokens)
            if plan is None:
                # a None plan must mean GENUINE infeasibility: fresh
                # blocks needed beyond the matched chain exceed free +
                # reclaimable-outside-the-chain (independent re-derivation
                # of plan()'s arithmetic)
                nodes = idx.lookup(tokens)
                cow = bool(nodes) and len(nodes) * bs == len(tokens)
                n_shared = len(nodes) - 1 if cow else len(nodes)
                need = blocks_for(len(tokens) + 1, bs) - n_shared
                pinned = {n.block for n in nodes}
                outside = sum(1 for b in pool._parked if b not in pinned)
                assert need > pool.free_blocks + outside, (
                    "plan returned None while the pool could satisfy it"
                )
                continue
            table, cow_dst = idx.commit(plan)
            if cow_dst is not None:
                pool.free([plan.cow_src])  # unpin, as the engine does post-copy
            assert len(table) == blocks_for(len(tokens) + 1, bs)
            tables.append(table)
        # ---- invariants ----
        owned = {b for t in tables for b in t}
        for b in owned:
            refs = sum(t.count(b) for t in tables)
            assert pool.refcount(b) == refs, "refcount != table multiplicity"
        free, parked = set(pool._free), set(pool._parked)
        assert not (free & owned) and not (parked & owned)
        assert not (free & parked)
        assert len(free) + len(parked) + len(owned) == n_blocks, (
            "every block must be exactly one of free/parked/owned"
        )
        # every cached block reachable root-first, parents cached too
        for b, node in idx._node_of_block.items():
            assert node.block == b
            walk = node
            while walk.parent is not None:
                assert walk.parent.children.get(walk.chunk) is walk
                walk = walk.parent
        # parked blocks are all cached (reclaimable by eviction)
        assert parked <= pool._cached
    for t in tables:
        pool.free(t)
    # drain the cache: every parked block must be evictable leaf-by-leaf
    while pool.reclaimable_blocks:
        assert idx.evict_one(), "zero-ref cached block not reclaimable"
    assert pool.free_blocks == n_blocks


# ------------------------------------------------------------------ #
# sharded pool: partition semantics + row-affine allocation
# ------------------------------------------------------------------ #
def test_sharded_pool_partition_and_alloc_affinity():
    """n_shards partitions the id space into contiguous ranges; a shard
    arg pins allocation, no arg picks the shard with the most headroom,
    and a shard-local OOM raises even when the GLOBAL pool has room —
    requests never span shards."""
    with pytest.raises(ValueError, match="divide"):
        BlockPool(6, 4, n_shards=4)
    pool = BlockPool(8, 4, n_shards=2)
    assert pool.free_blocks_by_shard == [4, 4]
    assert [pool.shard_of(b) for b in range(8)] == [0] * 4 + [1] * 4
    a = pool.alloc(3, shard=1)
    assert all(pool.shard_of(b) == 1 for b in a)
    b = pool.alloc(2)  # unpinned -> shard 0 has more headroom now
    assert all(pool.shard_of(x) == 0 for x in b)
    # shard 1 has 1 free block: a 2-block alloc there must refuse even
    # though the pool holds 3 free blocks globally
    assert not pool.can_alloc(2, shard=1)
    with pytest.raises(BlockPoolOOM):
        pool.alloc(2, shard=1)
    assert pool.free_blocks == 3  # failed alloc took nothing
    pool.free(a)
    pool.free(b)
    assert pool.free_blocks_by_shard == [4, 4]


def test_sharded_readmission_lands_on_recorded_shard():
    """Demote a chain that lived on shard 1, then re-admit it under a
    warm hit: the fresh device blocks must come from shard 1 again (the
    node records its owning shard across the spill round-trip)."""
    pool, store, idx = _tiered(8, n_shards=2)
    A, B = (1, 1, 1, 1), (2, 2, 2, 2)
    p = idx.plan(_toks(A, B) + [9])
    p.shard = 1  # pin the cold chain to shard 1
    t1, _ = idx.commit(p)
    assert all(pool.shard_of(b) == 1 for b in t1)
    pool.free(t1)  # A, B park on shard 1
    assert idx.evict_one() and idx.evict_one()  # demote leaf B, then A
    assert idx.n_spilled == 2 and pool.free_blocks_by_shard == [4, 4]
    warm = idx.plan(_toks(A, B) + [5])
    assert warm is not None and warm.shard == 1
    assert [n.chunk for n in warm.readmit] == [A, B]
    t2, _ = idx.commit(warm)
    assert all(pool.shard_of(b) == 1 for b in t2), (
        "re-admitted chain must land back on its recorded shard"
    )
    pool.free(t2)


# ------------------------------------------------------------------ #
# host tier: bounded spill store + demote / re-admit lifecycle
# ------------------------------------------------------------------ #
def _tiered(n_blocks, bs=4, max_bytes=1024, nbytes=8, n_shards=1):
    """Pool + store + index wired the way the engine does it, with a
    fetch_block that returns the chunk's own tokens as the 'payload' so
    tests can check demote->re-admit round-trips content-identically."""
    from repro.serving.kv_cache import HostBlockStore

    pool = BlockPool(n_blocks, bs, n_shards=n_shards)
    store = HostBlockStore(max_bytes)
    idx = PrefixIndex(
        pool, spill_store=store,
        fetch_block=lambda b: (idx._node_of_block[b].chunk, nbytes),
    )
    return pool, store, idx


def test_host_store_put_peek_pop_and_byte_bound():
    from repro.serving.kv_cache import HostBlockStore

    with pytest.raises(ValueError, match="positive byte budget"):
        HostBlockStore(0)
    store = HostBlockStore(100)
    assert store.put("a", "PA", 60)
    with pytest.raises(ValueError, match="duplicate"):
        store.put("a", "PA", 1)
    assert "a" in store and len(store) == 1 and store.peek("a") == "PA"
    assert not store.put("big", "PB", 101)  # can never fit the budget
    assert not store.put("b", "PB", 60)  # would overflow, no evictor to help
    assert store.used_bytes == 60 and store.n_puts == 1
    assert store.pop("a") == "PA" and store.used_bytes == 0 and len(store) == 0


def test_demotion_then_readmission_roundtrips_content():
    """Pool pressure demotes the LRU parked leaf to the host store; a
    later prefix hit re-admits it onto a fresh device block with the
    exact payload the demotion fetched (never recomputed)."""
    pool, store, idx = _tiered(4)
    A, B = (1, 1, 1, 1), (2, 2, 2, 2)
    tAB, _ = idx.commit(idx.plan(_toks(A, B) + [9]))  # 3 blocks: A, B, tail
    pool.free(tAB)  # A and B park, tail block recycles
    # a cold 9-token request needs 3 fresh blocks; free=1 -> demote leaf B
    tC, _ = idx.commit(idx.plan([7] * 9))
    assert idx.n_demotions == 1 and idx.n_spilled == 1
    assert len(store) == 1 and store.used_bytes == 8
    assert idx.lookup(_toks(A, B))[1].block is None, "leaf B must spill, not parent A"
    pool.free(tC)
    # warm request over A+B: A shares on-device, B re-admits from host
    p = idx.plan(_toks(A, B) + [5, 5])
    assert p is not None and p.start == 8
    assert p.shared == [tAB[0]] and [n.chunk for n in p.readmit] == [B]
    t2, cow = idx.commit(p)
    assert cow is None and idx.n_readmits == 1
    # B is back on device (its own alloc pressure may have demoted OTHER
    # parked chunks — that's the tier working, not a failure)
    assert all(n.chunk != B for n in idx._spilled)
    assert idx.lookup(_toks(A, B))[1].block == t2[1]
    assert p.uploads[0] == (B, t2[1]), "payload must be the demoted chunk, verbatim"
    assert t2[0] == tAB[0]
    pool.free(t2)


def test_spilled_boundary_chunk_uploads_as_host_cow():
    """A full-prefix hit whose boundary chunk is spilled needs no device
    copy: the host payload uploads straight into the request's private
    block and the spilled entry stays authoritative."""
    pool, store, idx = _tiered(4)
    A, B = (1, 1, 1, 1), (2, 2, 2, 2)
    tAB, _ = idx.commit(idx.plan(_toks(A, B)))
    pool.free(tAB)
    tC, _ = idx.commit(idx.plan([7] * 9))  # demotes leaf B
    pool.free(tC)
    while pool.reclaimable_blocks:  # clear C's parked chunks off-device too
        assert idx.evict_one()
    p = idx.plan(_toks(A, B))
    assert p is not None and p.host_cow and p.cow_src is None
    assert p.start == len(_toks(A, B)) - 1
    t2, cow_dst = idx.commit(p)
    assert cow_dst is not None and t2[1] == cow_dst
    assert (B, cow_dst) in p.uploads
    assert idx.lookup(_toks(A, B))[1].block is None, "spilled entry stays authoritative"
    assert any(n.chunk == B for n in idx._spilled) and len(store) >= 1
    pool.free(t2)


def test_store_pressure_drops_lru_spilled_leaf():
    """An over-budget put makes room by dropping the LRU spilled LEAF;
    a store too small for even one chunk forces plain eviction instead
    (chunk gone from the trie, no demotion counted)."""
    # store holds exactly one 8-byte chunk: demoting a second drops the first
    pool, store, idx = _tiered(4, max_bytes=8)
    A, B = (1, 1, 1, 1), (2, 2, 2, 2)
    tAB, _ = idx.commit(idx.plan(_toks(A, B)))
    pool.free(tAB)
    assert idx.evict_one()  # demote leaf B -> store full
    assert idx.evict_one()  # demote A: store drops spilled leaf B to make room
    assert store.n_drops == 1 and idx.n_demotions == 2 and idx.n_spilled == 1
    assert idx.lookup(_toks(A, B)) and len(idx.lookup(_toks(A, B))) == 1, (
        "dropped chunk B must leave the trie; A survives spilled"
    )
    assert pool.free_blocks == 4
    # a store that cannot fit ANY chunk degenerates to plain eviction
    pool2, store2, idx2 = _tiered(4, max_bytes=4, nbytes=8)
    t, _ = idx2.commit(idx2.plan(_toks(A)))
    pool2.free(t)
    assert idx2.evict_one()
    assert idx2.n_demotions == 0 and idx2.n_spilled == 0 and store2.n_puts == 0
    assert idx2.lookup(_toks(A)) == []


@given(
    n_shards=st.sampled_from([1, 2]),
    blocks_per_shard=st.integers(2, 8),
    store_chunks=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_tiered_prefix_index_random_traffic_invariants(
    n_shards, blocks_per_shard, store_chunks, seed
):
    """Random admit/retire traffic over a SPILL-TIERED index: every
    device block is exactly one of free/parked/owned; every cached chunk
    is exactly one of device-backed or spilled; the host store never
    exceeds its byte budget; spilled nodes never have device-resident
    children (leaf-first across the tier boundary); and every re-admitted
    payload is byte-identical to what demotion fetched.  With a sharded
    pool, allocation stays row-affine (every committed table lives on
    one shard) and re-admission lands on each node's RECORDED owning
    shard — the coordinate survives the demotion round-trip."""
    import random

    rng = random.Random(seed)
    bs, nbytes = 4, 16
    n_blocks = n_shards * blocks_per_shard
    pool, store, idx = _tiered(n_blocks, bs=bs, max_bytes=store_chunks * nbytes,
                               nbytes=nbytes, n_shards=n_shards)
    vocab = [(i, i, i, i) for i in range(1, 5)]
    tables: list[list[int]] = []
    for _ in range(150):
        if tables and rng.random() < 0.45:
            pool.free(tables.pop(rng.randrange(len(tables))))
        else:
            chunks = [rng.choice(vocab) for _ in range(rng.randint(0, 2))]
            tail = [9] * rng.randint(1, bs - 1) if rng.random() < 0.7 else []
            tokens = _toks(*chunks) + tail
            if not tokens:
                continue
            plan = idx.plan(tokens)
            if plan is None:
                continue
            recorded = [n.shard for n in plan.readmit]
            table, cow_dst = idx.commit(plan)
            assert len({pool.shard_of(b) for b in table}) == 1, (
                "row affinity: a committed table must live on one shard"
            )
            assert [pool.shard_of(n.block) for n in plan.readmit] == recorded, (
                "re-admission must land on the recorded owning shard"
            )
            # re-admitted payloads come back verbatim (fetch_block stored
            # the chunk's own tokens, so identity is checkable)
            n_r = len(plan.readmit)
            assert [p for p, _ in plan.uploads[:n_r]] == [n.chunk for n in plan.readmit]
            if plan.host_cow:
                assert plan.uploads[n_r][0] == plan.cow_node.chunk
                assert plan.uploads[n_r][1] == cow_dst
            if cow_dst is not None and plan.cow_src is not None:
                pool.free([plan.cow_src])  # unpin, as the engine does post-copy
            assert len(table) == blocks_for(len(tokens) + 1, bs)
            tables.append(table)
        # ---- invariants ----
        owned = {b for t in tables for b in t}
        free, parked = set(pool._free), set(pool._parked)
        assert not (free & owned) and not (parked & owned) and not (free & parked)
        assert len(free) + len(parked) + len(owned) == n_blocks, (
            "every device block must be exactly one of free/parked/owned"
        )
        device_nodes = set(idx._node_of_block.values())
        assert not (device_nodes & idx._spilled), (
            "a cached chunk must be exactly one of device-backed or spilled"
        )
        for node in device_nodes:
            assert node.block is not None
            assert node.shard == pool.shard_of(node.block), (
                "recorded shard coordinate drifted from the block's owner"
            )
        assert sum(pool.free_blocks_by_shard) == pool.free_blocks
        assert 0 <= store.used_bytes <= store.max_bytes, "store blew its byte bound"
        assert store.used_bytes == nbytes * len(store)
        for node in idx._spilled:
            assert node.block is None and node in store
            assert all(c.block is None for c in node.children.values()), (
                "spilled chunk with a device-resident child breaks leaf-first"
            )
    for t in tables:
        pool.free(t)
    while pool.reclaimable_blocks:
        assert idx.evict_one(), "zero-ref cached block not reclaimable"
    assert pool.free_blocks == n_blocks
