"""Quickstart: stand up a 2-site C-FedRAG system and answer queries.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's Algorithm 1 end to end on the synthetic provenance
corpus: providers vectorize their shards, the enclave orchestrator
broadcasts a query over attested channels, collects local top-8s,
re-ranks 16 -> 8 in-enclave, and reports whether the gold evidence made
the context window.
"""
import sys

sys.path.insert(0, "src")

from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus
from repro.data.tokenizer import HashTokenizer
from repro.launch.serve import overlap_reranker


def main():
    print("building federated corpus (4 corpora x 2 sites, known provenance)...")
    corpus = make_federated_corpus(n_facts=128, n_distractors=128, n_queries=20)
    tok = HashTokenizer()

    print("standing up providers + enclave orchestrator (mutual attestation)...")
    system = CFedRAGSystem(
        corpus,
        CFedRAGConfig(aggregation="rerank", m_local=8, n_global=8),
        tokenizer=tok,
        reranker=overlap_reranker(tok),
    )
    for p in system.providers:
        print(f"  provider {p.provider_id}: {p.list_products()}")

    print("\nanswering queries through the confidential pipeline:")
    for q in corpus.queries[:5]:
        res = system.orchestrator.answer(q.text)
        ids = list(res["context"]["chunk_ids"])
        hit = q.gold_chunk_id in ids
        srcs = sorted(set(int(x) for x in res["context"]["providers"]))
        print(
            f"  {q.text!r:44s} -> gold in context: {'YES' if hit else 'no '}"
            f"  (context from providers {srcs}, {res['context']['n_candidates']} candidates)"
        )

    stats = system.eval_retrieval(20)
    print(f"\nrecall@8 = {stats['recall_at_n']:.3f}   MRR = {stats['mrr']:.3f}")
    print("done — see examples/federated_medqa.py for the trained end-to-end variant.")


if __name__ == "__main__":
    main()
