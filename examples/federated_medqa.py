"""End-to-end driver: TRAIN a ~1M-param generator for a few hundred steps
on the grounding/copy stream, then SERVE it as F_inf inside the C-FedRAG
pipeline and measure end-to-end QA exact-match with vs without federated
retrieval — the full paper loop (train -> retrieve -> re-rank -> generate)
at CPU scale.

    PYTHONPATH=src python examples/federated_medqa.py --steps 300

Also exercises checkpoint/restart: the trainer checkpoints every 50 steps
and `--resume auto` continues a killed run.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus
from repro.data.pipeline import LMBatchStream
from repro.data.tokenizer import ANS, HashTokenizer
from repro.launch.serve import overlap_reranker
from repro.models import lm as LM
from repro.optim.optimizers import cosine_schedule, get_optimizer
from repro.runtime.sharding import ShardingPolicy, base_rules
from repro.runtime.train_loop import Trainer, TrainerConfig

POL = ShardingPolicy(rules=base_rules(False), mesh=None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--ckpt-dir", default="/tmp/medqa_ckpt")
    ap.add_argument("--queries", type=int, default=24)
    args = ap.parse_args()

    tok = HashTokenizer(2048)
    cfg = (
        smoke_config(get_config("qwen3-0.6b"))
        .with_overrides(vocab_size=2048, n_layers=4, d_model=128, n_heads=4,
                        n_kv_heads=2, head_dim=32, d_ff=256)
    )

    print(f"1) training the generator ({args.steps} steps on the grounding stream)...")
    stream = LMBatchStream(args.batch, args.seq, cfg.vocab_size, seed=3, copy_task_frac=0.8)
    trainer = Trainer(
        cfg, POL, get_optimizer("adamw"), stream,
        TrainerConfig(total_steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir),
        lr_fn=cosine_schedule(3e-3, 20, args.steps),
    )
    params, _ = trainer.run(resume="auto")
    print(f"   loss: {trainer.metrics_log[0]['loss']:.3f} -> {trainer.metrics_log[-1]['loss']:.3f}")

    print("2) standing up C-FedRAG with the trained generator as F_inf...")
    corpus = make_federated_corpus(n_facts=128, n_distractors=128, n_queries=args.queries, seed=2)

    def generator(prompt_tokens: np.ndarray) -> np.ndarray:
        return np.asarray(
            LM.generate(cfg, POL, params, {"tokens": jnp.asarray(prompt_tokens)}, n_tokens=2)
        )

    system = CFedRAGSystem(
        corpus, CFedRAGConfig(aggregation="rerank"), tokenizer=tok,
        reranker=overlap_reranker(tok), generator=generator,
    )

    print("3) end-to-end QA: answer exact-match with vs without retrieval")
    em_rag, em_norag, recall = 0, 0, 0
    for q in corpus.queries[: args.queries]:
        ans_tok = tok.token(q.answer)
        res = system.orchestrator.answer(q.text)
        recall += q.gold_chunk_id in list(res["context"]["chunk_ids"])
        em_rag += int(res["answer_tokens"][0] == ans_tok)
        # no-RAG: query-only prompt
        bare = system.orchestrator.build_prompt(q.text, {"chunk_tokens": np.zeros((0, 1), np.int32)})
        em_norag += int(generator(bare)[0][0] == ans_tok)
    n = args.queries
    print(f"   recall@8 = {recall/n:.3f}")
    print(f"   answer EM with C-FedRAG   : {em_rag/n:.3f}")
    print(f"   answer EM without retrieval: {em_norag/n:.3f}")
    if em_rag > em_norag:
        print("   -> retrieval grounding improves generation (paper Table 1 direction)")
    else:
        print("   -> (CPU-scale model too weak to exploit context at this budget; "
              "recall@8 above is the retrieval-quality signal)")


if __name__ == "__main__":
    main()
