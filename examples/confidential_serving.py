"""Confidential serving drill: attestation policy, sealed transport,
straggler/failure tolerance, and the privacy filters — the paper's §2.3
security story exercised end to end.

    PYTHONPATH=src python examples/confidential_serving.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.confidential import AttestationError, Enclave, SecureChannel, measure
from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus
from repro.data.tokenizer import HashTokenizer


def main():
    corpus = make_federated_corpus(n_facts=96, n_distractors=96, n_queries=12)
    system = CFedRAGSystem(corpus, CFedRAGConfig(aggregation="embedding_rank"))

    print("1) attestation policy: a tampered orchestrator is rejected")
    provider = system.providers[0]
    evil = Enclave("cfedrag-orchestrator-v1-BACKDOORED")
    try:
        SecureChannel.establish(
            provider.enclave, evil, measure("cfedrag-orchestrator-v1")
        )
        print("   !! accepted (BUG)")
    except AttestationError as e:
        print(f"   rejected as expected: {e}")

    print("\n2) sealed transport: orchestrator->provider payloads are AEAD-protected")
    q = corpus.queries[0]
    res = system.orchestrator.answer(q.text)
    print(f"   query answered via {res['n_providers']} attested channels; "
          f"context window = {len(res['context']['chunk_ids'])} chunks")

    print("\n3) straggler mitigation (Alg. 1: k_n <= k): kill site 1, keep serving")
    system.providers[1].fail = True
    ok, n = 0, 8
    for q in corpus.queries[:n]:
        r = system.orchestrator.answer(q.text)
        ok += q.gold_chunk_id in list(r["context"]["chunk_ids"])
    print(f"   with 1/2 sites down: answered {n}/{n} queries, recall@8 = {ok/n:.2f} "
          f"(degraded but alive)")
    system.providers[1].fail = False

    print("\n4) privacy filters: what actually leaves a provider")
    payload = system.providers[0].retrieve(
        HashTokenizer().encode(q.text, max_len=24), 4
    )
    print(f"   outbound payload keys: {sorted(payload.keys())} (provenance stripped)")

    print("\nall confidential-path drills passed.")


if __name__ == "__main__":
    main()
