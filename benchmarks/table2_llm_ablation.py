"""Table 2 reproduction: generator-LLM ablation.

The paper ablates the inference LLM (LLaMA-3/3.1/3.2 at 1B/3B/8B) under
CoT.  Offline stand-in: train reduced same-family generators of three
sizes on the identical copy-task stream for a fixed step budget and report
(a) final LM loss and (b) RAG-style copy-answer exact-match — showing the
same monotone capability ordering the paper's Table 2 shows, on compute
honest for CPU."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data.pipeline import LMBatchStream
from repro.data.tokenizer import ANS, QRY
from repro.models import lm as LM
from repro.models.params import init_params, param_count
from repro.optim.optimizers import get_optimizer
from repro.runtime.sharding import ShardingPolicy, base_rules
from repro.runtime.steps import make_train_step

POL = ShardingPolicy(rules=base_rules(False), mesh=None)

SIZES = {
    "tiny-1L": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128),
    "small-4L": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256),
    "base-6L": dict(n_layers=6, d_model=192, n_heads=6, n_kv_heads=2, head_dim=32, d_ff=384),
}


def copy_em(cfg, params, n=64, seq=64, seed=9):
    """exact-match of the copy-task answer (retrieval-grounding proxy)."""
    stream = LMBatchStream(n, seq, cfg.vocab_size, seed=seed, copy_task_frac=1.0)
    b = stream.next()
    logits, _ = LM.forward(cfg, POL, params, {"tokens": jnp.asarray(b["tokens"])})
    pred = np.asarray(jnp.argmax(logits, -1))
    hits, total = 0, 0
    for i in range(n):
        row = b["tokens"][i]
        tgt = b["targets"][i]
        ans_pos = np.where(row == ANS)[0]
        if len(ans_pos) == 0:
            continue
        p = int(ans_pos[0])
        total += 1
        hits += int(pred[i, p] == tgt[p])
    return hits / max(total, 1)


def run(steps=150, batch=16, seq=48):
    base = smoke_config(get_config("qwen3-0.6b")).with_overrides(vocab_size=256)
    rows = []
    for name, kw in SIZES.items():
        cfg = base.with_overrides(**kw)
        params = init_params(LM.param_specs(cfg), jax.random.PRNGKey(0))
        n_params = param_count(LM.param_specs(cfg))
        opt = get_optimizer("adamw")
        state = opt.init(params)
        step = jax.jit(make_train_step(cfg, POL, opt, lambda s: 3e-3))
        # fixed random bigram language: achievable CE is capacity-bounded
        stream = LMBatchStream(batch, seq, cfg.vocab_size, seed=1, copy_task_frac=0.0)
        t0 = time.monotonic()
        losses = []
        for i in range(steps):
            params, state, m = step(params, state, {k: jnp.asarray(v) for k, v in stream.next().items()}, jnp.asarray(i))
            losses.append(float(m["loss"]))
        dt = time.monotonic() - t0
        tail = float(np.mean(losses[-20:]))  # CE (nats) on the bigram language
        rows.append(
            {"model": name, "params": n_params, "lm_ce": round(tail, 4), "us_per_step": round(dt / steps * 1e6, 0)}
        )
    return rows


def main(argv=None):
    rows = run()
    print(f"{'model':10s} {'params':>10s} {'lm_CE':>10s} {'us/step':>10s}")
    for r in rows:
        print(f"{r['model']:10s} {r['params']:>10,d} {r['lm_ce']:10.4f} {r['us_per_step']:10.0f}")
    ces = [r["lm_ce"] for r in rows]
    print(f"\nclaim check (capability ordering, cf. Table 2): larger model => lower CE on the fixed bigram language: {ces[-1] < ces[0]}")
    return rows


if __name__ == "__main__":
    main()
