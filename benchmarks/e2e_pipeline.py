"""End-to-end C-FedRAG pipeline benchmarks (paper Fig. 2/3 flow).

Six views of the serving cost picture:
  * stage latency — dispatch+seal / local retrieval / aggregate (rerank) /
    prompt build, per stage, per query
  * throughput — queries/sec through ``answer`` (B=1) vs ``answer_batch``
    at B in {1, 8, 32}: one sealed request per provider per batch, so
    seal/serialize/embed overheads amortize across the batch
  * latency distribution — collect under straggler delays (one slow
    provider): sequential dispatch pays the SUM of provider round-trips,
    concurrent fan-out pays the MAX; per-query p50/p95 through the
    concurrent path
  * ragged goodput — continuous-batching scheduler vs lock-step
    ``step_batch`` on a mixed short/long generation workload: retiring
    rows free their cache slot for queued work instead of idling until
    the longest row finishes
  * pipeline overlap — pipelined ``serve_stream`` (collect for
    micro-batch N+1 overlaps decode of N) vs the phase-barrier ``serve``
    loop, with provider RTT calibrated to decode time
  * KV capacity — paged block-pool cache vs contiguous stripes at equal
    HBM on a short-prompt-heavy workload: concurrent slots, qps, and the
    bucketed-admission dispatch amortization
  * sharded capacity — the block pool partitioned over 4 mesh devices
    vs 1 at MATCHED per-shard HBM: ~4x the admissible slots through one
    distributed mixed dispatch per step, bit-identical answers
  * chunked prefill — short-decode traffic with periodic long-prompt
    arrivals: unbudgeted whole-prompt mixed dispatch vs the token-budget
    mixed dispatch (short-request p95, dispatches/step)
  * tenant SLO — interactive + batch classes through one resident
    engine under saturation: weighted-fair/priority admission vs the
    FIFO baseline (interactive p95), plus the repeated-session
    warm-start arm (persistent prefix cache across serve calls)

``main(["--json"])`` (or benchmarks/run.py --json) writes BENCH_e2e.json
rows with the stable ``{name, us, derived}`` schema so the perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import functools
import json
import os
import sys
import time

# the sharded-capacity arm partitions the KV pool over 4 devices; faking
# them on a CPU host only works BEFORE jax first loads, so claim them
# here, ahead of the repro imports below (no-op when the operator already
# set a device count, or when jax is loaded — run_sharded_capacity then
# checks the live device count and fails loudly)
if "jax" not in sys.modules and (
    "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")
):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
    )

import numpy as np

from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus
from repro.data.tokenizer import HashTokenizer
from repro.launch.serve import overlap_reranker

BATCH_SIZES = (1, 8, 32)


N_QUERIES = 64

# straggler profile for the latency-distribution mode: 4 providers
# (corpus split), one slow — sum = 0.5s/round, max = 0.2s/round
STRAGGLER_DELAYS = (0.1, 0.2, 0.1, 0.1)


@functools.lru_cache(maxsize=1)
def _build_system():
    """Corpus + system shared by the stage-latency and throughput passes
    (corpus generation + index embedding is the dominant setup cost)."""
    corpus = make_federated_corpus(n_facts=192, n_distractors=192, n_queries=N_QUERIES)
    tok = HashTokenizer()
    sys_ = CFedRAGSystem(
        corpus, CFedRAGConfig(aggregation="rerank"), tokenizer=tok, reranker=overlap_reranker(tok)
    )
    return corpus, sys_


def run(n_queries=40):
    """Per-stage latency decomposition (sequential path)."""
    corpus, sys_ = _build_system()
    queries = corpus.queries[:n_queries]
    n_queries = len(queries)
    sys_.orchestrator.answer(corpus.queries[0].text)  # warm jit caches
    stages = {"collect": 0.0, "aggregate": 0.0, "prompt": 0.0}
    for q in queries:
        t0 = time.monotonic()
        responses = sys_.orchestrator.collect_contexts(q.text)
        t1 = time.monotonic()
        ctx = sys_.orchestrator.aggregate(q.text, responses)
        t2 = time.monotonic()
        sys_.orchestrator.build_prompt(q.text, ctx)
        t3 = time.monotonic()
        stages["collect"] += t1 - t0
        stages["aggregate"] += t2 - t1
        stages["prompt"] += t3 - t2
    return [(f"e2e_{k}", v / n_queries * 1e6, "per-query") for k, v in stages.items()]


def run_throughput(n_queries=N_QUERIES, batch_sizes=BATCH_SIZES):
    """Queries/sec through the full answer path at each batch size."""
    corpus, sys_ = _build_system()
    texts = [q.text for q in corpus.queries[:n_queries]]
    # warm the jit caches for every batch shape before timing
    sys_.orchestrator.answer(texts[0])
    for b in batch_sizes:
        if b > 1:
            sys_.orchestrator.answer_batch(texts[:b])
    rows = []
    base_qps = None
    for b in batch_sizes:
        t0 = time.monotonic()
        if b == 1:
            for t in texts:
                sys_.orchestrator.answer(t)
        else:
            for i in range(0, len(texts), b):
                sys_.orchestrator.answer_batch(texts[i : i + b])
        dt = time.monotonic() - t0
        qps = len(texts) / dt
        if base_qps is None:
            base_qps = qps
        rows.append(
            (f"e2e_throughput_b{b}", dt / len(texts) * 1e6, f"{qps:.1f} qps ({qps / base_qps:.2f}x vs b1)")
        )
    return rows


def _pctl(lats, p):
    return float(np.percentile(np.asarray(lats), p))


def run_latency_distribution(n_rounds=3, batch=4):
    """Collect latency under stragglers: sequential (sum of round-trips)
    vs concurrent fan-out (max), plus per-query answer() p50/p95 through
    the concurrent path.  Fresh systems per mode — delays are mutated."""
    corpus = make_federated_corpus(n_facts=96, n_distractors=96, n_queries=16)
    tok = HashTokenizer()

    def build(concurrent):
        sys_ = CFedRAGSystem(
            corpus,
            CFedRAGConfig(aggregation="rerank", split_by="corpus", concurrent_collect=concurrent),
            tokenizer=tok,
            reranker=overlap_reranker(tok),
        )
        for p, d in zip(sys_.providers, STRAGGLER_DELAYS):
            p.delay_s = d
        return sys_

    texts = [q.text for q in corpus.queries]
    rows = []
    lat_by_mode = {}
    for name, conc in (("sequential", False), ("concurrent", True)):
        sys_ = build(conc)
        sys_.orchestrator.collect_contexts_batch(texts[:batch])  # warm jit caches
        lats = []
        for r in range(n_rounds):
            t0 = time.monotonic()
            sys_.orchestrator.collect_contexts_batch(texts[r * batch : (r + 1) * batch])
            lats.append(time.monotonic() - t0)
        lat_by_mode[name] = lats
        rows.append(
            (
                f"e2e_collect_{name}",
                float(np.mean(lats)) * 1e6,
                f"straggler batch collect (sum={sum(STRAGGLER_DELAYS):.1f}s max={max(STRAGGLER_DELAYS):.1f}s)",
            )
        )
    speedup = np.mean(lat_by_mode["sequential"]) / np.mean(lat_by_mode["concurrent"])
    # per-query latency distribution through the concurrent path
    sys_ = build(True)
    q_lats = []
    for t in texts[:8]:
        t0 = time.monotonic()
        sys_.orchestrator.answer(t)
        q_lats.append(time.monotonic() - t0)
    rows.append(
        (
            "e2e_collect_per_query",
            float(np.mean(q_lats)) * 1e6,
            f"p50={_pctl(q_lats, 50) * 1e3:.0f}ms p95={_pctl(q_lats, 95) * 1e3:.0f}ms "
            f"(concurrent {speedup:.2f}x vs sequential)",
        )
    )
    return rows


def _smoke_engine(cfg_overrides=None, **serve_cfg_kw):
    """Reduced-LM ServeEngine shared by the goodput and overlap
    benchmarks: sized so one decode step costs more than one dispatch —
    the regime any real serving deployment lives in (on a toy model,
    scheduler dispatch overhead and decode compute are the same order)."""
    import jax

    from repro.configs import get_config, smoke_config
    from repro.models import lm as LM
    from repro.models.params import init_params
    from repro.runtime.sharding import ShardingPolicy, base_rules
    from repro.serving.engine import ServeConfig, ServeEngine

    cfg = smoke_config(get_config("qwen3-0.6b")).with_overrides(
        dtype="float32", d_model=192, n_layers=4, d_ff=384, n_heads=4, head_dim=32,
        **(cfg_overrides or {}),
    )
    params = init_params(LM.param_specs(cfg), jax.random.PRNGKey(0))
    pol = ShardingPolicy(rules=base_rules(False), mesh=None)
    return ServeEngine(cfg, pol, params, ServeConfig(**serve_cfg_kw)), cfg


def run_scheduler_goodput(n_requests=32):
    """Ragged-generation goodput: lock-step ``step_batch`` decodes every
    chunk to its slowest row, the continuous scheduler retires short rows
    and admits queued work into the freed slot.  Budgets alternate
    short/long so every lock-step chunk contains a long row (the
    adversarial-but-typical mixed workload)."""
    from repro.serving.scheduler import Scheduler

    short, long_ = 2, 64
    eng, cfg = _smoke_engine(
        max_batch=4, max_prompt_len=32, max_new_tokens=long_, sched_chunk=8
    )
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(8, cfg.vocab_size, size=int(rng.integers(8, 32))).astype(np.int32)
        for _ in range(n_requests)
    ]
    budgets = [short if i % 2 else long_ for i in range(n_requests)]

    def lockstep():
        for p in prompts:
            eng.submit(p)
        outs = []
        while eng.queue:
            outs.extend(eng.step_batch())
        # lock-step cannot honor per-request budgets in flight; truncate after
        return [o[:b] for o, b in zip(outs, budgets)]

    def continuous():
        sched = Scheduler()
        for p, b in zip(prompts, budgets):
            sched.submit(p, max_new_tokens=b)
        eng.serve(sched)
        return sched

    lockstep(), continuous()  # warm both jit paths
    rows = []
    qps = {}
    for name, fn in (("lockstep", lockstep), ("continuous", continuous)):
        t0 = time.monotonic()
        sched = fn()
        dt = time.monotonic() - t0
        qps[name] = n_requests / dt
        derived = f"{qps[name]:.1f} qps ragged {short}/{long_}-token workload"
        if name == "continuous":
            st = sched.latency_stats()
            derived += (
                f" p50={st['p50_s'] * 1e3:.0f}ms p95={st['p95_s'] * 1e3:.0f}ms"
                f" ({qps['continuous'] / qps['lockstep']:.2f}x vs lockstep)"
            )
        rows.append((f"e2e_sched_{name}", dt / n_requests * 1e6, derived))
    return rows


def run_pipeline_overlap(n_queries=24, collect_batch=4, max_new_tokens=32):
    """Overlap gain of the pipelined front door: serve_stream runs
    collect/aggregate for micro-batch N+1 on a collector thread while the
    engine decodes micro-batch N, so steady-state wall-clock per
    micro-batch is max(collect, decode) instead of the phase-barrier's
    collect + decode.  Provider RTT is calibrated to the measured decode
    time of one micro-batch (the adversarial-but-typical regime: neither
    stage dominates, so a barrier wastes half the wall-clock); with M
    micro-batches the ideal gain is 2M/(M+1) -> ~1.6x at M=4."""
    from repro.serving.engine import engine_generator

    engine, _ = _smoke_engine(
        max_batch=collect_batch, max_prompt_len=256,
        max_new_tokens=max_new_tokens, sched_chunk=8,
    )
    corpus = make_federated_corpus(n_facts=96, n_distractors=96, n_queries=n_queries)
    tok = HashTokenizer()
    sys_ = CFedRAGSystem(
        corpus,
        CFedRAGConfig(aggregation="rerank", split_by="corpus", concurrent_collect=True),
        tokenizer=tok,
        reranker=overlap_reranker(tok),
        generator=engine_generator(engine),
    )
    texts = [q.text for q in corpus.queries[:n_queries]]
    # warm every jit path (embed, admit, decode) before any timing
    sys_.serve(texts[:collect_batch], max_new_tokens=max_new_tokens)
    # calibrate: decode wall-clock of one micro-batch, then give every
    # provider that much RTT so collect(N+1) can fully hide under decode(N)
    orch = sys_.orchestrator
    contexts = orch.aggregate_batch(
        texts[:collect_batch], orch.collect_contexts_batch(texts[:collect_batch])
    )
    prompts = [orch.build_prompt(q, c) for q, c in zip(texts[:collect_batch], contexts)]
    t0 = time.monotonic()
    engine.serve_prompts(prompts, max_new_tokens=max_new_tokens)
    d_dec = time.monotonic() - t0

    def phase_barrier():
        outs = []
        for i in range(0, n_queries, collect_batch):
            outs.extend(
                sys_.serve(texts[i : i + collect_batch], max_new_tokens=max_new_tokens)
            )
        return outs

    def pipelined():
        outs = [None] * n_queries
        for qidx, out in sys_.serve_stream(
            texts, max_new_tokens=max_new_tokens, collect_batch=collect_batch
        ):
            outs[qidx] = out
        return outs

    try:
        for p in sys_.providers:
            p.delay_s = d_dec
        t0 = time.monotonic()
        barrier_outs = phase_barrier()
        dt_barrier = time.monotonic() - t0
        t0 = time.monotonic()
        stream_outs = pipelined()
        dt_stream = time.monotonic() - t0
    finally:
        for p in sys_.providers:
            p.delay_s = 0.0
    for a, b in zip(barrier_outs, stream_outs):
        assert np.array_equal(a["answer_tokens"], b["answer_tokens"]) and np.array_equal(
            a["context"]["chunk_ids"], b["context"]["chunk_ids"]
        ), "pipelined results diverged from the phase-barrier path"
    speedup = dt_barrier / dt_stream
    n_batches = -(-n_queries // collect_batch)
    return [
        (
            "e2e_pipeline_barrier",
            dt_barrier / n_queries * 1e6,
            f"collect+decode per micro-batch, no overlap (RTT~decode {d_dec * 1e3:.0f}ms)",
        ),
        (
            "e2e_pipeline_stream",
            dt_stream / n_queries * 1e6,
            f"{speedup:.2f}x vs phase-barrier (ideal {2 * n_batches / (n_batches + 1):.2f}x "
            f"at {n_batches} micro-batches of {collect_batch}); results bit-identical",
        ),
    ]


def run_paged_capacity(n_requests=64):
    """Paged-vs-contiguous KV cache at EQUAL HBM on a short-prompt-heavy
    workload (the tiered-context traffic Algorithm 1 produces: per-query
    context varies with provider quorum and re-rank cut, so most prompts
    are far below the window).

    Contiguous reserves one max_prompt_len+max_new_tokens stripe per slot
    — 4 stripes here — so 4 requests decode concurrently no matter how
    short they are.  The paged engines get the SAME cache bytes as a
    20-block pool (16 tokens/block) and more decode slots: a short
    request holds at most 2 blocks instead of a 5-block stripe, so at 10
    slots the pool covers every request's WORST case (zero truncation,
    identical total work, 2.5x the concurrency — the headline row), and
    at 16 slots admission oversubscribes the pool, so some requests hit
    OOM at a chunk boundary and retire with a truncated, flagged answer
    (the designed degradation mode; its arm emits fewer tokens, which is
    why throughput is reported as generated tokens/s with the truncation
    count disclosed).  Also reported: peak concurrent slots (from the
    scheduler's min_free_slots gauge), cache bytes, and the dispatch
    shape — the contiguous arm's bucketed-admission amortization (rows
    prefilled per fused admit dispatch) vs the paged arms' single mixed
    dispatch per engine step."""
    from repro.serving.scheduler import Scheduler

    short_new = 8
    rng = np.random.default_rng(1)
    prompts = [
        rng.integers(8, 256, size=int(rng.integers(8, 25))).astype(np.int32)
        for _ in range(n_requests)
    ]
    common = dict(max_prompt_len=64, max_new_tokens=16, sched_chunk=8)
    eng_c, _ = _smoke_engine(max_batch=4, **common)
    # equal HBM: 4 contiguous stripes of ceil(80/16)=5 blocks -> 20 blocks
    paged_kw = dict(paged=True, block_size=16, n_pool_blocks=20, **common)
    eng_p, _ = _smoke_engine(max_batch=10, **paged_kw)
    eng_o, _ = _smoke_engine(max_batch=16, **paged_kw)
    assert eng_p.cache_nbytes() <= eng_c.cache_nbytes() * 1.21, (
        "paged pool exceeds the contiguous HBM budget "
        "(+1 trash block is the only allowed overhead)"
    )

    def serve_all(eng):
        sched = Scheduler()
        sched.submit_many(prompts, short_new)
        eng.serve(sched)
        return sched

    rows, tps, peak = [], {}, {}
    for name, eng in (("contiguous", eng_c), ("paged", eng_p), ("paged_oversub", eng_o)):
        serve_all(eng)  # warm every admit-bucket/decode jit path
        eng.admit_dispatches = eng.admit_rows_total = 0
        t0 = time.monotonic()
        sched = serve_all(eng)
        dt = time.monotonic() - t0
        st = sched.latency_stats()
        n_tokens = sum(len(r.answer) for r in sched.results.values())
        tps[name] = n_tokens / dt
        peak[name] = eng.scfg.max_batch - st["min_free_slots"]
        if name == "contiguous":
            amort = eng.admit_rows_total / max(eng.admit_dispatches, 1)
            dispatch_txt = (
                f"admit {eng.admit_rows_total} rows/{eng.admit_dispatches} "
                f"dispatches ({amort:.1f}x amortized)"
            )
        else:
            dispatch_txt = f"{st['dispatches_per_step']:.2f} dispatch/step unified"
        derived = (
            f"{tps[name]:.0f} tok/s ({n_tokens} tokens, "
            f"{st['n_truncated']} OOM-truncated), "
            f"peak {peak[name]}/{eng.scfg.max_batch} slots, "
            f"cache {eng.cache_nbytes() / 1e6:.2f}MB, {dispatch_txt}"
        )
        if name != "contiguous":
            derived += (
                f" | {tps[name] / tps['contiguous']:.2f}x tok/s, "
                f"{peak[name] / peak['contiguous']:.2f}x concurrent slots vs "
                "contiguous at equal HBM"
            )
            if name == "paged":
                assert st["n_truncated"] == 0, (
                    "10 slots x 2 worst-case blocks == the 20-block pool: "
                    "the matched-work arm must never truncate"
                )
        rows.append((f"e2e_kv_{name}", dt / n_requests * 1e6, derived))
    return rows


def run_sharded_capacity(n_requests=16):
    """Sharded block pool at MATCHED per-shard HBM: both arms give every
    shard the same 8-block pool (plus its trash block), so a 4-shard
    engine holds 4x the aggregate KV of the 1-shard engine while no
    single device grows.  Row-affine allocation keeps every request on
    one shard and each step is ONE distributed mixed dispatch whose
    cross-shard combine passes the owning shard through bitwise — the
    arms must answer every request identically, bit for bit, while the
    4-shard arm admits ~4x the concurrent slots (the 1-shard arm is
    pool-bound at 2-3 residents).

    Prompt lengths are chosen so ``blocks_for(len + 1) ==
    blocks_for(len + new)``: admission's reservation already covers the
    whole decode, so neither arm can hit a mid-decode OOM truncation and
    the parity claim is unconditional."""
    import jax

    from repro.serving.scheduler import Scheduler

    if len(jax.devices()) < 4:
        raise RuntimeError(
            "run_sharded_capacity needs >= 4 devices; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=4 before jax loads"
        )
    per_shard = 8  # pool blocks per shard, identical in both arms
    short_new = 6
    rng = np.random.default_rng(7)
    # len = 2 (mod 8): len+1 .. len+6 stay inside the reserved block span
    prompts = [
        rng.integers(8, 256, size=(10 if i % 2 == 0 else 18)).astype(np.int32)
        for i in range(n_requests)
    ]
    common = dict(max_prompt_len=24, max_new_tokens=8, sched_chunk=8,
                  paged=True, block_size=8, max_batch=12)
    engines = {
        1: _smoke_engine(n_pool_blocks=per_shard, shards=1, **common)[0],
        4: _smoke_engine(n_pool_blocks=per_shard * 4, shards=4, **common)[0],
    }

    def serve_all(eng):
        sched = Scheduler()
        sched.submit_many(prompts, short_new)
        eng.serve(sched)
        return sched

    rows, answers, tps, peak = [], {}, {}, {}
    for shards, eng in engines.items():
        serve_all(eng)  # warm the jit paths
        t0 = time.monotonic()
        sched = serve_all(eng)
        dt = time.monotonic() - t0
        st = sched.latency_stats()
        answers[shards] = {rid: r.answer for rid, r in sched.results.items()}
        n_tokens = sum(len(a) for a in answers[shards].values())
        tps[shards] = n_tokens / dt
        peak[shards] = eng.scfg.max_batch - st["min_free_slots"]
        assert st["n_truncated"] == 0, "reservation covers decode: no truncation"
        derived = (
            f"{tps[shards]:.0f} tok/s, peak {peak[shards]}/{eng.scfg.max_batch} "
            f"slots, {per_shard} pool blocks/shard "
            f"({eng.cache_nbytes() / 1e6:.2f}MB total)"
        )
        if shards == 4:
            drift = sum(
                not np.array_equal(answers[1][rid], answers[4][rid])
                for rid in answers[1]
            )
            assert drift == 0, f"{drift} answers drifted between 1 and 4 shards"
            assert peak[4] >= 3 * peak[1], (
                f"4-shard arm admitted {peak[4]} peak slots, wanted >= 3x "
                f"the 1-shard arm's {peak[1]}"
            )
            derived += (
                f" | {peak[4] / peak[1]:.2f}x admissible slots and "
                f"{tps[4] / tps[1]:.2f}x tok/s vs 1 shard at matched "
                "per-shard HBM, zero parity drift"
            )
        rows.append((f"e2e_shard_{shards}", dt / n_requests * 1e6, derived))
    return rows


def run_prefix_reuse(n_batches=6, batch=8, preamble_len=128, max_new=8):
    """Prefix-cache gain on shared-preamble traffic (the C-FedRAG front
    door's native shape: ``build_prompt`` emits a stable ``[BOS] CTX
    <context> QRY`` preamble, so micro-batch siblings served against the
    same aggregated context — and every retry — repeat the expensive
    prefix verbatim).

    Workload: ``n_batches`` micro-batches of ``batch`` requests; within a
    micro-batch every prompt shares a calibrated ``preamble_len``-token
    context preamble and differs only in a short query tail.  Three arms
    at the same engine geometry:
      * ``off``  — paged pool, no prefix cache: every row prefills its
        whole prompt (the PR-4 baseline).
      * ``on``   — refcounted prefix cache: the first sibling prefills
        the preamble once, the rest share its blocks and prefill only
        their tails.  Results are asserted BIT-identical to ``off``; the
        headline number is the prefill-token reduction (must be >= 2x on
        this workload) plus the wall-clock ratio.
      * ``capacity`` — both engines again at HALF the KV pool: sharing
        keeps all ``batch`` slots decoding concurrently where the
        unshared pool's memory-aware admission gate has to hold requests
        back — the HBM headroom the cache buys back.
    """
    from repro.serving.scheduler import Scheduler

    common = dict(max_batch=batch, max_prompt_len=192, max_new_tokens=max_new,
                  sched_chunk=8, paged=True, block_size=16)
    eng_off, cfg = _smoke_engine(**common)
    eng_on, _ = _smoke_engine(prefix_cache=True, **common)
    full_pool = eng_off._n_pool_blocks
    half_pool = full_pool // 2
    eng_off_h, _ = _smoke_engine(n_pool_blocks=half_pool, **common)
    eng_on_h, _ = _smoke_engine(n_pool_blocks=half_pool, prefix_cache=True, **common)

    rng = np.random.default_rng(7)
    prompts = []
    for _ in range(n_batches):
        pre = rng.integers(8, cfg.vocab_size, size=preamble_len).astype(np.int32)
        for _ in range(batch):
            tail = rng.integers(8, cfg.vocab_size, size=int(rng.integers(8, 25))).astype(np.int32)
            prompts.append(np.concatenate([pre, tail]))
    n_requests = len(prompts)
    prefill_total = sum(len(p) for p in prompts)

    def serve_all(eng):
        sched = Scheduler()
        sched.submit_many(prompts, max_new)
        return sched, eng.serve(sched)

    engines = {"off": eng_off, "on": eng_on, "off_half": eng_off_h, "on_half": eng_on_h}
    for eng in engines.values():
        serve_all(eng)  # warm every mixed/decode jit path
    stats, times, results = {}, {}, {}
    for name, eng in engines.items():
        # the engine is RESIDENT now: drop the warm pass's cached chains
        # so every timed arm starts from a cold prefix index
        eng.reset_cache()
        eng.prefix_lookups = eng.prefix_hits = 0
        eng.prefill_tokens_total = eng.prefill_tokens_saved = eng.prefix_shared_total = 0
        t0 = time.monotonic()
        sched, res = serve_all(eng)
        times[name] = time.monotonic() - t0
        results[name] = res
        st = sched.latency_stats()
        st["prefill_executed"] = prefill_total - eng.prefill_tokens_saved
        st["peak_slots"] = eng.scfg.max_batch - st["min_free_slots"]
        stats[name] = st
    for name in ("on", "off_half", "on_half"):
        for rid, w in results["off"].items():
            assert np.array_equal(w, results[name][rid]), (
                f"prefix arm {name} diverged from the unshared baseline at rid={rid}"
            )
    reduction = stats["off"]["prefill_executed"] / stats["on"]["prefill_executed"]
    assert reduction >= 2.0, (
        f"shared-preamble workload must cut prefill tokens >= 2x, got {reduction:.2f}x"
    )
    assert stats["on"]["n_truncated"] == 0 and stats["on_half"]["n_truncated"] == 0
    return [
        (
            "e2e_prefix_off",
            times["off"] / n_requests * 1e6,
            f"no sharing: {prefill_total} prompt tokens all prefilled, "
            f"peak {stats['off']['peak_slots']}/{batch} slots, {full_pool}-block pool",
        ),
        (
            "e2e_prefix_on",
            times["on"] / n_requests * 1e6,
            f"{reduction:.1f}x fewer prefill tokens "
            f"({stats['on']['prefill_executed']}/{prefill_total} executed, "
            f"hit rate {stats['on'].get('prefix_hit_rate', 0.0):.0%}), "
            f"{times['off'] / times['on']:.2f}x wall-clock vs unshared; "
            f"results bit-identical",
        ),
        (
            "e2e_prefix_capacity",
            times["on_half"] / n_requests * 1e6,
            f"at {half_pool} blocks (50% HBM): shared keeps "
            f"{stats['on_half']['peak_slots']}/{batch} slots vs "
            f"{stats['off_half']['peak_slots']}/{batch} unshared "
            f"({times['off_half'] / times['on_half']:.2f}x wall-clock) — "
            f"sharing buys back the admission gate's memory headroom",
        ),
    ]


def run_mixed_prefill(n_requests=24, long_every=6, long_len=256, short_new=24,
                      long_new=8, token_budget=16):
    """Decode-latency tail under periodic long-prompt arrivals: the
    workload chunked prefill exists for.  Mostly short prompts decoding
    ``short_new`` tokens each, with every ``long_every``-th arrival a
    ``long_len``-token prompt.

    Two paged engines at identical geometry, differing ONLY in
    ``token_budget`` (both run the unified mixed dispatch — the legacy
    dense admission pipeline is retired):
      * ``off`` — unbudgeted: the lane cap defaults to the full prompt
        window, so a long arrival's prefill lands in ONE whole-prompt-
        width dispatch and every in-flight decode row stalls behind it.
      * ``on``  — token-budget chunking: each step's mixed dispatch
        advances at most ``token_budget`` prefill lanes AND every decode
        row together, so the long prompt's cost is spread across steps
        that short requests keep streaming through.  (The mixed dispatch
        pads to its lane cap every step, so on the toy CPU model — where
        compute, not dispatch, is nearly free — small budgets win; real
        deployments size the budget to the accelerator's prefill/decode
        roofline instead.)

    Reported: short-request (decode-traffic) p50/p95 submit->finish
    latency for both arms, plus the dispatch-count gauges.  Asserted
    (deterministic, not timing): answers token-identical across arms,
    BOTH arms run exactly 1 dispatch per engine step, and neither arm
    truncates or deadlocks."""
    from repro.serving.scheduler import Scheduler

    bs = 16
    common = dict(
        max_batch=4, max_prompt_len=long_len, max_new_tokens=short_new,
        sched_chunk=4, paged=True, block_size=bs,
        n_pool_blocks=4 * -(-(long_len + short_new) // bs),
    )
    eng_off, cfg = _smoke_engine(**common)
    eng_on, _ = _smoke_engine(token_budget=token_budget, **common)

    rng = np.random.default_rng(11)
    reqs = []  # (prompt, budget, is_long)
    for i in range(n_requests):
        long_ = i % long_every == long_every - 1
        size = long_len if long_ else int(rng.integers(8, 17))
        p = rng.integers(8, cfg.vocab_size, size=size).astype(np.int32)
        reqs.append((p, long_new if long_ else short_new, long_))
    n_long = sum(1 for _, _, l in reqs if l)

    def serve_all(eng):
        sched = Scheduler()
        rids = [sched.submit(p, max_new_tokens=b) for p, b, _ in reqs]
        return sched, rids, eng.serve(sched)

    stats, times, results = {}, {}, {}
    for name, eng in (("off", eng_off), ("on", eng_on)):
        serve_all(eng)  # warm every admit-bucket / mixed / decode jit path
        t0 = time.monotonic()
        sched, rids, res = serve_all(eng)
        times[name] = time.monotonic() - t0
        results[name] = [res[rid] for rid in rids]
        st = sched.latency_stats()
        short_lat = [
            sched.results[rid].latency_s
            for rid, (_, _, long_) in zip(rids, reqs) if not long_
        ]
        st["short_p50_s"] = _pctl(short_lat, 50)
        st["short_p95_s"] = _pctl(short_lat, 95)
        assert st["n_truncated"] == 0 and st["n_deadlocked"] == 0, (
            f"mixed-prefill workload must fit the pool (arm {name})"
        )
        stats[name] = st
    for i, (a, b) in enumerate(zip(results["off"], results["on"])):
        assert np.array_equal(a, b), (
            f"unified arm diverged from the dense pipeline at request {i}"
        )
    assert stats["on"]["dispatches_per_step"] == 1.0, (
        "unified serving must stay at exactly one dispatch per engine step"
    )
    assert stats["off"]["dispatches_per_step"] == 1.0, (
        "the unbudgeted arm runs the same unified path: 1 dispatch/step"
    )
    off, on = stats["off"], stats["on"]
    return [
        (
            "e2e_chunked_off",
            times["off"] / n_requests * 1e6,
            f"unbudgeted lanes: {n_long}x {long_len}-tok arrivals land whole-"
            f"prompt dispatches that stall decode: short-request "
            f"p50={off['short_p50_s'] * 1e3:.0f}ms "
            f"p95={off['short_p95_s'] * 1e3:.0f}ms, "
            f"1.00 dispatch/step over {off['engine_steps']} steps",
        ),
        (
            "e2e_chunked_on",
            times["on"] / n_requests * 1e6,
            f"token_budget={token_budget}: short-request "
            f"p50={on['short_p50_s'] * 1e3:.0f}ms "
            f"p95={on['short_p95_s'] * 1e3:.0f}ms "
            f"({off['short_p95_s'] / on['short_p95_s']:.2f}x vs unbudgeted), "
            f"1.00 dispatch/step over {on['engine_steps']} steps; "
            f"answers token-identical",
        ),
    ]


def run_spec_decode(n_requests=16, new_tokens=24, draft_k=3, token_budget=16):
    """Speculative decoding (draft-k/verify-1) through the unified mixed
    dispatch: the multi-token-per-target-forward headline of the paged
    engine.

    Two paged engines at identical geometry, differing ONLY in
    ``draft_k``:
      * ``off`` — plain greedy decode: every committed token costs one
        target forward pass (the sequential dependency speculation
        exists to break).
      * ``on``  — ``draft_k`` self-speculation (drafter = target, the
        accept-rate ceiling): the resident drafter proposes k tokens per
        slot from its own paged pool, the target verifies all k+1 lanes
        in ONE mixed dispatch, and greedy accept-prefix commits the
        matching run plus one correction token.

    Both arms run ``sched_chunk=1`` so one engine step == one target
    forward and the step counts compare the quantity speculation
    actually saves.  (On the toy CPU model the drafter costs as much as
    the target, so wall-clock does NOT improve — the gauges that
    transfer to a real deployment, where the drafter is ~10x smaller,
    are target forwards, tokens/round, and accept rate.)

    Reported: committed tokens per spec round, accept rate, dispatches
    per spec round, and the target-forward reduction.  Asserted
    (deterministic, not timing): answers bit-identical across arms,
    tokens/round > 1, fewer target forwards than plain decode, at most
    2 dispatches per spec round (1 draft + 1 verify), both arms at
    exactly 1 unified dispatch per engine step, and zero legacy decode
    dispatches in the speculative arm."""
    from repro.serving.scheduler import Scheduler

    common = dict(
        max_batch=4, max_prompt_len=32, max_new_tokens=new_tokens,
        sched_chunk=1, paged=True, block_size=16, token_budget=token_budget,
    )
    eng_off, cfg = _smoke_engine(**common)
    eng_on, _ = _smoke_engine(draft_k=draft_k, **common)

    rng = np.random.default_rng(17)
    prompts = [
        rng.integers(8, cfg.vocab_size, size=int(rng.integers(8, 25))).astype(np.int32)
        for _ in range(n_requests)
    ]

    def serve_all(eng):
        sched = Scheduler()
        rids = sched.submit_many(prompts, new_tokens)
        res = eng.serve(sched)
        return sched, [res[rid] for rid in rids]

    stats, times, results = {}, {}, {}
    for name, eng in (("off", eng_off), ("on", eng_on)):
        serve_all(eng)  # warm the mixed / drafter / verify jit paths
        t0 = time.monotonic()
        sched, outs = serve_all(eng)
        times[name] = time.monotonic() - t0
        results[name] = outs
        st = sched.latency_stats()
        assert st["n_truncated"] == 0 and st["n_deadlocked"] == 0, (
            f"speculative workload must fit the pool (arm {name})"
        )
        assert st["dispatches_per_step"] == 1.0, (
            "both arms run the unified path: 1 mixed dispatch per engine step"
        )
        stats[name] = st
    for i, (a, b) in enumerate(zip(results["off"], results["on"])):
        assert np.array_equal(a, b), (
            f"speculative arm changed tokens at request {i} — accept-prefix "
            "must keep outputs bit-identical to plain greedy decode"
        )
    off, on = stats["off"], stats["on"]
    assert eng_on.decode_dispatches == 0, "legacy decode path must stay retired"
    assert on["spec_tokens_per_round"] > 1.0, (
        f"speculation must commit >1 token per round "
        f"(got {on['spec_tokens_per_round']:.2f})"
    )
    assert on["dispatches_per_spec_round"] <= 2.0, (
        f"O(2) bound: 1 draft + 1 verify dispatch per spec round "
        f"(got {on['dispatches_per_spec_round']:.2f})"
    )
    assert on["engine_steps"] < off["engine_steps"], (
        f"speculation must cut target forwards "
        f"({on['engine_steps']} vs {off['engine_steps']})"
    )
    return [
        (
            "e2e_spec_off",
            times["off"] / n_requests * 1e6,
            f"plain greedy decode: {n_requests}x {new_tokens}-tok "
            f"generations, 1 target forward per committed token — "
            f"{off['engine_steps']} forwards, 1.00 dispatch/step",
        ),
        (
            "e2e_spec_on",
            times["on"] / n_requests * 1e6,
            f"draft_k={draft_k} self-speculation: "
            f"{on['spec_tokens_per_round']:.2f} tokens/round at accept rate "
            f"{on['spec_accept_rate']:.0%}, "
            f"{on['dispatches_per_spec_round']:.2f} dispatches/round "
            f"(bound 2), {off['engine_steps'] / on['engine_steps']:.2f}x "
            f"fewer target forwards ({on['engine_steps']} vs "
            f"{off['engine_steps']}); answers bit-identical",
        ),
    ]


def run_tenant_slo(n_batchjobs=12, n_interactive=6, batch_new=24, inter_new=4):
    """Per-tenant SLO classes through ONE resident engine under
    saturation (the headline of the multi-tenant serving core).

    Workload: a flood of ``n_batchjobs`` long-budget "batch" requests
    submitted ahead of ``n_interactive`` short "interactive" requests, at
    ``max_batch=2`` so the queue is the contended resource.  Three arms:
      * ``fifo`` — global arrival order: every interactive request waits
        behind the whole batch flood, so its p95 collapses to roughly the
        flood's makespan.
      * ``fair`` — class priority + stride weighted-fair admission: the
        interactive class preempts the QUEUE (never a running slot — at
        most one in-flight batch decode of ``batch_new`` tokens bounds
        its wait) and holds its p95; the batch class's added wait is
        disclosed, not hidden.
      * ``warm`` — the repeated-session arm: the same resident engine
        serves one session's shared-preamble prompts twice; the second
        call rides the persistent prefix cache (hit rate, prefill tokens
        saved, wall-clock ratio — state survives across ``serve()``
        calls, the thing a per-call engine cannot do).

    Asserted: interactive p95 under weighted-fair beats FIFO; the warm
    pass hits the cache on every prompt and its answers are bit-identical
    to the cold pass."""
    from repro.serving.scheduler import Scheduler

    common = dict(max_batch=2, max_prompt_len=128, max_new_tokens=batch_new,
                  sched_chunk=4, paged=True, prefix_cache=True, block_size=16)
    eng, cfg = _smoke_engine(**common)
    rng = np.random.default_rng(13)
    batch_prompts = [
        rng.integers(8, cfg.vocab_size, size=int(rng.integers(24, 48))).astype(np.int32)
        for _ in range(n_batchjobs)
    ]
    inter_prompts = [
        rng.integers(8, cfg.vocab_size, size=int(rng.integers(8, 16))).astype(np.int32)
        for _ in range(n_interactive)
    ]
    n_total = n_batchjobs + n_interactive
    weights = {"batch": 1.0, "interactive": 4.0}

    def serve_arm(fifo):
        eng.reset_cache()
        sched = Scheduler(tenant_weights=weights, fifo=fifo)
        sched.submit_many(batch_prompts, batch_new, tenants="batch")
        sched.submit_many(inter_prompts, inter_new, tenants="interactive", priorities=1)
        t0 = time.monotonic()
        eng.serve(sched)
        return sched.latency_stats(), time.monotonic() - t0

    serve_arm(True)  # warm every mixed/decode jit path
    st_fifo, dt_fifo = serve_arm(fifo=True)
    st_fair, dt_fair = serve_arm(fifo=False)
    i_fifo = st_fifo["tenants"]["interactive"]
    i_fair = st_fair["tenants"]["interactive"]
    b_fair = st_fair["tenants"]["batch"]
    assert i_fair["p95_s"] < i_fifo["p95_s"], (
        "weighted-fair admission must beat FIFO on interactive p95 "
        f"({i_fair['p95_s']:.3f}s vs {i_fifo['p95_s']:.3f}s)"
    )

    # repeated-session warm start: same resident engine, same session
    pre = rng.integers(8, cfg.vocab_size, size=96).astype(np.int32)
    session = [
        np.concatenate([pre, rng.integers(8, cfg.vocab_size, size=8).astype(np.int32)])
        for _ in range(n_interactive)
    ]

    def serve_session():
        sched = Scheduler()
        rids = sched.submit_many(session, inter_new, tenants="interactive")
        t0 = time.monotonic()
        res = eng.serve(sched)
        return sched.latency_stats(), time.monotonic() - t0, [res[r] for r in rids]

    eng.reset_cache()
    st_cold, dt_cold, ans_cold = serve_session()
    st_warm, dt_warm, ans_warm = serve_session()
    assert st_warm["prefix_hit_rate"] == 1.0 and st_warm["prefill_tokens_saved"] > 0, (
        "the resident prefix cache must survive into the second serve call"
    )
    for a, b in zip(ans_cold, ans_warm):
        assert np.array_equal(a, b), "warm restart changed tokens"
    return [
        (
            "e2e_tenant_fifo",
            dt_fifo / n_total * 1e6,
            f"FIFO baseline: interactive p50={i_fifo['p50_s'] * 1e3:.0f}ms "
            f"p95={i_fifo['p95_s'] * 1e3:.0f}ms behind a {n_batchjobs}-job "
            f"batch flood at 2 slots",
        ),
        (
            "e2e_tenant_fair",
            dt_fair / n_total * 1e6,
            f"priority + weighted-fair: interactive "
            f"p50={i_fair['p50_s'] * 1e3:.0f}ms p95={i_fair['p95_s'] * 1e3:.0f}ms "
            f"({i_fifo['p95_s'] / i_fair['p95_s']:.1f}x better than FIFO); "
            f"batch p95={b_fair['p95_s'] * 1e3:.0f}ms "
            f"({b_fair['n_done']}/{n_batchjobs} done — queue preemption only, "
            f"running slots never preempted)",
        ),
        (
            "e2e_tenant_warm",
            dt_warm / n_interactive * 1e6,
            f"2nd serve() on the resident engine: hit rate "
            f"{st_warm['prefix_hit_rate']:.0%}, "
            f"{st_warm['prefill_tokens_saved']} prefill tokens saved, "
            f"{dt_cold / dt_warm:.2f}x wall-clock vs cold session; "
            f"answers bit-identical",
        ),
    ]


def write_json(rows, path="BENCH_e2e.json"):
    payload = [{"name": n, "us": round(us, 1), "derived": d} for n, us, d in rows]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main(argv=None):
    argv = list(argv or [])
    rows = (
        run()
        + run_throughput()
        + run_latency_distribution()
        + run_scheduler_goodput()
        + run_pipeline_overlap()
        + run_paged_capacity()
        + run_sharded_capacity()
        + run_prefix_reuse()
        + run_mixed_prefill()
        + run_spec_decode()
        + run_tenant_slo()
    )
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if "--json" in argv:
        print(f"wrote {write_json(rows)}")
    return 0


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
