"""End-to-end C-FedRAG pipeline latency decomposition (paper Fig. 2/3 flow):
dispatch+seal / local retrieval / aggregate (rerank) / prompt build,
per stage, per query — the serving-cost picture of the architecture."""
from __future__ import annotations

import time

import numpy as np

from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus
from repro.data.tokenizer import HashTokenizer
from repro.launch.serve import overlap_reranker


def run(n_queries=40):
    corpus = make_federated_corpus(n_facts=192, n_distractors=192, n_queries=n_queries)
    tok = HashTokenizer()
    sys_ = CFedRAGSystem(
        corpus, CFedRAGConfig(aggregation="rerank"), tokenizer=tok, reranker=overlap_reranker(tok)
    )
    stages = {"collect": 0.0, "aggregate": 0.0, "prompt": 0.0}
    for q in corpus.queries[:n_queries]:
        t0 = time.monotonic()
        responses = sys_.orchestrator.collect_contexts(q.text)
        t1 = time.monotonic()
        ctx = sys_.orchestrator.aggregate(q.text, responses)
        t2 = time.monotonic()
        sys_.orchestrator.build_prompt(q.text, ctx)
        t3 = time.monotonic()
        stages["collect"] += t1 - t0
        stages["aggregate"] += t2 - t1
        stages["prompt"] += t3 - t2
    return [(k, v / n_queries * 1e6) for k, v in stages.items()]


def main(argv=None):
    for name, us in run():
        print(f"e2e_{name},{us:.1f},per-query")
    return 0


if __name__ == "__main__":
    main()
