"""End-to-end C-FedRAG pipeline benchmarks (paper Fig. 2/3 flow).

Two views of the serving cost picture:
  * stage latency — dispatch+seal / local retrieval / aggregate (rerank) /
    prompt build, per stage, per query
  * throughput — queries/sec through ``answer`` (B=1) vs ``answer_batch``
    at B in {1, 8, 32}: one sealed request per provider per batch, so
    seal/serialize/embed overheads amortize across the batch

``main(["--json"])`` (or benchmarks/run.py --json) writes BENCH_e2e.json
rows with the stable ``{name, us, derived}`` schema so the perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import functools
import json
import time

import numpy as np

from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus
from repro.data.tokenizer import HashTokenizer
from repro.launch.serve import overlap_reranker

BATCH_SIZES = (1, 8, 32)


N_QUERIES = 64


@functools.lru_cache(maxsize=1)
def _build_system():
    """Corpus + system shared by the stage-latency and throughput passes
    (corpus generation + index embedding is the dominant setup cost)."""
    corpus = make_federated_corpus(n_facts=192, n_distractors=192, n_queries=N_QUERIES)
    tok = HashTokenizer()
    sys_ = CFedRAGSystem(
        corpus, CFedRAGConfig(aggregation="rerank"), tokenizer=tok, reranker=overlap_reranker(tok)
    )
    return corpus, sys_


def run(n_queries=40):
    """Per-stage latency decomposition (sequential path)."""
    corpus, sys_ = _build_system()
    queries = corpus.queries[:n_queries]
    n_queries = len(queries)
    sys_.orchestrator.answer(corpus.queries[0].text)  # warm jit caches
    stages = {"collect": 0.0, "aggregate": 0.0, "prompt": 0.0}
    for q in queries:
        t0 = time.monotonic()
        responses = sys_.orchestrator.collect_contexts(q.text)
        t1 = time.monotonic()
        ctx = sys_.orchestrator.aggregate(q.text, responses)
        t2 = time.monotonic()
        sys_.orchestrator.build_prompt(q.text, ctx)
        t3 = time.monotonic()
        stages["collect"] += t1 - t0
        stages["aggregate"] += t2 - t1
        stages["prompt"] += t3 - t2
    return [(f"e2e_{k}", v / n_queries * 1e6, "per-query") for k, v in stages.items()]


def run_throughput(n_queries=N_QUERIES, batch_sizes=BATCH_SIZES):
    """Queries/sec through the full answer path at each batch size."""
    corpus, sys_ = _build_system()
    texts = [q.text for q in corpus.queries[:n_queries]]
    # warm the jit caches for every batch shape before timing
    sys_.orchestrator.answer(texts[0])
    for b in batch_sizes:
        if b > 1:
            sys_.orchestrator.answer_batch(texts[:b])
    rows = []
    base_qps = None
    for b in batch_sizes:
        t0 = time.monotonic()
        if b == 1:
            for t in texts:
                sys_.orchestrator.answer(t)
        else:
            for i in range(0, len(texts), b):
                sys_.orchestrator.answer_batch(texts[i : i + b])
        dt = time.monotonic() - t0
        qps = len(texts) / dt
        if base_qps is None:
            base_qps = qps
        rows.append(
            (f"e2e_throughput_b{b}", dt / len(texts) * 1e6, f"{qps:.1f} qps ({qps / base_qps:.2f}x vs b1)")
        )
    return rows


def write_json(rows, path="BENCH_e2e.json"):
    payload = [{"name": n, "us": round(us, 1), "derived": d} for n, us, d in rows]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main(argv=None):
    argv = list(argv or [])
    rows = run() + run_throughput()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if "--json" in argv:
        print(f"wrote {write_json(rows)}")
    return 0


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
