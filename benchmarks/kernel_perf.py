"""Kernel micro-benchmarks: wall time of the jnp production path on CPU
(numbers are CPU-relative; the TPU roofline for the same ops comes from
the dry-run) + interpret-mode correctness spot checks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.retrieval_topk.ref import retrieval_topk_ref
from repro.kernels.ssd_scan.ref import ssd_chunk_ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters * 1e6  # us


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    # retrieval: paper scale = 10k snippets/corpus, d=768 (contriever)
    q = jax.random.normal(key, (8, 256))
    c = jax.random.normal(jax.random.fold_in(key, 1), (10_000, 256))
    f = jax.jit(lambda q, c: retrieval_topk_ref(q, c, 8))
    us = _time(f, q, c)
    rows.append(("retrieval_topk_10k", us, f"{2*8*10_000*256/us/1e3:.2f} GFLOP/s-cpu"))

    # flash attention fwd, 1k seq
    qq = jax.random.normal(key, (1, 1024, 8, 64), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(key, 2), (1, 1024, 4, 64), jnp.float32)
    vv = jax.random.normal(jax.random.fold_in(key, 3), (1, 1024, 4, 64), jnp.float32)
    f = jax.jit(lambda a, b, c_: flash_attention_ref(a, b, c_))
    us = _time(f, qq, kk, vv)
    rows.append(("attention_fwd_1k", us, f"{4*1024*1024*8*64/us/1e3:.2f} GFLOP/s-cpu"))

    # decode attention against 8k cache
    qd = jax.random.normal(key, (4, 8, 64))
    kc = jax.random.normal(jax.random.fold_in(key, 4), (4, 8192, 4, 64))
    vc = jax.random.normal(jax.random.fold_in(key, 5), (4, 8192, 4, 64))
    lens = jnp.full((4,), 8192)
    f = jax.jit(lambda a, b, c_, l: decode_attention_ref(a, b, c_, l))
    us = _time(f, qd, kc, vc, lens)
    bytes_moved = 4 * 8192 * 4 * 64 * 4 * 2
    rows.append(("decode_attn_8k_cache", us, f"{bytes_moved/us/1e3:.2f} GB/s-cpu"))

    # ssd chunk
    x = jax.random.normal(key, (2, 256, 8, 64))
    b = jax.random.normal(jax.random.fold_in(key, 6), (2, 256, 8, 64))
    cc2 = jax.random.normal(jax.random.fold_in(key, 7), (2, 256, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 8), (2, 256, 8)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 9), (8,)))
    f = jax.jit(lambda *t: ssd_chunk_ref(*t))
    us = _time(f, x, b, cc2, dt, a)
    rows.append(("ssd_chunk_L256", us, ""))
    return rows


def main(argv=None):
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
    return 0


if __name__ == "__main__":
    main()
