"""Benchmark-schema guard: the perf trajectory across PRs lives in the
``name`` keys of BENCH_e2e.json / BENCH_kernels.json, so a refactor that
silently drops a row (e.g. a renamed ``run_*`` function falling out of
``benchmarks/run.py --json``) would erase history without failing
anything.  This guard pins the accumulated key set in
``benchmarks/bench_schema.json`` and fails when a BENCH file no longer
carries every previously-recorded key.

  python benchmarks/check_schema.py            # verify (CI step)
  python benchmarks/check_schema.py --update   # adopt newly-added keys

New keys are allowed (they are the point of new PRs) — ``--update``
records them; verification only ever fails on *missing* keys or a
missing/unreadable BENCH file.
"""
from __future__ import annotations

import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
MANIFEST = os.path.join(HERE, "bench_schema.json")


def _bench_names(path: str) -> set[str]:
    with open(path) as f:
        return {row["name"] for row in json.load(f)}


def verify(manifest_path: str = MANIFEST, root: str = ROOT) -> list[str]:
    """Returns a list of human-readable failures (empty == green)."""
    with open(manifest_path) as f:
        manifest = json.load(f)
    failures: list[str] = []
    for fname, want in manifest.items():
        path = os.path.join(root, fname)
        try:
            have = _bench_names(path)
        except (OSError, ValueError, KeyError) as e:
            failures.append(f"{fname}: unreadable ({e})")
            continue
        missing = sorted(set(want) - have)
        if missing:
            failures.append(
                f"{fname}: previously-recorded benchmark key(s) dropped: "
                + ", ".join(missing)
            )
    return failures


def update(manifest_path: str = MANIFEST, root: str = ROOT) -> dict:
    """Extend the manifest with any new keys present in the BENCH files
    (never removes — dropping a key is an explicit manifest edit)."""
    with open(manifest_path) as f:
        manifest = json.load(f)
    for fname, want in manifest.items():
        path = os.path.join(root, fname)
        if os.path.exists(path):
            manifest[fname] = sorted(set(want) | _bench_names(path))
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
        f.write("\n")
    return manifest


def main(argv=None, root: str = ROOT) -> int:
    """``root``: directory holding the BENCH files to validate — the repo
    checkout by default (CI validates the committed files), or the
    writer's cwd when invoked right after ``run.py --json`` so the guard
    inspects exactly what was just written."""
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--update" in argv:
        manifest = update(root=root)
        print(f"bench_schema.json now pins {sum(len(v) for v in manifest.values())} keys")
    failures = verify(root=root)
    for msg in failures:
        print(f"SCHEMA GUARD: {msg}", file=sys.stderr)
    if failures:
        return 1
    print("bench schema ok: no previously-recorded keys dropped")
    return 0


if __name__ == "__main__":
    sys.exit(main())
