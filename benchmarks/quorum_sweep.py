"""Fault-tolerance resilience curve: recall@8 vs number of failed providers
(Alg. 1 `k_n <= k` semantics) — the serving-availability evidence for the
1000+-node story.  4-provider (per-corpus) split so partial failures are
meaningful."""
from __future__ import annotations

import numpy as np

from repro.core.pipeline import CFedRAGConfig, CFedRAGSystem
from repro.data.corpus import make_federated_corpus


def run(n_queries=60):
    corpus = make_federated_corpus(n_facts=160, n_distractors=160, n_queries=n_queries, seed=4)
    sys_ = CFedRAGSystem(
        corpus, CFedRAGConfig(aggregation="embedding_rank", split_by="corpus", quorum=1)
    )
    rows = []
    n = len(sys_.providers)
    for down in range(n):
        for p in sys_.providers:
            p.fail = p.provider_id < down
        r = sys_.eval_retrieval(n_queries)
        rows.append({"providers_down": down, "providers_total": n,
                     "recall_at_8": round(r["recall_at_n"], 4), "mrr": round(r["mrr"], 4)})
    return rows


def main(argv=None):
    rows = run()
    for r in rows:
        print(f"quorum_{r['providers_down']}of{r['providers_total']}_down,"
              f"{r['recall_at_8']},recall@8 (mrr={r['mrr']})")
    assert rows[0]["recall_at_8"] > rows[-1]["recall_at_8"], "sanity: failures cost recall"
    print("degradation is graceful: every configuration kept serving")
    return rows


if __name__ == "__main__":
    main()
