"""Tier-1-adjacent smoke: run the quickstart example under a 60s budget.

    python benchmarks/smoke.py

Exercises the full import surface + Algorithm 1 end to end (providers,
attested channels, batched eval) in a subprocess, so CI surfaces both
perf regressions (budget blown) and import breakage without waiting for
the full benchmark suite.  Exit code 0 iff the example succeeds in time.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

BUDGET_S = 60


def main() -> int:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    t0 = time.monotonic()
    try:
        r = subprocess.run(
            [sys.executable, os.path.join(repo, "examples", "quickstart.py")],
            cwd=repo,
            env=env,
            timeout=BUDGET_S,
            capture_output=True,
            text=True,
        )
    except subprocess.TimeoutExpired:
        print(f"smoke_quickstart,FAIL,budget {BUDGET_S}s exceeded")
        return 1
    dt = time.monotonic() - t0
    if r.returncode != 0:
        print(r.stdout[-2000:])
        print(r.stderr[-2000:], file=sys.stderr)
        print(f"smoke_quickstart,FAIL,exit {r.returncode}")
        return 1
    print(f"smoke_quickstart,{dt*1e6:.0f},budget {BUDGET_S}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
