"""Tier-1-adjacent smoke: run the quickstart example under a 60s budget,
then the sharded-serving capacity/parity arm under its own budget.

    python benchmarks/smoke.py

Exercises the full import surface + Algorithm 1 end to end (providers,
attested channels, batched eval) in a subprocess, so CI surfaces both
perf regressions (budget blown) and import breakage without waiting for
the full benchmark suite.  The second subprocess fakes 4 host devices
(XLA_FLAGS) and runs ``e2e_pipeline.run_sharded_capacity`` — the 4-shard
pool must admit >= 3x the 1-shard slots at matched per-shard HBM with
bit-identical answers.  Exit code 0 iff both arms succeed in time.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

BUDGET_S = 60

_SHARDED_SNIPPET = """
import sys
sys.path.insert(0, "src")
from benchmarks import e2e_pipeline
for name, us, derived in e2e_pipeline.run_sharded_capacity(n_requests=16):
    print(f"{name},{us:.1f},{derived}")
"""


def _arm(name, cmd, cwd, env, budget=BUDGET_S) -> int:
    t0 = time.monotonic()
    try:
        r = subprocess.run(
            cmd, cwd=cwd, env=env, timeout=budget, capture_output=True, text=True
        )
    except subprocess.TimeoutExpired:
        print(f"{name},FAIL,budget {budget}s exceeded")
        return 1
    dt = time.monotonic() - t0
    if r.returncode != 0:
        print(r.stdout[-2000:])
        print(r.stderr[-2000:], file=sys.stderr)
        print(f"{name},FAIL,exit {r.returncode}")
        return 1
    print(f"{name},{dt*1e6:.0f},budget {budget}s")
    return 0


def main() -> int:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    rc = _arm(
        "smoke_quickstart",
        [sys.executable, os.path.join(repo, "examples", "quickstart.py")],
        repo, env,
    )
    env_sharded = dict(
        env,
        XLA_FLAGS="--xla_force_host_platform_device_count=4 "
        + env.get("XLA_FLAGS", ""),
    )
    rc |= _arm(
        "smoke_sharded_parity",
        [sys.executable, "-c", _SHARDED_SNIPPET],
        repo, env_sharded,
    )
    return rc


if __name__ == "__main__":
    sys.exit(main())
