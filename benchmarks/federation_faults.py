"""Federation resilience benchmark: graceful degradation on a flaky
32-provider topology (paper §2.3/§4.1 threat model, Algorithm 1 k_n <= k).

Topology: the corpus is round-robin sharded across 32 providers with
ragged per-provider RTT (seeded 1-5ms ``delay_s``).  Every provider is
wrapped in the deterministic fault-injection harness
(``core.resilience.FaultyProvider``) at a ~20% aggregate fault rate:
most providers carry a low mixed rate (connection drops, timeouts, WAN
jitter, sealed-payload corruption, replayed nonces, poisoned scores) and
a few are *flappers* — mostly-dead links whose failures still burn the
detection latency a real dead connect costs.

Three arms over the same seeded schedule:

  * ``e2e_fault_off``         same topology, no faults, resilience off —
                              the clean-path wall-clock floor
  * ``e2e_fault_breaker_off`` 20% faults, retries=3 + self-heal + score
                              gate, NO breaker: every round pays the
                              flappers' detection latency x attempts
  * ``e2e_fault_breaker_on``  same + per-provider circuit breakers:
                              flappers trip open after 2 failed rounds
                              and get skipped (then probed half-open),
                              so steady-state wall-clock returns toward
                              the clean floor

The harness asserts, per provider, that every injected fault reconciles
against the orchestrator's observed ledger (injected conn/timeout ==
observed; corrupt+replay == observed IntegrityErrors; attempts ==
successes + faults) and that no round ever missed quorum or hung —
graceful degradation, not survivorship of a lucky run.

``--smoke`` shrinks to 8 providers / 6 rounds for the CI lane.
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core.filters import MaxChunksFilter, ProvenanceStripFilter
from repro.core.orchestrator import Orchestrator
from repro.core.provider import DataProvider
from repro.core.resilience import (
    BreakerPolicy,
    FaultSpec,
    FaultyProvider,
    QuorumNotMet,
    RetryPolicy,
    ScoreGate,
)
from repro.data.corpus import make_federated_corpus
from repro.data.embeddings import bag_embed
from repro.data.tokenizer import HashTokenizer

M_LOCAL = 4

# low mixed rate for the rank-and-file providers (~10.5% per request)
BASE_SPEC = FaultSpec(
    seed=23, p_conn=0.02, p_timeout=0.01, p_delay=0.03, delay_jitter_s=0.004,
    p_corrupt=0.015, p_replay=0.015, p_poison=0.015, poison_scale=50.0,
    fault_latency_s=0.02,
)
# flappers: mostly-dead links; the 50ms fault latency (x3 retry attempts)
# is what a breaker saves every round once it opens
FLAPPER_SPEC = FaultSpec(seed=23, p_conn=0.92, p_timeout=0.03, fault_latency_s=0.05)


def _build(n_providers: int, n_facts: int, tok: HashTokenizer):
    corpus = make_federated_corpus(
        n_facts=n_facts, n_distractors=n_facts, n_queries=32, seed=13
    )
    embed = lambda toks: bag_embed(jnp.asarray(toks), dim=256)  # noqa: E731
    providers = [
        DataProvider(
            provider_id=i,
            chunks=corpus.chunks[i::n_providers],
            embed_fn=embed,
            tokenizer=tok,
            chunk_max_len=16,
            filters=[MaxChunksFilter(M_LOCAL), ProvenanceStripFilter()],
        )
        for i in range(n_providers)
    ]
    rng = np.random.default_rng(17)
    for p in providers:
        p.build_index()
        p.delay_s = float(rng.uniform(0.001, 0.005))  # ragged WAN RTT
    return corpus, providers


def _check_accounting(orch: Orchestrator) -> dict:
    """Every injected fault must show up in the observed ledger (and
    vice versa): the stats are an audit trail, not an estimate."""
    stats = orch.federation_stats()
    for pid, d in stats["providers"].items():
        inj = d.get("injected")
        if inj is None:
            continue
        obs = d["faults"]
        assert obs["conn"] == inj["conn"], (pid, obs, inj)
        assert obs["timeout"] == inj["timeout"], (pid, obs, inj)
        assert obs["integrity"] == inj["corrupt"] + inj["replay"], (pid, obs, inj)
        assert d["attempts"] == d["successes"] + sum(obs.values()), (pid, d)
    return stats


def _run_arm(
    providers, tok, texts, rounds: int, quorum: int, *,
    flappers: int = 0, faults: bool = False, breaker: bool = False,
):
    ps = list(providers)
    if faults:
        ps = [
            FaultyProvider(
                p, FLAPPER_SPEC if i >= len(ps) - flappers else BASE_SPEC
            )
            for i, p in enumerate(ps)
        ]
    orch = Orchestrator(
        ps, tok,
        aggregation="embedding_rank",
        m_local=M_LOCAL, n_global=8,
        quorum=quorum,
        concurrent_collect=True,
        retry=RetryPolicy(max_attempts=3, backoff_s=0.005) if faults else None,
        breaker=BreakerPolicy(fail_threshold=2, cooldown_s=2.0) if breaker else None,
        score_gate=ScoreGate() if faults else None,
    )
    orch.collect_contexts(texts[0])  # warm jit caches outside the timing
    responders, quorum_misses = [], 0
    t0 = time.monotonic()
    for r in range(rounds):
        text = texts[r % len(texts)]
        try:
            responses = orch.collect_contexts(text)
        except QuorumNotMet:
            quorum_misses += 1
            continue
        responders.append(len(responses))
        orch.aggregate(text, responses)
    wall = time.monotonic() - t0
    stats = _check_accounting(orch)
    assert quorum_misses == 0, f"{quorum_misses} rounds fell below quorum"
    assert min(responders) >= quorum
    return wall, responders, stats


def run(smoke: bool = False):
    n_providers, flappers, rounds, n_facts = (8, 1, 6, 32) if smoke else (32, 4, 40, 96)
    quorum = n_providers // 2
    tok = HashTokenizer()
    corpus, providers = _build(n_providers, n_facts, tok)
    texts = [q.text for q in corpus.queries]
    rows = []

    wall, resp, _ = _run_arm(providers, tok, texts, rounds, quorum)
    ms = wall / rounds * 1e3
    rows.append((
        "e2e_fault_off",
        wall / rounds * 1e6,
        f"{n_providers} providers ragged RTT, no faults: {ms:.1f}ms/round, "
        f"{int(np.mean(resp))} responders",
    ))

    walls = {}
    for name, brk in (("e2e_fault_breaker_off", False), ("e2e_fault_breaker_on", True)):
        wall, resp, stats = _run_arm(
            providers, tok, texts, rounds, quorum,
            flappers=flappers, faults=True, breaker=brk,
        )
        walls[name] = wall
        tot = stats["totals"]
        injected = sum(
            sum(d["injected"].values()) for d in stats["providers"].values()
        )
        derived = (
            f"{flappers}/{n_providers} flappers, {injected} faults injected, "
            f"mean responders {np.mean(resp):.1f}/{n_providers} "
            f"(min {min(resp)}, quorum {quorum}), retries {tot['retries']}, "
            f"rechannels {tot['rechannels']}, quarantined {tot['quarantined']}"
        )
        if brk:
            trips = sum(
                d["breaker_trips"] for d in stats["providers"].values()
            )
            derived += (
                f", breaker trips {trips}, skips {tot['skips']}, "
                f"{walls['e2e_fault_breaker_off'] / wall:.2f}x vs breaker-off"
            )
        rows.append((name, wall / rounds * 1e6, derived))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="8 providers / 6 rounds CI lane")
    args = ap.parse_args(argv)
    for name, us, derived in run(smoke=args.smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
