"""Benchmark harness: one entry per paper table/figure + substrate perf.
Prints ``name,us_per_call,derived`` CSV rows (and richer per-table output).
"""
from __future__ import annotations

import time


def main() -> None:
    from benchmarks import e2e_pipeline, kernel_perf, table1_federated_rag, table2_llm_ablation

    print("== Table 1: federated RAG vs silo vs centralized (recall@8 on provenance corpus) ==")
    t0 = time.monotonic()
    table1_federated_rag.main()
    print(f"table1,{(time.monotonic()-t0)*1e6:.0f},total")

    print("\n== Table 2: generator ablation (size vs copy-grounding EM) ==")
    t0 = time.monotonic()
    table2_llm_ablation.main()
    print(f"table2,{(time.monotonic()-t0)*1e6:.0f},total")

    print("\n== kernel perf (CPU wall; TPU roofline in EXPERIMENTS.md) ==")
    kernel_perf.main()

    print("\n== e2e pipeline stage latency ==")
    e2e_pipeline.main()

    print("\n== fault tolerance: recall vs providers down (Alg. 1 k_n <= k) ==")
    from benchmarks import quorum_sweep

    quorum_sweep.main()


if __name__ == "__main__":
    main()
