"""Benchmark harness: one entry per paper table/figure + substrate perf.
Prints ``name,us_per_call,derived`` CSV rows (and richer per-table output).

``--json`` additionally writes BENCH_kernels.json and BENCH_e2e.json with
the stable ``[{name, us, derived}, ...]`` schema, so CI can diff perf
across PRs without parsing stdout.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# the sharded-capacity rows need >= 4 devices; claim them before any
# transitive jax import (no-op if the operator already set a count)
if "jax" not in sys.modules and (
    "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")
):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true", help="emit BENCH_*.json artifacts")
    args = ap.parse_args(argv)

    from benchmarks import e2e_pipeline, kernel_perf, table1_federated_rag, table2_llm_ablation

    print("== Table 1: federated RAG vs silo vs centralized (recall@8 on provenance corpus) ==")
    t0 = time.monotonic()
    table1_federated_rag.main()
    print(f"table1,{(time.monotonic()-t0)*1e6:.0f},total")

    print("\n== Table 2: generator ablation (size vs copy-grounding EM) ==")
    t0 = time.monotonic()
    table2_llm_ablation.main()
    print(f"table2,{(time.monotonic()-t0)*1e6:.0f},total")

    print("\n== kernel perf (CPU wall; TPU roofline in EXPERIMENTS.md) ==")
    kernel_rows = kernel_perf.run()
    for name, us, derived in kernel_rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        print(f"wrote {e2e_pipeline.write_json(kernel_rows, 'BENCH_kernels.json')}")

    print("\n== e2e pipeline stage latency + batched throughput ==")
    e2e_rows = e2e_pipeline.run() + e2e_pipeline.run_throughput()
    for name, us, derived in e2e_rows:
        print(f"{name},{us:.1f},{derived}")

    print("\n== straggler fan-out latency + continuous-batching goodput ==")
    sched_rows = e2e_pipeline.run_latency_distribution() + e2e_pipeline.run_scheduler_goodput()
    for name, us, derived in sched_rows:
        print(f"{name},{us:.1f},{derived}")
    e2e_rows += sched_rows

    print("\n== pipelined serve_stream vs phase-barrier serve ==")
    ov_rows = e2e_pipeline.run_pipeline_overlap()
    for name, us, derived in ov_rows:
        print(f"{name},{us:.1f},{derived}")
    e2e_rows += ov_rows

    print("\n== paged vs contiguous KV cache at equal HBM (short-prompt workload) ==")
    kv_rows = e2e_pipeline.run_paged_capacity()
    for name, us, derived in kv_rows:
        print(f"{name},{us:.1f},{derived}")
    e2e_rows += kv_rows

    print("\n== sharded KV pool over the mesh at matched per-shard HBM ==")
    sh_rows = e2e_pipeline.run_sharded_capacity()
    for name, us, derived in sh_rows:
        print(f"{name},{us:.1f},{derived}")
    e2e_rows += sh_rows

    print("\n== prefix-cache reuse on shared-preamble micro-batches ==")
    px_rows = e2e_pipeline.run_prefix_reuse()
    for name, us, derived in px_rows:
        print(f"{name},{us:.1f},{derived}")
    e2e_rows += px_rows

    print("\n== chunked prefill: decode tail under periodic long-prompt arrivals ==")
    cp_rows = e2e_pipeline.run_mixed_prefill()
    for name, us, derived in cp_rows:
        print(f"{name},{us:.1f},{derived}")
    e2e_rows += cp_rows

    print("\n== speculative decoding: draft-k/verify-1 on the paged engine ==")
    sp_rows = e2e_pipeline.run_spec_decode()
    for name, us, derived in sp_rows:
        print(f"{name},{us:.1f},{derived}")
    e2e_rows += sp_rows

    print("\n== tenant SLO: weighted-fair vs FIFO + warm restart ==")
    tn_rows = e2e_pipeline.run_tenant_slo()
    for name, us, derived in tn_rows:
        print(f"{name},{us:.1f},{derived}")
    e2e_rows += tn_rows

    print("\n== federation resilience under injected faults (breaker on/off) ==")
    from benchmarks import federation_faults

    fault_rows = federation_faults.run()
    for name, us, derived in fault_rows:
        print(f"{name},{us:.1f},{derived}")
    e2e_rows += fault_rows
    if args.json:
        print(f"wrote {e2e_pipeline.write_json(e2e_rows)}")
        # schema guard: regenerating the jsons must never drop a
        # previously-recorded perf-trajectory key.  write_json writes to
        # the cwd, so validate the files just written there
        import os

        from benchmarks import check_schema

        if check_schema.main([], root=os.getcwd()):
            raise SystemExit("benchmark schema regressed (key dropped)")

    print("\n== fault tolerance: recall vs providers down (Alg. 1 k_n <= k) ==")
    from benchmarks import quorum_sweep

    quorum_sweep.main()


if __name__ == "__main__":
    main()
