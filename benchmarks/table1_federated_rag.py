"""Table 1 reproduction: C-FedRAG vs vanilla single-silo RAG vs centralized.

Paper protocol (§3): 4 corpora across 2 sites, top-8 per site, re-rank
32 -> 8 context window.  MedRAG/MIRAGE are unavailable offline, so the
synthetic provenance corpus (data/corpus.py) provides exact ground truth;
the metric is recall@8 / MRR of the gold chunk in the final context window
(the mechanism behind the paper's accuracy numbers), plus end-to-end QA
exact-match when a generator checkpoint is supplied.

Rows mirror the paper:  no-RAG (CoT)  ->  0 by construction here,
MedRag(<corpus>) silos, MedRag(MedCorp) centralized,
C-FedRAG (Embedding Rank), C-FedRAG (Re-rank Model).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.pipeline import (
    CFedRAGConfig,
    CFedRAGSystem,
    centralized_system,
    single_silo_system,
)
from repro.data.corpus import CORPORA, make_federated_corpus
from repro.data.tokenizer import HashTokenizer
from repro.launch.serve import overlap_reranker


def run(n_facts=192, n_queries=120, seed=0, use_pallas=False) -> list[dict]:
    corpus = make_federated_corpus(n_facts=n_facts, n_distractors=n_facts, n_queries=n_queries, seed=seed)
    tok = HashTokenizer()
    rows = []

    def add(name, system):
        t0 = time.monotonic()
        r = system.eval_retrieval(n_queries)
        dt = (time.monotonic() - t0) / n_queries
        rows.append(
            {
                "method": name,
                "recall_at_8": round(r["recall_at_n"], 4),
                "mrr": round(r["mrr"], 4),
                "us_per_query": round(dt * 1e6, 1),
                "per_corpus": {k: round(v, 3) for k, v in r["per_corpus"].items()},
            }
        )

    rows.append({"method": "CoT (no RAG)", "recall_at_8": 0.0, "mrr": 0.0, "us_per_query": 0.0,
                 "per_corpus": {}})  # no retrieval -> no gold context, by definition
    for c in CORPORA:
        add(f"MedRag({c})", single_silo_system(corpus, c, CFedRAGConfig(use_pallas=use_pallas)))
    add("MedRag(MedCorp/centralized)", centralized_system(corpus, CFedRAGConfig(use_pallas=use_pallas)))
    add(
        "C-FedRAG (Embedding Rank)",
        CFedRAGSystem(corpus, CFedRAGConfig(aggregation="embedding_rank", use_pallas=use_pallas), tokenizer=tok),
    )
    add(
        "C-FedRAG (Re-rank Model)",
        CFedRAGSystem(
            corpus, CFedRAGConfig(aggregation="rerank", use_pallas=use_pallas),
            tokenizer=tok, reranker=overlap_reranker(tok),
        ),
    )
    return rows


def main(argv=None):
    rows = run()
    print(f"{'method':34s} {'recall@8':>9s} {'MRR':>7s} {'us/query':>10s}")
    for r in rows:
        print(f"{r['method']:34s} {r['recall_at_8']:9.3f} {r['mrr']:7.3f} {r['us_per_query']:10.1f}")
    # paper-claim ordering checks (Table 1 mechanism)
    by = {r["method"]: r for r in rows}
    fed_rr = by["C-FedRAG (Re-rank Model)"]["recall_at_8"]
    fed_er = by["C-FedRAG (Embedding Rank)"]["recall_at_8"]
    best_silo = max(by[f"MedRag({c})"]["recall_at_8"] for c in CORPORA)
    print("\nclaim checks:")
    print(f"  C-FedRAG(rerank) >= C-FedRAG(embed): {fed_rr >= fed_er - 1e-9} ({fed_rr:.3f} vs {fed_er:.3f})")
    print(f"  C-FedRAG(rerank) > best single silo: {fed_rr > best_silo} ({fed_rr:.3f} vs {best_silo:.3f})")
    return rows


if __name__ == "__main__":
    main()
